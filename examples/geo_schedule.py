"""Geo-distributed scenario: the control plane schedules a job onto a
cross-region pipeline path, the data plane trains it with that placement's
geometry (stages split across a 2-"pod" debug mesh = 2 regions), and a region
failure mid-run triggers Pathfinder re-placement + checkpoint restore.

    PYTHONPATH=src python examples/geo_schedule.py
"""

import dataclasses
import os
import shutil
import subprocess
import sys
import textwrap

from repro.core import (
    BACEPipePolicy,
    ClusterState,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    find_placement,
    get_scenario,
)


def control_plane():
    """BACE-Pipe decides a cross-region pipeline placement."""
    regions = [
        Region("us-east", 2, 0.156),
        Region("ea-east", 2, 0.191),
        Region("eu-central", 1, 0.288),
    ]
    gbps = {("us-east", "ea-east"): 80.0, ("ea-east", "eu-central"): 40.0,
            ("us-east", "eu-central"): 30.0}
    cluster = ClusterState.build(regions, gbps, symmetric=True)
    prof = JobProfile(
        JobSpec(0, ModelSpec("demo-4l", 2e8, 4, 512, 8), iterations=40),
        gpu_flops=300e12, gpu_memory=400e9,
    )
    placement = find_placement(prof, cluster, k_star=4)
    print(f"[control] Pathfinder placement: {placement.describe()}")
    print(f"[control] crossing edges: {placement.crossing_edges}")

    # simulate failure of the first region and re-place on survivors
    dead = placement.path[0]
    cluster.free_gpus[dead] = 0
    replaced = find_placement(prof, cluster, k_star=4)
    print(f"[control] after losing {dead}: {replaced.describe()}")
    return placement


def dynamic_control_plane():
    """The same control plane under a *dynamic* environment: the registered
    link-flap scenario collapses the fattest WAN link mid-run; the simulator
    preempts the stranded pipeline, checkpoints it, and re-places it."""
    scenario = get_scenario("link-flap")
    res = scenario.run(BACEPipePolicy(), seed=0)
    print(f"[control] scenario {scenario.name!r}: {res.summary()}")
    for job_id, n in sorted(res.migrations.items()):
        segs = [r for r in res.records if r.job_id == job_id]
        paths = " | ".join(r.placement.describe() for r in segs)
        print(
            f"[control] job {job_id} migrated {n}x "
            f"(stall {res.stall_seconds[job_id]:.0f}s): {paths}"
        )


def data_plane():
    """Train the same 4-layer model with a 2-stage geo pipeline (pod axis =
    cross-region link) on 8 host devices, in a subprocess so this process
    keeps the default device count."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import build_everything
        from repro.launch import steps as S
        from repro.data import SyntheticLM, make_batch_iterator
        from repro.distributed.compat import use_mesh

        cfg = dataclasses.replace(
            get_config("qwen1.5-32b").reduced(),
            n_layers=4, pp_stages=2, vocab=512,
        )
        mesh = make_debug_mesh(multi_pod=True)   # (pod, data, model)
        state, step_fn, _ = build_everything(
            cfg, mesh, batch=8, seq=64, multi_pod=True, dtype=jnp.float32)
        src = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=8)
        it = make_batch_iterator(src, cfg, mesh, S.batch_axis_spec(
            mesh, True, 8, pipe_axes=("pod", "model")))
        losses = []
        with use_mesh(mesh):
            for i in range(30):
                state, loss = step_fn(state, next(it))
                losses.append(float(loss))
        print(f"[data] geo-pipeline (4 stages over pod x model) "
              f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
        assert np.isfinite(losses).all() and losses[-1] < losses[0]
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-2000:])
        raise SystemExit("data plane failed")


def main() -> None:
    control_plane()
    dynamic_control_plane()
    data_plane()
    print("[geo] OK: control-plane placement + geo-pipelined training ran.")


if __name__ == "__main__":
    main()
