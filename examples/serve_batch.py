"""Serve a small model with batched requests through the decode path.

    PYTHONPATH=src python examples/serve_batch.py
"""

from repro.launch.serve import main as serve_main


def main() -> None:
    for arch in ("gemma2-2b", "mamba2-2.7b"):
        serve_main([
            "--arch", arch, "--reduced",
            "--batch", "4", "--prompt-len", "8", "--new-tokens", "16",
        ])


if __name__ == "__main__":
    main()
