"""End-to-end driver: train a ~100M-parameter qwen-family model for a few
hundred steps on synthetic data, with checkpointing and an injected region
failure mid-run (the geo-failover path, executed for real).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""

import argparse
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM, make_batch_iterator
from repro.distributed.compat import use_mesh
from repro.ft import FailureInjector, resilient_train_loop
from repro.launch import steps as S
from repro.launch.train import build_everything


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # ~100M decoder: qwen1.5 family wiring, scaled dims.
    cfg = dataclasses.replace(
        get_config("qwen1.5-32b"),
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        d_ff=2048, vocab=8192, model_axis="tp", pp_stages=0,
    )
    n_analytic = cfg.param_count()
    print(f"[100m] analytic params: {n_analytic / 1e6:.1f}M")

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    state, jit_step, _ = build_everything(
        cfg, mesh, batch=args.batch, seq=args.seq, multi_pod=False,
        dtype=jnp.float32,
    )
    n_real = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[100m] actual params: {n_real / 1e6:.1f}M")

    source = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    batches = make_batch_iterator(
        source, cfg, mesh, S.batch_axis_spec(mesh, False, args.batch)
    )
    if os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    def wrapped(state_, batch_):
        with use_mesh(mesh):
            return jit_step(state_, batch_)

    out = resilient_train_loop(
        train_step=wrapped,
        state=state,
        batches=batches,
        n_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        injector=FailureInjector({args.steps // 2: "eu-central"}),
        log_every=20,
    )
    losses = out["losses"]
    first = float(np.mean(losses[:10]))
    last = float(np.mean(losses[-10:]))
    print(f"[100m] loss {first:.4f} -> {last:.4f} over {len(losses)} steps "
          f"(restarts={out['restarts']})")
    assert last < first, "loss did not improve"
    print("[100m] OK: loss improved through a mid-run region failure.")


if __name__ == "__main__":
    main()
