"""Quickstart: schedule the paper's 8-job workload on the 6-region cluster
with BACE-Pipe and compare against every baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    BACEPipePolicy,
    CRLCFPolicy,
    CRLDFPolicy,
    LCFPolicy,
    LDFPolicy,
    paper_cluster,
    paper_jobs,
    paper_profiles,
    simulate,
)


def main() -> None:
    cluster = paper_cluster()
    profiles = paper_profiles(paper_jobs(seed=0))

    print("=== Job profiles (Table III + analytic timing model) ===")
    for p in profiles:
        k = p.optimal_gpus(cluster.total_gpus())
        print(
            f"  {p.spec.model.name:18s} K*={k:3d} min={p.min_gpus:3d} "
            f"t_comp(K*)={p.t_comp(k) * 1e3:6.1f} ms "
            f"b_j={p.bandwidth_requirement(k) / 1.25e8:5.1f} Gbps "
            f"iters={p.spec.iterations}"
        )

    print("\n=== Scheduling (avg JCT / total electricity cost) ===")
    results = {}
    for policy in (
        BACEPipePolicy(), LDFPolicy(), LCFPolicy(), CRLCFPolicy(), CRLDFPolicy()
    ):
        res = simulate(cluster, profiles, policy)
        results[res.policy] = res
        print(f"  {res.summary()}")

    base = results["bace-pipe"]
    print("\n=== Overheads vs BACE-Pipe (paper: JCT +27.9..64.7%) ===")
    for name, res in results.items():
        if name == "bace-pipe":
            continue
        print(
            f"  {name:8s} JCT {100 * (res.average_jct / base.average_jct - 1):+6.1f}%  "
            f"cost {100 * (res.total_cost / base.total_cost - 1):+6.1f}%"
        )

    print("\n=== BACE-Pipe placements (the paper's S_j decisions) ===")
    for r in base.records:
        print(f"  {r.model_name:18s} -> {r.placement.describe()}  "
              f"(wait {r.wait / 3600:.2f} h, run {r.execution / 3600:.2f} h)")


if __name__ == "__main__":
    main()
