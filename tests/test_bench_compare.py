"""Regression tests for scripts/bench_compare.py input hardening.

A truncated or malformed BENCH_*.json must produce a clean one-line
SystemExit naming the offending file — never a traceback — and valid
files must keep comparing exactly as before.
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SCRIPT = REPO / "scripts" / "bench_compare.py"

sys.path.insert(0, str(SCRIPT.parent))
import bench_compare  # noqa: E402


def _cell(jobs=10, regions=4, engine="vectorized", backend="numpy", us=50.0):
    return {
        "jobs": jobs, "regions": regions, "engine": engine,
        "backend": backend, "us_per_call": us,
    }


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(
        payload if isinstance(payload, str) else json.dumps(payload),
        encoding="utf-8",
    )
    return p


def test_missing_file_exits_with_message(tmp_path):
    with pytest.raises(SystemExit, match="no such file"):
        bench_compare.load_cells(tmp_path / "absent.json")


def test_truncated_json_exits_with_message(tmp_path):
    full = json.dumps({"cells": [_cell()]})
    p = _write(tmp_path, "trunc.json", full[: len(full) // 2])
    with pytest.raises(SystemExit, match="malformed JSON") as exc:
        bench_compare.load_cells(p)
    assert "truncated" in str(exc.value)


def test_wrong_toplevel_type_exits(tmp_path):
    p = _write(tmp_path, "list.json", [1, 2, 3])
    with pytest.raises(SystemExit, match="expected a JSON object"):
        bench_compare.load_cells(p)


def test_non_dict_cells_exit(tmp_path):
    p = _write(tmp_path, "cells.json", {"cells": ["not-a-dict"]})
    with pytest.raises(SystemExit, match="list of objects"):
        bench_compare.load_cells(p)


def test_empty_cells_exit(tmp_path):
    p = _write(tmp_path, "empty.json", {"cells": []})
    with pytest.raises(SystemExit, match="no cells"):
        bench_compare.load_cells(p)


def test_missing_field_exits(tmp_path):
    c = _cell()
    del c["us_per_call"]
    p = _write(tmp_path, "nofield.json", {"cells": [c]})
    with pytest.raises(SystemExit, match="missing required field 'us_per_call'"):
        bench_compare.load_cells(p)


def test_uncastable_field_exits(tmp_path):
    c = _cell()
    c["us_per_call"] = "not-a-number"
    p = _write(tmp_path, "badfield.json", {"cells": [c]})
    with pytest.raises(SystemExit, match="not a float"):
        bench_compare.load_cells(p)


def test_named_cells_require_names(tmp_path):
    p = _write(tmp_path, "unnamed.json", {"cells": [_cell()]})
    with pytest.raises(SystemExit, match="without a name"):
        bench_compare.load_named_cells(p)


def test_named_cells_validate_metric_types(tmp_path):
    p = _write(
        tmp_path, "badmetric.json",
        {"cells": [{"name": "s1", "jct_s": "oops"}]},
    )
    with pytest.raises(SystemExit, match="not a float"):
        bench_compare.load_named_cells(p)


def test_cli_reports_cleanly_without_traceback(tmp_path):
    bad = _write(tmp_path, "bad.json", '{"cells": [{"jobs":')
    good = _write(tmp_path, "good.json", {"cells": [_cell()]})
    proc = subprocess.run(
        [sys.executable, str(SCRIPT), str(bad), str(good)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 1
    assert "malformed JSON" in proc.stderr
    assert "Traceback" not in proc.stderr


def test_valid_files_still_compare(tmp_path):
    old = _write(tmp_path, "old.json", {"cells": [_cell(us=50.0)]})
    new_ok = _write(tmp_path, "new_ok.json", {"cells": [_cell(us=55.0)]})
    new_slow = _write(tmp_path, "new_slow.json", {"cells": [_cell(us=80.0)]})
    ok = subprocess.run(
        [sys.executable, str(SCRIPT), str(old), str(new_ok)],
        capture_output=True, text=True,
    )
    assert ok.returncode == 0, ok.stdout + ok.stderr
    slow = subprocess.run(
        [sys.executable, str(SCRIPT), str(old), str(new_slow)],
        capture_output=True, text=True,
    )
    assert slow.returncode == 1
    assert "REGRESSION" in slow.stdout


def test_checked_in_artifacts_still_load():
    for name in sorted(REPO.glob("BENCH_*.json")):
        cells = bench_compare._load_payload(name)
        assert cells, name
