"""Decision-backend parity: numpy vs jax kernels, both vs the seed reference.

The batched decision kernels (``core/kernels_decide``) promise *bit-identical*
decisions on either backend — same feasibility masks, same Eq. 6 admissions,
same tie-break order — with the legacy scalar walk as the ground truth.  This
suite enforces that promise at three levels:

1. kernel level   — ``prim_expand`` returns identical arrays on both backends;
2. decision level — ``find_placement`` yields identical placements across
   backends and against ``legacy_find_placement``, on random clusters
   including multi-pool heterogeneous regions and zero-capacity links;
3. simulation level — full runs of every registered scenario serialize to
   identical ``to_jsonable()`` payloads under ``decision_backend="jax"``.

Fixed cases always run (jax-dependent ones skip cleanly when jax is absent);
a hypothesis sweep widens the random-cluster coverage when the library is
installed, same convention as the other property suites.
"""

from __future__ import annotations

import random

import numpy as np
import pytest

from repro.core import (
    BACEPipePolicy,
    ClusterState,
    GpuPool,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    Simulator,
    find_placement,
    jax_available,
    legacy_find_placement,
    resolve_backend,
    scenario_names,
    simulate,
)
from repro.core.kernels_decide import (
    DECISION_BACKENDS,
    decay_table_len,
    phase1_pick,
    prim_expand,
)
from repro.core.scenarios import get_scenario
from repro.core.workloads import paper_cluster, paper_profiles

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

needs_jax = pytest.mark.skipif(
    not jax_available(), reason="jax not installed"
)


# --------------------------------------------------------------- generators
def random_cluster(rng: random.Random, *, hetero: bool = False) -> ClusterState:
    """Random cluster; with ``hetero`` some regions carry multiple typed
    pools (different FLOPS/memory/kW, spot discounts).  Some link capacities
    are zero — the kernels must treat those edges as absent."""
    n = rng.randint(2, 7)
    regions = []
    for i in range(n):
        price = rng.uniform(0.05, 0.40)
        cap = rng.choice([0, 2, 4, 8, 16, 32])
        if hetero and rng.random() < 0.5:
            pools = [GpuPool("h100", cap, flops=300e12, memory=80e9, gpu_kw=0.7)]
            if rng.random() < 0.7:
                pools.append(
                    GpuPool(
                        "spot",
                        rng.choice([0, 2, 4, 8]),
                        spot=True,
                        price_mult=rng.uniform(0.2, 0.8),
                    )
                )
            regions.append(Region.with_pools(f"r{i}", price, pools))
        else:
            regions.append(Region(f"r{i}", cap, price))
    gbps = {}
    for i, a in enumerate(regions):
        for b in regions[i + 1 :]:
            # Duplicated values provoke the bandwidth tie-break; zeros
            # exercise absent links.
            gbps[(a.name, b.name)] = rng.choice(
                [0.0, 10.0, 10.0, 25.0, 50.0, 50.0, 100.0]
            )
    cluster = ClusterState.build(regions, gbps, symmetric=True)
    # Pre-existing load: reserve a few GPUs so free != capacity.
    for r in cluster.region_names():
        free = int(cluster.free_gpus[r])
        if free > 1 and rng.random() < 0.4:
            cluster.reserve_gpus({r: rng.randint(1, free - 1)})
    return cluster


def random_profile(rng: random.Random, job_id: int = 0) -> JobProfile:
    spec = JobSpec(
        job_id=job_id,
        model=ModelSpec(
            f"m{job_id}",
            rng.uniform(0.5e9, 40e9),
            rng.choice([8, 16, 24, 32]),
            rng.choice([1024, 2048, 4096]),
            rng.choice([8, 16, 32]),
        ),
        iterations=rng.randint(1, 40),
    )
    return JobProfile(spec, gpu_flops=300e12, gpu_memory=400e9)


def placement_key(p):
    """Everything a placement decides, in comparable form (None passes
    through so 'both infeasible' also counts as agreement)."""
    if p is None:
        return None
    return (
        tuple(p.path),
        tuple(sorted(p.alloc.items())),
        tuple((r, tuple(sorted(t.items()))) for r, t in sorted(p.typed_alloc.items())),
        tuple(p.comm_times),
        tuple(sorted(p.reserved_bw.items())),
        p.eff_flops,
        p.eff_memory,
    )


def _prim_inputs(cluster: ClusterState, profile: JobProfile):
    k = max(profile.optimal_gpus(cluster.total_gpus()), profile.min_gpus)
    if cluster.is_heterogeneous:
        flops_vec = cluster.min_available_flops_vector(profile.gpu_flops)
    else:
        flops_vec = np.full(len(cluster.region_names()), profile.gpu_flops)
    return (
        cluster.available_matrix(),
        cluster.free_vector(),
        cluster.name_rank_vector(),
        flops_vec,
        profile.decay_table(decay_table_len(k)),
        profile.fwd_flops_per_microbatch,
        profile.stage_overhead,
        profile.spec.model.activation_bytes,
        k,
    )


# ------------------------------------------------------------ backend seam
def test_resolve_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown decision backend"):
        resolve_backend("torch")


def test_resolve_backend_numpy_identity():
    assert resolve_backend("numpy") == "numpy"


def test_simulator_rejects_unknown_backend():
    cluster = paper_cluster()
    profs = paper_profiles()
    with pytest.raises(ValueError, match="decision backend"):
        Simulator(cluster, profs, BACEPipePolicy(), decision_backend="torch")


def test_simulator_rejects_legacy_engine_with_jax_backend():
    cluster = paper_cluster()
    profs = paper_profiles()
    with pytest.raises(ValueError, match="legacy"):
        Simulator(
            cluster,
            profs,
            BACEPipePolicy(),
            engine="legacy",
            decision_backend="jax",
        )


@needs_jax
def test_resolve_backend_jax_identity_when_available():
    assert resolve_backend("jax") == "jax"


def test_backends_registry():
    assert DECISION_BACKENDS == ("numpy", "jax")


# -------------------------------------------------------------- kernel level
def test_decay_table_matches_scalar_factors():
    rng = random.Random(5)
    for job_id in range(6):
        prof = random_profile(rng, job_id)
        tab = prof.decay_table(decay_table_len(37))
        assert len(tab) == 64
        for g in range(1, len(tab)):
            assert tab[g] == prof._decay_factor(g)


def test_phase1_pick_matches_scalar_reference():
    rng = random.Random(11)
    for _ in range(200):
        n = rng.randint(1, 12)
        free = np.array([rng.choice([0, 1, 3, 8, 8, 16]) for _ in range(n)])
        prices = np.array(
            [rng.choice([0.1, 0.1, 0.2, 0.25]) for _ in range(n)]
        )
        names = [f"r{rng.randint(0, 99):02d}-{i}" for i in range(n)]
        order = sorted(range(n), key=lambda i: names[i])
        name_rank = np.empty(n, dtype=np.int64)
        for rank, i in enumerate(order):
            name_rank[i] = rank
        k = rng.randint(1, 20)
        # scalar reference: cheapest region with free >= k, ties by name
        feas = [i for i in range(n) if free[i] >= k]
        want = (
            min(feas, key=lambda i: (prices[i], names[i])) if feas else -1
        )
        assert phase1_pick(free, prices, name_rank, k) == want


@needs_jax
def test_prim_expand_backends_bit_identical():
    rng = random.Random(23)
    for case in range(40):
        cluster = random_cluster(rng, hetero=(case % 3 == 0))
        prof = random_profile(rng, case)
        inputs = _prim_inputs(cluster, prof)
        g_np, len_np, paths_np = prim_expand(*inputs, backend="numpy")
        g_jx, len_jx, paths_jx = prim_expand(*inputs, backend="jax")
        np.testing.assert_array_equal(g_np, g_jx)
        np.testing.assert_array_equal(len_np, len_jx)
        np.testing.assert_array_equal(paths_np, paths_jx)


@needs_jax
def test_prim_expand_zero_capacity_links_bit_identical():
    # All links zero: every seed must stop at its own region on both backends.
    regions = [Region("a", 4, 0.1), Region("b", 8, 0.2), Region("c", 0, 0.3)]
    gbps = {("a", "b"): 0.0, ("b", "c"): 0.0, ("a", "c"): 0.0}
    cluster = ClusterState.build(regions, gbps, symmetric=True)
    prof = random_profile(random.Random(1))
    inputs = _prim_inputs(cluster, prof)
    for backend in DECISION_BACKENDS:
        g, path_len, paths = prim_expand(*inputs, backend=backend)
        assert list(path_len) == [1, 1, 0]
        assert list(g[:2]) == [
            min(4, inputs[-1]),
            min(8, inputs[-1]),
        ]
        assert paths[0, 0] == 0 and paths[1, 0] == 1


# ------------------------------------------------------------ decision level
@needs_jax
def test_find_placement_backend_parity_random_clusters():
    rng = random.Random(37)
    for case in range(60):
        cluster = random_cluster(rng, hetero=(case % 2 == 0))
        prof = random_profile(rng, case)
        p_np = find_placement(prof, cluster, backend="numpy")
        p_jx = find_placement(prof, cluster, backend="jax")
        assert placement_key(p_np) == placement_key(p_jx)


def test_find_placement_numpy_matches_legacy_homogeneous():
    rng = random.Random(41)
    for case in range(60):
        cluster = random_cluster(rng, hetero=False)
        prof = random_profile(rng, case)
        p_new = find_placement(prof, cluster, backend="numpy")
        p_ref = legacy_find_placement(prof, cluster)
        assert placement_key(p_new) == placement_key(p_ref)


@needs_jax
def test_find_placement_jax_matches_legacy_homogeneous():
    rng = random.Random(43)
    for case in range(30):
        cluster = random_cluster(rng, hetero=False)
        prof = random_profile(rng, case)
        p_jx = find_placement(prof, cluster, backend="jax")
        p_ref = legacy_find_placement(prof, cluster)
        assert placement_key(p_jx) == placement_key(p_ref)


# ---------------------------------------------------------- simulation level
@needs_jax
@pytest.mark.parametrize("scenario", scenario_names())
def test_scenario_runs_identical_across_backends(scenario):
    sc = get_scenario(scenario)
    res_np = sc.run(BACEPipePolicy(), seed=0, decision_backend="numpy")
    res_jx = sc.run(BACEPipePolicy(), seed=0, decision_backend="jax")
    assert res_np.to_jsonable() == res_jx.to_jsonable()


@needs_jax
def test_paper_workload_identical_across_backends_and_engines():
    from repro.core.workloads import paper_jobs

    for seed in (0, 1, 2):
        def fresh():
            return paper_cluster(), paper_profiles(paper_jobs(seed=seed))

        cluster, profs = fresh()
        res_np = simulate(cluster, profs, BACEPipePolicy())
        cluster, profs = fresh()
        res_jx = simulate(
            cluster, profs, BACEPipePolicy(), decision_backend="jax"
        )
        cluster, profs = fresh()
        res_legacy = simulate(
            cluster, profs, BACEPipePolicy(), engine="legacy"
        )
        assert res_np.to_jsonable() == res_jx.to_jsonable()
        assert res_np.to_jsonable() == res_legacy.to_jsonable()


# ------------------------------------------------------------ property sweep
if HAVE_HYPOTHESIS:

    cluster_seed_st = st.integers(min_value=0, max_value=2**31 - 1)
    job_seed_st = st.integers(min_value=0, max_value=2**31 - 1)

    @settings(max_examples=40, deadline=None)
    @given(cluster_seed_st, job_seed_st, st.booleans())
    def test_property_numpy_matches_legacy(cseed, jseed, hetero):
        cluster = random_cluster(random.Random(cseed), hetero=hetero)
        prof = random_profile(random.Random(jseed))
        p_new = find_placement(prof, cluster, backend="numpy")
        if hetero:
            # The legacy reference predates typed pools; on hetero clusters
            # assert internal consistency instead: any placement respects
            # the memory floor and only uses regions with free GPUs.
            if p_new is not None:
                assert p_new.total_gpus >= prof.min_gpus
                for r, c in p_new.alloc.items():
                    assert c >= 1
        else:
            p_ref = legacy_find_placement(prof, cluster)
            assert placement_key(p_new) == placement_key(p_ref)

    if jax_available():

        @settings(max_examples=40, deadline=None)
        @given(cluster_seed_st, job_seed_st, st.booleans())
        def test_property_backends_bit_identical(cseed, jseed, hetero):
            cluster = random_cluster(random.Random(cseed), hetero=hetero)
            prof = random_profile(random.Random(jseed))
            inputs = _prim_inputs(cluster, prof)
            outs = {
                b: prim_expand(*inputs, backend=b) for b in DECISION_BACKENDS
            }
            for a, b in zip(outs["numpy"], outs["jax"]):
                np.testing.assert_array_equal(a, b)
            assert placement_key(
                find_placement(prof, cluster, backend="numpy")
            ) == placement_key(find_placement(prof, cluster, backend="jax"))
