import os
import sys

# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches see the 1 real CPU device.  Multi-device distribution tests spawn
# subprocesses that set the flag themselves (see test_distributed.py), and
# the dry-run sets 512 in launch/dryrun.py only.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_addoption(parser):
    parser.addoption(
        "--regen",
        action="store_true",
        default=False,
        help="regenerate the golden-trace files under tests/golden/ from "
        "the current engine instead of asserting against them",
    )
