import os
import sys

# NOTE: no --xla_force_host_platform_device_count here — smoke tests and
# benches see the 1 real CPU device.  Multi-device distribution tests spawn
# subprocesses that set the flag themselves (see test_distributed.py), and
# the dry-run sets 512 in launch/dryrun.py only.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
