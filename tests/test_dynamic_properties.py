"""Property-based tests of the dynamic engine's conservation invariants.

The invariant checker (``check_dynamic_invariants``) is plain code so the
fixed-case tests at the bottom exercise it even when ``hypothesis`` (an
optional dev extra) is absent; the ``@given`` tests then sweep it over
arbitrary clusters, job sets, and bandwidth/price traces.

Invariants, under *any* trace (and with voluntary migration on or off):
- every job eventually completes exactly once (final non-preempted segment);
- segments of one job never overlap and strictly alternate
  preempt -> restart;
- everything reserved is released: the simulator's cluster ends with all
  GPUs free and zero reserved bandwidth on every link;
- instantaneous GPU usage never exceeds any region's capacity (replay);
- no placement ever dips below the job's memory floor (``min_gpus``),
  migrations included, and pipeline continuity (>=1 GPU per path region)
  holds;
- migration/stall bookkeeping is consistent with the per-segment records,
  and voluntary counts are a subset of total migrations;
- cost is monotone in time: every settled segment cost is >= 0 (so each
  job's cumulative Eq. 4 ledger never decreases), and the segment costs
  partition the per-job total;
- migration never increases owed work: replaying the checkpoint floor over
  the segment records yields a non-increasing remaining-iteration sequence
  that exactly explains the final segment's duration.
"""

import pytest

from repro.core import (
    DEFAULT_RESTART_PENALTY_S,
    BACEPipePolicy,
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    Simulator,
)


def build_cluster(caps_prices, bw=8.0):
    regs = [Region(f"r{i}", c, p) for i, (c, p) in enumerate(caps_prices)]
    gbps = {}
    for i, a in enumerate(regs):
        for b in regs[i + 1 :]:
            gbps[(a.name, b.name)] = bw
    return ClusterState.build(regs, gbps, symmetric=True)


def build_profiles(raw):
    profs = []
    for i, (params, layers, hidden, batch, iters, submit) in enumerate(raw):
        spec = JobSpec(
            job_id=i,
            model=ModelSpec(f"j{i}", params, layers, hidden, batch),
            iterations=iters,
            submit_time=submit,
        )
        # generous memory => min_gpus small => every job fits *some* region
        # even with all links dead, so completion is guaranteed
        profs.append(JobProfile(spec, gpu_flops=300e12, gpu_memory=400e9))
    return profs


def build_trace(cluster, raw_updates):
    links = sorted(cluster.bandwidth)
    regions = cluster.region_names()
    updates = []
    for t, link_sel, bw_mult, price_sel, price_mult in raw_updates:
        bw = {links[i % len(links)]: bw_mult for i in link_sel}
        pr = {regions[i % len(regions)]: price_mult for i in price_sel}
        updates.append(EnvUpdate(time=t, bandwidth=bw, prices=pr))
    return BandwidthTrace(updates)


def check_dynamic_invariants(cluster, profiles, trace, *, threshold=None):
    sim = Simulator(
        cluster,
        profiles,
        BACEPipePolicy(),
        trace=trace,
        voluntary_migration_threshold=threshold,
    )
    res = sim.run()

    # -- every job completes exactly once
    final = [r for r in res.records if not r.preempted]
    assert sorted(r.job_id for r in final) == sorted(
        p.spec.job_id for p in profiles
    )

    # -- per-job segment structure: ordered, non-overlapping, aborted
    #    segments all precede the completion
    by_job = {}
    for r in res.records:
        by_job.setdefault(r.job_id, []).append(r)
    for job_id, segs in by_job.items():
        assert segs == sorted(segs, key=lambda r: r.start)
        for a, b in zip(segs, segs[1:]):
            assert a.preempted and a.finish <= b.start
        assert not segs[-1].preempted
        assert all(s.preempted for s in segs[:-1])

    # -- migration / stall bookkeeping mirrors the records
    for job_id, segs in by_job.items():
        n_aborted = sum(1 for s in segs if s.preempted)
        assert res.migrations.get(job_id, 0) == n_aborted
        if n_aborted:
            assert res.stall_seconds[job_id] >= 0.0
    assert set(res.migrations) == set(res.stall_seconds)
    for job_id, n_vol in res.voluntary_migrations.items():
        assert 0 < n_vol <= res.migrations[job_id]
    assert sum(res.forced_migrations.values()) + sum(
        res.voluntary_migrations.values()
    ) == res.total_migrations

    # -- cost monotone in time: every settled segment cost is >= 0 (the
    #    per-job cumulative ledger is then non-decreasing by construction)
    #    and segment costs partition the per-job Eq. 4 total
    for job_id, segs in by_job.items():
        for s in segs:
            assert s.cost >= 0.0
        assert sum(s.cost for s in segs) == pytest.approx(
            res.costs[job_id], rel=1e-9, abs=1e-12
        )
        assert res.costs[job_id] >= 0.0

    # -- migration (forced or voluntary) never increases owed work: replay
    #    the checkpoint floor over the segments; remaining is non-increasing
    #    and the final segment's duration is exactly the owed work plus the
    #    restart restore window
    prof_by_id = {p.spec.job_id: p for p in profiles}
    penalty = DEFAULT_RESTART_PENALTY_S
    for job_id, segs in by_job.items():
        remaining = prof_by_id[job_id].spec.iterations
        for i, s in enumerate(segs[:-1]):
            restore = penalty if i > 0 else 0.0
            trained = max(0.0, (s.finish - s.start) - restore)
            done = int(trained // s.iteration_seconds)
            new_remaining = max(1, remaining - max(0, done))
            assert new_remaining <= remaining
            remaining = new_remaining
        final = segs[-1]
        restore = penalty if len(segs) > 1 else 0.0
        assert final.execution == pytest.approx(
            remaining * final.iteration_seconds + restore, rel=1e-9
        )

    # -- released == reserved: the ledgers are back at their initial state
    assert sim.cluster.total_free_gpus() == sim.cluster.total_gpus()
    for region in sim.cluster.region_names():
        free = sim.cluster.free_gpus[region]
        assert 0 <= free <= sim.cluster.regions[region].gpu_capacity
    for link, reserved in sim.cluster.reserved_bw.items():
        assert reserved == pytest.approx(0.0, abs=1e-6), link

    # -- memory floor + continuity + per-region capacity, every segment
    for r in res.records:
        prof = prof_by_id[r.job_id]
        assert r.placement.total_gpus >= prof.min_gpus
        assert all(n >= 1 for n in r.placement.alloc.values())
        for region, n in r.placement.alloc.items():
            assert n <= cluster.regions[region].gpu_capacity

    # -- instantaneous GPU usage never exceeds capacity (timeline replay;
    #    at equal timestamps releases happen before reservations)
    deltas = []
    for r in res.records:
        for region, n in r.placement.alloc.items():
            deltas.append((r.start, n, region))
            deltas.append((r.finish, -n, region))
    usage = {}
    for t, delta, region in sorted(deltas, key=lambda e: (e[0], e[1])):
        usage[region] = usage.get(region, 0) + delta
        assert usage[region] <= cluster.regions[region].gpu_capacity
        assert usage[region] >= 0 or abs(usage[region]) == 0

    # -- event log is chronological and internally consistent ("preempt" =
    #    forced eviction, "migrate" = price-reactive voluntary checkpoint)
    times = [t for t, _, _ in res.events]
    assert times == sorted(times)
    n_forced = sum(1 for _, k, _ in res.events if k == "preempt")
    n_vol = sum(1 for _, k, _ in res.events if k == "migrate")
    assert n_forced + n_vol == res.total_migrations
    assert n_vol == res.total_voluntary_migrations

    return res


# ---------------------------------------------------------------- fixed cases
FIXED_CASES = [
    # (caps_prices, raw_jobs, raw_updates)
    (
        [(8, 0.10), (4, 0.20), (2, 0.30)],
        [(8e9, 16, 1024, 16, 10, 0.0), (2e9, 8, 1024, 8, 5, 600.0)],
        [(1800.0, [0, 1, 2], 0.05, [0], 2.0), (7200.0, [0, 1, 2], 1.0, [0], 1.0)],
    ),
    (
        [(6, 0.15), (6, 0.12)],
        [(20e9, 24, 2048, 16, 12, 0.0), (1e9, 8, 1024, 8, 30, 100.0)],
        [(900.0, [0, 1], 0.0, [], 1.0)],  # link fully dead, never recovers
    ),
    (
        [(16, 0.10), (8, 0.25), (8, 0.18), (4, 0.30)],
        [
            (30e9, 32, 2048, 32, 8, 0.0),
            (10e9, 16, 2048, 16, 20, 50.0),
            (5e9, 12, 1024, 16, 40, 50.0),
        ],
        [
            (1000.0, [0, 2, 4], 0.2, [1], 3.0),
            (1000.0, [1, 3], 0.6, [], 1.0),  # same-timestamp second update
            (5000.0, [0, 1, 2, 3, 4, 5], 1.0, [1], 1.0),
        ],
    ),
    # Voluntary-migration exerciser: a long job on the cheap region whose
    # price quintuples mid-run with the (now cheaper) other region idle —
    # under threshold=0.1 this produces exactly the voluntary checkpoint
    # path (see test_fixed_cases_reach_voluntary_migration).
    (
        [(8, 0.05), (8, 0.15)],
        [(8e9, 4, 1024, 16, 5000, 0.0)],
        [(1000.0, [], 1.0, [0], 5.0)],
    ),
]


@pytest.mark.parametrize("threshold", [None, 0.1], ids=["stay", "migrate"])
@pytest.mark.parametrize("caps_prices,raw_jobs,raw_updates", FIXED_CASES)
def test_dynamic_invariants_fixed(caps_prices, raw_jobs, raw_updates, threshold):
    cluster = build_cluster(caps_prices)
    profiles = build_profiles(raw_jobs)
    trace = build_trace(cluster, raw_updates)
    check_dynamic_invariants(cluster, profiles, trace, threshold=threshold)


def test_fixed_cases_reach_voluntary_migration():
    """The 'migrate' parametrization above must not be vacuous: at least one
    fixed case has to actually take the voluntary checkpoint path (the
    hypothesis sweep is an optional extra, so without this the voluntary
    preempt/settle path could regress with the unit suite green)."""
    total = 0
    for caps_prices, raw_jobs, raw_updates in FIXED_CASES:
        cluster = build_cluster(caps_prices)
        profiles = build_profiles(raw_jobs)
        trace = build_trace(cluster, raw_updates)
        res = check_dynamic_invariants(
            cluster, profiles, trace, threshold=0.1
        )
        total += res.total_voluntary_migrations
    assert total > 0


def test_dead_links_still_complete_via_single_region():
    """With every link at multiplier 0 forever, Phase-2 single-seed paths
    keep the cluster schedulable: all jobs must still finish."""
    cluster = build_cluster([(8, 0.1), (8, 0.2)])
    profiles = build_profiles(
        [(4e9, 16, 1024, 16, 10, 0.0), (4e9, 16, 1024, 16, 10, 0.0)]
    )
    trace = build_trace(cluster, [(10.0, [0, 1], 0.0, [], 1.0)])
    res = check_dynamic_invariants(cluster, profiles, trace)
    for r in res.completed_records:
        if r.start > 10.0:
            assert r.placement.n_regions == 1


# ------------------------------------------------------------- property sweep
# hypothesis is an optional dev extra: the fixed cases above always run; the
# @given sweep below only exists when it is installed.
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover
    given = None

if given is not None:
    regions_st = st.lists(
        st.tuples(
            st.integers(min_value=2, max_value=32),     # capacity
            st.floats(min_value=0.05, max_value=0.40),  # price
        ),
        min_size=2,
        max_size=5,
    )

    jobs_st = st.lists(
        st.tuples(
            st.floats(min_value=0.5e9, max_value=40e9),   # params
            st.sampled_from([8, 16, 24, 32]),             # layers
            st.sampled_from([1024, 2048]),                # hidden
            st.sampled_from([8, 16, 32]),                 # batch
            st.integers(min_value=1, max_value=40),       # iterations
            st.floats(min_value=0.0, max_value=20_000.0),  # submit time
        ),
        min_size=1,
        max_size=5,
    )

    updates_st = st.lists(
        st.tuples(
            st.floats(min_value=0.0, max_value=80_000.0),      # breakpoint time
            st.lists(st.integers(min_value=0, max_value=19),    # link selector
                     max_size=6),
            st.floats(min_value=0.0, max_value=1.5),            # bw multiplier
            st.lists(st.integers(min_value=0, max_value=9),     # region selector
                     max_size=3),
            st.floats(min_value=0.25, max_value=4.0),           # price multiplier
        ),
        max_size=6,
    )


    @settings(max_examples=40, deadline=None)
    @given(regions_st, jobs_st, updates_st)
    def test_dynamic_invariants_hold_under_arbitrary_traces(
        caps_prices, raw_jobs, raw_updates
    ):
        cluster = build_cluster(caps_prices)
        profiles = build_profiles(raw_jobs)
        trace = build_trace(cluster, raw_updates)
        check_dynamic_invariants(cluster, profiles, trace)


    @settings(max_examples=25, deadline=None)
    @given(regions_st, jobs_st, updates_st)
    def test_dynamic_invariants_hold_with_voluntary_migration(
        caps_prices, raw_jobs, raw_updates
    ):
        """Same sweep with the price-reactive voluntary pass armed: cost
        monotonicity and the remaining-iterations replay must survive
        arbitrary combinations of forced and voluntary checkpoints."""
        cluster = build_cluster(caps_prices)
        profiles = build_profiles(raw_jobs)
        trace = build_trace(cluster, raw_updates)
        check_dynamic_invariants(cluster, profiles, trace, threshold=0.05)


    @settings(max_examples=25, deadline=None)
    @given(regions_st, jobs_st, updates_st)
    def test_dynamic_runs_are_deterministic(caps_prices, raw_jobs, raw_updates):
        def once():
            cluster = build_cluster(caps_prices)
            profiles = build_profiles(raw_jobs)
            trace = build_trace(cluster, raw_updates)
            return Simulator(
                cluster, profiles, BACEPipePolicy(), trace=trace
            ).run()

        assert once().to_jsonable() == once().to_jsonable()
