"""Microplan subsystem tests: planner semantics, analytic agreement, the
TimingModel seam, and the JobSpec/ModelSpec validation satellites.

The analytic↔microplan agreement suite runs fixed cases always and widens
into a randomized sweep when hypothesis is installed (dev extra), mirroring
the repo's other property tests.
"""

import math

import pytest

from repro.core import (
    PIPELINE_SCHEDULES,
    BACEPipePolicy,
    JobProfile,
    JobSpec,
    ModelSpec,
    PipelineTopology,
    plan_from_topology,
    plan_schedule,
    simulate,
    topology_from_placement,
)
from repro.core.scenarios import SCENARIOS
from repro.core.timing import (
    analytic_iteration_time,
    get_timing_model,
    iteration_time,
)

REL = 1e-9


def eq1(topo: PipelineTopology) -> float:
    """Closed-form Eq. (1) recomputed from a raw topology."""
    fill_comm = sum(topo.all_hops)
    t = topo.stage_time_fwd[0]
    m = topo.n_microbatches
    return (fill_comm + topo.n_stages * t + (m - 1) * topo.bottleneck) * 2.0


def uniform_topo(m, stages, t, hops=(), egress=()):
    return PipelineTopology(
        n_microbatches=m,
        stage_time_fwd=(t,) * stages,
        stage_time_bwd=(t,) * stages,
        boundaries=tuple(tuple(h) for h in hops),
        egress=tuple(egress),
    )


# ------------------------------------------------------------ fixed topologies
def test_gpipe_no_comm_matches_closed_form():
    topo = uniform_topo(8, 4, 0.5, hops=[(), (), ()])
    plan = plan_from_topology(topo, "gpipe")
    expect = (4 * 0.5 + 7 * 0.5) * 2.0
    assert math.isclose(plan.iteration_time, expect, rel_tol=REL)
    assert plan.peak_activations == 8.0


def test_gpipe_with_hops_matches_eq1():
    topo = uniform_topo(6, 3, 0.4, hops=[(0.1, 0.05), (0.4,)])
    plan = plan_from_topology(topo, "gpipe")
    assert math.isclose(plan.iteration_time, eq1(topo), rel_tol=REL)


def test_gpipe_comm_bound_delta():
    # A hop slower than compute dominates the steady state.
    topo = uniform_topo(5, 2, 0.2, hops=[(0.7,)])
    plan = plan_from_topology(topo, "gpipe")
    assert math.isclose(plan.iteration_time, eq1(topo), rel_tol=REL)
    assert topo.bottleneck == 0.7


def test_single_stage_gpipe():
    topo = uniform_topo(4, 1, 0.3)
    plan = plan_from_topology(topo, "gpipe")
    assert math.isclose(plan.iteration_time, 2 * 4 * 0.3, rel_tol=REL)


def test_single_stage_with_egress_hops_matches_eq1():
    # Degenerate 1-layer model spread over several GPUs: the trailing hops
    # are still paid, exactly as Eq. (1)'s fill term pays them.
    topo = uniform_topo(4, 1, 0.3, egress=(0.1, 0.1))
    plan = plan_from_topology(topo, "gpipe")
    assert math.isclose(plan.iteration_time, eq1(topo), rel_tol=REL)


def test_single_stage_1f1b_alternates():
    # One stage, no hops: true 1F1B alternation — one activation in flight,
    # same total stage time as GPipe.
    topo = uniform_topo(4, 1, 0.3)
    ofb = plan_from_topology(topo, "1f1b")
    gp = plan_from_topology(topo, "gpipe")
    assert math.isclose(ofb.iteration_time, gp.iteration_time, rel_tol=REL)
    assert ofb.peak_activations == 1.0
    # With egress hops, alternation would stall on the round trip per pair;
    # the planner falls back to the phase-decoupled GPipe order.
    hop_topo = uniform_topo(4, 1, 0.3, egress=(0.1,))
    ofb2 = plan_from_topology(hop_topo, "1f1b")
    gp2 = plan_from_topology(hop_topo, "gpipe")
    assert ofb2.iteration_time <= gp2.iteration_time * (1 + REL)


def test_single_stage_overlap_egress_events_within_makespan():
    topo = uniform_topo(4, 1, 0.3, egress=(0.1, 0.1))
    plan = plan_from_topology(topo, "gpipe-overlap", keep_events=True)
    # The trailing round trip is not hidden by any lockstep tick.
    assert math.isclose(
        plan.iteration_time, 2 * 4 * 0.3 + 2 * 0.2, rel_tol=REL
    )
    assert {e.kind for e in plan.events} == {
        "fwd", "bwd", "fwd_comm", "bwd_comm",
    }
    for e in plan.events:
        assert -1e-12 <= e.start <= e.end <= plan.iteration_time + 1e-12
    # Hop chains are serial, not simultaneous.
    fwd_hops = [
        e for e in plan.events if e.kind == "fwd_comm" and e.microbatch == 0
    ]
    assert fwd_hops[0].end <= fwd_hops[1].start + 1e-12


def test_1f1b_no_comm_equals_gpipe_time_with_smaller_stash():
    topo = uniform_topo(16, 4, 0.5, hops=[(), (), ()])
    gp = plan_from_topology(topo, "gpipe")
    ofb = plan_from_topology(topo, "1f1b")
    assert math.isclose(ofb.iteration_time, gp.iteration_time, rel_tol=REL)
    # Classic 1F1B stash: ~L-s in flight, not M.
    assert ofb.peak_activations <= 4.0
    assert gp.peak_activations == 16.0


def test_1f1b_never_slower_than_gpipe_with_wan_hop():
    topo = uniform_topo(12, 4, 0.5, hops=[(0.01,), (0.5,), (0.01,)])
    gp = plan_from_topology(topo, "gpipe")
    ofb = plan_from_topology(topo, "1f1b")
    assert ofb.iteration_time <= gp.iteration_time * (1 + REL)
    assert ofb.peak_activations <= gp.peak_activations


def test_gpipe_overlap_ticks_and_time():
    topo = uniform_topo(6, 3, 0.4, hops=[(0.1,), (0.2,)])
    plan = plan_from_topology(topo, "gpipe-overlap")
    assert plan.n_ticks == 6 + 3 - 1
    assert math.isclose(
        plan.iteration_time, 2 * plan.n_ticks * 0.4, rel_tol=REL
    )


def test_interleaved_reduces_to_gpipe_when_unchunked():
    topo = uniform_topo(8, 3, 0.4, hops=[(0.01,), (0.01,)])
    il1 = plan_from_topology(topo, "interleaved", virtual_stages=1)
    gp = plan_from_topology(topo, "gpipe")
    assert math.isclose(il1.iteration_time, gp.iteration_time, rel_tol=REL)


def test_interleaved_pays_wrap_transfers():
    # With a fat WAN hop, the v-1 extra wrap round trips make interleaving a
    # net loss — the cross-DC observation the ablation benchmark surfaces.
    wan = uniform_topo(8, 3, 0.4, hops=[(0.01,), (0.4,)])
    il = plan_from_topology(wan, "interleaved", virtual_stages=2)
    gp = plan_from_topology(wan, "gpipe")
    assert il.iteration_time > gp.iteration_time


def test_planner_rejects_bad_inputs():
    with pytest.raises(ValueError):
        plan_from_topology(uniform_topo(4, 2, 0.1, hops=[()]), "nope")
    with pytest.raises(ValueError):
        uniform_topo(0, 2, 0.1, hops=[()])
    with pytest.raises(ValueError):
        PipelineTopology(
            n_microbatches=2,
            stage_time_fwd=(0.1, 0.1),
            stage_time_bwd=(0.1, 0.1),
            boundaries=(),  # needs exactly one boundary group
        )


def test_plan_events_materialization():
    topo = uniform_topo(3, 2, 0.5, hops=[(0.1,)])
    plan = plan_from_topology(topo, "gpipe", keep_events=True)
    assert plan.events and plan.edges
    kinds = {e.kind for e in plan.events}
    assert kinds == {"fwd", "bwd", "fwd_comm", "bwd_comm"}
    # 3 fwd + 3 bwd per stage, 3 transfers per direction on the boundary.
    assert len(plan.events) == 2 * (3 * 2) + 2 * 3
    for prod, cons in plan.edges:
        assert plan.events[cons].start >= plan.events[prod].end - 1e-12
    # Events cover the makespan.
    assert math.isclose(
        max(e.end for e in plan.events), plan.iteration_time, rel_tol=REL
    )
    # Without keep_events the timeline is not materialized.
    assert plan_from_topology(topo, "gpipe").events == ()


def test_overlap_events_cover_both_directions():
    topo = uniform_topo(3, 2, 0.5, hops=[(0.1,)])
    plan = plan_from_topology(topo, "gpipe-overlap", keep_events=True)
    kinds = {e.kind for e in plan.events}
    assert kinds == {"fwd", "bwd", "fwd_comm", "bwd_comm"}
    # Same slot counts as the op-simulated gpipe timeline; lockstep plans
    # carry no explicit dependency edges (the tick barrier is the structure).
    assert len(plan.events) == 2 * (3 * 2) + 2 * 3
    assert plan.edges == ()
    for e in plan.events:
        assert 0.0 <= e.start <= e.end


# ----------------------------------------------- static-paper placement sweep
@pytest.fixture(scope="module")
def static_placements():
    scen = SCENARIOS["static-paper"]
    cluster, profiles, _ = scen.build(seed=0)
    res = simulate(cluster, profiles, BACEPipePolicy())
    profs = {p.spec.job_id: p for p in profiles}
    return [(profs[r.job_id], r.placement) for r in res.completed_records]


def test_topology_hop_multiset_matches_placement(static_placements):
    for prof, placement in static_placements:
        topo = topology_from_placement(prof, placement)
        assert sorted(topo.all_hops) == pytest.approx(
            sorted(placement.comm_times)
        )
        assert topo.n_stages == prof.pipeline_depth(placement.total_gpus)


def test_gpipe_plan_reproduces_eq1_on_all_static_placements(
    static_placements,
):
    for prof, placement in static_placements:
        plan = plan_schedule(prof, placement, "gpipe")
        expect = analytic_iteration_time(prof, placement)
        assert math.isclose(plan.iteration_time, expect, rel_tol=REL), (
            prof.spec.job_id
        )


def test_schedule_orderings_on_all_static_placements(static_placements):
    for prof, placement in static_placements:
        gp = plan_schedule(prof, placement, "gpipe")
        ofb = plan_schedule(prof, placement, "1f1b")
        ov = plan_schedule(prof, placement, "gpipe-overlap")
        assert ofb.iteration_time <= gp.iteration_time * (1 + REL)
        assert ov.iteration_time <= gp.iteration_time * (1 + REL)
        assert ofb.peak_activations <= gp.peak_activations


def test_all_schedules_plan_on_all_static_placements(static_placements):
    for prof, placement in static_placements:
        for schedule in PIPELINE_SCHEDULES:
            plan = plan_schedule(prof, placement, schedule)
            assert plan.iteration_time > 0.0
            assert 0.0 <= plan.bubble_fraction < 1.0
            assert len(plan.stage_bubble) == plan.n_stages


# ------------------------------------------------------------ timing backends
def _tiny_spec(**kw):
    return JobSpec(
        0, ModelSpec("m", 2e9, 8, 1024, batch_size=8), iterations=5, **kw
    )


def test_timing_seam_analytic_default_is_closed_form(static_placements):
    prof, placement = static_placements[0]
    assert prof.spec.timing_model == "analytic"
    assert iteration_time(prof, placement) == analytic_iteration_time(
        prof, placement
    )


def test_timing_seam_microplan_backend(static_placements):
    import dataclasses

    prof, placement = static_placements[0]
    for schedule in ("gpipe", "1f1b"):
        spec = dataclasses.replace(
            prof.spec, timing_model="microplan", pipeline_schedule=schedule
        )
        mp = JobProfile(spec, gpu_flops=prof.gpu_flops)
        expect = plan_schedule(mp, placement, schedule).iteration_time
        assert iteration_time(mp, placement) == expect


def test_get_timing_model_unknown_raises():
    with pytest.raises(KeyError):
        get_timing_model("nope")


def test_microplan_simulation_matches_analytic():
    """End-to-end seam check: the whole static-paper simulation under the
    microplan/gpipe backend lands on the analytic schedule (Eq. (1)
    agreement), and 1f1b never does worse."""
    scen = SCENARIOS["static-paper"]
    base = scen.run(BACEPipePolicy(), seed=0, n_jobs=4)
    gp = scen.run(
        BACEPipePolicy(),
        seed=0,
        n_jobs=4,
        job_kwargs={"timing_model": "microplan", "pipeline_schedule": "gpipe"},
    )
    assert math.isclose(gp.average_jct, base.average_jct, rel_tol=REL)
    assert math.isclose(gp.makespan, base.makespan, rel_tol=REL)
    ofb = scen.run(
        BACEPipePolicy(),
        seed=0,
        n_jobs=4,
        job_kwargs={"timing_model": "microplan", "pipeline_schedule": "1f1b"},
    )
    assert ofb.average_jct <= base.average_jct * (1 + REL)


def test_jobspec_rejects_unknown_backend_and_schedule():
    with pytest.raises(ValueError):
        _tiny_spec(timing_model="nope")
    with pytest.raises(ValueError):
        _tiny_spec(pipeline_schedule="nope")
    spec = _tiny_spec(timing_model="microplan", pipeline_schedule="1f1b")
    assert spec.pipeline_schedule == "1f1b"


# ------------------------------------------- ModelSpec microbatch validation
def test_microbatch_divisibility_validated():
    with pytest.raises(ValueError, match="not divisible"):
        ModelSpec("m", 2e9, 8, 1024, batch_size=10, microbatch_seqs=3)
    with pytest.raises(ValueError):
        ModelSpec("m", 2e9, 8, 1024, batch_size=0)
    with pytest.raises(ValueError):
        ModelSpec("m", 2e9, 8, 1024, batch_size=8, microbatch_seqs=0)


def test_microbatch_count_exact_when_divisible():
    spec = ModelSpec("m", 2e9, 8, 1024, batch_size=12, microbatch_seqs=3)
    assert spec.microbatches == 4
    assert ModelSpec("m", 2e9, 8, 1024, batch_size=1).microbatches == 1


# --------------------------------------------------- hypothesis widening
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=24),
        stages=st.integers(min_value=1, max_value=8),
        t=st.floats(min_value=1e-3, max_value=1.0),
        hop_scale=st.floats(min_value=0.0, max_value=2.0),
        data=st.data(),
    )
    def test_hypothesis_gpipe_matches_eq1_and_orderings(
        m, stages, t, hop_scale, data
    ):
        hops = tuple(
            tuple(
                data.draw(
                    st.floats(min_value=0.0, max_value=max(hop_scale * t, 1e-9))
                )
                for _ in range(data.draw(st.integers(1, 3)))
            )
            for _ in range(stages - 1)
        )
        topo = uniform_topo(m, stages, t, hops=hops)
        gp = plan_from_topology(topo, "gpipe")
        assert math.isclose(gp.iteration_time, eq1(topo), rel_tol=1e-9)
        ofb = plan_from_topology(topo, "1f1b")
        assert ofb.iteration_time <= gp.iteration_time * (1 + 1e-9)
        assert ofb.peak_activations <= gp.peak_activations
        il = plan_from_topology(topo, "interleaved")
        assert il.iteration_time > 0.0

except ImportError:  # hypothesis is a dev extra; fixed cases always run
    pass
