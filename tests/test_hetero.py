"""Typed GPU pools: heterogeneous accelerators + spot capacity.

Covers the (region, type) ledger layout (single-type round-trip bit-exact,
per-type reserve/release conservation), the typed Cost-Min/Pathfinder
pricing, granted-hardware timing and memory floors, spot reclaim through the
forced-preemption path, and the ledger edge-case regressions this PR fixes
(negative free-count writes, zero-capacity-link tolerances).

Fixed cases always run; a hypothesis sweep widens the conservation property
when the library is installed (same convention as the other property
suites).
"""

from __future__ import annotations

import random

import pytest

from repro.core import (
    BACEPipePolicy,
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    GpuPool,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    build_placement,
    cost_min_allocate,
    find_placement,
    simulate,
)
from repro.core.cluster import DEFAULT_GPU_TYPE
from repro.core.job import DEFAULT_GPU_KW
from repro.core.timing import (
    average_price,
    iteration_time,
    placement_power_rate,
)
from repro.core.workloads import (
    hetero_fleet_cluster,
    paper_cluster,
    spot_fleet_cluster,
    spot_reclaim_trace,
)


def _plain_cluster() -> ClusterState:
    regions = [Region("a", 8, 0.10), Region("b", 6, 0.20), Region("c", 4, 0.15)]
    gbps = {("a", "b"): 50.0, ("b", "c"): 50.0, ("a", "c"): 50.0}
    return ClusterState.build(regions, gbps, symmetric=True)


def _hetero_cluster() -> ClusterState:
    regions = [
        Region.with_pools(
            "a",
            0.10,
            [
                GpuPool("h100", 4, flops=300e12, memory=80e9, gpu_kw=0.7),
                GpuPool("spot", 4, spot=True, price_mult=0.35),
            ],
        ),
        Region.with_pools("b", 0.20, [GpuPool("a100", 6)]),
        Region("c", 4, 0.15),
    ]
    gbps = {("a", "b"): 50.0, ("b", "c"): 50.0, ("a", "c"): 50.0}
    return ClusterState.build(regions, gbps, symmetric=True)


def _profile(iters: int = 20) -> JobProfile:
    return JobProfile(
        JobSpec(0, ModelSpec("m", 8e9, 24, 4096, 32), iters),
        gpu_memory=400e9,
    )


# ---------------------------------------------------- typed-ledger round-trip
def test_single_type_layout_is_one_default_column():
    cluster = _plain_cluster()
    assert not cluster.is_heterogeneous
    assert cluster.typed_capacity_matrix().shape == (3, 1)
    for r in cluster.region_names():
        assert cluster.gpu_types(r) == [DEFAULT_GPU_TYPE]
        assert cluster.capacity_typed(r) == {
            DEFAULT_GPU_TYPE: cluster.regions[r].gpu_capacity
        }
        assert cluster.free_gpus_typed(r) == {
            DEFAULT_GPU_TYPE: cluster.free_gpus[r]
        }


def _reference_ledger_walk(ops):
    """Drive the same op sequence through the typed cluster and a pure dict
    model; the aggregates must stay bit-identical (ints, so bit == value)."""
    cluster = _plain_cluster()
    ref = {r: cluster.regions[r].gpu_capacity for r in cluster.regions}
    for kind, region, n in ops:
        if kind == "reserve":
            ok_ref = 0 <= n <= ref[region]
            try:
                cluster.reserve_gpus({region: n})
                assert ok_ref
                ref[region] -= n
            except ValueError:
                assert not ok_ref
        else:
            cap = cluster.regions[region].gpu_capacity
            ok_ref = ref[region] + n <= cap
            try:
                cluster.release_gpus({region: n})
                assert ok_ref
                ref[region] += n
            except ValueError:
                assert not ok_ref
    for r, free in ref.items():
        assert cluster.free_gpus[r] == free
        assert cluster.free_gpus_typed(r) == {DEFAULT_GPU_TYPE: free}
    assert cluster.total_free_gpus() == sum(ref.values())


FIXED_OP_SEQUENCES = [
    [("reserve", "a", 3), ("reserve", "b", 6), ("release", "a", 3)],
    [("reserve", "a", 8), ("release", "a", 9)],  # over-release rejected
    [("reserve", "c", 4), ("release", "c", 2), ("reserve", "c", 2)],
    [("reserve", "a", 9)],  # over-reserve rejected
    [("reserve", "b", 2), ("reserve", "b", 2), ("release", "b", 4)],
]


@pytest.mark.parametrize("ops", FIXED_OP_SEQUENCES)
def test_single_type_round_trip_fixed(ops):
    _reference_ledger_walk(ops)


def test_single_type_round_trip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        st.lists(
            st.tuples(
                st.sampled_from(["reserve", "release"]),
                st.sampled_from(["a", "b", "c"]),
                st.integers(min_value=0, max_value=10),
            ),
            max_size=30,
        )
    )
    @hyp.settings(deadline=None, max_examples=100)
    def run(ops):
        _reference_ledger_walk(ops)

    run()


def test_snapshot_round_trips_typed_state():
    cluster = _hetero_cluster()
    cluster.reserve_gpus_typed({"a": {"spot": 3, "h100": 1}, "b": {"a100": 2}})
    cluster.set_spot_multipliers({("a", "spot"): 0.5})
    snap = cluster.snapshot()
    assert (snap.typed_capacity_matrix() == cluster.typed_capacity_matrix()).all()
    assert (snap.typed_used_matrix() == cluster.typed_used_matrix()).all()
    assert snap.total_gpus() == cluster.total_gpus()
    assert snap.total_free_gpus() == cluster.total_free_gpus()
    for r in cluster.region_names():
        assert snap.free_gpus_typed(r) == cluster.free_gpus_typed(r)
    assert snap.oversubscribed_pools() == cluster.oversubscribed_pools()


# -------------------------------------------------- per-type conservation
def test_typed_reserve_release_conservation():
    cluster = _hetero_cluster()
    cap0 = {r: cluster.capacity_typed(r) for r in cluster.region_names()}
    rng = random.Random(7)
    held = []
    for _ in range(50):
        if held and rng.random() < 0.4:
            alloc = held.pop(rng.randrange(len(held)))
            cluster.release_gpus_typed(alloc)
            continue
        r = rng.choice(cluster.region_names())
        free = cluster.free_gpus_typed(r)
        types = [t for t, f in free.items() if f > 0]
        if not types:
            continue
        t = rng.choice(types)
        n = rng.randint(1, free[t])
        alloc = {r: {t: n}}
        cluster.reserve_gpus_typed(alloc)
        held.append(alloc)
    # conservation: free + in-use == capacity, per (region, type)
    for r in cluster.region_names():
        free = cluster.free_gpus_typed(r)
        used = {}
        for alloc in held:
            for t, n in alloc.get(r, {}).items():
                used[t] = used.get(t, 0) + n
        for t, cap in cap0[r].items():
            assert free[t] + used.get(t, 0) == cap
    for alloc in held:
        cluster.release_gpus_typed(alloc)
    for r in cluster.region_names():
        assert cluster.free_gpus_typed(r) == cap0[r]
    assert cluster.total_free_gpus() == cluster.total_gpus()


def test_typed_over_release_raises():
    cluster = _hetero_cluster()
    cluster.reserve_gpus_typed({"a": {"h100": 2}})
    with pytest.raises(ValueError, match="over-release"):
        cluster.release_gpus_typed({"a": {"h100": 3}})
    # all-or-nothing: the failed release left the ledger untouched
    assert cluster.free_gpus_typed("a")["h100"] == 2
    with pytest.raises(KeyError):
        cluster.release_gpus_typed({"a": {"nope": 1}})


def test_untyped_reserve_takes_cheapest_cells_first():
    cluster = _hetero_cluster()
    # region a: spot (0.35 * 0.30 kW) is cheaper than h100 (1.0 * 0.7 kW)
    assert cluster.gpu_types("a") == ["spot", "h100"]
    cluster.reserve_gpus({"a": 5})
    assert cluster.free_gpus_typed("a") == {"spot": 0, "h100": 3}
    cluster.release_gpus({"a": 5})
    assert cluster.free_gpus_typed("a") == {"spot": 4, "h100": 4}


# ----------------------------------------------------- ledger regressions
def test_free_gpu_setitem_rejects_negative_counts():
    cluster = _plain_cluster()
    with pytest.raises(ValueError, match="negative free-GPU count"):
        cluster.free_gpus["a"] = -1
    # the running total survived the rejected write
    assert cluster.total_free_gpus() == 18
    cluster.free_gpus["a"] = 0  # zero stays legal (region-outage tests)
    assert cluster.total_free_gpus() == 10


def test_free_gpu_setitem_ambiguous_on_multi_pool_region():
    cluster = _hetero_cluster()
    with pytest.raises(TypeError, match="typed"):
        cluster.free_gpus["a"] = 3
    cluster.free_gpus["b"] = 2  # single-pool regions keep the aggregate API
    assert cluster.free_gpus_typed("b") == {"a100": 2}


# --------------------------------------------------- typed pricing/timing
def test_cost_min_pours_into_cheapest_cells_globally():
    cluster = _hetero_cluster()
    # cell rates: a/spot 0.0105 < b/a100 0.060 < a/h100 0.070 — the surplus
    # drains a's spot pool, then overflows into b's cheaper a100s, leaving
    # a's pricey h100s for the pinned continuity GPU only.
    alloc = cost_min_allocate(cluster, ["b", "a"], 8)
    assert alloc == {"b": 4, "a": 4}
    placement = build_placement(cluster=cluster, profile=_profile(),
                                path=["b", "a"], alloc=alloc)
    assert placement.typed_alloc["a"] == {"spot": 4}
    assert placement.typed_alloc["b"] == {"a100": 4}


def test_placement_effective_hardware_is_bottleneck():
    cluster = _hetero_cluster()
    prof = _profile()
    placement = build_placement(
        cluster=cluster, profile=prof, path=["a"], alloc={"a": 8}
    )
    # granted: 4 spot (profile-default hw) + 4 h100 -> bottleneck flops is
    # the profile default, bottleneck memory is the h100's 80 GB
    assert placement.eff_flops == prof.gpu_flops
    assert placement.eff_memory == 80e9
    # h100-only grant runs faster than the same GPU count at reference hw
    fast = build_placement(
        cluster=cluster,
        profile=prof,
        path=["a"],
        alloc={"a": 4},
        typed_alloc={"a": {"h100": 4}},
    )
    assert fast.eff_flops == 300e12
    ref = build_placement(
        cluster=cluster,
        profile=prof,
        path=["a"],
        alloc={"a": 4},
        typed_alloc={"a": {"spot": 4}},
    )
    assert iteration_time(prof, fast) < iteration_time(prof, ref)


def test_power_rate_honours_spot_discount_and_board_power():
    cluster = _hetero_cluster()
    prof = _profile()
    spot = build_placement(
        cluster=cluster, profile=prof, path=["a"], alloc={"a": 4},
        typed_alloc={"a": {"spot": 4}},
    )
    h100 = build_placement(
        cluster=cluster, profile=prof, path=["a"], alloc={"a": 4},
        typed_alloc={"a": {"h100": 4}},
    )
    rate_spot = placement_power_rate(prof, spot, cluster)
    rate_h100 = placement_power_rate(prof, h100, cluster)
    assert rate_spot == pytest.approx(
        0.10 * 0.35 * DEFAULT_GPU_KW * 4 / 3600.0
    )
    assert rate_h100 == pytest.approx(0.10 * 1.0 * 0.7 * 4 / 3600.0)
    assert average_price(spot, cluster) < average_price(h100, cluster)


def test_memory_floor_evaluates_against_granted_type():
    # 28 GB v100s cannot hold what reference-memory GPUs can at the same k.
    regions = [
        Region.with_pools(
            "v", 0.10, [GpuPool("v100", 8, flops=60e12, memory=28e9,
                                gpu_kw=0.25)]
        ),
        Region("ref", 8, 0.10),
    ]
    cluster = ClusterState.build(regions, {("v", "ref"): 50.0}, symmetric=True)
    prof = JobProfile(JobSpec(0, ModelSpec("m", 20e9, 40, 4096, 32), 10))
    floor_ref = prof.min_gpus
    floor_v100 = prof.min_gpus_for_memory(28e9)
    assert floor_v100 > floor_ref
    k = floor_ref
    build_placement(  # reference pool fits at its floor
        cluster=cluster, profile=prof, path=["ref"], alloc={"ref": k}
    )
    with pytest.raises(ValueError, match="memory floor"):
        build_placement(
            cluster=cluster, profile=prof, path=["v"], alloc={"v": k}
        )


def test_find_placement_on_hetero_cluster_is_typed_and_feasible():
    cluster = hetero_fleet_cluster()
    prof = _profile()
    placement = find_placement(prof, cluster)
    assert placement is not None and placement.typed_alloc
    for r, n in placement.alloc.items():
        assert sum(placement.typed_alloc[r].values()) == n
    # granted cells actually exist and fit their free counts
    for r, types in placement.typed_alloc.items():
        free = cluster.free_gpus_typed(r)
        for t, n in types.items():
            assert 0 < n <= free[t]


# --------------------------------------------------------- spot reclaim
def test_spot_multiplier_validation_and_oversubscription():
    cluster = _hetero_cluster()
    with pytest.raises(ValueError, match="not spot"):
        cluster.set_spot_multipliers({("a", "h100"): 0.5})
    with pytest.raises(KeyError):
        cluster.set_spot_multipliers({("a", "nope"): 0.5})
    cluster.reserve_gpus_typed({"a": {"spot": 4}})
    cluster.set_spot_multipliers({("a", "spot"): 0.25})  # cap 4 -> 1
    assert cluster.capacity_typed("a")["spot"] == 1
    assert cluster.oversubscribed_pools() == [("a", "spot")]
    assert cluster.free_gpus_typed("a")["spot"] == 0
    assert cluster.total_gpus() == 8 + 6 + 4 - 3
    # the running job still owns 4; releasing settles the deficit
    cluster.release_gpus_typed({"a": {"spot": 4}})
    assert cluster.oversubscribed_pools() == []
    assert cluster.free_gpus_typed("a")["spot"] == 1
    cluster.set_spot_multipliers({("a", "spot"): 1.0})
    assert cluster.free_gpus_typed("a")["spot"] == 4


def test_env_update_spot_routes_through_forced_preemption():
    regs = [
        Region.with_pools(
            "a",
            0.10,
            [
                GpuPool("h100", 8, flops=300e12, memory=80e9, gpu_kw=0.7),
                GpuPool("spot", 8, spot=True, price_mult=0.35),
            ],
        ),
        Region.with_pools("b", 0.20, [GpuPool("a100", 12)]),
    ]
    cluster = ClusterState.build(regs, {("a", "b"): 50.0}, symmetric=True)
    prof = JobProfile(
        JobSpec(0, ModelSpec("m", 8e9, 24, 4096, 32), 2000),
        gpu_memory=400e9,
    )
    trace = BandwidthTrace(
        [
            EnvUpdate(time=200.0, spot={("a", "spot"): 0.0}),
            EnvUpdate(time=5000.0, spot={("a", "spot"): 1.0}),
        ]
    )
    res = simulate(cluster, [prof], BACEPipePolicy(), trace=trace)
    kinds = [k for _, k, _ in res.events]
    assert "preempt" in kinds  # the reclaim evicted the running segment
    assert res.migrations == {0: 1}
    assert res.forced_migrations == {0: 1}
    # the re-placed segment avoided the reclaimed pool
    final = [r for r in res.records if not r.preempted][0]
    assert final.placement.typed_alloc.get("a", {}).get("spot", 0) == 0
    # settle-path invariants: non-negative segment costs partitioning totals
    for rec in res.records:
        assert rec.cost >= 0.0
    assert sum(r.cost for r in res.records) == pytest.approx(
        res.total_cost, rel=1e-9
    )
    # determinism
    res2 = simulate(cluster, [prof], BACEPipePolicy(), trace=trace)
    assert res.to_jsonable() == res2.to_jsonable()


def test_spot_reclaim_trace_is_seeded_and_absolute():
    cluster = spot_fleet_cluster()
    t1 = spot_reclaim_trace(cluster, seed=3, horizon_s=4 * 3600.0)
    t2 = spot_reclaim_trace(cluster, seed=3, horizon_s=4 * 3600.0)
    assert [u.spot for u in t1] == [u.spot for u in t2]
    assert all(
        0.0 <= m <= 1.0 for u in t1 for m in u.spot.values()
    )
    with pytest.raises(ValueError, match="no spot pools"):
        spot_reclaim_trace(paper_cluster())


def test_scaled_and_single_type_parity_of_hetero_machinery():
    # scaled() carries pools and spot multipliers through
    cluster = _hetero_cluster()
    cluster.set_spot_multipliers({("a", "spot"): 0.5})
    half = cluster.scaled(capacity_factor=0.5)
    assert half.capacity_typed("a") == {"spot": 1, "h100": 2}
    assert half.pool("a", "spot").price_mult == 0.35
    # a plain cluster stays on the homogeneous (untyped) paths end to end
    plain = paper_cluster()
    prof = _profile()
    placement = find_placement(prof, plain)
    assert placement is not None
    assert placement.typed_alloc == {}
    assert placement.eff_flops is None and placement.eff_memory is None


def test_gpu_pool_and_region_validation():
    with pytest.raises(ValueError):
        GpuPool("x", -1)
    with pytest.raises(ValueError):
        GpuPool("x", 1, flops=-1.0)
    with pytest.raises(ValueError):
        Region.with_pools("r", 0.1, [GpuPool("x", 1), GpuPool("x", 2)])
    with pytest.raises(ValueError, match="sum to"):
        Region("r", 5, 0.1, pools=(GpuPool("x", 1), GpuPool("y", 2)))
    with pytest.raises(ValueError):
        EnvUpdate(time=0.0, spot={("a", "x"): -0.5})
