"""Control-plane ↔ data-plane schedule parity.

The jax data plane (``repro.pipeline.gpipe``) executes a lockstep GPipe
schedule of ``M + S - 1`` scan ticks per direction by construction; the
control plane's microplan ``gpipe-overlap`` plan models exactly that
schedule.  These tests pin the two tick counts together — through the
shared ``schedule_ticks`` helper the data plane actually calls — so the
schedule the scheduler prices cannot drift from the one XLA runs.
"""

import pytest

from repro.core import (
    BACEPipePolicy,
    plan_from_topology,
    plan_schedule,
    simulate,
)
from repro.core.scenarios import SCENARIOS
from tests.test_microplan import uniform_topo

gpipe_data_plane = pytest.importorskip(
    "repro.pipeline.gpipe", reason="jax data plane unavailable"
)


def test_schedule_ticks_formula():
    assert gpipe_data_plane.schedule_ticks(8, 4) == 11
    assert gpipe_data_plane.schedule_ticks(1, 1) == 1


@pytest.mark.parametrize("m,stages", [(1, 1), (4, 2), (8, 4), (16, 3)])
def test_overlap_plan_ticks_match_data_plane(m, stages):
    topo = uniform_topo(m, stages, 0.25, hops=[(0.1,)] * (stages - 1))
    plan = plan_from_topology(topo, "gpipe-overlap")
    assert plan.n_ticks == gpipe_data_plane.schedule_ticks(m, stages)


def test_overlap_plan_ticks_match_data_plane_on_static_placements():
    """Every placement the static-paper scenario produces: the microplan
    gpipe-overlap tick count equals the n_ticks the data plane would scan
    for that (microbatch count, pipeline depth)."""
    scen = SCENARIOS["static-paper"]
    cluster, profiles, _ = scen.build(seed=0)
    res = simulate(cluster, profiles, BACEPipePolicy())
    profs = {p.spec.job_id: p for p in profiles}
    for rec in res.completed_records:
        prof = profs[rec.job_id]
        plan = plan_schedule(prof, rec.placement, "gpipe-overlap")
        m = prof.spec.model.microbatches
        depth = prof.pipeline_depth(rec.placement.total_gpus)
        assert plan.n_ticks == gpipe_data_plane.schedule_ticks(m, depth)
        # Other microplan schedules report no tick count: they are not
        # lockstep, so claiming data-plane parity for them would be wrong.
        assert plan_schedule(prof, rec.placement, "gpipe").n_ticks is None
