"""Observability suite: tracing bit-identity, exporters, CLI, fleet health.

The load-bearing contract is *bit-identity*: attaching a
``SimTraceRecorder`` must not move a single float in the simulation —
``to_jsonable()`` of the traced and untraced runs compare equal with ``==``
for every registered scenario on every decision backend.  Everything else
(Perfetto structure, JSONL round-trip, the report CLI) is exercised against
the acceptance scenario (mixed-stress with voluntary migration on, which
produces migration flow arrows).
"""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.kernels_decide import jax_available
from repro.core.scenarios import SCENARIOS, get_scenario
from repro.core.scheduler import BACEPipePolicy, Simulator, simulate
from repro.ft.monitor import HeartbeatMonitor, StragglerDetector
from repro.obs import (
    FleetHealth,
    MetricsLog,
    SimTraceRecorder,
    TraceRecorder,
    check_trace,
    load_jsonl,
    render_report,
    to_perfetto,
    write_jsonl,
    write_perfetto,
)

REPO = Path(__file__).resolve().parent.parent

BACKENDS = ["numpy"] + (["jax"] if jax_available() else [])


def _acceptance_trace():
    """mixed-stress cell with voluntary migration on: has migration flows."""
    rec = SimTraceRecorder()
    result = get_scenario("mixed-stress").run(
        BACEPipePolicy(),
        seed=1,
        voluntary_migration_threshold=0.0,
        recorder=rec,
    )
    return rec, result


# ------------------------------------------------------------ bit-identity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_tracing_is_bit_identical_for_every_scenario(name, backend):
    sc = get_scenario(name)
    kwargs = dict(seed=0, n_jobs=min(sc.default_n_jobs, 8),
                  decision_backend=backend)
    plain = sc.run(BACEPipePolicy(), **kwargs)
    rec = SimTraceRecorder()
    traced = sc.run(BACEPipePolicy(), recorder=rec, **kwargs)
    assert plain.to_jsonable() == traced.to_jsonable()
    assert rec.records, "recorder attached but saw nothing"


def test_tracing_bit_identity_with_voluntary_migration():
    sc = get_scenario("mixed-stress")
    plain = sc.run(
        BACEPipePolicy(), seed=1, voluntary_migration_threshold=0.0
    )
    rec, traced = _acceptance_trace()
    assert plain.to_jsonable() == traced.to_jsonable()
    assert traced.total_voluntary_migrations >= 1


def test_recorder_satisfies_protocol():
    assert isinstance(SimTraceRecorder(), TraceRecorder)


def test_legacy_engine_rejects_recorder():
    cluster, profiles, _ = get_scenario("static-paper").build(seed=0, n_jobs=2)
    with pytest.raises(ValueError, match="legacy"):
        Simulator(
            cluster,
            profiles,
            BACEPipePolicy(),
            engine="legacy",
            recorder=SimTraceRecorder(),
        )


# ------------------------------------------------------------ record shape
def test_trace_records_cover_the_decision_path():
    rec, _ = _acceptance_trace()
    kinds = {r["kind"] for r in rec.records}
    assert {"event", "queue", "place", "candidate", "alloc",
            "start", "settle", "probe", "preempt"} <= kinds
    # Queue snapshots carry Eq. 12 priority scores for the head.
    q = next(r for r in rec.records if r["kind"] == "queue")
    assert q["depth"] >= len(q["head"]) and all(
        "score" in h for h in q["head"]
    )
    # Start records carry the placement and billed rate.
    s = next(r for r in rec.records if r["kind"] == "start")
    assert s["path"] and s["gpus"] >= 1 and s["rate_per_s"] > 0.0
    # Settle records carry the ledger snapshot.
    st = next(r for r in rec.records if r["kind"] == "settle")
    assert st["cost"] >= 0.0 and "rate_per_s" in st["ledger"]
    # Migration probes record the stay-vs-move comparison.
    pr = next(r for r in rec.records if r["kind"] == "probe")
    assert {"stay_cost", "move_cost", "moved"} <= set(pr)
    # Wall-clock histograms exist per backend, and sim records never hold
    # wall time except inside the place records' wall_us field.
    assert any(
        k.startswith("decide_wall_us/") for k in rec.metrics.histograms
    )


def test_candidate_records_name_the_binding_constraint():
    # Saturate a small cluster so placements fail: every failed candidate
    # must name gpu (Eq. 5) or bandwidth (Eq. 6) as its binding constraint.
    sc = get_scenario("burst-arrival")
    rec = SimTraceRecorder()
    sc.run(BACEPipePolicy(), seed=0, recorder=rec)
    cands = [r for r in rec.records if r["kind"] == "candidate"]
    assert cands
    for c in cands:
        if c["outcome"] in ("rejected", "skipped-floor", "alloc-failed"):
            assert c["binding"] == "gpu"
        elif c["outcome"] == "comm-infeasible":
            assert c["binding"] == "bandwidth"
        else:
            assert c["binding"] is None


def test_hol_wait_attribution_accumulates_for_blocked_jobs():
    rec = SimTraceRecorder()
    get_scenario("burst-arrival").run(BACEPipePolicy(), seed=0, recorder=rec)
    if rec.hol_wait:  # burst arrival saturates the fleet; jobs queue
        assert all(w > 0.0 for w in rec.hol_wait.values())
    else:  # nothing blocked: no failed placements either
        assert not any(
            r["kind"] == "place" and not r["ok"] for r in rec.records
        )


# --------------------------------------------------------------- exporters
def test_perfetto_export_has_tracks_and_migration_flows():
    rec, _ = _acceptance_trace()
    pf = to_perfetto(rec)
    events = pf["traceEvents"]
    procs = {
        e["pid"]: e["args"]["name"]
        for e in events
        if e["ph"] == "M" and e["name"] == "process_name"
    }
    assert set(procs.values()) >= {"regions", "links", "scheduler"}
    region_tracks = [
        e for e in events if e["ph"] == "M" and e["name"] == "thread_name"
    ]
    assert region_tracks, "no per-region thread tracks"
    link_counters = {
        e["name"]
        for e in events
        if e["ph"] == "C" and e["name"].startswith("link_util/")
    }
    assert link_counters, "no per-link counter tracks"
    slices = [e for e in events if e["ph"] == "X"]
    assert slices and all(e["dur"] >= 0 for e in slices)
    flow_s = [e for e in events if e["ph"] == "s"]
    flow_f = [e for e in events if e["ph"] == "f"]
    assert len(flow_s) >= 1 and len(flow_f) >= 1, "no migration flow arrows"
    assert all(e.get("bp") == "e" for e in flow_f)
    # Trace-event schema basics on every event.
    for e in events:
        assert "ph" in e and "pid" in e
        if e["ph"] in ("X", "C", "i", "s", "f"):
            assert "ts" in e


def test_jsonl_round_trip_reproduces_report_and_perfetto(tmp_path):
    rec, _ = _acceptance_trace()
    path = tmp_path / "trace.jsonl"
    write_jsonl(path, rec, meta={"scenario": "mixed-stress"})
    loaded = load_jsonl(path)
    assert loaded.records == json.loads(json.dumps(rec.records))
    assert to_perfetto(loaded) == to_perfetto(rec)
    # The report from disk matches the live one except the context line
    # (meta exists only on the loaded trace).
    live = render_report(rec).splitlines()
    from_disk = [
        ln
        for ln in render_report(loaded).splitlines()
        if not ln.startswith("context:")
    ]
    assert from_disk == live


def test_check_trace_passes_on_real_and_fails_on_corrupt(tmp_path):
    rec, _ = _acceptance_trace()
    assert check_trace(rec) == []
    path = tmp_path / "trace.jsonl"
    write_jsonl(path, rec)
    loaded = load_jsonl(path)
    assert check_trace(loaded) == []
    loaded.records[0]["t"] = -5.0
    assert check_trace(loaded)


def test_report_cli_smoke(tmp_path):
    rec, _ = _acceptance_trace()
    path = tmp_path / "trace.jsonl"
    pf_path = tmp_path / "trace.perfetto.json"
    write_jsonl(path, rec, meta={"scenario": "mixed-stress"})
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "repro.obs",
            "report",
            str(path),
            "--check",
            "--perfetto",
            str(pf_path),
        ],
        capture_output=True,
        text=True,
        cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert proc.returncode == 0, proc.stderr
    assert "obs trace report" in proc.stdout
    assert "check: trace OK" in proc.stdout
    pf = json.loads(pf_path.read_text())
    assert pf["traceEvents"]
    missing = subprocess.run(
        [sys.executable, "-m", "repro.obs", "report", str(tmp_path / "no")],
        capture_output=True,
        text=True,
        cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
    )
    assert missing.returncode == 2


# ------------------------------------------------------------ fleet health
def test_fleet_health_bridges_ft_monitor():
    metrics = MetricsLog()
    health = FleetHealth(
        metrics, heartbeat_timeout_s=10.0, straggler_factor=2.0
    )
    health.beat_regions(0.0, ["a", "b"])
    health.sample(5.0)
    assert metrics.latest("dead_regions") == 0.0
    health.beat_regions(8.0, ["a"])  # b misses its heartbeat
    health.sample(17.0)  # a beat 9s ago (alive), b 17s ago (dead)
    assert metrics.latest("dead_regions") == 1.0
    # Straggler detection: steady decisions then a 10x spike.
    for _ in range(6):
        health.observe_decision(0.001)
    health.observe_decision(0.010)
    assert metrics.counters.get("straggler_decisions", 0) == 1


def test_ft_monitor_primitives():
    hb = HeartbeatMonitor(timeout_s=5.0)
    hb.beat("w0", now=0.0)
    hb.beat("w1", now=0.0)
    assert hb.dead_workers(now=1.0) == []
    hb.beat("w0", now=4.0)
    assert hb.dead_workers(now=6.0) == ["w1"]
    events = []
    det = StragglerDetector(
        factor=2.0, alpha=0.5, on_straggler=lambda s, d, e: events.append(s)
    )
    for step in range(5):
        det.observe(step, 1.0)
    assert det.observe(5, 10.0) and events == [5]


# --------------------------------------------------- result schema satellite
def test_result_jsonable_has_schema_version_and_cluster_gpus():
    cluster, profiles, _ = get_scenario("static-paper").build(seed=0, n_jobs=3)
    result = simulate(cluster, profiles, BACEPipePolicy())
    out = result.to_jsonable()
    assert out["schema_version"] == 2
    assert out["cluster_gpus"] == cluster.total_gpus()


def test_summary_has_hol_wait_and_utilization_lines():
    _, result = _acceptance_trace()
    s = result.summary()
    assert "hol_wait=" in s and "util=" in s
    assert result.gpu_utilization is not None
    assert 0.0 < result.gpu_utilization <= 1.0
