"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_bhtd
from repro.kernels.ssd_scan import ssd_scan_pallas

KEY = jax.random.PRNGKey(7)


def rand(shape, dtype, salt):
    return jax.random.normal(jax.random.fold_in(KEY, salt), shape).astype(dtype)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize(
    "b,hq,hkv,tq,tk,d,causal,window,softcap",
    [
        (1, 4, 4, 128, 128, 64, True, None, None),
        (2, 8, 2, 100, 100, 64, True, None, 50.0),   # GQA + softcap + ragged
        (1, 4, 2, 64, 256, 128, False, None, None),  # cross-length, non-causal
        (2, 4, 4, 256, 256, 64, True, 96, None),     # sliding window
        (1, 2, 1, 32, 32, 32, True, None, None),     # tiny
    ],
)
def test_flash_attention_matches_oracle(
    b, hq, hkv, tq, tk, d, causal, window, softcap, dtype, atol
):
    q = rand((b, hq, tq, d), dtype, 1)
    k = rand((b, hkv, tk, d), dtype, 2)
    v = rand((b, hkv, tk, d), dtype, 3)
    out = flash_attention_bhtd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=64, block_k=64, interpret=True,
    )
    want = ref.attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=atol
    )


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-4), (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize(
    "b,t,h,p,n,q,bh",
    [
        (2, 64, 8, 16, 16, 16, 8),
        (1, 128, 16, 32, 64, 32, 8),
        (2, 256, 4, 64, 128, 64, 4),
        (1, 32, 2, 8, 8, 8, 2),
    ],
)
def test_ssd_scan_matches_oracle(b, t, h, p, n, q, bh, dtype, atol):
    ks = jax.random.split(jax.random.fold_in(KEY, 11), 5)
    x = jax.random.normal(ks[0], (b, t, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    b_ = jax.random.normal(ks[3], (b, t, n)).astype(dtype)
    c_ = jax.random.normal(ks[4], (b, t, n)).astype(dtype)
    y, s = ssd_scan_pallas(x, dt, a, b_, c_, chunk=q, head_block=bh, interpret=True)
    yr, sr = ref.ssd_ref(x, dt, a, b_, c_, chunk=q)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32), atol=atol
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=atol)


def test_flash_attention_vjp_matches_reference_grad():
    q = rand((1, 16, 4, 32), jnp.float32, 21).swapaxes(1, 2)  # model layout
    k = rand((1, 16, 2, 32), jnp.float32, 22).swapaxes(1, 2)
    v = rand((1, 16, 2, 32), jnp.float32, 23).swapaxes(1, 2)

    def via_kernel(q, k, v):
        return (ops.flash_attention(q, k, v) ** 2).sum()

    def via_ref(q, k, v):
        from repro.models.layers import attention_ref

        return (attention_ref(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(via_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(via_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_ssd_decode_consistent_with_scan():
    """Chunked scan == step-by-step recurrence (train/serve parity)."""
    from repro.models.ssm import ssd_chunked_ref, ssd_decode_step

    b, t, h, p, n = 2, 32, 4, 8, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    b_ = jax.random.normal(ks[3], (b, t, n))
    c_ = jax.random.normal(ks[4], (b, t, n))
    y_chunk, s_chunk = ssd_chunked_ref(x, dt, a, b_, c_, chunk=8)
    s = jnp.zeros((b, h, p, n))
    ys = []
    for i in range(t):
        y, s = ssd_decode_step(s, x[:, i], dt[:, i], a, b_[:, i], c_[:, i])
        ys.append(y)
    np.testing.assert_allclose(
        np.asarray(y_chunk), np.asarray(jnp.stack(ys, 1)), atol=1e-4
    )
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s), atol=1e-4)
