"""Flow-sensitive rules (RPL7xx/RPL8xx), result cache, and SARIF export.

Four layers:

* acceptance mutations — re-introducing either core defect this PR fixed
  (deleting the preempt path's ``release_bandwidth``; pouring a $-valued
  expression into the ``rate=`` ($/s) ledger slot) must fail the CLI with a
  diagnostic that names the path / the units;
* behavioral regressions — the two scheduler fixes themselves: the
  voluntary-migration probe restores the reservation when the pricing path
  raises, and ``preempt`` keeps the reservation intact when the settle
  raises (both fail on the pre-fix orderings);
* cache — per-file hits/misses, edit and rule-edit invalidation, and that
  cached runs report identical diagnostics;
* SARIF — schema shape, rule catalog, and location mapping.
"""

import json
import shutil
from pathlib import Path

import pytest

from repro.analysis.staticcheck import Project, all_rules, main, rule_catalog
from repro.analysis.staticcheck import cache as cache_mod
from repro.analysis.staticcheck.engine import run_rules
from repro.analysis.staticcheck.sarif import write_sarif
from repro.core import (
    BACEPipePolicy,
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    Simulator,
    simulate,
)
from repro.core.accounting import SegmentLedger

REPO = Path(__file__).resolve().parents[1]
SCHEDULER = REPO / "src" / "repro" / "core" / "scheduler.py"
ACCOUNTING = REPO / "src" / "repro" / "core" / "accounting.py"


# ------------------------------------------------------ acceptance mutations
def _lint_mutated(tmp_path, monkeypatch, source: Path, old: str, new: str):
    """Copy ``source`` into a tmp ``core/`` with one edit and lint it."""
    text = source.read_text(encoding="utf-8")
    assert old in text, f"mutation anchor gone: {old!r}"
    core = tmp_path / "core"
    core.mkdir()
    target = core / source.name
    target.write_text(text.replace(old, new, 1), encoding="utf-8")
    monkeypatch.chdir(tmp_path)  # no repo baseline, fresh cache
    project = Project.collect([target], root=tmp_path)
    return target, run_rules(project, all_rules())


def test_deleting_preempt_release_fails_with_path_naming_diagnostic(
    tmp_path, monkeypatch, capsys
):
    target, diags = _lint_mutated(
        tmp_path,
        monkeypatch,
        SCHEDULER,
        "cluster.release_bandwidth(run.placement.reserved_bw)",
        "pass",
    )
    typestate = [d for d in diags if d.code == "RPL701"]
    assert typestate, "\n".join(d.render() for d in diags)
    # the diagnostic names the unreleased kind and the function
    msgs = " ".join(d.message for d in typestate)
    assert "bandwidth" in msgs and "'preempt'" in msgs
    assert main([str(target)]) == 1
    assert "RPL701" in capsys.readouterr().out


def test_swapping_dollars_into_rate_slot_fails_with_unit_naming_diagnostic(
    tmp_path, monkeypatch, capsys
):
    target, diags = _lint_mutated(
        tmp_path,
        monkeypatch,
        ACCOUNTING,
        "rate=placement_power_rate(profile, placement, cluster)",
        "rate=electricity_cost(profile, placement, cluster)",
    )
    units = [d for d in diags if d.code == "RPL801"]
    assert units, "\n".join(d.render() for d in diags)
    assert any(
        "expects $/s" in d.message and "receives $" in d.message
        for d in units
    )
    assert main([str(target)]) == 1
    assert "RPL801" in capsys.readouterr().out


def test_unmutated_core_files_are_clean(tmp_path, monkeypatch):
    core = tmp_path / "core"
    core.mkdir()
    for src in (SCHEDULER, ACCOUNTING):
        shutil.copy(src, core / src.name)
    monkeypatch.chdir(tmp_path)
    project = Project.collect([core], root=tmp_path)
    diags = run_rules(project, all_rules())
    assert diags == [], "\n".join(d.render() for d in diags)


# --------------------------------------------------- behavioral regressions
def _one_region_job_cluster():
    regions = [Region("a", 8, 0.10), Region("b", 8, 0.30)]
    return ClusterState.build(regions, {("a", "b"): 50.0}, symmetric=True)


def _small_job(job_id=0):
    spec = JobSpec(
        job_id,
        ModelSpec(f"j{job_id}", 2e9, 4, 1024, batch_size=16),
        iterations=30,
    )
    return JobProfile(spec, gpu_flops=300e12, gpu_memory=400e9)


class _ProbeBoom(Exception):
    pass


def _spiked_simulator(threshold=0.10):
    static = simulate(
        _one_region_job_cluster(), [_small_job()], BACEPipePolicy()
    )
    rec = static.records[0]
    t_spike = 0.4 * rec.finish
    sim = Simulator(
        _one_region_job_cluster(),
        [_small_job()],
        BACEPipePolicy(),
        trace=BandwidthTrace([EnvUpdate(time=t_spike, prices={"a": 10.0})]),
        restart_penalty_s=10.0,
        voluntary_migration_threshold=threshold,
    )
    return sim, rec.placement.total_gpus


def test_probe_restores_reservation_when_pricing_path_raises():
    """The voluntary-migration probe releases the running job's resources to
    price an alternative; if the pricing path raises, the try/finally must
    re-reserve before propagating (fails on the pre-fix unguarded probe)."""
    sim, gpus_held = _spiked_simulator()
    policy = sim.policy
    orig_place = policy.place
    calls = {"n": 0}

    def exploding_place(profile, cluster):
        calls["n"] += 1
        if calls["n"] >= 2:  # first call places the job; second is the probe
            raise _ProbeBoom()
        return orig_place(profile, cluster)

    policy.place = exploding_place
    with pytest.raises(_ProbeBoom):
        sim.run()
    assert calls["n"] >= 2, "the probe never ran"
    free = sim.cluster.total_free_gpus()
    assert free == sim.cluster.total_gpus() - gpus_held


def test_preempt_keeps_reservation_when_settle_raises():
    """``preempt`` settles the segment ledger *before* touching the cluster
    ledgers; an exception in the settle must leave the reservation intact,
    not released-but-unsettled (fails on the pre-fix release-first order)."""
    sim, gpus_held = _spiked_simulator(threshold=0.0)
    orig_settle = SegmentLedger.settle
    state = {"armed": False}

    def exploding_settle(self, now):
        if state["armed"]:
            raise _ProbeBoom()
        return orig_settle(self, now)

    # Arm only once the simulation is constructed: the first settle event in
    # this scenario is the voluntary preempt at the price spike.
    state["armed"] = True
    SegmentLedger.settle = exploding_settle
    try:
        with pytest.raises(_ProbeBoom):
            sim.run()
    finally:
        SegmentLedger.settle = orig_settle
    free = sim.cluster.total_free_gpus()
    assert free == sim.cluster.total_gpus() - gpus_held


# -------------------------------------------------------------------- cache
def _write_module(path, body):
    path.write_text(body, encoding="utf-8")
    return path


def test_cache_hits_misses_and_identical_diags(tmp_path):
    a = _write_module(tmp_path / "a.py", "import random\nR = random.random()\n")
    b = _write_module(tmp_path / "b.py", "X = 1\n")
    project = Project.collect([a, b], root=tmp_path)
    cache_path = tmp_path / "cache.json"
    rules = all_rules()

    cold, stats = cache_mod.run_rules_cached(project, rules, cache_path)
    assert (stats.hits, stats.misses) == (0, 2)
    assert [d.code for d in cold] == ["RPL101"]

    warm, stats = cache_mod.run_rules_cached(project, rules, cache_path)
    assert (stats.hits, stats.misses) == (2, 0)
    assert warm == cold  # cached diagnostics are bit-identical

    # editing one file invalidates exactly that file
    _write_module(tmp_path / "b.py", "import random\nY = random.random()\n")
    project = Project.collect([a, b], root=tmp_path)
    edited, stats = cache_mod.run_rules_cached(project, rules, cache_path)
    assert (stats.hits, stats.misses) == (1, 1)
    assert [d.code for d in edited] == ["RPL101", "RPL101"]


def test_cache_invalidated_by_ruleset_fingerprint(tmp_path):
    a = _write_module(tmp_path / "a.py", "X = 1\n")
    project = Project.collect([a], root=tmp_path)
    cache_path = tmp_path / "cache.json"
    rules = all_rules()

    cache_mod.run_rules_cached(project, rules, cache_path)
    # same selection: warm
    _, stats = cache_mod.run_rules_cached(project, rules, cache_path)
    assert stats.hits == 1
    # a different rule selection changes the fingerprint: cold again
    _, stats = cache_mod.run_rules_cached(
        project, rules, cache_path, extra_tokens=["RPL101"]
    )
    assert stats.misses == 1


def test_cli_cache_speedup_and_no_cache_flag(tmp_path, monkeypatch):
    mod = _write_module(tmp_path / "m.py", "X = 1\n")
    monkeypatch.chdir(tmp_path)
    assert main([str(mod)]) == 0
    cache_file = tmp_path / cache_mod.DEFAULT_CACHE
    assert cache_file.exists()
    payload = json.loads(cache_file.read_text(encoding="utf-8"))
    assert payload["version"] == cache_mod.CACHE_VERSION
    assert len(payload["files"]) == 1

    cache_file.unlink()
    assert main([str(mod), "--no-cache"]) == 0
    assert not cache_file.exists()


# -------------------------------------------------------------------- SARIF
def test_sarif_export_shape_and_locations(tmp_path, monkeypatch):
    mod = _write_module(
        tmp_path / "m.py", "import random\nR = random.random()\n"
    )
    monkeypatch.chdir(tmp_path)
    out = tmp_path / "findings.sarif"
    assert main([str(mod), "--sarif", str(out)]) == 1

    log = json.loads(out.read_text(encoding="utf-8"))
    assert log["version"] == "2.1.0"
    assert "sarif-schema-2.1.0" in log["$schema"]
    (run,) = log["runs"]
    driver = run["tool"]["driver"]
    assert driver["name"] == "reprolint"
    assert {r["id"] for r in driver["rules"]} == set(rule_catalog())

    (result,) = run["results"]
    assert result["ruleId"] == "RPL101"
    assert result["level"] == "error"
    loc = result["locations"][0]["physicalLocation"]
    assert loc["artifactLocation"]["uri"].endswith("m.py")
    assert loc["region"]["startLine"] == 2
    assert loc["region"]["startColumn"] >= 1


def test_sarif_marks_baselined_findings_as_notes(tmp_path):
    diag_new = run_rules(
        Project.collect(
            [_write_module(tmp_path / "n.py", "T = sum(set([1]))\n")],
            root=tmp_path,
        ),
        all_rules(),
    )
    assert diag_new
    out = tmp_path / "log.sarif"
    write_sarif(out, [], diag_new, rule_catalog())
    log = json.loads(out.read_text(encoding="utf-8"))
    (result,) = log["runs"][0]["results"]
    assert result["level"] == "note"
    assert result["baselineState"] == "unchanged"
