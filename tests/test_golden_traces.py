"""Golden-trace regression tests: the engine's full observable behavior —
records (per segment), costs, makespan, migrations, stalls, and the
chronological event log — is serialized per (scenario, policy) into
``tests/golden/*.json``.  Any behavioral drift in the scheduling engine
fails these tests loudly.

Intentional behavior changes regenerate the files with::

    PYTHONPATH=src python -m pytest tests/test_golden_traces.py --regen

and the diff is then reviewed like any other code change.  JSON float
round-tripping is exact (shortest-repr), so comparisons are ``==``, not
approximate.
"""

import json
from pathlib import Path

import pytest

from repro.core import (
    BACEPipePolicy,
    CRLCFPolicy,
    CRLDFPolicy,
    LCFPolicy,
    LDFPolicy,
    get_scenario,
)

GOLDEN_DIR = Path(__file__).parent / "golden"

ALL_POLICIES = [BACEPipePolicy, LCFPolicy, LDFPolicy, CRLCFPolicy, CRLDFPolicy]

#: One static scenario (the engine-parity surface) plus the dynamic regimes:
#: link-flap (forced preemptive migration), price-spike (piecewise
#: repricing + voluntary migration), and diurnal (dense bandwidth-breakpoint
#: stream under Poisson arrivals), per policy.
GOLDEN_SCENARIOS = ("static-paper", "link-flap", "price-spike", "diurnal")

SEED = 0


def _case_path(scenario_name: str, policy_name: str) -> Path:
    return GOLDEN_DIR / f"{scenario_name}__{policy_name}.json"


@pytest.mark.parametrize("policy_cls", ALL_POLICIES, ids=lambda c: c.__name__)
@pytest.mark.parametrize("scenario_name", GOLDEN_SCENARIOS)
def test_golden_trace(scenario_name, policy_cls, request):
    policy = policy_cls()
    result = get_scenario(scenario_name).run(policy, seed=SEED)
    got = result.to_jsonable()
    path = _case_path(scenario_name, policy.name)

    if request.config.getoption("--regen"):
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(json.dumps(got, indent=1, sort_keys=True) + "\n")
        # regeneration still asserts the serialization round-trips exactly
        assert json.loads(path.read_text()) == got
        return

    assert path.is_file(), (
        f"missing golden file {path.name}; generate it with "
        f"`pytest {__file__} --regen`"
    )
    expected = json.loads(path.read_text())
    assert got == expected, (
        f"engine behavior drifted from {path.name}; if the change is "
        f"intentional, regenerate with `pytest {__file__} --regen` and "
        f"review the diff"
    )


def test_golden_covers_a_migration():
    """The dynamic golden scenario must actually exercise the preemption
    path for at least one policy — otherwise the golden files silently stop
    covering migration semantics."""
    migrated = 0
    for policy_cls in ALL_POLICIES:
        res = get_scenario("link-flap").run(policy_cls(), seed=SEED)
        migrated += res.total_migrations
    assert migrated > 0
