"""Control-plane behaviour tests: Eqs. (1)-(13), Alg. 1/2, simulator."""

import pytest

from repro.core import (
    BACEPipePolicy,
    CRLCFPolicy,
    CRLDFPolicy,
    ClusterState,
    JobProfile,
    JobSpec,
    LCFPolicy,
    LDFPolicy,
    ModelSpec,
    Region,
    bottleneck_delta,
    build_placement,
    cost_min_allocate,
    electricity_cost,
    execution_time,
    find_placement,
    iteration_time,
    paper_cluster,
    paper_jobs,
    paper_profiles,
    priority_scores,
    simulate,
    uniform_allocate,
)


def tiny_cluster():
    regions = [
        Region("a", 8, 0.10),
        Region("b", 4, 0.20),
        Region("c", 2, 0.30),
    ]
    gbps = {("a", "b"): 100.0, ("b", "c"): 50.0, ("a", "c"): 10.0}
    return ClusterState.build(regions, gbps, symmetric=True)


def tiny_profile(iters=10, layers=8, params=1e9, batch=16):
    spec = JobSpec(
        job_id=0,
        model=ModelSpec("m", params, layers, 1024, batch),
        iterations=iters,
    )
    return JobProfile(spec, gpu_flops=300e12)


# ------------------------------------------------------------------ Eq. 1-4
def test_iteration_time_structure():
    prof = tiny_profile()
    cl = tiny_cluster()
    p = build_placement(prof, cl, ["a"], {"a": 4})
    t_comp = prof.t_comp(4)
    m = prof.spec.model.microbatches
    expected = (sum(p.comm_times) + 4 * t_comp + (m - 1) * bottleneck_delta(prof, p)) * 2
    assert iteration_time(prof, p) == pytest.approx(expected)
    assert execution_time(prof, p) == pytest.approx(10 * expected)


def test_cost_accrues_only_while_running():
    prof = tiny_profile()
    cl = tiny_cluster()
    p = build_placement(prof, cl, ["a"], {"a": 4})
    c = electricity_cost(prof, p, cl)
    rate = 0.10 * prof.gpu_kw * 4 / 3600.0
    assert c == pytest.approx(execution_time(prof, p) * rate)


def test_t_comp_decreases_then_overheads_dominate():
    prof = tiny_profile(layers=64, params=50e9)
    ts = [prof.t_iter_ideal(k) for k in range(prof.min_gpus, prof.max_gpus + 1)]
    k_star = prof.optimal_gpus()
    assert prof.min_gpus <= k_star <= prof.max_gpus
    assert min(ts) == pytest.approx(prof.t_iter_ideal(k_star))


# -------------------------------------------------------------------- Alg. 2
def test_cost_min_allocator_fills_cheapest_first():
    cl = tiny_cluster()
    alloc = cost_min_allocate(cl, ["c", "a", "b"], 10)
    assert alloc["a"] == 8  # cheapest filled to capacity
    assert all(v >= 1 for v in alloc.values())
    assert sum(alloc.values()) == 10


def test_cost_min_allocator_requires_continuity():
    cl = tiny_cluster()
    with pytest.raises(ValueError):
        cost_min_allocate(cl, ["a", "b"], 1)  # < one GPU per region


def test_uniform_allocator_spreads():
    cl = tiny_cluster()
    alloc = uniform_allocate(cl, ["a", "b"], 6)
    assert alloc == {"a": 3, "b": 3}


# -------------------------------------------------------------------- Alg. 1
def test_pathfinder_single_region_fast_path():
    cl = tiny_cluster()
    prof = tiny_profile(layers=8)
    placement = find_placement(prof, cl, k_star=4)
    assert placement.n_regions == 1
    # cheapest region with capacity wins
    assert placement.path == ("a",)


def test_pathfinder_multi_region_respects_bandwidth():
    cl = tiny_cluster()
    prof = tiny_profile(layers=16, params=20e9)
    placement = find_placement(prof, cl, k_star=12)
    assert placement is not None
    assert placement.total_gpus <= 12
    # every crossing edge sustains b_j: comm time <= compute time
    t_comp = prof.t_comp(placement.total_gpus)
    for t in placement.comm_times:
        assert t <= t_comp * (1 + 1e-9)


def test_placement_reserves_only_crossing_edges():
    cl = tiny_cluster()
    prof = tiny_profile(layers=16, params=20e9)
    p = build_placement(prof, cl, ["a", "b"], {"a": 8, "b": 2})
    assert set(p.reserved_bw) == {("a", "b")}
    assert p.stage_regions() == ["a"] * 8 + ["b"] * 2


# ----------------------------------------------------------------- Eq. 9-12
def test_priority_prefers_short_jobs_when_idle():
    cl = paper_cluster()
    profs = paper_profiles(paper_jobs(seed=0))
    scores = priority_scores(profs, cl)
    singles = {p.spec.job_id: p.single_gpu_execution() for p in profs}
    shortest = min(singles, key=singles.get)
    assert scores[shortest] == max(scores.values())


def test_priority_shifts_to_bandwidth_under_congestion():
    cl = paper_cluster()
    profs = paper_profiles(paper_jobs(seed=0))
    # saturate the ledger artificially
    for link in cl.bandwidth:
        cl.reserved_bw[link] = cl.bandwidth[link]
    assert cl.congestion_alpha() == pytest.approx(1.0)
    scores = priority_scores(profs, cl)
    demands = {
        p.spec.job_id: p.bandwidth_requirement(p.optimal_gpus(cl.total_gpus()))
        for p in profs
    }
    thirstiest = max(demands, key=demands.get)
    assert scores[thirstiest] == min(scores.values())


# ---------------------------------------------------------------- simulator
@pytest.mark.parametrize(
    "policy_cls", [BACEPipePolicy, LCFPolicy, LDFPolicy, CRLCFPolicy, CRLDFPolicy]
)
def test_simulation_completes_all_jobs(policy_cls):
    res = simulate(paper_cluster(), paper_profiles(paper_jobs(seed=1)), policy_cls())
    assert len(res.records) == 8
    for r in res.records:
        assert r.finish > r.start >= r.submit
    assert res.average_jct > 0 and res.total_cost > 0


def test_bace_beats_all_baselines_on_jct():
    profs = paper_profiles(paper_jobs(seed=0))
    base = simulate(paper_cluster(), profs, BACEPipePolicy())
    for cls in (LCFPolicy, LDFPolicy, CRLCFPolicy, CRLDFPolicy):
        other = simulate(paper_cluster(), profs, cls())
        assert base.average_jct < other.average_jct, cls.__name__


def test_resource_ledgers_return_to_initial():
    cl = paper_cluster()
    res = simulate(cl, paper_profiles(paper_jobs(seed=2)), BACEPipePolicy())
    assert res is not None
    # simulate() snapshots: original ledger untouched
    assert cl.total_free_gpus() == cl.total_gpus()
    assert all(v == 0 for v in cl.reserved_bw.values())
