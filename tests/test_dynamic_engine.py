"""Dynamic-engine behavior tests: bandwidth traces, preemptive migration,
event-ordering determinism, and the trace/cluster plumbing itself.

The documented same-timestamp semantics (see ``core/scheduler.py``): all
events sharing a timestamp drain *atomically* — completions release,
environment updates rescale, arrivals enqueue — before the preemption check
and the (single) scheduling pass for that timestamp run.  The tests here pin
the observable consequences: a job finishing exactly at a drop time is never
preempted, an arrival coinciding with a drop is placed under the reduced
capacity, and results are invariant to the caller's profile ordering.
"""

import math

import pytest

from repro.core import (
    BACEPipePolicy,
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    Simulator,
    get_scenario,
    simulate,
)


def two_region_cluster(cap=6, gbps=50.0):
    regions = [Region("a", cap, 0.10), Region("b", cap, 0.20)]
    return ClusterState.build(regions, {("a", "b"): gbps}, symmetric=True)


def spanning_profile(job_id=0, iters=20):
    """A job whose memory floor (8 GPUs at 44 GB each) exceeds either
    region's pool, forcing a cross-region pipeline over the a<->b link."""
    spec = JobSpec(
        job_id,
        ModelSpec(f"j{job_id}", 20e9, 16, 2048, batch_size=16),
        iterations=iters,
    )
    return JobProfile(spec, gpu_flops=300e12)


FLAP_LINKS = {("a", "b"): 0.01, ("b", "a"): 0.01}
RESTORE_LINKS = {("a", "b"): 1.0, ("b", "a"): 1.0}


# ------------------------------------------------------- preemption semantics
def test_link_drop_preempts_migrates_and_completes():
    prof = spanning_profile()
    static = simulate(two_region_cluster(), [prof], BACEPipePolicy())
    assert len(static.records) == 1
    t_it = static.records[0].iteration_seconds
    finish0 = static.records[0].finish
    t_drop = 0.4 * finish0  # mid-run, not iteration-aligned
    t_up = finish0 * 2.0

    trace = BandwidthTrace(
        [
            EnvUpdate(time=t_drop, bandwidth=FLAP_LINKS),
            EnvUpdate(time=t_up, bandwidth=RESTORE_LINKS),
        ]
    )
    penalty = 500.0
    sim = Simulator(
        two_region_cluster(),
        [spanning_profile()],
        BACEPipePolicy(),
        trace=trace,
        restart_penalty_s=penalty,
    )
    res = sim.run()

    # one aborted segment + one completed segment
    assert [r.preempted for r in res.records] == [True, False]
    aborted, done = res.records
    assert aborted.finish == t_drop
    assert res.migrations == {0: 1}

    # no placement possible while the link is down: the job stalls until the
    # recovery breakpoint, then restarts from its checkpoint
    assert done.start == t_up
    assert res.stall_seconds[0] == pytest.approx(t_up - t_drop)

    # progress floors to whole iterations; the restart pays the penalty
    done_iters = math.floor(t_drop / t_it)
    expected_exec = (20 - done_iters) * done.iteration_seconds + penalty
    assert done.finish == pytest.approx(t_up + expected_exec)
    assert res.makespan == done.finish

    # Eq. 4 cost accrues exactly over the active (non-stalled) time
    rate = res.costs[0] / (aborted.execution + done.execution)
    assert res.costs[0] == pytest.approx(
        rate * ((t_drop - 0.0) + (done.finish - t_up))
    )

    # conservation: the simulator's cluster returned to its initial ledger
    assert sim.cluster.total_free_gpus() == sim.cluster.total_gpus()
    assert all(v == 0.0 for v in sim.cluster.reserved_bw.values())

    # event log tells the story in order
    kinds = [k for _, k, _ in res.events]
    assert kinds == ["arrival", "start", "env", "preempt", "env", "start",
                     "complete"]
    assert all(
        t1 <= t2 for (t1, _, _), (t2, _, _) in zip(res.events, res.events[1:])
    )


def test_repreemption_does_not_credit_restore_time_as_progress():
    """A restarted segment spends its first ``restart_penalty_s`` restoring,
    not training; preempting it again must not count that window as
    iterations.  With a penalty far longer than the second up-window, zero
    iterations complete between the flaps — the job must still owe (almost)
    everything afterwards, i.e. its total trained time stays ~(iters × t_it)."""
    prof = spanning_profile()
    static = simulate(two_region_cluster(), [prof], BACEPipePolicy())
    t_it = static.records[0].iteration_seconds
    penalty = 300.0 * t_it  # dwarfs the inter-flap gap below
    t1 = 5.0 * t_it + 0.3 * t_it          # first drop, mid-iteration 6
    trace = BandwidthTrace(
        [
            EnvUpdate(time=t1, bandwidth=FLAP_LINKS),
            EnvUpdate(time=t1 + t_it, bandwidth=RESTORE_LINKS),  # restart
            # second drop: the restarted segment has only restored for
            # 2*t_it << penalty, so it has trained 0 iterations
            EnvUpdate(time=t1 + 3.0 * t_it, bandwidth=FLAP_LINKS),
            EnvUpdate(time=t1 + 4.0 * t_it, bandwidth=RESTORE_LINKS),
        ]
    )
    sim = Simulator(
        two_region_cluster(),
        [spanning_profile()],
        BACEPipePolicy(),
        trace=trace,
        restart_penalty_s=penalty,
    )
    res = sim.run()
    assert res.migrations == {0: 2}
    segs = res.records
    assert [r.preempted for r in segs] == [True, True, False]
    # segment 1 trained 5 whole iterations; segment 2 trained 0 (all restore)
    final = segs[-1]
    expected_exec = (20 - 5) * final.iteration_seconds + penalty
    assert final.finish - final.start == pytest.approx(expected_exec)


def test_background_reservation_oversubscription_does_not_crash():
    """An over-subscribed link whose reservation is owned by no running job
    (a background reservation handed to the ClusterState) is unresolvable by
    preemption and must be skipped, not crash the victim search."""
    cluster = two_region_cluster(gbps=50.0)
    cluster.reserve_bandwidth({("a", "b"): cluster.bandwidth[("a", "b")] * 0.5})
    snapshot_seed = cluster  # simulate() snapshots, preserving the reservation
    prof = spanning_profile()
    static = simulate(snapshot_seed, [spanning_profile()], BACEPipePolicy())
    t_drop = 0.5 * static.records[0].finish
    trace = BandwidthTrace(
        [EnvUpdate(time=t_drop, bandwidth={("a", "b"): 0.01, ("b", "a"): 1.0})]
    )
    # the running job reserves only on (b, a) or none after the background
    # load; whichever way it lands, resolution must terminate without error
    res = simulate(snapshot_seed, [spanning_profile()], BACEPipePolicy(),
                   trace=trace)
    assert len(res.completed_records) == 1


def test_completion_exactly_at_drop_time_is_not_preempted():
    """Same-timestamp tiebreak: completions drain before the preemption
    check, so a pipeline finishing at the drop instant migrates nowhere."""
    prof = spanning_profile()
    static = simulate(two_region_cluster(), [prof], BACEPipePolicy())
    finish0 = static.records[0].finish
    trace = BandwidthTrace([EnvUpdate(time=finish0, bandwidth=FLAP_LINKS)])
    res = simulate(
        two_region_cluster(), [spanning_profile()], BACEPipePolicy(),
        trace=trace,
    )
    assert res.migrations == {}
    assert [r.preempted for r in res.records] == [False]
    assert res.records[0].finish == finish0


def test_arrival_at_drop_time_sees_reduced_capacity():
    """Same-timestamp tiebreak: the environment update is folded in before
    the scheduling pass, so a job arriving at the drop instant reserves
    against the *shrunk* link."""
    cluster = two_region_cluster(gbps=50.0)
    t0 = 3600.0
    half = {("a", "b"): 0.5, ("b", "a"): 0.5}
    trace = BandwidthTrace([EnvUpdate(time=t0, bandwidth=half)])
    spec = JobSpec(
        0, ModelSpec("j0", 20e9, 16, 2048, batch_size=16), iterations=20,
        submit_time=t0,
    )
    prof = JobProfile(spec, gpu_flops=300e12)
    res = simulate(cluster, [prof], BACEPipePolicy(), trace=trace)
    rec = res.records[0]
    assert rec.start == t0
    from repro.core import GBPS

    cap_after = 50.0 * GBPS * 0.5
    for share in rec.placement.reserved_bw.values():
        assert share <= cap_after * (1 + 1e-9)


def test_victim_is_latest_started_on_the_flapped_link():
    """Preemption victim rule: among jobs sharing the over-subscribed link,
    the latest-started one is evicted (LIFO keeps old pipelines running)."""
    res = get_scenario("link-flap").run(BACEPipePolicy(), seed=0)
    assert res.total_migrations > 0
    flapped = {("us-east-2", "ea-east"), ("ea-east", "us-east-2")}
    for t, kind, job_id in res.events:
        if kind != "preempt":
            continue
        victim = next(
            r for r in res.records if r.job_id == job_id and r.finish == t
            and r.preempted
        )
        running_peers = [
            r
            for r in res.records
            if r.start <= t < r.finish
            and set(r.placement.reserved_bw) & flapped
        ]
        for peer in running_peers:
            assert peer.start <= victim.start


# ------------------------------------------------------ determinism contracts
def test_result_invariant_to_profile_ordering():
    cluster, profiles, trace = get_scenario("mixed-stress").build(seed=3)
    a = simulate(cluster.snapshot(), profiles, BACEPipePolicy(), trace=trace)
    b = simulate(
        cluster.snapshot(), list(reversed(profiles)), BACEPipePolicy(),
        trace=trace,
    )
    assert a.to_jsonable() == b.to_jsonable()


def test_same_seed_identical_result_all_scenarios():
    from repro.core import SCENARIOS

    for name, scenario in SCENARIOS.items():
        r1 = scenario.run(BACEPipePolicy(), seed=7)
        r2 = scenario.run(BACEPipePolicy(), seed=7)
        assert r1.to_jsonable() == r2.to_jsonable(), name


def test_legacy_engine_rejects_traces():
    cluster, profiles, trace = get_scenario("link-flap").build(seed=0)
    with pytest.raises(ValueError, match="legacy"):
        simulate(cluster, profiles, BACEPipePolicy(), engine="legacy",
                 trace=trace)
    # an empty trace is not dynamic: legacy accepts it
    res = simulate(
        cluster, profiles, BACEPipePolicy(), engine="legacy",
        trace=BandwidthTrace([]),
    )
    assert res.records


# --------------------------------------------------------- trace/cluster unit
def test_multipliers_are_absolute_not_compounding():
    cluster = two_region_cluster(gbps=40.0)
    base = cluster.bandwidth[("a", "b")]
    cluster.set_link_multipliers({("a", "b"): 0.5})
    cluster.set_link_multipliers({("a", "b"): 0.5})
    assert cluster.link_bandwidth("a", "b") == pytest.approx(0.5 * base)
    cluster.set_link_multipliers({("a", "b"): 1.0})
    assert cluster.link_bandwidth("a", "b") == pytest.approx(base)

    p0 = cluster.price("a")
    cluster.set_price_multipliers({"a": 2.0})
    cluster.set_price_multipliers({"a": 2.0})
    assert cluster.price("a") == pytest.approx(2.0 * p0)
    cluster.set_price_multipliers({"a": 1.0})
    assert cluster.price("a") == pytest.approx(p0)


def test_multiplier_updates_are_all_or_nothing():
    """A rejected update must leave the cluster untouched, even when valid
    entries precede the bad one (same convention as reserve/release)."""
    cluster = two_region_cluster(gbps=40.0)
    base_bw = cluster.bandwidth[("a", "b")]
    base_price = cluster.price("a")
    with pytest.raises(KeyError):
        cluster.set_link_multipliers({("a", "b"): 0.5, ("a", "nope"): 0.5})
    assert cluster.link_bandwidth("a", "b") == base_bw
    with pytest.raises(ValueError):
        cluster.set_price_multipliers({"a": 2.0, "b": -1.0})
    assert cluster.price("a") == base_price
    with pytest.raises(KeyError):
        cluster.apply_env_update(
            EnvUpdate(time=0.0, bandwidth={("a", "nope"): 0.5},
                      prices={"a": 2.0})
        )
    assert cluster.price("a") == base_price
    assert cluster.link_bandwidth("a", "b") == base_bw


def test_multiplier_validation():
    cluster = two_region_cluster()
    with pytest.raises(KeyError):
        cluster.set_link_multipliers({("a", "nope"): 0.5})
    with pytest.raises(ValueError):
        cluster.set_link_multipliers({("a", "b"): -0.1})
    with pytest.raises(KeyError):
        cluster.set_price_multipliers({"nope": 0.5})
    with pytest.raises(ValueError):
        cluster.set_price_multipliers({"a": -1.0})
    with pytest.raises(ValueError):
        EnvUpdate(time=-1.0)
    with pytest.raises(ValueError):
        EnvUpdate(time=0.0, bandwidth={("a", "b"): -0.5})


def test_trace_sorting_and_change_times():
    u1 = EnvUpdate(time=30.0, bandwidth={})
    u2 = EnvUpdate(time=10.0, prices={})
    u3 = EnvUpdate(time=30.0, prices={})
    trace = BandwidthTrace([u1, u2, u3])
    assert [u.time for u in trace.updates] == [10.0, 30.0, 30.0]
    # stable within equal times: u1 (given first) stays ahead of u3
    assert trace.updates[1] is u1 and trace.updates[2] is u3
    assert trace.change_times() == [10.0, 30.0]
    merged = trace.merged(BandwidthTrace([EnvUpdate(time=20.0)]))
    assert [u.time for u in merged.updates] == [10.0, 20.0, 30.0, 30.0]


def test_snapshot_preserves_multipliers_and_base():
    """Simulator snapshots its input cluster; live multipliers must survive
    the copy, and the copy must keep the *original* base so later absolute
    multipliers rescale correctly."""
    cluster = two_region_cluster(gbps=40.0)
    base_bw = cluster.bandwidth[("a", "b")]
    base_price = cluster.price("a")
    cluster.set_link_multipliers({("a", "b"): 0.5})
    cluster.set_price_multipliers({"a": 2.0})
    snap = cluster.snapshot()
    assert snap.link_bandwidth("a", "b") == pytest.approx(0.5 * base_bw)
    assert snap.price("a") == pytest.approx(2.0 * base_price)
    # rescaling against the same installed baseline, not the shrunk value
    snap.set_link_multipliers({("a", "b"): 1.0})
    snap.set_price_multipliers({"a": 1.0})
    assert snap.link_bandwidth("a", "b") == pytest.approx(base_bw)
    assert snap.price("a") == pytest.approx(base_price)
    # congestion denominator tracks the live (scaled) totals
    assert cluster.congestion_alpha() == snap.congestion_alpha() == 0.0


def test_placement_feasible_probe():
    from repro.core import placement_feasible

    prof = spanning_profile()
    cluster = two_region_cluster()
    res = simulate(cluster, [prof], BACEPipePolicy())
    placement = res.records[0].placement
    probe = cluster.snapshot()
    assert placement_feasible(placement, probe)
    probe.set_link_multipliers(FLAP_LINKS)
    assert not placement_feasible(placement, probe)
    probe.set_link_multipliers(RESTORE_LINKS)
    assert placement_feasible(placement, probe)


def test_oversubscribed_links_probe():
    cluster = two_region_cluster(gbps=40.0)
    cluster.reserve_bandwidth({("a", "b"): cluster.bandwidth[("a", "b")] * 0.8})
    assert cluster.oversubscribed_links() == []
    cluster.set_link_multipliers({("a", "b"): 0.5})
    assert cluster.oversubscribed_links() == [("a", "b")]
    cluster.set_link_multipliers({("a", "b"): 1.0})
    assert cluster.oversubscribed_links() == []


# ------------------------------------------- zero/near-zero-capacity hazards
def test_placement_feasible_tolerance_is_purely_relative():
    """Regression: the old ``cap * (1+tol) + 1e-6`` slack let any sub-1e-6
    overage pass on a 1 B/s link — and *any* tiny reservation pass on a
    zero-capacity link — masking genuine Eq. 6 violations."""
    from repro.core import Placement, placement_feasible

    regions = [Region("a", 2, 0.1), Region("b", 2, 0.1)]
    cluster = ClusterState(
        regions={r.name: r for r in regions},
        bandwidth={("a", "b"): 1.0},  # a 1 B/s link, installed directly
    )
    over = Placement(
        path=("a", "b"),
        alloc={"a": 1, "b": 1},
        comm_times=(1.0,),
        reserved_bw={("a", "b"): 1.0 + 5e-7},  # > cap, < old absolute slack
    )
    assert not placement_feasible(over, cluster)
    exact = Placement(
        path=("a", "b"),
        alloc={"a": 1, "b": 1},
        comm_times=(1.0,),
        reserved_bw={("a", "b"): 1.0},
    )
    assert placement_feasible(exact, cluster)
    # zero-capacity (fully-outaged) link: any positive reservation is
    # infeasible, however tiny
    cluster.set_link_multipliers({("a", "b"): 0.0})
    tiny = Placement(
        path=("a", "b"),
        alloc={"a": 1, "b": 1},
        comm_times=(1.0,),
        reserved_bw={("a", "b"): 1e-9},
    )
    assert not placement_feasible(tiny, cluster)


def test_zero_capacity_link_rejects_reservations():
    """Regression: ``reserve_bandwidth``'s absolute 1e-6 slack admitted tiny
    reservations onto links a full-outage multiplier had zeroed."""
    cluster = two_region_cluster()
    cluster.set_link_multipliers({("a", "b"): 0.0})
    assert cluster.link_bandwidth("a", "b") == 0.0
    assert cluster.available_bandwidth("a", "b") == 0.0
    with pytest.raises(ValueError, match="over-subscription"):
        cluster.reserve_bandwidth({("a", "b"): 5e-7})


def test_full_outage_multiplier_is_division_safe():
    """A multiplier of exactly 0.0 on every link (or a region's whole
    installed total) must never divide by zero anywhere in the admission or
    congestion paths, and the Pathfinder must simply refuse WAN paths."""
    from repro.core import find_placement

    cluster = two_region_cluster()
    prof = spanning_profile()

    # total outage: every installed link to zero
    cluster.set_link_multipliers(
        {("a", "b"): 0.0, ("b", "a"): 0.0}
    )
    assert (cluster.available_matrix() == 0.0).all()
    # alpha's denominator (the installed total) is now 0: defined as 0.0
    assert cluster.total_link_capacity() == 0.0
    assert cluster.congestion_alpha() == 0.0
    # the spanning job needs both regions; with the WAN dark there is no
    # admissible path and the Pathfinder must return None, not crash
    assert find_placement(prof, cluster) is None
    # single-region jobs still place
    small = JobProfile(
        JobSpec(9, ModelSpec("s", 4e9, 8, 2048, 8), 5), gpu_flops=300e12
    )
    placement = find_placement(small, cluster)
    assert placement is not None and placement.n_regions == 1


def test_outage_trace_preempts_without_division_errors():
    """End-to-end: a mid-run EnvUpdate zeroing the only WAN link (bandwidth
    == 0.0 is legal in a trace) must preempt the spanning pipeline through
    the normal path and leave the job parked until recovery."""
    prof = spanning_profile()
    static = simulate(two_region_cluster(), [prof], BACEPipePolicy())
    finish0 = static.records[0].finish
    t_drop = 0.4 * finish0
    t_up = finish0 * 2.0
    trace = BandwidthTrace(
        [
            EnvUpdate(
                time=t_drop, bandwidth={("a", "b"): 0.0, ("b", "a"): 0.0}
            ),
            EnvUpdate(time=t_up, bandwidth=RESTORE_LINKS),
        ]
    )
    res = simulate(
        two_region_cluster(), [prof], BACEPipePolicy(), trace=trace
    )
    kinds = [k for _, k, _ in res.events]
    assert "preempt" in kinds
    assert res.migrations == {0: 1}
    final = [r for r in res.records if not r.preempted][0]
    assert final.start >= t_up  # nothing placeable while the WAN was dark
    assert res.costs[0] >= 0.0
    res2 = simulate(
        two_region_cluster(), [prof], BACEPipePolicy(), trace=trace
    )
    assert res.to_jsonable() == res2.to_jsonable()


def test_oversubscribed_links_reports_zeroed_link():
    cluster = two_region_cluster()
    cluster.reserve_bandwidth({("a", "b"): cluster.bandwidth[("a", "b")] * 0.5})
    cluster.set_link_multipliers({("a", "b"): 0.0})
    assert cluster.oversubscribed_links() == [("a", "b")]
