"""CFG builder edge cases (repro.analysis.staticcheck.dataflow.cfg).

The dataflow rules are only as sound as the graph: these tests pin the
exception/unwinding encodings the typestate rule leans on — finally
duplication with an exceptional re-raising copy, ``with``-as-try/finally,
loop ``break`` bypassing the ``else`` clause, calls discovered inside
nested comprehensions, and bare ``raise`` inside an except handler.
"""

import ast
import textwrap

from repro.analysis.staticcheck.dataflow import build_cfg, default_may_raise
from repro.analysis.staticcheck.dataflow.cfg import (
    EXC,
    NORMAL,
    ROLE_DISPATCH,
    ROLE_ITER,
    ROLE_WITH_ENTER,
    ROLE_WITH_EXIT,
)
from repro.analysis.staticcheck.dataflow.framework import (
    ForwardAnalysis,
    run_forward,
)


def cfg_of(src):
    fdef = ast.parse(textwrap.dedent(src)).body[0]
    return build_cfg(fdef)


def at_line(cfg, line, role=None):
    return [
        b
        for b in cfg.blocks
        if b.line == line and (role is None or b.role == role)
    ]


def reachable(cfg, start, kinds=(NORMAL, EXC)):
    seen, stack = {start}, [start]
    while stack:
        for e in cfg.succ[stack.pop()]:
            if e.kind in kinds and e.dst not in seen:
                seen.add(e.dst)
                stack.append(e.dst)
    return seen


def test_try_finally_reraise_runs_finally_then_escapes():
    cfg = cfg_of(
        """
        def f(x):
            try:
                step(x)
            finally:
                cleanup(x)
        """
    )
    # finally duplication: one copy per continuation (normal + exceptional)
    cleanups = at_line(cfg, 6)
    assert len(cleanups) >= 2

    step = at_line(cfg, 4)[0]
    exc_dsts = [e.dst for e in cfg.succ[step.id] if e.kind == EXC]
    assert exc_dsts, "a call must have an exception edge"
    # the exceptional continuation runs a cleanup copy...
    exc_cont = reachable(cfg, exc_dsts[0])
    exc_copy = next(b.id for b in cleanups if b.id in exc_cont)
    # ...whose tail re-raises out of the function
    assert any(
        e.dst == cfg.raise_exit and e.note == "reraise"
        for e in cfg.succ[exc_copy]
    )
    # the normal path runs a *different* cleanup copy and reaches exit
    normal = reachable(cfg, cfg.entry, kinds=(NORMAL,))
    assert cfg.exit in normal
    assert any(
        b.id in normal and b.id != exc_copy for b in cleanups
    )


def test_with_unwinds_through_exit_on_exception():
    cfg = cfg_of(
        """
        def f(x):
            with ctx(x) as h:
                work(h)
            done(x)
        """
    )
    assert at_line(cfg, 3, ROLE_WITH_ENTER)
    exits = [b for b in cfg.blocks if b.role == ROLE_WITH_EXIT]
    assert len(exits) >= 2  # normal + exceptional unwinding copies

    work = at_line(cfg, 4)[0]
    exc_dsts = [e.dst for e in cfg.succ[work.id] if e.kind == EXC]
    assert exc_dsts
    exc_cont = reachable(cfg, exc_dsts[0])
    exc_exit = next(b.id for b in exits if b.id in exc_cont)
    assert any(
        e.dst == cfg.raise_exit and e.note == "reraise"
        for e in cfg.succ[exc_exit]
    )
    # the normal path unwinds through a different __exit__ copy into done()
    normal = reachable(cfg, cfg.entry, kinds=(NORMAL,))
    done = at_line(cfg, 5)[0]
    assert done.id in normal
    assert any(b.id in normal and b.id != exc_exit for b in exits)


def test_break_bypasses_loop_else():
    cfg = cfg_of(
        """
        def f(xs):
            for x in xs:
                if x:
                    break
            else:
                tail(xs)
            after(xs)
        """
    )
    head = at_line(cfg, 3, ROLE_ITER)[0]
    assert head.id in cfg.loop_heads
    brk = at_line(cfg, 5)[0]
    tail = at_line(cfg, 7)[0]
    after = at_line(cfg, 8)[0]
    # break jumps straight past the else clause
    assert [e.dst for e in cfg.succ[brk.id] if e.kind == NORMAL] == [after.id]
    assert tail.id not in reachable(cfg, brk.id, kinds=(NORMAL,))
    # the else clause hangs off the loop head's exhaustion edge
    assert all(e.src == head.id for e in cfg.pred[tail.id])


def test_while_true_has_no_false_exit():
    cfg = cfg_of(
        """
        def f():
            while True:
                step()
        """
    )
    assert cfg.exit not in reachable(cfg, cfg.entry, kinds=(NORMAL,))
    # ...but an exception inside the body still escapes
    assert cfg.raise_exit in reachable(cfg, cfg.entry)


def test_bare_raise_in_except_escapes_and_dispatch_falls_through():
    cfg = cfg_of(
        """
        def f(x):
            try:
                step(x)
            except ValueError:
                fix(x)
                raise
        """
    )
    dispatch = next(b for b in cfg.blocks if b.role == ROLE_DISPATCH)
    # an exception not matching the handler re-raises past the dispatch
    assert any(
        e.dst == cfg.raise_exit and e.note == "reraise"
        for e in cfg.succ[dispatch.id]
    )
    bare = at_line(cfg, 7)[0]
    assert any(e.dst == cfg.raise_exit for e in cfg.succ[bare.id])
    # the handler body is only reachable along exception edges
    fix = at_line(cfg, 6)[0]
    assert fix.id not in reachable(cfg, cfg.entry, kinds=(NORMAL,))
    assert fix.id in reachable(cfg, cfg.entry)


def test_nested_comprehension_is_one_block_with_visible_calls():
    src = """
        def f(xs):
            ys = [g(x) for x in xs if any(h(y) for y in x)]
            return ys
        """
    cfg = cfg_of(src)
    assign = at_line(cfg, 3)
    assert len(assign) == 1  # no CFG explosion inside comprehensions
    stmt = assign[0].stmt
    # calls nested inside the comprehension still drive may_raise
    assert default_may_raise(stmt)
    assert not default_may_raise(
        stmt, atomic_callees=frozenset({"g", "h", "any"})
    )


def test_run_forward_terminates_on_ascending_loop_state():
    # A transfer that grows the state at every loop visit must be cut off
    # by widening, not loop forever.
    cfg = cfg_of(
        """
        def f(xs):
            while xs:
                xs = step(xs)
            return xs
        """
    )

    class Grow(ForwardAnalysis):
        def initial(self):
            return frozenset()

        def transfer(self, block, state, report=None):
            if block.line == 4:  # the loop-body assignment
                return frozenset(state | {len(state)})
            return state

        def join(self, a, b):
            return a | b

        def widen(self, old, new):
            return frozenset({-1})  # collapse to a fixed sentinel

    in_states = run_forward(cfg, Grow(), widen_after=4)
    assert in_states  # converged without hitting the relaxation cap
