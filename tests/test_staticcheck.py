"""Tests for the reprolint static-analysis suite (repro.analysis.staticcheck).

Three layers:

* fixture tests — every rule has a violation/clean fixture pair under
  ``tests/fixtures/staticcheck``; ``# expect: RPL###`` markers in the
  violation files pin the diagnostics *line-exactly*;
* contract tests — the twin differ is exercised against the real
  ``core/kernels_decide.py`` (a one-token mutation must trip RPL301, a
  broken convention must trip RPL302), and ``PRIVATE_LEDGER_FIELDS`` is
  cross-checked against the real ``ClusterState``;
* runner tests — suppression comments, baseline ratchet semantics, CLI
  exit codes, and the self-check that the shipped tree is clean under the
  checked-in baseline.
"""

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.staticcheck import (
    Project,
    all_rules,
    main,
    rule_catalog,
    run_rules,
)
from repro.analysis.staticcheck.rules import rule_codes
from repro.analysis.staticcheck import baseline as baseline_mod
from repro.analysis.staticcheck.engine import SourceFile
from repro.analysis.staticcheck.rules.ledger import PRIVATE_LEDGER_FIELDS
from repro.analysis.staticcheck.rules.twins import extract_jax, extract_numpy
from repro.core import ClusterState, Region

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "staticcheck"
KERNELS = REPO / "src" / "repro" / "core" / "kernels_decide.py"
BASELINE = REPO / "reprolint_baseline.json"

EXPECT_RE = re.compile(r"#\s*expect:\s*((?:RPL\d+[,\s]*)+)")

VIOLATION_FILES = sorted(
    (FIXTURES / "violations").rglob("*.py"), key=lambda p: p.as_posix()
)
CLEAN_FILES = sorted(
    (FIXTURES / "clean").rglob("*.py"), key=lambda p: p.as_posix()
)


def lint(*paths: Path):
    project = Project.collect(list(paths), root=REPO, include_fixtures=True)
    return run_rules(project, all_rules())


def expected_markers(path: Path):
    out = []
    for lineno, line in enumerate(
        path.read_text(encoding="utf-8").splitlines(), start=1
    ):
        m = EXPECT_RE.search(line)
        if m:
            for code in m.group(1).replace(",", " ").split():
                out.append((lineno, code))
    return sorted(out)


# --------------------------------------------------------------- fixtures
@pytest.mark.parametrize(
    "path",
    VIOLATION_FILES,
    ids=[p.relative_to(FIXTURES).as_posix() for p in VIOLATION_FILES],
)
def test_violation_fixture_flags_exactly_the_marked_lines(path):
    expected = expected_markers(path)
    assert expected, f"{path} has no '# expect:' markers"
    actual = sorted((d.line, d.code) for d in lint(path))
    assert actual == expected


@pytest.mark.parametrize(
    "path",
    CLEAN_FILES,
    ids=[p.relative_to(FIXTURES).as_posix() for p in CLEAN_FILES],
)
def test_clean_fixture_produces_no_diagnostics(path):
    diags = lint(path)
    assert diags == [], "\n".join(d.render() for d in diags)


def test_every_runnable_rule_has_a_violation_fixture():
    covered = {code for p in VIOLATION_FILES for _, code in expected_markers(p)}
    runnable = {code for r in all_rules() for code in rule_codes(r)}
    # RPL302 (twin convention breakage) needs a whole-file mutation of the
    # real kernels, so it is exercised by a dedicated test instead of a
    # fixture marker: test_broken_twin_convention_trips_rpl302.
    assert runnable - {"RPL302"} <= covered


# ------------------------------------------------- twin differ vs the real twins
def _load_sf(path: Path) -> SourceFile:
    return SourceFile.load(path, REPO)


def test_real_twins_extract_and_agree():
    sf = _load_sf(KERNELS)
    np_prog = extract_numpy(sf)
    jx_prog = extract_jax(sf)
    # Non-vacuous: the real frontier kernel carries substantial loop state.
    assert len(np_prog.loop_vars) >= 5
    assert set(np_prog.loop_vars) == set(jx_prog.loop_vars)
    assert lint(KERNELS) == []


def test_mutated_twin_trips_rpl301_and_fails_the_cli(tmp_path, monkeypatch, capsys):
    text = KERNELS.read_text(encoding="utf-8")
    assert ".argmax(axis=1)" in text
    core = tmp_path / "core"
    core.mkdir()
    mutated = core / "kernels_decide.py"
    # One-token drift in the numpy twin only (first occurrence is numpy's).
    mutated.write_text(
        text.replace(".argmax(axis=1)", ".argmin(axis=1)", 1),
        encoding="utf-8",
    )
    diags = lint(mutated)
    assert diags and all(d.code == "RPL301" for d in diags)
    assert any("per-step update" in d.message for d in diags)

    monkeypatch.chdir(tmp_path)  # no default baseline in tmp cwd
    assert main([str(mutated)]) == 1
    assert "RPL301" in capsys.readouterr().out


def test_broken_twin_convention_trips_rpl302(tmp_path):
    text = KERNELS.read_text(encoding="utf-8")
    core = tmp_path / "core"
    core.mkdir()
    broken = core / "kernels_decide.py"
    # Renaming the jax twin breaks the structural convention: parity can no
    # longer be proven, which must be loud (RPL302), not silently clean.
    broken.write_text(
        text.replace("def _prim(", "def _prim_renamed(", 1), encoding="utf-8"
    )
    diags = lint(broken)
    assert [d.code for d in diags] == ["RPL302"]
    assert "not found" in diags[0].message


def test_fixture_twin_divergence_names_both_infected_variables():
    diags = lint(FIXTURES / "violations" / "core" / "kernels_decide.py")
    msgs = " ".join(d.message for d in diags)
    assert "'acc'" in msgs and "'active'" in msgs


# -------------------------------------------------- ledger field cross-check
def test_private_ledger_fields_match_the_real_clusterstate():
    regions = [Region("a", 4, 0.1), Region("b", 4, 0.2)]
    cluster = ClusterState(
        regions={r.name: r for r in regions},
        bandwidth={("a", "b"): 50.0e9},
    )
    # Every guarded name exists on the real class (field or memo method) —
    # a rename there must force an update here.
    for field in PRIVATE_LEDGER_FIELDS:
        assert hasattr(cluster, field), f"stale guarded field {field!r}"
    # ... and every private instance attribute is guarded (completeness).
    private_attrs = {k for k in vars(cluster) if k.startswith("_")}
    assert private_attrs <= PRIVATE_LEDGER_FIELDS, (
        private_attrs - PRIVATE_LEDGER_FIELDS
    )


# ------------------------------------------------------------- suppression
def test_suppression_comment_silences_exactly_its_code(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(
        "def t(xs):\n"
        "    return sum(set(xs))  # reprolint: disable=RPL103\n",
        encoding="utf-8",
    )
    assert lint(f) == []

    f.write_text(
        "def t(xs):\n"
        "    return sum(set(xs))  # reprolint: disable=RPL999\n",
        encoding="utf-8",
    )
    assert [d.code for d in lint(f)] == ["RPL103"]

    # the wildcard, and suppression on a *different* line not applying
    f.write_text(
        "def t(xs):\n"
        "    # reprolint: disable=*\n"
        "    return sum(set(xs))\n",
        encoding="utf-8",
    )
    assert [d.code for d in lint(f)] == ["RPL103"]


# ---------------------------------------------------------------- baseline
def test_baseline_ratchet_semantics(tmp_path):
    diags = lint(FIXTURES / "violations" / "rpl101.py")
    assert diags
    bl = tmp_path / "baseline.json"
    baseline_mod.save(bl, diags)

    # grandfathered: everything baselined, nothing new, nothing stale
    res = baseline_mod.apply(diags, baseline_mod.load(bl))
    assert res.new == [] and len(res.baselined) == len(diags) and res.stale == []

    # a finding beyond the baseline is new
    extra = lint(FIXTURES / "violations" / "rpl103.py")
    res = baseline_mod.apply(diags + extra, baseline_mod.load(bl))
    assert sorted(d.code for d in res.new) == sorted(d.code for d in extra)

    # a fixed finding leaves a stale entry behind
    res = baseline_mod.apply(diags[1:], baseline_mod.load(bl))
    assert len(res.stale) == 1

    # line numbers are not part of the key: entries match on (code, path,
    # message) so unrelated edits don't churn the file
    data = json.loads(bl.read_text(encoding="utf-8"))
    assert data["version"] == 1
    assert all("line" not in e for e in data["entries"])


def test_cli_baseline_flow(tmp_path, monkeypatch, capsys):
    viol = tmp_path / "mod.py"
    viol.write_text("TOTAL = sum(set([1, 2]))\n", encoding="utf-8")
    monkeypatch.chdir(tmp_path)

    assert main([str(viol)]) == 1  # no baseline: findings fail

    bl = tmp_path / "bl.json"
    assert main([str(viol), "--write-baseline", "--baseline", str(bl)]) == 0
    assert main([str(viol), "--baseline", str(bl)]) == 0  # grandfathered

    viol.write_text("TOTAL = sum(sorted(set([1, 2])))\n", encoding="utf-8")
    capsys.readouterr()
    # fixed finding: stale entry is celebrated, strict mode ratchets
    assert main([str(viol), "--baseline", str(bl)]) == 0
    assert "stale" in capsys.readouterr().out
    assert main([str(viol), "--baseline", str(bl), "--strict-baseline"]) == 1


# --------------------------------------------------------------- CLI misc
def test_cli_exit_codes(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    clean = tmp_path / "ok.py"
    clean.write_text("X = 1\n", encoding="utf-8")
    assert main([str(clean)]) == 0

    assert main([str(tmp_path / "missing.py")]) == 2

    bad = tmp_path / "bad.py"
    bad.write_text("def broken(:\n", encoding="utf-8")
    assert main([str(bad)]) == 2


def test_cli_select_filters_rules(tmp_path, monkeypatch):
    f = tmp_path / "mod.py"
    f.write_text(
        "import random\n"
        "R = random.random()\n"
        "T = sum(set([1, 2]))\n",
        encoding="utf-8",
    )
    monkeypatch.chdir(tmp_path)
    assert main([str(f), "--select", "RPL101"]) == 1
    assert main([str(f), "--select", "RPL501"]) == 0


def test_list_rules_covers_all_codes(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in (
        "RPL101", "RPL102", "RPL103", "RPL104", "RPL201",
        "RPL301", "RPL302", "RPL401", "RPL402", "RPL403", "RPL501",
        "RPL601", "RPL701", "RPL702", "RPL703", "RPL801", "RPL802",
    ):
        assert code in out
    assert set(re.findall(r"RPL\d+", out)) == set(rule_catalog())


# ------------------------------------------------------------- self-check
def test_shipped_tree_is_clean_under_the_checked_in_baseline():
    project = Project.collect(
        [REPO / "src", REPO / "benchmarks", REPO / "scripts", REPO / "tests"],
        root=REPO,
    )
    diags = run_rules(project, all_rules())
    res = baseline_mod.apply(diags, baseline_mod.load(BASELINE))
    assert res.new == [], "\n".join(d.render() for d in res.new)
    assert res.stale == [], res.stale


def test_acceptance_command_exits_zero():
    proc = subprocess.run(
        [
            sys.executable, "-m", "repro.analysis.staticcheck",
            "src", "benchmarks", "scripts",
        ],
        cwd=REPO,
        env={**os.environ, "PYTHONPATH": "src"},
        capture_output=True,
        text=True,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout
