"""Engine parity: the vectorized scheduling engine must reproduce the seed
(legacy) engine decision-for-decision — identical records, costs, and
makespan — for every policy and ablation on the paper workload.

This is the contract that lets the repo keep one semantic definition of the
scheduler (the legacy reference in ``core/legacy.py``) while running the fast
array-backed path everywhere: any divergence, including tie-break drift, is a
bug.  Comparisons are exact (``==``), not approximate.
"""

import pytest

from repro.core import (
    ALL_ABLATIONS,
    BACEPipePolicy,
    CRLCFPolicy,
    CRLDFPolicy,
    LCFPolicy,
    LDFPolicy,
    paper_cluster,
    paper_jobs,
    paper_profiles,
    simulate,
)

ALL_POLICIES = [
    BACEPipePolicy,
    LCFPolicy,
    LDFPolicy,
    CRLCFPolicy,
    CRLDFPolicy,
    *ALL_ABLATIONS,
]

SEEDS = (0, 1, 2)


def _assert_identical(vec, leg):
    assert vec.policy == leg.policy
    assert vec.makespan == leg.makespan
    assert vec.costs == leg.costs
    assert len(vec.records) == len(leg.records)
    for rv, rl in zip(vec.records, leg.records):
        assert rv.job_id == rl.job_id
        assert rv.model_name == rl.model_name
        assert rv.submit == rl.submit
        assert rv.start == rl.start
        assert rv.finish == rl.finish
        assert rv.iteration_seconds == rl.iteration_seconds
        assert rv.placement.path == rl.placement.path
        assert dict(rv.placement.alloc) == dict(rl.placement.alloc)
        assert rv.placement.comm_times == rl.placement.comm_times
        assert dict(rv.placement.reserved_bw) == dict(rl.placement.reserved_bw)


@pytest.mark.parametrize("policy_cls", ALL_POLICIES, ids=lambda c: c.__name__)
@pytest.mark.parametrize("seed", SEEDS)
def test_engines_bit_identical_on_paper_workload(policy_cls, seed):
    profiles = paper_profiles(paper_jobs(seed=seed))
    vec = simulate(paper_cluster(), profiles, policy_cls(), engine="vectorized")
    leg = simulate(paper_cluster(), profiles, policy_cls(), engine="legacy")
    _assert_identical(vec, leg)


@pytest.mark.parametrize("policy_cls", [BACEPipePolicy, CRLDFPolicy])
def test_engines_bit_identical_with_staggered_arrivals(policy_cls):
    """Arrivals interleaved with completions exercise the incremental re-rank
    (queue membership churns) rather than one big t=0 batch."""
    jobs = paper_jobs(
        n_jobs=12, seed=3, submit_times=[i * 1800.0 for i in range(12)]
    )
    profiles = paper_profiles(jobs)
    vec = simulate(paper_cluster(), profiles, policy_cls(), engine="vectorized")
    leg = simulate(paper_cluster(), profiles, policy_cls(), engine="legacy")
    _assert_identical(vec, leg)


def test_unknown_engine_rejected():
    profiles = paper_profiles(paper_jobs(seed=0))
    with pytest.raises(ValueError, match="unknown engine"):
        simulate(paper_cluster(), profiles, BACEPipePolicy(), engine="turbo")


def test_bandwidth_over_release_raises():
    """Satellite guard: releasing more than reserved is a double-release bug
    and must raise instead of silently clamping to zero."""
    cluster = paper_cluster()
    link = next(iter(cluster.bandwidth))
    cluster.reserve_bandwidth({link: 1e9})
    with pytest.raises(ValueError, match="over-release"):
        cluster.release_bandwidth({link: 2e9})
    # exact release is fine and returns the ledger to zero
    cluster.release_bandwidth({link: 1e9})
    assert cluster.reserved_bw[link] == 0.0
