"""Piecewise segment accounting + price-aware voluntary migration.

Tentpole coverage for the settle-on-event refactor (``core/accounting.py``):

* mid-segment repricing integrates exactly (closed-form piecewise sum,
  1e-9), and a breakpoint that does not move the rate keeps the
  placement-time projection *bit-exactly* (the static-parity contract);
* settled costs are structurally non-negative, preemption included, and the
  per-segment costs partition the per-job Eq. 4 ledger;
* voluntary migration fires only when the live-priced alternative beats
  staying by the threshold, re-queues through the normal pending path,
  never increases the iterations still owed, and is accounted separately
  from forced (Eq. 6) evictions;
* satellite regressions: ``ClusterState.scaled()`` rebuilds from base
  capacities/prices and re-applies live multipliers;
  ``oversubscribed_links()`` sees reservations on uninstalled links.
"""

import pytest

from repro.core import (
    BACEPipePolicy,
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    Simulator,
    get_scenario,
    placement_power_rate,
    simulate,
)


def one_region_job_cluster(price_a=0.10, price_b=0.30, cap=8, gbps=50.0):
    regions = [Region("a", cap, price_a), Region("b", cap, price_b)]
    return ClusterState.build(regions, {("a", "b"): gbps}, symmetric=True)


def small_job(job_id=0, iters=30, layers=4):
    """Fits inside a single region (generous memory, few layers):
    ``max_gpus = 2 * layers <= cap`` so Phase 1 picks the cheapest region."""
    spec = JobSpec(
        job_id,
        ModelSpec(f"j{job_id}", 2e9, layers, 1024, batch_size=16),
        iterations=iters,
    )
    return JobProfile(spec, gpu_flops=300e12, gpu_memory=400e9)


# -------------------------------------------------------- piecewise repricing
def test_mid_segment_price_doubling_matches_closed_form():
    """Analytic fixture: the hosting region's price doubles halfway through
    the (single) segment; the settled cost must equal the closed-form
    piecewise sum  r·(t_mid − t_0) + 2r·(t_end − t_mid)  within 1e-9."""
    prof = small_job()
    static = simulate(one_region_job_cluster(), [prof], BACEPipePolicy())
    rec = static.records[0]
    assert rec.placement.path == ("a",)  # cheapest region hosts the job
    t_mid = 0.5 * rec.finish

    cluster = one_region_job_cluster()
    trace = BandwidthTrace([EnvUpdate(time=t_mid, prices={"a": 2.0})])
    res = simulate(
        one_region_job_cluster(), [small_job()], BACEPipePolicy(), trace=trace
    )
    assert res.migrations == {}  # prices never force an eviction
    rec_d = res.records[0]
    assert rec_d.finish == rec.finish  # repricing never moves the schedule

    rate = placement_power_rate(prof, rec_d.placement, cluster)
    expected = rate * (t_mid - rec_d.start) + 2.0 * rate * (
        rec_d.finish - t_mid
    )
    assert res.costs[0] == pytest.approx(expected, rel=1e-9)
    assert rec_d.cost == res.costs[0]
    # and strictly more than the stale-price projection would have claimed
    assert res.costs[0] > static.costs[0]


def test_rate_neutral_breakpoint_keeps_projection_bit_exact():
    """A price breakpoint that leaves the placement's $/s rate unchanged
    (multiplier re-set to its current value, or only foreign regions listed)
    must not split the ledger: the settled cost is the placement-time
    projection, bitwise — the contract that keeps static goldens frozen."""
    prof = small_job()
    static = simulate(one_region_job_cluster(), [prof], BACEPipePolicy())
    t_mid = 0.5 * static.records[0].finish
    trace = BandwidthTrace(
        [
            EnvUpdate(time=t_mid, prices={"a": 1.0}),  # rate-neutral
            EnvUpdate(time=t_mid, prices={"b": 5.0}),  # foreign region
        ]
    )
    res = simulate(
        one_region_job_cluster(), [small_job()], BACEPipePolicy(), trace=trace
    )
    assert res.costs[0] == static.costs[0]  # exact, not approx


def test_multi_breakpoint_piecewise_sum():
    """Spike-and-revert: three sub-intervals, closed form within 1e-9."""
    prof = small_job()
    static = simulate(one_region_job_cluster(), [prof], BACEPipePolicy())
    rec = static.records[0]
    t1, t2 = 0.25 * rec.finish, 0.75 * rec.finish
    trace = BandwidthTrace(
        [
            EnvUpdate(time=t1, prices={"a": 3.0}),
            EnvUpdate(time=t2, prices={"a": 1.0}),
        ]
    )
    res = simulate(
        one_region_job_cluster(), [small_job()], BACEPipePolicy(), trace=trace
    )
    cluster = one_region_job_cluster()
    rate = placement_power_rate(prof, rec.placement, cluster)
    expected = rate * (
        (t1 - rec.start) + 3.0 * (t2 - t1) + (rec.finish - t2)
    )
    assert res.costs[0] == pytest.approx(expected, rel=1e-9)


def test_preempted_segment_costs_stay_non_negative():
    """Satellite: the old ``cost -= (finish - t) * rate`` back-out is gone;
    every settled segment cost is a sum of duration × rate terms, so even a
    segment preempted while still inside its restore window accrues a
    non-negative cost, and the segment costs partition the job's total."""
    regions = [Region("a", 6, 0.10), Region("b", 6, 0.20)]
    cluster = ClusterState.build(regions, {("a", "b"): 50.0}, symmetric=True)
    spec = JobSpec(
        0, ModelSpec("j0", 20e9, 16, 2048, batch_size=16), iterations=20
    )
    prof = JobProfile(spec, gpu_flops=300e12)
    static = simulate(cluster.snapshot(), [prof], BACEPipePolicy())
    t_it = static.records[0].iteration_seconds
    flap = {("a", "b"): 0.01, ("b", "a"): 0.01}
    restore = {("a", "b"): 1.0, ("b", "a"): 1.0}
    t1 = 5.3 * t_it
    # second drop lands inside the restarted segment's restore window
    trace = BandwidthTrace(
        [
            EnvUpdate(time=t1, bandwidth=flap),
            EnvUpdate(time=t1 + t_it, bandwidth=restore),
            EnvUpdate(time=t1 + 2.0 * t_it, bandwidth=flap),
            EnvUpdate(time=t1 + 3.0 * t_it, bandwidth=restore),
        ]
    )
    res = simulate(
        cluster.snapshot(),
        [JobProfile(spec, gpu_flops=300e12)],
        BACEPipePolicy(),
        trace=trace,
        restart_penalty_s=100.0 * t_it,  # restore dwarfs the up-window
    )
    assert res.migrations == {0: 2}
    assert all(r.cost >= 0.0 for r in res.records)
    assert res.costs[0] >= 0.0
    assert sum(r.cost for r in res.records) == pytest.approx(
        res.costs[0], rel=1e-9
    )


# ------------------------------------------------------- voluntary migration
def spike_trace(t, factor=10.0):
    return BandwidthTrace([EnvUpdate(time=t, prices={"a": factor})])


def test_voluntary_migration_moves_off_spiked_region():
    """Price of the hosting region ×10 mid-run with the other region idle:
    the job checkpoints voluntarily, restarts on the now-cheaper region, and
    both segments settle at their live prices."""
    prof = small_job()
    static = simulate(one_region_job_cluster(), [prof], BACEPipePolicy())
    rec0 = static.records[0]
    assert rec0.placement.path == ("a",)
    t_spike = 0.4 * rec0.finish
    penalty = 10.0

    res = simulate(
        one_region_job_cluster(),
        [small_job()],
        BACEPipePolicy(),
        trace=spike_trace(t_spike),
        restart_penalty_s=penalty,
        voluntary_migration_threshold=0.10,
    )
    assert res.voluntary_migrations == {0: 1}
    assert res.forced_migrations == {}
    assert res.migrations == {0: 1}  # voluntary counts as a migration
    aborted, done = res.records
    assert aborted.preempted and aborted.finish == t_spike
    assert aborted.placement.path == ("a",)
    assert done.placement.path == ("b",)
    assert done.start == t_spike  # re-placed in the same scheduling pass
    assert res.stall_seconds[0] == 0.0
    kinds = [k for _, k, _ in res.events]
    assert "migrate" in kinds and "preempt" not in kinds

    # both segments settle at live prices: closed-form check
    cluster = one_region_job_cluster()
    rate_a = placement_power_rate(prof, aborted.placement, cluster)
    rate_b = placement_power_rate(prof, done.placement, cluster)
    expected = rate_a * (t_spike - 0.0) + rate_b * (done.finish - t_spike)
    assert res.costs[0] == pytest.approx(expected, rel=1e-9)

    # migrating must beat staying put, measured by the same piecewise ledger
    stay = simulate(
        one_region_job_cluster(),
        [small_job()],
        BACEPipePolicy(),
        trace=spike_trace(t_spike),
        restart_penalty_s=penalty,
    )
    assert stay.total_migrations == 0
    assert res.total_cost < stay.total_cost


def test_voluntary_migration_respects_threshold():
    """A threshold larger than the achievable saving keeps the job put."""
    prof = small_job()
    static = simulate(one_region_job_cluster(), [prof], BACEPipePolicy())
    t_spike = 0.4 * static.records[0].finish
    res = simulate(
        one_region_job_cluster(),
        [small_job()],
        BACEPipePolicy(),
        trace=spike_trace(t_spike),
        restart_penalty_s=10.0,
        voluntary_migration_threshold=1000.0,
    )
    assert res.total_migrations == 0
    assert [r.preempted for r in res.records] == [False]


def test_voluntary_migration_never_increases_remaining_iterations():
    """The restarted segment owes ``iterations − floor(trained)`` (+ restart
    penalty time), never more: checkpointing floors progress but migration
    cannot add work."""
    prof = small_job(iters=30)
    static = simulate(one_region_job_cluster(), [prof], BACEPipePolicy())
    rec0 = static.records[0]
    t_it = rec0.iteration_seconds
    t_spike = 0.4 * rec0.finish
    penalty = 10.0
    res = simulate(
        one_region_job_cluster(),
        [small_job(iters=30)],
        BACEPipePolicy(),
        trace=spike_trace(t_spike),
        restart_penalty_s=penalty,
        voluntary_migration_threshold=0.10,
    )
    assert res.voluntary_migrations == {0: 1}
    done_iters = int(t_spike // t_it)
    final = res.records[-1]
    owed = 30 - done_iters
    assert 0 < owed <= 30
    assert final.execution == pytest.approx(
        owed * final.iteration_seconds + penalty, rel=1e-9
    )


def test_price_spike_scenario_beats_stale_baseline():
    """Acceptance: on the registered price-spike scenario, BACE-Pipe with
    voluntary migration (the scenario default) ends strictly cheaper than
    the stay-put schedule the stale-price accounting used to produce — both
    measured by the same piecewise-accurate ledger."""
    sc = get_scenario("price-spike")
    assert sc.voluntary_migration_threshold is not None
    on = sc.run(BACEPipePolicy(), seed=0)
    off = sc.run(BACEPipePolicy(), seed=0, voluntary_migration_threshold=None)
    assert off.total_voluntary_migrations == 0
    assert on.total_voluntary_migrations > 0
    assert on.total_cost < off.total_cost


def test_voluntary_threshold_validation():
    cluster = one_region_job_cluster()
    with pytest.raises(ValueError, match="voluntary_migration_threshold"):
        Simulator(
            cluster,
            [small_job()],
            BACEPipePolicy(),
            voluntary_migration_threshold=-0.1,
        )


# ------------------------------------------------------- satellite: scaled()
def test_scaled_rebuilds_from_base_and_reapplies_multipliers():
    """Regression: ``scaled()`` used to rebuild from the *live* (multiplier-
    scaled) bandwidth next to construction-time prices, silently compounding
    dynamic state into the new installed baseline.  It must scale the base
    and re-apply both multiplier sets."""
    cluster = one_region_job_cluster(gbps=40.0)
    base_bw = cluster.bandwidth[("a", "b")]
    base_price = cluster.price("a")
    cluster.set_link_multipliers({("a", "b"): 0.5})
    cluster.set_price_multipliers({"a": 2.0})

    out = cluster.scaled(bandwidth_factor=2.0, capacity_factor=2.0)
    # live state carries over on top of the scaled base...
    assert out.link_bandwidth("a", "b") == pytest.approx(
        2.0 * base_bw * 0.5
    )
    assert out.price("a") == pytest.approx(2.0 * base_price)
    assert out.regions["a"].gpu_capacity == 16
    # ...and resetting the multipliers lands on the scaled *base*, proving
    # the baseline never absorbed the live multiplier
    out.set_link_multipliers({("a", "b"): 1.0})
    out.set_price_multipliers({"a": 1.0})
    assert out.link_bandwidth("a", "b") == pytest.approx(2.0 * base_bw)
    assert out.price("a") == pytest.approx(base_price)
    # untouched direction scales cleanly too
    assert out.link_bandwidth("b", "a") == pytest.approx(2.0 * base_bw)


def test_scaled_without_multipliers_matches_old_behavior():
    cluster = one_region_job_cluster(gbps=40.0)
    out = cluster.scaled(bandwidth_factor=0.5)
    assert out.link_bandwidth("a", "b") == pytest.approx(
        0.5 * cluster.bandwidth[("a", "b")]
    )
    assert out.price("a") == cluster.price("a")


# ------------------------------------- satellite: oversubscribed _res_extra
def test_oversubscribed_links_sees_uninstalled_reservations():
    """Reservations parked on uninstalled links (zero capacity) are standing
    Eq. 6 violations and must be reported, not silently skipped."""
    regions = [Region("a", 4, 0.1), Region("b", 4, 0.2)]
    # only a->b installed; a background reservation arrives on b->a
    cluster = ClusterState(
        regions={r.name: r for r in regions},
        bandwidth={("a", "b"): 50.0e9},
        reserved_bw={("b", "a"): 1.0e9},
    )
    assert cluster.oversubscribed_links() == [("b", "a")]
    # dust below tolerance is not a violation
    cluster.reserved_bw[("b", "a")] = 1e-9
    assert cluster.oversubscribed_links() == []


def test_simulation_tolerates_uninstalled_background_reservation():
    """The preemption pass must classify an uninstalled-link violation as
    unresolvable (no running job owns it) and carry on."""
    regions = [Region("a", 8, 0.1), Region("b", 8, 0.2)]
    cluster = ClusterState(
        regions={r.name: r for r in regions},
        bandwidth={("a", "b"): 50.0e9, ("b", "a"): 50.0e9},
        reserved_bw={("a", "nowhere"): 1.0e9},
    )
    trace = BandwidthTrace(
        [EnvUpdate(time=100.0, bandwidth={("a", "b"): 0.5})]
    )
    res = simulate(cluster, [small_job()], BACEPipePolicy(), trace=trace)
    assert len(res.completed_records) == 1
