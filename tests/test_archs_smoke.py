"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (full configs are exercised only by
the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import ModelCtx, build_model

B, T = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg):
    if cfg.family == "encdec":
        return {
            "src_embeds": jax.random.normal(KEY, (B, T, cfg.d_model)) * 0.1,
            "tgt_tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
            "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
        }
    if cfg.family == "vlm":
        tv = 8
        return {
            "tokens": jax.random.randint(KEY, (B, T - tv), 0, cfg.vocab),
            "vision_embeds": jax.random.normal(KEY, (B, tv, cfg.d_model)) * 0.1,
            "positions3": jnp.broadcast_to(jnp.arange(T)[None, None], (3, B, T)),
            "labels": jax.random.randint(KEY, (B, T - tv), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_batch(cfg)
    ctx = ModelCtx()

    h, aux = jax.jit(lambda p, b: api.hidden(p, b, cfg, ctx))(params, batch)
    t_total = T if cfg.family != "vlm" else T  # vision+text concat == T here
    assert h.shape == (B, t_total, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()

    loss, grads = jax.jit(jax.value_and_grad(lambda p: api.loss(p, batch)))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY)
    cache = api.init_cache(B, T)
    if cfg.family == "encdec":
        cache["memory"] = jax.random.normal(KEY, (B, T, cfg.d_model)) * 0.1
    batch = {"token": jnp.ones((B, 1), jnp.int32), "pos": jnp.int32(T // 2)}
    logits, cache2 = jax.jit(
        lambda p, c, b: api.decode_step(p, c, b, cfg, ModelCtx())
    )(params, cache, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structure round-trips
    jax.tree.map(lambda a, b: None, cache, cache2)


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-2.7b", "zamba2-2.7b"])
def test_prefill_decode_parity(arch):
    """Decoding token-by-token equals the full-sequence forward."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(KEY, jnp.float32)
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (1, 8), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    h_full, _ = api.hidden(params, batch, cfg, ModelCtx())
    from repro.models.layers import lm_logits, rms_norm

    h_full = rms_norm(h_full, params["ln_f"], cfg.rms_eps)
    logits_full = lm_logits(params["embed"], h_full, cfg)

    cache = api.init_cache(1, 8)
    outs = []
    for i in range(8):
        step = {"token": toks[:, i : i + 1], "pos": jnp.int32(i)}
        logits, cache = api.decode_step(params, cache, step, cfg, ModelCtx())
        outs.append(logits[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), atol=2e-3, rtol=2e-3
    )


def test_param_count_close_to_published():
    """Analytic param counts should be within ~15% of the published sizes."""
    published = {
        "qwen1.5-32b": 32e9,
        "internlm2-20b": 20e9,
        "gemma2-2b": 2.6e9,
        "starcoder2-3b": 3e9,
        "mamba2-2.7b": 2.7e9,
        "zamba2-2.7b": 2.7e9,
        "deepseek-moe-16b": 16.4e9,
        # moonshot-v1-16b-a3b omitted: the assigned pool config (48L x 64
        # experts x d_ff 1408) analytically exceeds the published 16B total;
        # we implement the assignment's numbers as given.
        "qwen2-vl-2b": 1.5e9,  # backbone without vision tower
    }
    for arch, want in published.items():
        got = get_config(arch).param_count()
        assert 0.7 * want < got < 1.45 * want, (arch, got, want)
