"""RPL104 fixture: min/max tie-breaks over dict views (violating).

Not under core/, demonstrating that the min/max-with-key arm applies
everywhere (the sum arm is core-only).
"""


def cheapest(prices):
    return min(prices.items(), key=lambda kv: kv[1])  # expect: RPL104


def busiest(load):
    return max(load.keys(), key=lambda r: load[r])  # expect: RPL104
