"""RPL401 fixture: jitted function closing over rebound state (violating)."""

import jax

scale = 2.0
scale = 3.0  # rebinding after definition is what makes the closure mutable


@jax.jit
def apply_scale(x):  # expect: RPL401
    return x * scale
