"""RPL201 fixture: poking ClusterState private ledgers (violating)."""


def peek_free(cluster):
    return cluster._free.sum()  # expect: RPL201


def peek_typed(cluster):
    return cluster._cap_t[0, 0]  # expect: RPL201


def poke(cluster, amount) -> None:
    cluster._free_total = amount  # expect: RPL201
