"""RPL103 fixture: iteration over raw sets (violating)."""


def accumulate(xs):
    out = 0.0
    for x in {1.0, 2.0, 3.0}:  # expect: RPL103
        out += x
    return out


def enumerate_set(xs):
    for i, x in enumerate(set(xs)):  # expect: RPL103
        print(i, x)


def reduce_set(xs):
    return sum(set(xs))  # expect: RPL103


def comprehend(xs):
    return [x + 1 for x in set(xs)]  # expect: RPL103


def comprehension_of_comp(xs):
    return list({x for x in xs})  # expect: RPL103
