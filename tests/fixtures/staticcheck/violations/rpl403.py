"""RPL403 fixture: global x64 flips (violating)."""

from jax import config
from jax.experimental import enable_x64


def flip_globally() -> None:
    config.update("jax_enable_x64", True)  # expect: RPL403


def leak_context():
    return enable_x64()  # expect: RPL403
