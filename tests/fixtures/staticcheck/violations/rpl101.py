"""RPL101 fixture: process-global RNG calls (violating)."""

import random

import numpy as np


def roll() -> float:
    return random.random()  # expect: RPL101


def pick(items):
    return random.choice(items)  # expect: RPL101


def draw():
    return np.random.rand(3)  # expect: RPL101


def reseed() -> None:
    np.random.seed(0)  # expect: RPL101
