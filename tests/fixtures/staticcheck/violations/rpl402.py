"""RPL402 fixture: Python control flow on traced values (violating)."""

import jax


@jax.jit
def clamp(x, n):
    if x > 0:  # expect: RPL402
        return -x
    while n > 1:  # expect: RPL402
        n = n - 1
    m = x.shape[0]
    if m > 2:  # shape-derived: concrete at trace time, not flagged
        return x
    y = x + 1
    if y.sum() > 0:  # expect: RPL402
        return y
    return x
