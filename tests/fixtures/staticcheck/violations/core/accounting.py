"""RPL8xx fixture: units-of-measure violations (violating).

Must be named ``accounting.py`` under ``core/`` — the units rule only
engages on the five cost-model modules.  Units flow from the annotation
registry: ``now`` is seconds, ``.cost`` dollars, ``.rate`` $/s,
``rate=`` keyword slots $/s.
"""


def projected_total(job, now):
    return now + job.cost  # expect: RPL801


def open_ledger(job, now):
    return Ledger(start=now, rate=job.cost)  # expect: RPL801


def squared_rate(job):
    return job.rate * job.rate  # expect: RPL802


def deadline_exceeded(job, now):
    return job.cost > now  # expect: RPL801


def electricity_cost(job):
    return job.iteration_seconds  # expect: RPL801


def stamp(job, now):
    job.cost = now  # expect: RPL801
