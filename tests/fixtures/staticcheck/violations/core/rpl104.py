"""RPL104 fixture: dict-order-sensitive reductions in core/ (violating)."""


def total_cost(costs):
    return sum(costs.values())  # expect: RPL104


def total_gen(costs):
    return sum(v for v in costs.values())  # expect: RPL104
