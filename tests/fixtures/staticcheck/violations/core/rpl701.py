"""RPL7xx fixture: resource-typestate violations (violating).

Lives under ``core/`` (the typestate rule's scope) but is deliberately not
named ``scheduler.py`` so RPL501 stays out of the picture — each marker
below pins exactly one path-sensitive finding.
"""


class SegmentLedger:
    @classmethod
    def open(cls, profile):
        return cls()

    def settle(self, now: float) -> float:
        return 0.0


def leak_on_exception_path(ledger, cluster, alloc, now):
    cluster.release_gpus(alloc)
    audit(cluster)  # expect: RPL701
    ledger.settle(now)


def double_free(ledger, cluster, alloc, now):
    cluster.release_gpus(alloc)
    cluster.release_gpus(alloc)  # expect: RPL702
    ledger.settle(now)


def acquire_and_forget(cluster, alloc):
    cluster.reserve_gpus(alloc)  # expect: RPL701
    return None


def open_and_drop(profile):
    acct = SegmentLedger.open(profile)  # expect: RPL703
    return None


def settle_only_happy_branch(ledger, cluster, alloc, now, ok):
    cluster.release_gpus(alloc)  # expect: RPL703
    if ok:
        ledger.settle(now)
