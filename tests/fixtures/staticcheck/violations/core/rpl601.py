"""RPL601 fixture: obs imports from a core/ decision-path file (violating)."""

import repro.obs  # expect: RPL601
import repro.obs.metrics as obs_metrics  # expect: RPL601
from repro.obs import SimTraceRecorder  # expect: RPL601
from repro.obs.recorder import SimTraceRecorder as Rec  # expect: RPL601
from ..obs.metrics import MetricsLog  # expect: RPL601


def trace_everything(cluster):
    rec = SimTraceRecorder()
    rec.metrics = MetricsLog()
    return repro.obs, obs_metrics, Rec, rec
