"""RPL501 fixture: a release path that never settles (violating).

The file must be named ``scheduler.py`` under a ``core`` directory for the
rule to engage — it mirrors the shape of the engine's real scheduler.
"""


class SegmentLedger:
    def __init__(self) -> None:
        self.costs = {}

    def settle(self, now: float) -> None:
        self.costs["t"] = now


def release_gpus(cluster, alloc) -> None:
    pass


def release_bandwidth(cluster, edges) -> None:
    pass


def reserve_gpus(cluster, alloc) -> None:
    pass


def preempt_without_settling(ledger, cluster, alloc, now) -> None:
    release_gpus(cluster, alloc)  # expect: RPL501, RPL703
    # no settle / re-reserve afterwards: accrued cost is dropped


def drop_link_shares(cluster, edges) -> None:
    release_bandwidth(cluster, edges)  # expect: RPL501, RPL703


def preempt_and_settle(ledger, cluster, alloc, now) -> None:
    # The compliant shape, for contrast: release followed by settle.
    release_gpus(cluster, alloc)
    ledger.settle(now)
