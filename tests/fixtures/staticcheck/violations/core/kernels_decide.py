"""Twin-parity fixture: the jax twin drifted from the numpy twin (violating).

Identical to the clean pair except the jax body computes ``acc - x`` where
the numpy body computes ``acc + x``.  The differ reports the divergence at
the *numpy* side's update lines: ``acc`` diverges directly, and ``active``
diverges because its update embeds ``acc``'s.
"""

import numpy as np


def _prim_expand_numpy(x, k):
    acc = np.minimum(x, k)
    active = acc < k
    return _prim_steps_numpy(x, k, acc, active)


def _prim_steps_numpy(x, k, acc, active):
    while active.any():
        nxt = acc + x
        acc = np.where(active, nxt, acc)  # expect: RPL301
        active = active & (acc < k)  # expect: RPL301
    return acc


def _load_jax():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def _prim(x, k):
        acc0 = jnp.minimum(x, k)
        active0 = acc0 < k
        state0 = (acc0, active0)

        def cond(state):
            return jnp.any(state[1])

        def body(state):
            acc, active = state
            nxt = acc - x  # the drift: numpy adds, jax subtracts
            acc = jnp.where(active, nxt, acc)
            active = active & (acc < k)
            return (acc, active)

        acc, active = lax.while_loop(cond, body, state0)
        return acc

    return jax.jit(_prim)
