"""RPL102 fixture: wall-clock reads in a core/ file (violating)."""

import time
from datetime import datetime


def stamp() -> float:
    return time.time()  # expect: RPL102


def tick() -> float:
    return time.monotonic()  # expect: RPL102


def today():
    return datetime.now()  # expect: RPL102
