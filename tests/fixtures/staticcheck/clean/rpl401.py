"""RPL401 fixture: read-only closures are fine (clean)."""

import jax
import jax.numpy as jnp

SCALE = 2.0  # bound exactly once — a constant closure


@jax.jit
def apply_scale(x):
    return jnp.asarray(x) * SCALE


@jax.jit
def add_param(x, scale):
    # The mutable value is passed as an argument instead of closed over.
    return x * scale
