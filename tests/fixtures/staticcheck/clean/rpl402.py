"""RPL402 fixture: static args and shape projections (clean)."""

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("mode",))
def dispatch(x, mode):
    if mode == "fast":  # static argument: concrete at trace time
        return x
    m = len(x)
    if m > 2:  # len() projection is concrete
        return x + 1
    return jnp.where(x > 0, x, -x)  # traced branch expressed as where
