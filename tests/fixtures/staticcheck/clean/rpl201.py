"""RPL201 fixture: public accessors and unrelated private names (clean)."""


class Budget:
    def __init__(self, cap: float) -> None:
        # A private name that happens to collide with a ledger field is
        # fine on a self receiver — the rule only checks foreign receivers.
        self._cap = cap

    def remaining(self, spent: float) -> float:
        return self._cap - spent


def peek_free(cluster):
    return cluster.free_vector().sum()


def total_bw(cluster):
    return cluster.total_link_capacity()
