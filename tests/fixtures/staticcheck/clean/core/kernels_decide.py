"""Twin-parity fixture: a minimal numpy/jax twin pair that agrees (clean).

Follows the structural conventions the differ enforces (RPL302): the numpy
side is ``_prim_expand_numpy`` tail-calling ``_prim_steps_numpy``; the jax
side is ``_prim`` nested in ``_load_jax`` with cond/body defs around one
``lax.while_loop``.
"""

import numpy as np


def _prim_expand_numpy(x, k):
    acc = np.minimum(x, k)
    active = acc < k
    return _prim_steps_numpy(x, k, acc, active)


def _prim_steps_numpy(x, k, acc, active):
    while active.any():
        nxt = acc + x
        acc = np.where(active, nxt, acc)
        active = active & (acc < k)
    return acc


def _load_jax():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def _prim(x, k):
        acc0 = jnp.minimum(x, k)
        active0 = acc0 < k
        state0 = (acc0, active0)

        def cond(state):
            return jnp.any(state[1])

        def body(state):
            acc, active = state
            nxt = acc + x
            acc = jnp.where(active, nxt, acc)
            active = active & (acc < k)
            return (acc, active)

        acc, active = lax.while_loop(cond, body, state0)
        return acc

    return jax.jit(_prim)
