"""RPL104 fixture: dict reductions with a pinned order (clean)."""


def total_cost(costs):
    return sum(sorted(costs.values()))


def total_items(costs):
    return sum(v for _, v in sorted(costs.items()))
