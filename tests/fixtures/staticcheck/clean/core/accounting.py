"""RPL8xx fixture: units-of-measure compliant shapes (clean).

Mirrors the violating twin with the units transposed back into place;
literals stay unit-polymorphic (``now + 1e-12`` and ``0.95 * rate`` are
fine), and division composes units (``$ / s`` is a rate).
"""


def projected_total(job, now):
    return job.cost + job.rate * (job.finish - now)


def open_ledger(job, now):
    return Ledger(start=now + 1e-12, rate=0.95 * job.rate)


def effective_rate(job):
    return job.cost / (job.finish - job.start)


def deadline_exceeded(job, now):
    return job.finish > now


def electricity_cost(job):
    return job.rate * job.iteration_seconds


def stamp(job, now):
    job.finish = now
    job.cost = job.rate * job.iteration_seconds
