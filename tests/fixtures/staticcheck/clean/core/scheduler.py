"""RPL501 fixture: every release path settles or re-reserves (clean)."""


class SegmentLedger:
    def __init__(self) -> None:
        self.costs = {}

    def settle(self, now: float) -> None:
        self.costs["t"] = now


def release_gpus(cluster, alloc) -> None:
    pass


def reserve_gpus(cluster, alloc) -> None:
    pass


def _finish_segment(ledger, now) -> None:
    ledger.settle(now)


def preempt(ledger, cluster, alloc, now) -> None:
    release_gpus(cluster, alloc)
    # settle reached *indirectly* through the call graph
    _finish_segment(ledger, now)


def probe_alternative(cluster, alloc) -> None:
    # The voluntary-migration probe pattern: release to price an
    # alternative, then re-reserve the original.
    release_gpus(cluster, alloc)
    reserve_gpus(cluster, alloc)
