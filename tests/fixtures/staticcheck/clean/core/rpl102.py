"""RPL102 fixture: simulated time passed in explicitly (clean)."""


def stamp(sim_time: float) -> float:
    return sim_time


def elapsed(start: float, now: float) -> float:
    return now - start
