"""RPL7xx fixture: resource-typestate compliant shapes (clean).

Mirrors the violating twin: every release settles on *every* path, probes
re-reserve, reservations are released or handed off, and opened ledgers are
settled — including along exception edges.
"""


class SegmentLedger:
    @classmethod
    def open(cls, profile):
        return cls()

    def settle(self, now: float) -> float:
        return 0.0


def settle_in_finally(ledger, cluster, alloc, now):
    cluster.release_gpus(alloc)
    try:
        audit(cluster)  # may raise: the finally still settles that edge
    finally:
        ledger.settle(now)


def probe_then_restore(cluster, alloc):
    # The voluntary-migration probe: release to price an alternative,
    # re-reserve when declining to move.
    cluster.release_gpus(alloc)
    cluster.reserve_gpus(alloc)


def acquire_then_free(ledger, cluster, alloc, now):
    cluster.reserve_gpus(alloc)
    cluster.release_gpus(alloc)
    ledger.settle(now)


def acquire_and_hand_off(cluster, alloc, registry):
    cluster.reserve_gpus(alloc)
    registry.track(alloc)  # ownership moves to the registry


def open_and_settle(profile, now):
    acct = SegmentLedger.open(profile)
    return acct.settle(now)


def settle_on_both_branches(ledger, cluster, alloc, now, ok):
    cluster.release_gpus(alloc)
    if ok:
        ledger.settle(now)
    else:
        ledger.settle(now + 1.0)
