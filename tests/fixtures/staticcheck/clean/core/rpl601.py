"""RPL601 fixture: the sanctioned obs typing seam in a core/ file (clean)."""

from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from repro.obs.protocol import TraceRecorder


def place(profile, cluster, *, recorder: Optional["TraceRecorder"] = None):
    if recorder is not None:
        recorder.on_candidate(0, "phase1", (), 0, "chosen", None)
    return None
