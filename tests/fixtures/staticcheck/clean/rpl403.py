"""RPL403 fixture: scoped x64 region (clean)."""

from jax.experimental import enable_x64


def decide(kernel, *args):
    with enable_x64():
        return kernel(*args)
