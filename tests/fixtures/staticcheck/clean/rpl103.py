"""RPL103 fixture: set iteration pinned with sorted() (clean)."""


def accumulate(xs):
    out = 0.0
    for x in sorted({1.0, 2.0, 3.0}):
        out += x
    return out


def reduce_set(xs):
    return sum(sorted(set(xs)))


def comprehend(xs):
    return [x + 1 for x in sorted(set(xs))]


def membership_only(xs, probe):
    # Set *membership* is order-free and fine; only iteration is flagged.
    return probe in set(xs)
