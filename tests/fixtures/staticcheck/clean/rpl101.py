"""RPL101 fixture: seeded generator objects (clean)."""

import random

import numpy as np


def roll(seed: int) -> float:
    rng = random.Random(seed)
    return rng.random()


def draw(seed: int):
    g = np.random.default_rng(seed)
    return g.normal(size=3)
