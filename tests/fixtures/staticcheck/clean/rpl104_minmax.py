"""RPL104 fixture: min/max tie-breaks pinned with sorted() (clean)."""


def cheapest(prices):
    return min(sorted(prices.items()), key=lambda kv: kv[1])


def total(prices):
    # The sum() arm is core-only: outside core/ a plain sum over a dict
    # view is not flagged.
    return sum(prices.values())
