"""Distribution-layer tests.  These need >1 device, so each case runs in a
subprocess with its own --xla_force_host_platform_device_count (the main
pytest process keeps the single real CPU device, per the dry-run contract)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_pipeline_matches_sequential_reference():
    """GPipe pipeline loss+grads == plain sequential execution."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch import steps as S
        from repro.distributed.compat import use_mesh
        from repro.launch.mesh import make_debug_mesh
        from repro.models.model import build_model, ModelCtx
        from repro.models.layers import rms_norm, chunked_xent
        from repro.pipeline import stack_pipeline_params

        mesh = make_debug_mesh()
        cfg = dataclasses.replace(get_config("qwen1.5-32b").reduced(), pp_stages=2)
        b, t = 8, 32
        api = build_model(cfg)
        params = api.init(jax.random.PRNGKey(0), jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, t), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

        # reference: single-device loss
        ref_loss = float(api.loss(params, batch))
        ref_grads = jax.grad(lambda p: api.loss(p, batch))(params)

        # pipeline loss
        pp_params = dict(params)
        pp_params["blocks"] = stack_pipeline_params(params["blocks"], 2)
        train_step, _, lay = S.build_pp_train(cfg, mesh, multi_pod=False,
                                              batch=b, seq=t, dtype=jnp.float32)
        # extract just the loss via the internal fn: rebuild loss path
        from repro.launch.steps import _pp_forward_hidden
        def pp_loss(p, batch):
            h = _pp_forward_hidden(cfg, p, batch["tokens"], lay, mesh, t,
                                   False, jnp.float32)
            lbl = batch["labels"].reshape(lay.m_ub, lay.mb, t).reshape(-1, t)
            return chunked_xent(p["embed"], h, lbl, cfg)
        with use_mesh(mesh):
            loss = float(jax.jit(pp_loss)(pp_params, batch))
            grads = jax.jit(jax.grad(pp_loss))(pp_params, batch)
        assert abs(loss - ref_loss) < 1e-4, (loss, ref_loss)

        # microbatch-order invariance: labels were reordered identically, so
        # grads must match the sequential reference
        g1 = np.asarray(grads["embed"]["table"])
        g2 = np.asarray(ref_grads["embed"]["table"])
        np.testing.assert_allclose(g1, g2, atol=2e-4)
        gb1 = np.asarray(jax.tree.leaves(grads["blocks"])[0])
        gb2 = np.asarray(jax.tree.leaves(
            stack_pipeline_params(ref_grads["blocks"], 2))[0])
        np.testing.assert_allclose(gb1, gb2, atol=2e-4)
        print("pipeline==sequential OK", loss, ref_loss)
    """)


def test_compressed_pod_gradients_close_to_exact():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compat import shard_map, use_mesh
        from repro.distributed.compression import compressed_pmean
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 33))

        def f(g):
            out = compressed_pmean({"w": g}, "pod", 2)
            return out["w"]
        with use_mesh(mesh):
            got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                          out_specs=P("pod"), axis_names={"pod"},
                          check_vma=False))(g)
        want = jnp.broadcast_to(jnp.mean(g.reshape(2, 1, 64, 33), 0), g.shape)
        err = float(jnp.max(jnp.abs(got - want)))
        rng = float(jnp.max(jnp.abs(want)))
        assert err < 0.02 * rng, (err, rng)  # int8 quantization tolerance
        print("compressed pmean OK", err)
    """)


def test_moe_ep_all_to_all_matches_single_device():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as moe_mod
        from repro.models.moe import moe_ffn_apply, init_moe_ffn
        from repro.distributed.compat import use_mesh
        # generous capacity so shard-local vs global drop behaviour agrees
        moe_mod.CAPACITY_FACTOR = 16.0
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        cfg = get_config("deepseek-moe-16b").reduced()
        p = init_moe_ffn(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3

        y_ref, aux_ref = moe_ffn_apply(p, x, cfg)  # no EP
        with use_mesh(mesh):
            y_ep, aux_ep = jax.jit(lambda p, x: moe_ffn_apply(
                p, x, cfg, ep_axis="model", ep_size=2, mesh=mesh))(p, x)
        # EP capacity is per-shard so borderline drops can differ; the bulk
        # of tokens must agree.
        diff = np.abs(np.asarray(y_ep) - np.asarray(y_ref)).max(axis=-1)
        frac_same = float((diff < 1e-4).mean())
        assert frac_same > 0.99, frac_same
        print("moe EP OK, agreement:", frac_same)
    """)


def test_train_step_runs_on_debug_mesh_all_strategies():
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.distributed.compat import use_mesh
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.train import build_everything
        from repro.data import SyntheticLM, make_batch_iterator
        from repro.launch import steps as S

        for arch in ("qwen1.5-32b", "gemma2-2b", "deepseek-moe-16b", "mamba2-2.7b"):
            cfg = get_config(arch).reduced()
            if cfg.model_axis == "pp":
                cfg = dataclasses.replace(cfg, pp_stages=2)
            mesh = make_debug_mesh()
            state, step_fn, _ = build_everything(
                cfg, mesh, batch=8, seq=32, multi_pod=False, dtype=jnp.float32)
            src = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8)
            bspec = S.batch_axis_spec(mesh, False, 8)
            it = make_batch_iterator(src, cfg, mesh, bspec)
            losses = []
            with use_mesh(mesh):
                for i in range(3):
                    state, loss = step_fn(state, next(it))
                    losses.append(float(loss))
            assert all(np.isfinite(l) for l in losses), (arch, losses)
            print(arch, "losses:", [round(l, 3) for l in losses])
    """, timeout=560)
