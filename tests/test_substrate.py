"""Substrate tests: data pipeline, optimizer, checkpointing, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticLM, make_batch_iterator
from repro.ft import FailureInjector, HeartbeatMonitor, StragglerDetector
from repro.ft.loop import resilient_train_loop
from repro.models.model import build_model
from repro.optim import adamw_init, adamw_update, cosine_schedule


# ------------------------------------------------------------------- data
def test_synthetic_data_deterministic_and_restart_safe():
    src = SyntheticLM(vocab=128, seq_len=16, batch=4, seed=3)
    a = src.batch_at(7)
    b = src.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 128


def test_batch_iterator_family_stubs():
    for arch in ("qwen2-vl-2b", "seamless-m4t-medium"):
        cfg = get_config(arch).reduced()
        src = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=2)
        it = make_batch_iterator(src, cfg)
        batch = next(it)
        if cfg.family == "vlm":
            assert "vision_embeds" in batch and "positions3" in batch
            assert batch["vision_embeds"].shape[-1] == cfg.d_model
        else:
            assert "src_embeds" in batch


# -------------------------------------------------------------- optimizer
def test_adamw_converges_on_quadratic():
    w = {"a": jnp.array([2.0, -3.0]), "b": jnp.array(1.5)}
    state = adamw_init(w)
    loss = lambda w: jnp.sum(w["a"] ** 2) + w["b"] ** 2
    for _ in range(300):
        g = jax.grad(loss)(w)
        w, state = adamw_update(
            g, state, w, lr=jnp.float32(0.05), weight_decay=0.0
        )
    assert float(loss(w)) < 1e-3


def test_adamw_gradient_clipping():
    w = {"a": jnp.ones((4,))}
    state = adamw_init(w)
    g = {"a": jnp.full((4,), 1e6)}
    w2, state = adamw_update(g, state, w, lr=jnp.float32(0.1), clip_norm=1.0)
    assert np.isfinite(np.asarray(w2["a"])).all()
    # clipped step is bounded by lr * (1 + wd)
    assert float(jnp.max(jnp.abs(w2["a"] - w["a"]))) < 0.25


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), base_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0       # warmup rises
    assert lrs[50] > lrs[99]            # decay falls
    assert lrs[99] >= 0.1 - 1e-6        # floor


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_elastic_restore(tmp_path):
    cfg = get_config("gemma2-2b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    save_checkpoint(str(tmp_path), state, step=42, extra={"cursor": 42})
    assert latest_step(str(tmp_path)) == 42

    abstract = jax.eval_shape(lambda: state)
    restored, step, extra = restore_checkpoint(str(tmp_path), abstract)
    assert step == 42 and extra["cursor"] == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keeps_latest(tmp_path):
    state = {"w": jnp.arange(4.0)}
    save_checkpoint(str(tmp_path), state, step=1)
    save_checkpoint(str(tmp_path), {"w": jnp.arange(4.0) * 2}, step=5)
    assert latest_step(str(tmp_path)) == 5
    restored, step, _ = restore_checkpoint(
        str(tmp_path), jax.eval_shape(lambda: state)
    )
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4.0) * 2)


# --------------------------------------------------------- fault tolerance
def test_heartbeat_monitor():
    hb = HeartbeatMonitor(timeout_s=10.0)
    hb.beat("w0", now=0.0)
    hb.beat("w1", now=0.0)
    assert hb.healthy(now=5.0)
    hb.beat("w0", now=9.0)
    assert hb.dead_workers(now=12.0) == ["w1"]


def test_straggler_detector_flags_spikes():
    det = StragglerDetector(factor=2.0)
    for s in range(10):
        det.observe(s, 0.1)
    assert det.observe(10, 0.5) is True
    assert det.events == [10]
    # EMA not polluted by the spike
    assert det.ema == pytest.approx(0.1, rel=0.05)


def test_resilient_loop_recovers_from_failure(tmp_path):
    """Inject a failure mid-run; the loop restores the checkpoint and
    finishes all steps with finite losses."""
    cfg = get_config("gemma2-2b").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    src = SyntheticLM(vocab=cfg.vocab, seq_len=16, batch=2)
    batches = make_batch_iterator(src, cfg)

    @jax.jit
    def train_step(state_, batch_):
        loss, grads = jax.value_and_grad(lambda p: api.loss(p, batch_))(
            state_["params"]
        )
        new_p, new_opt = adamw_update(
            grads, state_["opt"], state_["params"], lr=jnp.float32(1e-3)
        )
        return {"params": new_p, "opt": new_opt}, loss

    out = resilient_train_loop(
        train_step=train_step,
        state=state,
        batches=batches,
        n_steps=12,
        ckpt_dir=str(tmp_path),
        ckpt_every=4,
        injector=FailureInjector({7: "region-ea-east"}),
        log=lambda *_: None,
    )
    assert out["restarts"] == 1
    assert len(out["losses"]) >= 12
    assert all(np.isfinite(l) for l in out["losses"])
    assert latest_step(str(tmp_path)) == 12


def test_failure_triggers_control_plane_rescheduling():
    """Region failure -> the Pathfinder re-places the job on survivors."""
    from repro.core import (
        ClusterState, JobProfile, JobSpec, ModelSpec, Region, find_placement,
    )

    regions = [Region("a", 8, 0.1), Region("b", 8, 0.2), Region("c", 4, 0.3)]
    gbps = {("a", "b"): 100.0, ("b", "c"): 100.0, ("a", "c"): 100.0}
    cluster = ClusterState.build(regions, gbps, symmetric=True)
    prof = JobProfile(
        JobSpec(0, ModelSpec("m", 4e9, 16, 2048, 16), 10),
        gpu_flops=300e12, gpu_memory=400e9,
    )
    before = find_placement(prof, cluster, k_star=12)
    assert "a" in before.path
    # region 'a' dies: zero its capacity, re-run the pathfinder
    cluster.free_gpus["a"] = 0
    after = find_placement(prof, cluster, k_star=12)
    assert after is not None and "a" not in after.path
    assert after.total_gpus >= prof.min_gpus
