"""Hypothesis property tests on the scheduler's invariants.

``hypothesis`` is an optional dev dependency (see pyproject.toml); the whole
module is skipped when it is absent so ``pytest -x -q`` still collects clean.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    BACEPipePolicy,
    ClusterState,
    JobProfile,
    JobSpec,
    ModelSpec,
    Region,
    cost_min_allocate,
    find_placement,
    simulate,
)

regions_st = st.lists(
    st.tuples(
        st.integers(min_value=2, max_value=64),     # capacity
        st.floats(min_value=0.05, max_value=0.40),  # price
    ),
    min_size=2,
    max_size=6,
)

jobs_st = st.lists(
    st.tuples(
        st.floats(min_value=0.5e9, max_value=60e9),   # params
        st.sampled_from([8, 16, 24, 32, 48]),         # layers
        st.sampled_from([1024, 2048, 4096]),          # hidden
        st.sampled_from([8, 16, 32]),                 # batch
        st.integers(min_value=1, max_value=50),       # iterations
    ),
    min_size=1,
    max_size=6,
)


def build_cluster(caps_prices, bw=40.0):
    regs = [Region(f"r{i}", c, p) for i, (c, p) in enumerate(caps_prices)]
    gbps = {}
    for i, a in enumerate(regs):
        for b in regs[i + 1 :]:
            gbps[(a.name, b.name)] = bw
    return ClusterState.build(regs, gbps, symmetric=True)


def build_profiles(raw):
    profs = []
    for i, (params, layers, hidden, batch, iters) in enumerate(raw):
        spec = JobSpec(
            job_id=i,
            model=ModelSpec(f"j{i}", params, layers, hidden, batch),
            iterations=iters,
        )
        profs.append(JobProfile(spec, gpu_flops=300e12, gpu_memory=400e9))
    return profs


@settings(max_examples=40, deadline=None)
@given(regions_st, jobs_st)
def test_simulation_invariants(caps_prices, raw_jobs):
    cluster = build_cluster(caps_prices)
    profs = build_profiles(raw_jobs)
    res = simulate(cluster, profs, BACEPipePolicy())

    # every job ran exactly once, no resource leaks, constraints held
    assert sorted(r.job_id for r in res.records) == sorted(
        p.spec.job_id for p in profs
    )
    for r in res.records:
        assert r.wait >= 0
        assert r.placement.total_gpus >= 1
        # Eq. 5: never more GPUs than a region's capacity
        for reg, n in r.placement.alloc.items():
            assert n <= cluster.regions[reg].gpu_capacity
        # pipeline continuity
        assert all(n >= 1 for n in r.placement.alloc.values())


@settings(max_examples=40, deadline=None)
@given(regions_st, jobs_st)
def test_eq6_bandwidth_never_oversubscribed(caps_prices, raw_jobs):
    """Replay the timeline and check instantaneous link usage (Eq. 6)."""
    cluster = build_cluster(caps_prices, bw=5.0)
    profs = build_profiles(raw_jobs)
    res = simulate(cluster, profs, BACEPipePolicy())
    events = []
    for r in res.records:
        for edge, b in r.placement.reserved_bw.items():
            events.append((r.start, edge, b))
            events.append((r.finish, edge, -b))
    usage = {}
    # at equal timestamps the simulator releases finished jobs before
    # admitting new ones; replay in the same order (releases first)
    for t, edge, delta in sorted(events, key=lambda e: (e[0], e[2])):
        usage[edge] = usage.get(edge, 0.0) + delta
        cap = cluster.bandwidth.get(edge, 0.0)
        assert usage[edge] <= cap * (1 + 1e-6), (edge, usage[edge], cap)


@settings(max_examples=40, deadline=None)
@given(regions_st, st.integers(min_value=2, max_value=40))
def test_cost_min_allocation_is_optimal(caps_prices, g):
    """Alg. 2 is the exact minimizer among allocations with >=1 per region."""
    cluster = build_cluster(caps_prices)
    path = cluster.region_names()
    free = sum(cluster.free_gpus[r] for r in path)
    if g < len(path) or g > free:
        return
    alloc = cost_min_allocate(cluster, path, g)
    got = sum(cluster.price(r) * n for r, n in alloc.items())

    # exchange argument: no single GPU can move to a cheaper region
    for src in path:
        for dst in path:
            if src == dst or alloc[src] <= 1:
                continue
            if alloc[dst] >= cluster.free_gpus[dst]:
                continue
            moved = got - cluster.price(src) + cluster.price(dst)
            assert moved >= got - 1e-9, "a profitable single-GPU move exists"


@settings(max_examples=30, deadline=None)
@given(regions_st, jobs_st)
def test_pathfinder_never_breaks_comm_constraint(caps_prices, raw_jobs):
    cluster = build_cluster(caps_prices, bw=8.0)
    for prof in build_profiles(raw_jobs):
        placement = find_placement(prof, cluster)
        if placement is None:
            continue
        t_comp = prof.t_comp(placement.total_gpus)
        for t in placement.comm_times:
            assert t <= t_comp * (1 + 1e-9)
