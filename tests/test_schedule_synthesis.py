"""Schedule-synthesis suite: the per-topology search, the planner bugfixes
it would have inherited, and the timeline/property invariants over every
schedule.

Covers the synthesized-schedule contract (never loses to an op-graph
template, wins on comm-bound boundaries, honors the activation cap), the
``_chunk_times`` overhead-floor fix, the gpipe-overlap backward-egress
causality fix, the process-wide plan memo, and a hypothesis-optional
property sweep over random topologies (fixed cases always run).
"""

import dataclasses
import math

import pytest

from repro.core import (
    PIPELINE_SCHEDULES,
    BACEPipePolicy,
    JobProfile,
    PipelineTopology,
    clear_plan_cache,
    plan_cache_info,
    plan_from_topology,
    plan_schedule,
    simulate,
    topology_from_placement,
)
from repro.core.microplan.planner import _chunk_times
from repro.core.scenarios import SCENARIOS
from repro.core.timing import iteration_time

REL = 1e-9

#: Schedules whose timeline runs on the shared `_OpSim` resource model —
#: the family the synthesized search can never lose to.
OP_GRAPH_TEMPLATES = ("gpipe", "1f1b", "interleaved")


def uniform_topo(m, stages, t, hops=(), egress=(), overhead=0.0):
    return PipelineTopology(
        n_microbatches=m,
        stage_time_fwd=(t,) * stages,
        stage_time_bwd=(t,) * stages,
        boundaries=tuple(tuple(h) for h in hops),
        stage_overhead=overhead,
        egress=tuple(egress),
    )


#: Fixed topologies the property assertions always run on: compute-bound
#: (the admission regime), comm-bound (Eq. (6)'s violation window),
#: degenerate single-stage with and without egress, multi-hop boundaries,
#: and per-stage overhead.
FIXED_TOPOLOGIES = (
    uniform_topo(8, 4, 1.0, hops=[(0.6,), (0.9,), (0.3,)]),
    uniform_topo(8, 4, 1.0, hops=[(2.4,), (3.6,), (1.2,)]),
    uniform_topo(6, 3, 0.4, hops=[(0.1, 0.05), (0.4,)]),
    uniform_topo(12, 2, 0.2, hops=[(0.7,)]),
    uniform_topo(4, 1, 0.3),
    uniform_topo(4, 1, 0.3, egress=(0.2, 0.2)),
    uniform_topo(5, 3, 0.5, hops=[(1.5,), (0.01,)], overhead=0.05),
)


# ------------------------------------------------------------- synthesized
def test_synthesized_never_loses_to_op_graph_templates():
    for topo in FIXED_TOPOLOGIES:
        synth = plan_from_topology(topo, "synthesized")
        for schedule in OP_GRAPH_TEMPLATES:
            tmpl = plan_from_topology(topo, schedule)
            assert synth.iteration_time <= tmpl.iteration_time * (1 + REL), (
                schedule,
                topo,
            )


def test_synthesized_beats_all_templates_on_comm_bound_topology():
    # The acceptance regime: hops several times the compute pair, where the
    # capped 1f1b warmup degrades toward GPipe's serialized halves but the
    # search keeps both directions of the full-duplex link busy.
    topo = uniform_topo(8, 4, 1.0, hops=[(2.4,), (3.6,), (1.2,)])
    synth = plan_from_topology(topo, "synthesized")
    best_time = math.inf
    best_peak = math.inf
    for schedule in PIPELINE_SCHEDULES:
        if schedule == "synthesized":
            continue
        plan = plan_from_topology(topo, schedule)
        if plan.iteration_time < best_time:
            best_time = plan.iteration_time
            best_peak = plan.peak_activations
    assert synth.iteration_time < best_time * (1 - 1e-6)
    assert synth.peak_activations <= best_peak + 1e-9


def test_synthesized_ties_gpipe_on_compute_bound_topology():
    # In the admission regime (every hop <= t_comp) GPipe already meets the
    # op-model makespan lower bound, so the search must tie it exactly —
    # any "win" here would mean the simulator model drifted.
    topo = uniform_topo(8, 4, 1.0, hops=[(0.6,), (0.9,), (0.3,)])
    synth = plan_from_topology(topo, "synthesized")
    gp = plan_from_topology(topo, "gpipe")
    assert math.isclose(synth.iteration_time, gp.iteration_time, rel_tol=REL)
    assert synth.peak_activations <= gp.peak_activations


def test_synthesized_activation_cap_respected_and_monotone():
    topo = uniform_topo(8, 4, 1.0, hops=[(2.4,), (3.6,), (1.2,)])
    uncapped = plan_from_topology(topo, "synthesized")
    prev_time = None
    for cap in (8.0, 4.0, 2.0, 1.0):
        plan = plan_from_topology(topo, "synthesized", activation_cap=cap)
        assert plan.peak_activations <= cap + 1e-9
        assert plan.iteration_time >= uncapped.iteration_time - 1e-9
        if prev_time is not None:
            # Tightening the cap can only cost time.
            assert plan.iteration_time >= prev_time - 1e-9
        prev_time = plan.iteration_time


def test_synthesized_single_stage_and_egress():
    plain = uniform_topo(4, 1, 0.3)
    synth = plan_from_topology(plain, "synthesized")
    gp = plan_from_topology(plain, "gpipe")
    assert synth.iteration_time <= gp.iteration_time * (1 + REL)
    # With egress hops, a cap of 1 forces the alternating order, which
    # stalls on the round trip — strictly slower, but within the cap.
    hop = uniform_topo(4, 1, 0.3, egress=(0.2, 0.2))
    free = plan_from_topology(hop, "synthesized")
    capped = plan_from_topology(hop, "synthesized", activation_cap=1.0)
    assert capped.peak_activations <= 1.0 + 1e-9
    assert capped.iteration_time >= free.iteration_time - 1e-9


def test_synthesized_is_deterministic():
    topo = uniform_topo(8, 4, 1.0, hops=[(2.4,), (3.6,), (1.2,)])
    a = plan_from_topology(topo, "synthesized", keep_events=True)
    b = plan_from_topology(topo, "synthesized", keep_events=True)
    assert a.iteration_time == b.iteration_time
    assert a.events == b.events


def test_activation_cap_validation():
    topo = uniform_topo(4, 2, 0.5, hops=[(0.1,)])
    with pytest.raises(ValueError, match="activation_cap"):
        plan_from_topology(topo, "gpipe", activation_cap=4.0)
    with pytest.raises(ValueError, match="activation_cap"):
        plan_from_topology(topo, "synthesized", activation_cap=0.5)


def test_timing_seam_prices_synthesized(static_placements):
    prof, placement = static_placements[0]
    spec = dataclasses.replace(
        prof.spec, timing_model="microplan", pipeline_schedule="synthesized"
    )
    mp = JobProfile(spec, gpu_flops=prof.gpu_flops)
    expect = plan_schedule(mp, placement, "synthesized").iteration_time
    assert iteration_time(mp, placement) == expect
    gp = plan_schedule(mp, placement, "gpipe").iteration_time
    assert expect <= gp * (1 + REL)


# ------------------------------------------- bugfix: _chunk_times overhead
def test_chunk_times_floor_and_continuity():
    # Regression: the old split priced a chunk at t/v once t <= overhead,
    # dropping below the fixed per-kernel cost with a jump at t == overhead.
    oh = 0.3
    for v in (2, 4):
        below = _chunk_times([oh - 1e-9], oh, v)[0]
        at = _chunk_times([oh], oh, v)[0]
        above = _chunk_times([oh + 1e-9], oh, v)[0]
        # Every chunk re-pays the overhead floor.
        assert below >= oh - 1e-12
        assert at == pytest.approx(oh)
        # Continuity across the boundary.
        assert abs(at - below) < 1e-8
        assert abs(above - at) < 1e-8
    # Zero overhead is a plain even split.
    assert _chunk_times([1.0], 0.0, 4) == [0.25]


def test_chunk_times_monotone_in_stage_time():
    oh = 0.2
    times = [oh * f for f in (0.25, 0.5, 1.0, 1.5, 3.0)]
    chunks = [_chunk_times([t], oh, 2)[0] for t in times]
    assert all(b >= a - 1e-12 for a, b in zip(chunks, chunks[1:]))
    assert all(c >= oh - 1e-12 for c in chunks)


def test_interleaved_never_prices_chunk_below_overhead():
    # Public-surface version of the regression: a stage time equal to the
    # overhead must still pay v overhead floors per stage pass, so the
    # interleaved plan cannot undercut the un-chunked gpipe plan.
    topo = uniform_topo(6, 3, 0.3, hops=[(0.01,), (0.01,)], overhead=0.3)
    il = plan_from_topology(topo, "interleaved", virtual_stages=2)
    gp = plan_from_topology(topo, "gpipe")
    assert il.iteration_time >= gp.iteration_time - 1e-9


# ----------------------------- bugfix: gpipe-overlap backward-egress anchor
def test_overlap_egress_ingress_causality():
    # Regression: the backward half used to anchor at the forward half's
    # midpoint unconditionally, rendering the first gradient ingress
    # *before* that microbatch's own forward egress had left the hops
    # whenever t_f + sum(egress) > delta.
    topo = uniform_topo(4, 1, 0.3, egress=(0.1, 0.1))
    plan = plan_from_topology(topo, "gpipe-overlap", keep_events=True)
    for m in range(topo.n_microbatches):
        fwd_out = [
            e for e in plan.events
            if e.kind == "fwd_comm" and e.microbatch == m
        ]
        ingress = [
            e for e in plan.events
            if e.kind == "bwd_comm" and e.microbatch == m
        ]
        bwd = [
            e for e in plan.events
            if e.kind == "bwd" and e.microbatch == m
        ]
        assert fwd_out and ingress and bwd
        # The gradient cannot enter the return hops before the forward
        # egress chain has fully drained...
        assert min(e.start for e in ingress) >= (
            max(e.end for e in fwd_out) - 1e-12
        )
        # ...and must have arrived before the backward compute starts.
        assert max(e.end for e in ingress) <= bwd[0].start + 1e-12


def test_overlap_egress_events_stay_within_makespan():
    # The causal shift must not leak past the lockstep makespan
    # (t_f + t_b <= 2*delta keeps the drained tail inside it).
    for egress in ((0.1, 0.1), (0.25,), (0.3, 0.15)):
        topo = uniform_topo(4, 1, 0.3, egress=egress)
        plan = plan_from_topology(topo, "gpipe-overlap", keep_events=True)
        for e in plan.events:
            assert -1e-12 <= e.start <= e.end <= plan.iteration_time + 1e-12


# --------------------------------------------------- timeline invariants
def _resource_of(event):
    """The serially-reused resource an event occupies (mirrors the builder
    naming: stage compute is shared by both directions, each boundary hop
    is full-duplex, interleaved wrap paths are dedicated per direction)."""
    if event.kind in ("fwd", "bwd"):
        return ("S", event.stage)
    if event.kind == "fwd_comm":
        return ("F", event.stage, event.hop)
    if event.kind == "bwd_comm":
        return ("B", event.stage, event.hop)
    if event.kind == "wrap_fwd":
        return ("WF", event.hop)
    if event.kind == "wrap_bwd":
        return ("WB", event.hop)
    raise AssertionError(f"unknown event kind {event.kind!r}")


@pytest.mark.parametrize("schedule", PIPELINE_SCHEDULES)
def test_timeline_invariants_per_schedule(schedule):
    """Per-resource event intervals never overlap and every dependency
    finishes before its consumer starts, for every schedule on every fixed
    topology (the executability contract the synthesizer builds on)."""
    for topo in FIXED_TOPOLOGIES:
        plan = plan_from_topology(topo, schedule, keep_events=True)
        by_resource = {}
        for e in plan.events:
            assert e.end >= e.start >= -1e-12
            by_resource.setdefault(_resource_of(e), []).append(e)
        for res, events in by_resource.items():
            events.sort(key=lambda e: (e.start, e.end))
            for a, b in zip(events, events[1:]):
                assert b.start >= a.end - 1e-9, (
                    f"{schedule}: overlap on {res}: {a} vs {b}"
                )
        for prod, cons in plan.edges:
            assert (
                plan.events[cons].start >= plan.events[prod].end - 1e-9
            ), f"{schedule}: dep violated: {prod} -> {cons}"
        # Op-graph schedules materialize their dependency edges.
        if schedule != "gpipe-overlap" and topo.n_stages > 1:
            assert plan.edges


# ------------------------------------------------------- plan memoization
@pytest.fixture(scope="module")
def static_placements():
    scen = SCENARIOS["static-paper"]
    cluster, profiles, _ = scen.build(seed=0, n_jobs=4)
    res = simulate(cluster, profiles, BACEPipePolicy())
    profs = {p.spec.job_id: p for p in profiles}
    return [(profs[r.job_id], r.placement) for r in res.completed_records]


def test_plan_cache_is_process_wide_not_lru(static_placements):
    """Regression for the 256-entry LRU: a working set larger than 256
    distinct plan keys must still be fully served from the memo on its
    second pass (the old cache evicted every entry before re-use)."""
    clear_plan_cache()
    # virtual_stages is part of the memo key even where it does not change
    # the plan, so the lockstep schedule (closed-form, microseconds per
    # plan) spans a >256-key working set without op-sim cost; a handful of
    # op-graph keys ride along for realism.
    keys = [
        (prof, placement, "gpipe-overlap", v)
        for prof, placement in static_placements
        for v in range(1, 81)
    ] + [
        (prof, placement, schedule, 1)
        for prof, placement in static_placements
        for schedule in ("gpipe", "1f1b")
    ]
    assert len(keys) > 256
    for prof, placement, schedule, v in keys:
        plan_schedule(prof, placement, schedule, virtual_stages=v)
    info = plan_cache_info()
    assert info.hits == 0
    assert info.misses == len(keys)
    assert info.size == len(keys)
    for prof, placement, schedule, v in keys:
        plan_schedule(prof, placement, schedule, virtual_stages=v)
    info = plan_cache_info()
    assert info.hits == len(keys), (
        f"second pass missed {len(keys) - info.hits} of {len(keys)} plans"
    )
    assert info.misses == len(keys)
    clear_plan_cache()
    assert plan_cache_info() == (0, 0, 0)


def test_plan_cache_keeps_keep_events_uncached(static_placements):
    clear_plan_cache()
    prof, placement = static_placements[0]
    plan_schedule(prof, placement, "gpipe", keep_events=True)
    assert plan_cache_info().size == 0
    clear_plan_cache()


# ------------------------------------------------------------ wan_stretch
def test_wan_stretch_scales_only_inter_region_hops(static_placements):
    cross = [
        (prof, placement)
        for prof, placement in static_placements
        if len(set(placement.stage_regions())) > 1
    ]
    assert cross, "static-paper seed 0 should place at least one job " \
        "across regions"
    for prof, placement in cross:
        base = topology_from_placement(prof, placement)
        stretched = topology_from_placement(prof, placement, wan_stretch=4.0)
        saw_wan = False
        for h1, h4 in zip(base.all_hops, stretched.all_hops):
            if math.isclose(h4, 4.0 * h1, rel_tol=REL):
                saw_wan = True
            else:
                assert math.isclose(h4, h1, rel_tol=REL)
        assert saw_wan
    prof, placement = static_placements[0]
    with pytest.raises(ValueError, match="wan_stretch"):
        topology_from_placement(prof, placement, wan_stretch=0.0)


# --------------------------------------------------- hypothesis widening
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=12),
        stages=st.integers(min_value=1, max_value=5),
        t=st.floats(min_value=1e-3, max_value=1.0),
        hop_scale=st.floats(min_value=0.0, max_value=5.0),
        cap=st.one_of(st.none(), st.integers(min_value=1, max_value=12)),
        data=st.data(),
    )
    def test_hypothesis_all_schedules_execute_and_order(
        m, stages, t, hop_scale, cap, data
    ):
        hops = tuple(
            tuple(
                data.draw(
                    st.floats(
                        min_value=0.0, max_value=max(hop_scale * t, 1e-9)
                    )
                )
                for _ in range(data.draw(st.integers(1, 2)))
            )
            for _ in range(stages - 1)
        )
        topo = uniform_topo(m, stages, t, hops=hops)
        # Every schedule executes without an _OpSim deadlock.
        plans = {
            s: plan_from_topology(topo, s) for s in PIPELINE_SCHEDULES
        }
        gp, ofb = plans["gpipe"], plans["1f1b"]
        assert ofb.iteration_time <= gp.iteration_time * (1 + 1e-9)
        best_op_graph = min(
            plans[s].iteration_time for s in OP_GRAPH_TEMPLATES
        )
        synth = plans["synthesized"]
        assert synth.iteration_time <= best_op_graph * (1 + 1e-9)
        assert synth.peak_activations <= gp.peak_activations + 1e-9
        if cap is not None:
            capped = plan_from_topology(
                topo, "synthesized", activation_cap=float(cap)
            )
            assert capped.peak_activations <= cap + 1e-9
            assert capped.iteration_time >= synth.iteration_time - 1e-9

except ImportError:  # hypothesis is a dev extra; fixed cases always run
    pass
