"""Shared benchmark harness utilities.

Every benchmark prints ``name,us_per_call,derived`` CSV rows: ``us_per_call``
is the wall-clock cost of one full scheduling simulation (the control-plane
operation a cloud operator runs online), ``derived`` carries the
paper-comparable metric for that row.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable, Dict, List, Sequence

from repro.core import (
    BACEPipePolicy,
    CRLCFPolicy,
    CRLDFPolicy,
    JobProfile,
    LCFPolicy,
    LDFPolicy,
    SchedulingPolicy,
    SimulationResult,
    simulate,
)
from repro.core.ablations import WithoutCostMin, WithoutPathfinder, WithoutPriority
from repro.core.job import JobProfile as _JP
from repro.core.workloads import paper_cluster, paper_jobs

#: Effective per-GPU throughput for all paper-figure benchmarks.  See
#: DESIGN.md "assumptions changed": the paper's own Fig. 1 arithmetic implies
#: accelerator-class effective FLOP/s well above an A6000's dense bf16 peak.
BENCH_GPU_FLOPS = 300e12

POLICY_FACTORIES: Dict[str, Callable[[], SchedulingPolicy]] = {
    "bace-pipe": BACEPipePolicy,
    "ldf": LDFPolicy,
    "lcf": LCFPolicy,
    "cr-lcf": CRLCFPolicy,
    "cr-ldf": CRLDFPolicy,
}

ABLATION_FACTORIES: Dict[str, Callable[[], SchedulingPolicy]] = {
    "bace-pipe": BACEPipePolicy,
    "wo-priority": WithoutPriority,
    "wo-pathfinder": WithoutPathfinder,
    "wo-costmin": WithoutCostMin,
}


def build_profiles(seed: int, n_jobs: int = 8) -> List[JobProfile]:
    return [
        _JP(j, gpu_flops=BENCH_GPU_FLOPS)
        for j in paper_jobs(seed=seed, n_jobs=n_jobs)
    ]


def run_policy_suite(
    factories: Dict[str, Callable[[], SchedulingPolicy]],
    *,
    seeds: Sequence[int] = range(5),
    n_jobs: int = 8,
    bandwidth_factor: float = 1.0,
    capacity_factor: float = 1.0,
) -> Dict[str, Dict[str, float]]:
    """Mean avg-JCT / total-cost per policy over seeds, plus sim latency."""
    out: Dict[str, Dict[str, float]] = {}
    for name, factory in factories.items():
        jcts, costs, laps = [], [], []
        for seed in seeds:
            cluster = paper_cluster(
                bandwidth_factor=bandwidth_factor,
                capacity_factor=capacity_factor,
            )
            profiles = build_profiles(seed, n_jobs)
            t0 = time.perf_counter()
            res: SimulationResult = simulate(cluster, profiles, factory())
            laps.append(time.perf_counter() - t0)
            jcts.append(res.average_jct)
            costs.append(res.total_cost)
        out[name] = {
            "avg_jct_s": statistics.mean(jcts),
            "total_cost": statistics.mean(costs),
            "us_per_call": 1e6 * statistics.mean(laps),
        }
    return out


def emit_rows(
    table: str,
    suite: Dict[str, Dict[str, float]],
    *,
    baseline: str = "bace-pipe",
) -> List[str]:
    """CSV rows normalized to BACE-Pipe (the paper's Fig. 4 convention)."""
    rows = []
    base = suite[baseline]
    for name, m in suite.items():
        jct_ratio = m["avg_jct_s"] / base["avg_jct_s"]
        cost_ratio = m["total_cost"] / base["total_cost"]
        rows.append(
            f"{table}/{name},{m['us_per_call']:.1f},"
            f"jct_h={m['avg_jct_s'] / 3600:.3f};jct_ratio={jct_ratio:.3f};"
            f"cost=${m['total_cost']:.2f};cost_ratio={cost_ratio:.3f}"
        )
    return rows


def check_claim(
    label: str, actual_pct: float, lo: float, hi: float, slack: float = 0.5
) -> str:
    """Compare an observed overhead (%) against the paper's claimed band.
    ``slack`` widens the band fractionally before judging (simulator
    constants the paper does not publish make exact replication impossible —
    see EXPERIMENTS.md)."""
    lo_s, hi_s = lo * (1 - slack), hi * (1 + slack)
    if lo <= actual_pct <= hi:
        verdict = "MATCH"
    elif lo_s <= actual_pct <= hi_s:
        verdict = "NEAR"
    elif actual_pct > 0:
        verdict = "DIRECTIONAL"
    else:
        verdict = "MISMATCH"
    return (
        f"# claim {label}: paper [{lo:+.1f}%, {hi:+.1f}%], "
        f"observed {actual_pct:+.1f}% -> {verdict}"
    )
