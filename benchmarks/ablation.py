"""Fig. 8 ablation: remove Priority / Pathfinder / Cost-Min one at a time.

Paper claims (vs full BACE-Pipe):
  * w/o Pathfinder: +52.5% JCT, +20.5% cost (the most critical component);
  * w/o Priority:   +41.9% JCT, +5.0% cost;
  * w/o Cost-Min:   +4.6% JCT, +13.9% cost.
"""

from __future__ import annotations

from typing import List

from .common import ABLATION_FACTORIES, check_claim, emit_rows, run_policy_suite


def run() -> List[str]:
    suite = run_policy_suite(ABLATION_FACTORIES)
    rows = emit_rows("fig8", suite)
    base_j = suite["bace-pipe"]["avg_jct_s"]
    base_c = suite["bace-pipe"]["total_cost"]

    def over(name, field, base):
        return 100.0 * (suite[name][field] / base - 1.0)

    rows.append(check_claim("w/o Pathfinder JCT", over("wo-pathfinder", "avg_jct_s", base_j), 52.5, 52.5))
    rows.append(check_claim("w/o Pathfinder cost", over("wo-pathfinder", "total_cost", base_c), 20.5, 20.5))
    rows.append(check_claim("w/o Priority JCT", over("wo-priority", "avg_jct_s", base_j), 41.9, 41.9))
    rows.append(check_claim("w/o Priority cost", over("wo-priority", "total_cost", base_c), 5.0, 5.0))
    rows.append(check_claim("w/o Cost-Min JCT", over("wo-costmin", "avg_jct_s", base_j), 4.6, 4.6))
    rows.append(check_claim("w/o Cost-Min cost", over("wo-costmin", "total_cost", base_c), 13.9, 13.9))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
