"""Fig. 6 GPU-capacity sensitivity: scale regional pools by 0.5/0.75/1.25x.

Paper claims:
  * 0.5x: baseline JCT inflation 32.2–69.9% (CR worst ~70%); cost +24.1–42.5%;
  * 1.25x: gaps narrow — JCT +5.5–20.7%, cost +0.2–9.4%.
"""

from __future__ import annotations

from typing import List

from .common import POLICY_FACTORIES, check_claim, emit_rows, run_policy_suite


def run() -> List[str]:
    rows: List[str] = []
    for factor in (0.5, 0.75, 1.25):
        suite = run_policy_suite(POLICY_FACTORIES, capacity_factor=factor)
        rows.extend(emit_rows(f"fig6/cap{factor:g}x", suite))
        base_j = suite["bace-pipe"]["avg_jct_s"]
        base_c = suite["bace-pipe"]["total_cost"]
        over_j = [
            100.0 * (m["avg_jct_s"] / base_j - 1.0)
            for n, m in suite.items()
            if n != "bace-pipe"
        ]
        over_c = [
            100.0 * (m["total_cost"] / base_c - 1.0)
            for n, m in suite.items()
            if n != "bace-pipe"
        ]
        if factor == 0.5:
            rows.append(check_claim("0.5x JCT inflation", max(over_j), 32.2, 69.9))
            rows.append(check_claim("0.5x cost inflation", max(over_c), 24.1, 42.5))
        if factor == 1.25:
            rows.append(check_claim("1.25x JCT inflation", max(over_j), 5.5, 20.7))
            rows.append(check_claim("1.25x cost inflation", max(over_c), 0.2, 9.4))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
