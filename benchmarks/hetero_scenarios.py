"""Heterogeneous-fleet + spot-capacity scenario sweep.

Runs the typed-pool scenarios (``hetero-fleet``, ``spot-churn`` — the ones
``benchmarks/dynamic_scenarios.py`` deliberately skips) across all five
policies and emits the usual ``name,us_per_call,derived`` CSV rows.  Every
cell asserts:

* determinism — the same seed twice yields byte-identical
  ``SimulationResult``\\ s (the contract the golden traces pin elsewhere);
* the piecewise-accounting invariants (segment costs non-negative and
  partitioning the per-job totals);
* the typed-grant invariants — every placement on a typed cluster carries a
  ``typed_alloc`` that partitions its per-region counts, and forced
  spot-reclaim evictions never appear on the reclaim-free scenario.

Two headline acceptance gates run at the registry's default seed (the
surface the scenarios were tuned for; other seeds just report):

* **spot beats on-demand**: BACE-Pipe on the spot fleet — reclaim churn,
  restart penalties and all — lands strictly cheaper than the same job set
  on the all-on-demand Table II cluster;
* **hetero-fleet JCT**: BACE-Pipe's average JCT is the minimum across all
  policies (typed-aware Pathfinder + Cost-Min earn their keep when the
  fleet mixes generations).

``--out FILE`` writes the per-cell metrics as JSON; the checked-in
``BENCH_hetero.json`` (generated with ``--smoke --out``) is the baseline
``scripts/bench_compare.py --metrics`` gates against in CI — the metrics
are deterministic, so any drift is a semantic regression, not noise.

Usage:
    PYTHONPATH=src python -m benchmarks.hetero_scenarios [--smoke]
                                                         [--seed N]
                                                         [--out FILE]
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List, Optional, Tuple

from repro.core import BACEPipePolicy, SCENARIOS, SimulationResult, simulate
from repro.core.workloads import paper_cluster

from .common import BENCH_GPU_FLOPS, POLICY_FACTORIES
from .dynamic_scenarios import assert_cost_invariants

#: Smoke-mode job count (CI-sized, ~seconds).
SMOKE_N_JOBS = 6


def assert_typed_invariants(
    res: SimulationResult, cell: str, *, expect_spot_evictions: bool
) -> None:
    """Typed-grant invariants every heterogeneous simulation must satisfy."""
    for rec in res.records:
        typed = rec.placement.typed_alloc
        if not typed:
            raise AssertionError(
                f"untyped placement on a typed cluster in {cell}: "
                f"{rec.placement.describe()}"
            )
        for region, n in rec.placement.alloc.items():
            if sum(typed.get(region, {}).values()) != n:
                raise AssertionError(
                    f"typed grant does not partition alloc[{region}] "
                    f"in {cell}"
                )
    if not expect_spot_evictions and res.forced_migrations:
        raise AssertionError(
            f"forced evictions on a reclaim-free scenario in {cell}: "
            f"{res.forced_migrations}"
        )


def run(
    *, smoke: bool = False, seed: int = 0, out: Optional[str] = None
) -> List[str]:
    rows: List[str] = []
    cells: List[Dict] = []
    pk = {"gpu_flops": BENCH_GPU_FLOPS}
    n_jobs = SMOKE_N_JOBS if smoke else None
    results: Dict[Tuple[str, str], SimulationResult] = {}
    for scen_name, scenario in SCENARIOS.items():
        if not scenario.hetero:
            continue
        for pol_name, factory in POLICY_FACTORIES.items():
            t0 = time.perf_counter()
            res = scenario.run(
                factory(), seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            lap = time.perf_counter() - t0
            rerun = scenario.run(
                factory(), seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            if res.to_jsonable() != rerun.to_jsonable():
                raise AssertionError(
                    f"non-deterministic result: {scen_name}/{pol_name} "
                    f"(seed={seed})"
                )
            cell = f"{scen_name}/{pol_name}"
            assert_cost_invariants(res, cell)
            assert_typed_invariants(
                res, cell, expect_spot_evictions=scenario.dynamic
            )
            results[(scen_name, pol_name)] = res
            rows.append(
                f"hetero/{cell},{1e6 * lap:.1f},"
                f"jct_h={res.average_jct / 3600:.3f};"
                f"cost=${res.total_cost:.2f};"
                f"migrations={res.total_migrations};"
                f"stall_h={res.total_stall_seconds / 3600:.3f}"
            )
            cells.append(
                {
                    "name": cell,
                    "us_per_call": 1e6 * lap,
                    "jct_s": res.average_jct,
                    "cost": res.total_cost,
                    "migrations": res.total_migrations,
                }
            )

    # ---- acceptance gate 1: spot pricing beats the on-demand-only fleet.
    # Same jobs, same Table II capacities/links — one fleet carries 40%
    # discounted-but-reclaimable spot capacity (churn included), the other
    # is all on-demand and churn-free.
    spot_scen = SCENARIOS["spot-churn"]
    cluster, profiles, trace = spot_scen.build(
        seed=seed, n_jobs=n_jobs, profile_kwargs=pk
    )
    on = simulate(
        cluster,
        profiles,
        BACEPipePolicy(),
        trace=trace,
        restart_penalty_s=spot_scen.restart_penalty_s,
    )
    off = simulate(paper_cluster(), profiles, BACEPipePolicy())
    if seed == 0 and not on.total_cost < off.total_cost:
        raise AssertionError(
            "BACE-Pipe on the spot fleet did not beat on-demand-only at "
            f"the default seed: ${on.total_cost:.2f} vs ${off.total_cost:.2f}"
        )
    rows.append(
        f"# spot-churn: spot fleet ${on.total_cost:.2f} "
        f"({on.total_migrations} reclaim evictions) vs on-demand-only "
        f"${off.total_cost:.2f}"
    )
    cells.append(
        {
            "name": "spot-churn/on-demand-counterfactual",
            "us_per_call": 0.0,
            "jct_s": off.average_jct,
            "cost": off.total_cost,
            "migrations": off.total_migrations,
        }
    )

    # ---- acceptance gate 2: on the mixed-generation fleet BACE-Pipe's
    # typed-aware Pathfinder + Cost-Min deliver the best average JCT.
    jcts = {
        pol: results[("hetero-fleet", pol)].average_jct
        for pol in POLICY_FACTORIES
    }
    best = min(jcts, key=jcts.get)
    if seed == 0 and best != "bace-pipe":
        raise AssertionError(
            f"BACE-Pipe lost the hetero-fleet JCT race to {best}: {jcts}"
        )
    rows.append(
        "# hetero-fleet: avg JCT "
        + ", ".join(f"{p}={t / 3600:.3f}h" for p, t in jcts.items())
    )

    if out is not None:
        payload = {
            "benchmark": "hetero_scenarios",
            "smoke": smoke,
            "seed": seed,
            "cells": cells,
        }
        with open(out, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
            fh.write("\n")
        rows.append(f"# wrote {len(cells)} cells to {out}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--out",
        default=None,
        help="write per-cell metrics JSON (bench_compare --metrics input)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke, seed=args.seed, out=args.out):
        print(row)


if __name__ == "__main__":
    main()
