"""Fig. 5 bandwidth sensitivity: scale inter-region links by 0.3/0.9/1.5x.

Paper claims:
  * 0.3x: LDF/CR-LDF JCT overheads ~+10.7%/+26.2%; cost advantage 29.2–34.9%;
  * 1.5x: baselines 42.9–240.3% longer JCT (CR-LDF collapse), cost +14.3–28.5%.
"""

from __future__ import annotations

from typing import List

from .common import POLICY_FACTORIES, check_claim, emit_rows, run_policy_suite


def run() -> List[str]:
    rows: List[str] = []
    for factor in (0.3, 0.9, 1.5):
        suite = run_policy_suite(POLICY_FACTORIES, bandwidth_factor=factor)
        rows.extend(emit_rows(f"fig5/bw{factor:g}x", suite))
        base_j = suite["bace-pipe"]["avg_jct_s"]
        base_c = suite["bace-pipe"]["total_cost"]
        over_j = [
            100.0 * (m["avg_jct_s"] / base_j - 1.0)
            for n, m in suite.items()
            if n != "bace-pipe"
        ]
        over_c = [
            100.0 * (m["total_cost"] / base_c - 1.0)
            for n, m in suite.items()
            if n != "bace-pipe"
        ]
        if factor == 0.3:
            rows.append(check_claim("0.3x JCT overheads", max(over_j), 10.7, 26.2))
            rows.append(check_claim("0.3x cost overheads", max(over_c), 29.2, 34.9))
        if factor == 1.5:
            rows.append(check_claim("1.5x JCT overheads", max(over_j), 42.9, 240.3))
            rows.append(check_claim("1.5x cost overheads", max(over_c), 14.3, 28.5))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
