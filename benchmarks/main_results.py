"""Fig. 4 main results: 8 jobs, 6 regions, BACE-Pipe vs 4 baselines.

Paper claims (normalized to BACE-Pipe):
  * baselines incur 27.9%–64.7% longer average JCT;
  * baselines incur 12.6%–30.6% higher total electricity cost;
  * cross-region paradox: CR-LDF/CR-LCF slower than LDF (+28.8% / +13.1%).
"""

from __future__ import annotations

from typing import List

from .common import POLICY_FACTORIES, check_claim, emit_rows, run_policy_suite


def run() -> List[str]:
    suite = run_policy_suite(POLICY_FACTORIES)
    rows = emit_rows("fig4", suite)
    base = suite["bace-pipe"]["avg_jct_s"]
    base_c = suite["bace-pipe"]["total_cost"]
    over_j = {
        n: 100.0 * (m["avg_jct_s"] / base - 1.0)
        for n, m in suite.items()
        if n != "bace-pipe"
    }
    over_c = {
        n: 100.0 * (m["total_cost"] / base_c - 1.0)
        for n, m in suite.items()
        if n != "bace-pipe"
    }
    rows.append(check_claim("baseline JCT overhead (min)", min(over_j.values()), 27.9, 64.7))
    rows.append(check_claim("baseline JCT overhead (max)", max(over_j.values()), 27.9, 64.7))
    rows.append(check_claim("baseline cost overhead (min)", min(over_c.values()), 12.6, 30.6))
    rows.append(check_claim("baseline cost overhead (max)", max(over_c.values()), 12.6, 30.6))
    # Cross-region paradox: CR-* slower than LDF.
    par_ldf = 100.0 * (suite["cr-ldf"]["avg_jct_s"] / suite["ldf"]["avg_jct_s"] - 1.0)
    par_lcf = 100.0 * (suite["cr-lcf"]["avg_jct_s"] / suite["ldf"]["avg_jct_s"] - 1.0)
    rows.append(check_claim("paradox CR-LDF vs LDF", par_ldf, 28.8, 28.8))
    rows.append(check_claim("paradox CR-LCF vs LDF", par_lcf, 13.1, 13.1))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
