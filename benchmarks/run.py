"""Benchmark driver: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [figure ...]

Prints ``name,us_per_call,derived`` CSV rows plus ``# claim`` verdict lines
comparing observed ratios against the paper's published numbers.  The
roofline benchmark additionally reads the dry-run artifact directory when
present (see launch/dryrun.py).
"""

from __future__ import annotations

import sys


def main() -> None:
    from . import (
        ablation,
        dynamic_scenarios,
        hetero_scenarios,
        main_results,
        motivation,
        schedule_ablation,
        scheduler_scaling,
        sensitivity_bandwidth,
        sensitivity_capacity,
        workload_intensity,
    )

    figures = {
        "motivation": motivation.run,        # Fig. 1
        "main_results": main_results.run,    # Fig. 4
        "sensitivity_bandwidth": sensitivity_bandwidth.run,  # Fig. 5
        "sensitivity_capacity": sensitivity_capacity.run,    # Fig. 6
        "workload_intensity": workload_intensity.run,        # Fig. 7
        "ablation": ablation.run,            # Fig. 8
        # Engine perf trajectory: quick smoke via the driver; the full sweep
        # (python -m benchmarks.scheduler_scaling) is what (re)writes the
        # BENCH_scheduler.json baseline that scripts/bench_compare.py gates on
        # — the driver must not silently clobber it.
        "scheduler_scaling": lambda: scheduler_scaling.run(quick=True),
        # Dynamic-environment regimes (PR 2): scenario registry × policies.
        "dynamic_scenarios": lambda: dynamic_scenarios.run(smoke=True),
        # Microbatch schedule ablation (microplan timing backend): quick
        # smoke via the driver; the full sweep (python -m
        # benchmarks.schedule_ablation) (re)writes BENCH_schedules.json.
        "schedule_ablation": lambda: schedule_ablation.run(smoke=True),
        # Typed GPU pools: mixed accelerator generations + spot reclaim
        # churn (the scenarios dynamic_scenarios skips).  The --smoke --out
        # invocation is what (re)writes the BENCH_hetero.json metrics
        # baseline CI gates on — the driver must not clobber it.
        "hetero_scenarios": lambda: hetero_scenarios.run(smoke=True),
    }
    try:
        from . import roofline

        figures["roofline"] = roofline.run
    except ImportError:
        pass

    wanted = sys.argv[1:] or list(figures)
    print("name,us_per_call,derived")
    for key in wanted:
        if key not in figures:
            print(f"# unknown figure '{key}' (have: {', '.join(figures)})")
            continue
        for row in figures[key]():
            print(row)


if __name__ == "__main__":
    main()
