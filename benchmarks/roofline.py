"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) cell:
  compute term    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective term = collective_bytes_per_device / link_bw      (50 GB/s ICI)

plus MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs (remat/redundancy waste).

Reads artifacts/dryrun/*.json written by launch/dryrun.py.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES_BY_NAME, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def model_flops_per_device(arch: str, shape: str, n_chips: int) -> float:
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    n = cfg.active_param_count() if cfg.family == "moe" else cfg.param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        total = 6.0 * n * tokens
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n * cell.global_batch
    if cfg.family == "encdec" and cell.kind != "decode":
        total *= 1.0  # enc+dec both counted in param_count already
    return total / n_chips


def analyse_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or rec.get("flops") is None:
        return None
    n_chips = {"16x16": 256, "2x16x16": 512, "2x2": 4, "2x2x2": 8}.get(
        rec["mesh"], 256
    )
    coll = sum(rec.get("collective_bytes", {}).values())
    t_comp = rec["flops"] / PEAK_FLOPS
    t_mem = (rec.get("bytes_accessed") or 0.0) / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], n_chips)
    useful = mf / rec["flops"] if rec["flops"] else 0.0
    # roofline fraction: useful compute time over the modeled step time
    bound = max(terms.values())
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    suggestions = {
        "compute": "cut redundant FLOPs (remat policy, fused attention, "
                   "avoid replicated compute)",
        "memory": "reduce bytes touched (fuse elementwise chains, lower-"
                  "precision caches/activations, larger tiles)",
        "collective": "reshard to cut collective volume (sharding axis "
                      "choice, overlap or compress transfers)",
    }
    return {
        **{k: rec.get(k) for k in ("arch", "shape", "mesh", "multi_pod")},
        "flops": rec["flops"],
        "bytes": rec.get("bytes_accessed"),
        "coll_bytes": coll,
        "t_compute_s": t_comp,
        "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_frac": frac,
        "next_lever": suggestions[dominant],
        "temp_bytes": rec.get("temp_size_in_bytes"),
        "arg_bytes": rec.get("argument_size_in_bytes"),
    }


def load_all(art_dir: str = ART_DIR) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        a = analyse_record(rec)
        if a:
            out.append(a)
    return out


def run() -> List[str]:
    rows: List[str] = []
    cells = load_all()
    if not cells:
        return [f"# roofline: no dry-run artifacts under {ART_DIR} "
                "(run: python -m repro.launch.dryrun --all --both-meshes)"]
    for c in cells:
        tag = "mp" if c["multi_pod"] else "sp"
        rows.append(
            f"roofline/{c['arch']}/{c['shape']}/{tag},0.0,"
            f"t_comp={c['t_compute_s']:.4f}s;t_mem={c['t_memory_s']:.4f}s;"
            f"t_coll={c['t_collective_s']:.4f}s;dominant={c['dominant']};"
            f"useful={c['useful_ratio']:.3f};frac={c['roofline_frac']:.3f}"
        )
    sp = [c for c in cells if not c["multi_pod"]]
    if sp:
        worst = min(sp, key=lambda c: c["roofline_frac"])
        coll_bound = [c for c in sp if c["dominant"] == "collective"]
        rows.append(
            f"# worst roofline fraction: {worst['arch']}/{worst['shape']} "
            f"({worst['roofline_frac']:.3f})"
        )
        rows.append(f"# collective-bound cells: "
                    f"{[(c['arch'], c['shape']) for c in coll_bound]}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
