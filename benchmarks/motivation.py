"""Fig. 1 motivation example: 4 regions (A–D), jobs P (14B) then Q (70B).

Validates the structural claims of the paper's motivating example:
  * LCF/LDF (single-region, FCFS) are slowest;
  * cross-region aggregation under FCFS order improves JCT;
  * BACE-Pipe's re-ordering (Q first onto the fat A–C link) is fastest and
    cheapest-or-tied (paper: 0.75 h / $0.52 vs 1.50 h / $0.53 for LCF).
"""

from __future__ import annotations

import time
from typing import List

from repro.core import (
    BACEPipePolicy,
    LCFPolicy,
    LDFPolicy,
    simulate,
)
from repro.core.ablations import WithoutPriority
from repro.core.workloads import motivation_cluster, motivation_profiles


def run() -> List[str]:
    rows: List[str] = []
    ordering = {}
    for policy in (
        LCFPolicy(),
        LDFPolicy(),
        WithoutPriority(),   # "Ours (FCFS)" in Fig. 1
        BACEPipePolicy(),    # "Ours (Reordered)"
    ):
        cluster = motivation_cluster()
        profiles = motivation_profiles()
        t0 = time.perf_counter()
        res = simulate(cluster, profiles, policy)
        us = 1e6 * (time.perf_counter() - t0)
        label = {
            "bace-pipe": "ours-reordered",
            "bace-pipe-wo-priority": "ours-fcfs",
        }.get(res.policy, res.policy)
        ordering[label] = res.average_jct
        placements = " | ".join(
            f"{r.model_name.split('-')[0]}:{r.placement.describe()}"
            for r in res.records
        )
        rows.append(
            f"motivation/{label},{us:.1f},"
            f"jct_h={res.average_jct / 3600:.3f};cost=${res.total_cost:.3f};"
            f"place={placements}"
        )
    # Structural check: reordered <= fcfs <= max(lcf, ldf)
    ok = (
        ordering["ours-reordered"] <= ordering["ours-fcfs"] + 1e-9
        and ordering["ours-fcfs"]
        <= max(ordering["lcf"], ordering["ldf"]) + 1e-9
    )
    rows.append(
        "# Fig.1 structural ordering (reordered <= fcfs <= single-region): "
        + ("MATCH" if ok else "MISMATCH")
    )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
