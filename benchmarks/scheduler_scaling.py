"""Scheduler scaling sweep: vectorized vs legacy engine (BENCH trajectory).

Sweeps the control-plane simulator over jobs ∈ {64, 256, 1024} × regions ∈
{9, 32, 64} with the BACE-Pipe policy, timing one full ``simulate()`` per
(cell, engine).  ``us_per_call`` is wall-clock microseconds per *scheduled
job* — the online decision an operator's control plane makes at every
arrival/completion — so cells of different sizes are comparable.

Emits the usual CSV rows plus ``BENCH_scheduler.json`` at the repo root with
per-cell timings for both engines; ``scripts/bench_compare.py`` diffs two
such files and gates on regression.  The legacy engine is the seed
implementation preserved in ``repro.core.legacy`` (recompute-per-call
ordering, dict-ledger Prim pathfinding); per-cell makespans are asserted
identical across engines, so the speedup is measured on provably equivalent
work.

Usage:  PYTHONPATH=src python -m benchmarks.scheduler_scaling [--quick]
"""

from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Dict, List

from repro.core import BACEPipePolicy, ClusterState, Region, simulate
from repro.core.job import JobProfile
from repro.core.workloads import paper_jobs

from .common import BENCH_GPU_FLOPS

JOB_COUNTS = (64, 256, 1024)
REGION_COUNTS = (9, 32, 64)
QUICK_JOB_COUNTS = (64, 256)
QUICK_REGION_COUNTS = (9, 32)

#: Inter-arrival gap (s).  Short against multi-hour job runtimes, so the
#: pending queue builds toward the job count — the regime where the seed
#: engine's per-pass recomputation is quadratic-or-worse.
ARRIVAL_GAP_S = 60.0

# Deterministic region templates, cycled to the requested count (Table II
# flavor: heterogeneous pools, prices, and egress bandwidths).
_CAPACITIES = (64, 32, 128, 16, 96, 48, 80, 24, 112)
_PRICES = (0.251, 0.156, 0.288, 0.191, 0.222, 0.295, 0.173, 0.262, 0.208)
_GBPS = (50.0, 90.0, 30.0, 70.0, 50.0, 70.0, 100.0, 40.0, 60.0)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_scheduler.json"


def synth_cluster(n_regions: int) -> ClusterState:
    regions = [
        Region(
            name=f"r{i:02d}",
            gpu_capacity=_CAPACITIES[i % len(_CAPACITIES)],
            price_kwh=_PRICES[i % len(_PRICES)],
        )
        for i in range(n_regions)
    ]
    gbps = {r.name: _GBPS[i % len(_GBPS)] for i, r in enumerate(regions)}
    return ClusterState.from_region_bandwidths(regions, gbps)


def synth_profiles(n_jobs: int) -> List[JobProfile]:
    jobs = paper_jobs(
        n_jobs=n_jobs,
        seed=0,
        submit_times=[i * ARRIVAL_GAP_S for i in range(n_jobs)],
    )
    return [JobProfile(j, gpu_flops=BENCH_GPU_FLOPS) for j in jobs]


def _time_cell(n_jobs: int, n_regions: int, engine: str) -> Dict[str, float]:
    cluster = synth_cluster(n_regions)
    profiles = synth_profiles(n_jobs)
    t0 = time.perf_counter()
    res = simulate(cluster, profiles, BACEPipePolicy(), engine=engine)
    wall = time.perf_counter() - t0
    assert len(res.records) == n_jobs
    return {
        "jobs": n_jobs,
        "regions": n_regions,
        "engine": engine,
        "wall_s": wall,
        "us_per_call": 1e6 * wall / n_jobs,
        "makespan_s": res.makespan,
        "avg_jct_s": res.average_jct,
    }


def run(*, quick: bool = False) -> List[str]:
    job_counts = QUICK_JOB_COUNTS if quick else JOB_COUNTS
    region_counts = QUICK_REGION_COUNTS if quick else REGION_COUNTS
    rows: List[str] = []
    cells: List[Dict[str, float]] = []
    for n_jobs in job_counts:
        for n_regions in region_counts:
            vec = _time_cell(n_jobs, n_regions, "vectorized")
            leg = _time_cell(n_jobs, n_regions, "legacy")
            if vec["makespan_s"] != leg["makespan_s"]:
                raise AssertionError(
                    f"engine divergence at jobs={n_jobs} regions={n_regions}: "
                    f"{vec['makespan_s']} != {leg['makespan_s']}"
                )
            cells.extend([vec, leg])
            speedup = leg["us_per_call"] / vec["us_per_call"]
            for m in (vec, leg):
                rows.append(
                    f"scheduler_scaling/j{n_jobs}xr{n_regions}/{m['engine']},"
                    f"{m['us_per_call']:.1f},"
                    f"wall_s={m['wall_s']:.3f};speedup={speedup:.2f}"
                )
    if quick:
        # Quick mode is a smoke run: don't clobber the full-sweep baseline
        # that bench_compare gates against.
        rows.append(f"# quick mode: {BENCH_PATH.name} not written")
        return rows
    payload = {
        "benchmark": "scheduler_scaling",
        "policy": "bace-pipe",
        "us_per_call_definition": "1e6 * simulate_wall_s / n_jobs",
        "arrival_gap_s": ARRIVAL_GAP_S,
        "cells": cells,
    }
    big = [
        c
        for c in cells
        if c["jobs"] == max(job_counts) and c["regions"] == max(region_counts)
    ]
    if len(big) == 2:
        by_engine = {c["engine"]: c for c in big}
        payload["speedup_biggest_cell"] = (
            by_engine["legacy"]["us_per_call"]
            / by_engine["vectorized"]["us_per_call"]
        )
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    rows.append(f"# wrote {BENCH_PATH}")
    return rows


def main() -> None:
    quick = "--quick" in sys.argv[1:]
    print("name,us_per_call,derived")
    for row in run(quick=quick):
        print(row)


if __name__ == "__main__":
    main()
