"""Scheduler scaling sweep: engines × decision backends (BENCH trajectory).

Sweeps the control-plane simulator over jobs ∈ {64, 256, 1024} × regions ∈
{9, 32, 64} plus one large cell at 10 000 jobs × 256 regions with the
BACE-Pipe policy, timing one full ``Simulator.run()`` per (cell, engine,
backend, seed).  ``us_per_call`` is wall-clock microseconds per *scheduled
job* — the online decision an operator's control plane makes at every
arrival/completion — so cells of different sizes are comparable.  The timer
covers ``run()`` only: cluster/workload construction and ``Simulator``
setup (including the cluster snapshot) happen outside it.

Three variants are timed per cell:

- ``vectorized``/``numpy``  — incremental engine, numpy decision kernels;
- ``vectorized``/``jax``    — same engine, jitted kernels from
  ``core/kernels_decide`` (skipped when jax is not importable);
- ``legacy``/``numpy``      — the preserved seed implementation
  (``repro.core.legacy``), timed only up to 1024 jobs × 64 regions: its
  per-pass recomputation is quadratic-or-worse, so the 10k × 256 cell is
  intractable and recorded under ``skipped`` in the JSON instead.

Per-cell, per-seed makespans are asserted identical across every variant
run, so the speedups are measured on provably equivalent work.  ``--seeds
N`` repeats each cell over workload seeds 0..N-1 and reports the mean;
``--quick`` restricts the grid for CI smoke runs (and does not rewrite the
checked-in baseline).

A trailing *plan-cache probe* selects the microplan timing backend end to
end and asserts the process-wide schedule-plan memo's hit-rate floor
(``PLAN_CACHE_HIT_FLOOR``) over two identical back-to-back runs — the
regression guard for the bounded-LRU thrash that re-planned every topology
each decision round at fleet scale.

A trailing *trace-overhead probe* runs one cell twice — bare vs with a
``SimTraceRecorder`` attached — asserts the makespans identical (tracing
is observational), and gates the traced/untraced wall ratio at
``TRACE_OVERHEAD_CEILING``: the regression guard for recorder hooks
creeping into the hot decision path.

Emits the usual CSV rows plus ``BENCH_scheduler.json`` at the repo root;
``scripts/bench_compare.py`` diffs two such files and gates on regression.

Usage:  PYTHONPATH=src python -m benchmarks.scheduler_scaling
            [--quick] [--seeds N]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Tuple

from repro.core import (
    BACEPipePolicy,
    ClusterState,
    Region,
    Simulator,
    clear_plan_cache,
    jax_available,
    plan_cache_info,
)
from repro.core.job import JobProfile
from repro.core.workloads import paper_jobs
from repro.obs import SimTraceRecorder

from .common import BENCH_GPU_FLOPS

JOB_COUNTS = (64, 256, 1024)
REGION_COUNTS = (9, 32, 64)
QUICK_JOB_COUNTS = (64, 256)
QUICK_REGION_COUNTS = (9, 32)

#: The large-regime cell (jobs, regions) appended after the dense grid.
BIG_CELL = (10_000, 256)

#: Plan-memo probe (microplan timing backend): two identical back-to-back
#: simulations of one cell; the second pass re-prices topologies the first
#: already planned, so with a process-wide memo the overall hit rate has a
#: hard floor.  The old ``lru_cache(maxsize=256)`` failed exactly this at
#: the full probe size — its ~350 distinct topologies cycle through a
#: 256-slot LRU, evicting every entry before its re-use.
PLAN_CACHE_PROBE_QUICK = (256, 32)
PLAN_CACHE_PROBE_FULL = (1024, 64)
PLAN_CACHE_HIT_FLOOR = 0.75

#: Trace-overhead probe: the cell timed bare vs with a ``SimTraceRecorder``
#: attached, min-of-``TRACE_OVERHEAD_TRIALS`` walls each.  The traced wall
#: must stay within ``TRACE_OVERHEAD_CEILING``x of the untraced one — the
#: observed ratio at the default ``gauge_stride`` is ~1.2x, so a breach
#: means a recorder hook leaked real work onto the untraced path or a gauge
#: stopped being decimated.
TRACE_OVERHEAD_CELL = (256, 32)
TRACE_OVERHEAD_CEILING = 1.3
TRACE_OVERHEAD_TRIALS = 5

#: Largest (jobs, regions) the legacy seed engine is still timed at.  Above
#: this the cell is recorded under ``skipped`` in the JSON.
LEGACY_MAX_JOBS = 1024
LEGACY_MAX_REGIONS = 64

#: Inter-arrival gap (s).  Short against multi-hour job runtimes, so the
#: pending queue builds toward the job count — the regime where the seed
#: engine's per-pass recomputation is quadratic-or-worse.
ARRIVAL_GAP_S = 60.0

# Deterministic region templates, cycled to the requested count (Table II
# flavor: heterogeneous pools, prices, and egress bandwidths).
_CAPACITIES = (64, 32, 128, 16, 96, 48, 80, 24, 112)
_PRICES = (0.251, 0.156, 0.288, 0.191, 0.222, 0.295, 0.173, 0.262, 0.208)
_GBPS = (50.0, 90.0, 30.0, 70.0, 50.0, 70.0, 100.0, 40.0, 60.0)

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_scheduler.json"


def synth_cluster(n_regions: int) -> ClusterState:
    regions = [
        Region(
            name=f"r{i:03d}",
            gpu_capacity=_CAPACITIES[i % len(_CAPACITIES)],
            price_kwh=_PRICES[i % len(_PRICES)],
        )
        for i in range(n_regions)
    ]
    gbps = {r.name: _GBPS[i % len(_GBPS)] for i, r in enumerate(regions)}
    return ClusterState.from_region_bandwidths(regions, gbps)


def synth_profiles(
    n_jobs: int, seed: int = 0, **job_kwargs
) -> List[JobProfile]:
    """Deterministic workload; ``job_kwargs`` (e.g. ``timing_model``,
    ``pipeline_schedule``) pass through to every ``JobSpec``."""
    jobs = paper_jobs(
        n_jobs=n_jobs,
        seed=seed,
        submit_times=[i * ARRIVAL_GAP_S for i in range(n_jobs)],
        **job_kwargs,
    )
    return [JobProfile(j, gpu_flops=BENCH_GPU_FLOPS) for j in jobs]


def _warm_jax(n_regions: int) -> None:
    """Trigger jit compilation for this region count before any timed run.

    The jitted Prim kernel compiles per (region count, decay-table bucket),
    so invoke it once untimed for every distinct bucket the workload's
    model mix produces at this cluster size; the timed cell then measures
    steady-state dispatch, not one-off tracing.  (A tiny warm-up
    *simulation* would not do: on an empty cluster every job places via
    the numpy Phase 1 and the Prim kernel never runs.)"""
    import numpy as np

    from repro.core.kernels_decide import decay_table_len, prim_expand

    cluster = synth_cluster(n_regions)
    total = cluster.total_gpus()
    # find_placement compacts the frontier to the free-region subgraph,
    # padded to buckets of 32 (capped at the region count), so the kernel
    # is compiled per (padded shape, decay-table bucket) — warm them all.
    pads = sorted(
        {min(n_regions, p) for p in range(32, n_regions + 32, 32)}
        | {n_regions}
    )
    warmed = set()
    for prof in synth_profiles(8):
        k = max(prof.optimal_gpus(total), prof.min_gpus)
        table_len = decay_table_len(k)
        for pad in pads:
            if (table_len, pad) in warmed:
                continue
            warmed.add((table_len, pad))
            prim_expand(
                np.zeros((pad, pad)),
                np.ones(pad, dtype=cluster.free_vector().dtype),
                np.arange(pad, dtype=cluster.name_rank_vector().dtype),
                np.full(pad, prof.gpu_flops),
                prof.decay_table(table_len),
                prof.fwd_flops_per_microbatch,
                prof.stage_overhead,
                prof.spec.model.activation_bytes,
                k,
                backend="jax",
            )


def _time_cell(
    n_jobs: int,
    n_regions: int,
    engine: str,
    backend: str,
    seeds: Tuple[int, ...],
) -> Dict[str, object]:
    walls: List[float] = []
    makespans: List[float] = []
    avg_jct = 0.0
    for seed in seeds:
        cluster = synth_cluster(n_regions)
        profiles = synth_profiles(n_jobs, seed=seed)
        sim = Simulator(
            cluster,
            profiles,
            BACEPipePolicy(),
            engine=engine,
            decision_backend=backend,
        )
        t0 = time.perf_counter()
        res = sim.run()
        walls.append(time.perf_counter() - t0)
        assert len(res.records) == n_jobs
        makespans.append(res.makespan)
        if seed == seeds[0]:
            avg_jct = res.average_jct
    mean_wall = sum(walls) / len(walls)
    return {
        "jobs": n_jobs,
        "regions": n_regions,
        "engine": engine,
        "backend": backend,
        "seeds": len(seeds),
        "wall_s": mean_wall,
        "us_per_call": 1e6 * mean_wall / n_jobs,
        "makespan_s": makespans[0],
        "makespans_by_seed": makespans,
        "avg_jct_s": avg_jct,
    }


def _plan_cache_cell(n_jobs: int, n_regions: int) -> Dict[str, object]:
    """Microplan-backend probe asserting the plan memo's hit-rate floor.

    Runs the same cell twice without clearing the cache between passes; the
    topologies the second pass prices were all planned in the first, so any
    memo that actually holds them (process-wide, unbounded) clears
    ``PLAN_CACHE_HIT_FLOOR`` easily and a bounded thrashing one does not."""
    clear_plan_cache()
    walls: List[float] = []
    for _pass in range(2):
        cluster = synth_cluster(n_regions)
        profiles = synth_profiles(n_jobs, seed=0, timing_model="microplan")
        sim = Simulator(
            cluster,
            profiles,
            BACEPipePolicy(),
            engine="vectorized",
            decision_backend="numpy",
        )
        t0 = time.perf_counter()
        res = sim.run()
        walls.append(time.perf_counter() - t0)
        assert len(res.records) == n_jobs
    info = plan_cache_info()
    if info.hit_rate < PLAN_CACHE_HIT_FLOOR:
        raise AssertionError(
            f"microplan plan-cache hit rate {info.hit_rate:.3f} below the "
            f"{PLAN_CACHE_HIT_FLOOR} floor at jobs={n_jobs} "
            f"regions={n_regions} ({info.hits} hits / {info.misses} misses; "
            "the plan memo is evicting topologies that are still live)"
        )
    return {
        "jobs": n_jobs,
        "regions": n_regions,
        "passes": 2,
        "wall_s_per_pass": walls,
        "hits": info.hits,
        "misses": info.misses,
        "distinct_topologies": info.size,
        "hit_rate": info.hit_rate,
        "floor": PLAN_CACHE_HIT_FLOOR,
    }


def _trace_overhead_cell(n_jobs: int, n_regions: int) -> Dict[str, object]:
    """Traced-vs-untraced probe gating the recorder's overhead ceiling.

    Min-of-N walls on both sides, with trials interleaved (bare, traced,
    bare, traced, …) so slow drift in the host hits both alike.  GC runs
    before each timed region and is disabled inside it: the traced run
    allocates ~100k record objects, and by this point in the sweep the
    process heap holds every earlier cell's live set, so cyclic-GC passes
    triggered mid-run would bill whole-heap scan time to the recorder.
    Makespans are asserted identical — the recorder must observe the run,
    never steer it."""
    import gc

    def one_wall(traced: bool) -> Tuple[float, float]:
        cluster = synth_cluster(n_regions)
        profiles = synth_profiles(n_jobs, seed=0)
        sim = Simulator(
            cluster,
            profiles,
            BACEPipePolicy(),
            engine="vectorized",
            decision_backend="numpy",
            recorder=SimTraceRecorder() if traced else None,
        )
        gc.collect()
        gc.disable()
        try:
            t0 = time.perf_counter()
            res = sim.run()
            wall = time.perf_counter() - t0
        finally:
            gc.enable()
        return wall, res.makespan

    bare_wall = traced_wall = float("inf")
    bare_makespan = traced_makespan = 0.0
    for _ in range(TRACE_OVERHEAD_TRIALS):
        wall, bare_makespan = one_wall(traced=False)
        bare_wall = min(bare_wall, wall)
        wall, traced_makespan = one_wall(traced=True)
        traced_wall = min(traced_wall, wall)
    if traced_makespan != bare_makespan:
        raise AssertionError(
            f"tracing moved the makespan at jobs={n_jobs} "
            f"regions={n_regions}: {traced_makespan} != {bare_makespan} "
            "(the recorder mutated engine state or consumed RNG)"
        )
    ratio = traced_wall / bare_wall
    if ratio > TRACE_OVERHEAD_CEILING:
        raise AssertionError(
            f"trace overhead {ratio:.2f}x above the "
            f"{TRACE_OVERHEAD_CEILING}x ceiling at jobs={n_jobs} "
            f"regions={n_regions} (bare {bare_wall:.3f}s, traced "
            f"{traced_wall:.3f}s; a recorder hook is doing hot-path work)"
        )
    return {
        "jobs": n_jobs,
        "regions": n_regions,
        "trials": TRACE_OVERHEAD_TRIALS,
        "bare_wall_s": bare_wall,
        "traced_wall_s": traced_wall,
        "ratio": ratio,
        "ceiling": TRACE_OVERHEAD_CEILING,
    }


def _cell_variants(n_jobs: int, n_regions: int, have_jax: bool):
    """(engine, backend) variants timed for a cell, reference path first."""
    variants = [("vectorized", "numpy")]
    if have_jax:
        variants.append(("vectorized", "jax"))
    if n_jobs <= LEGACY_MAX_JOBS and n_regions <= LEGACY_MAX_REGIONS:
        variants.append(("legacy", "numpy"))
    return variants


def run(*, quick: bool = False, n_seeds: int = 1) -> List[str]:
    job_counts = QUICK_JOB_COUNTS if quick else JOB_COUNTS
    region_counts = QUICK_REGION_COUNTS if quick else REGION_COUNTS
    grid = [(j, r) for j in job_counts for r in region_counts]
    if not quick:
        grid.append(BIG_CELL)
    seeds = tuple(range(n_seeds))
    have_jax = jax_available()
    rows: List[str] = []
    cells: List[Dict[str, object]] = []
    skipped: List[Dict[str, object]] = []
    warmed: set = set()
    for n_jobs, n_regions in grid:
        measured: List[Dict[str, object]] = []
        for engine, backend in _cell_variants(n_jobs, n_regions, have_jax):
            if backend == "jax" and n_regions not in warmed:
                _warm_jax(n_regions)
                warmed.add(n_regions)
            measured.append(
                _time_cell(n_jobs, n_regions, engine, backend, seeds)
            )
        base = measured[0]
        for m in measured[1:]:
            if m["makespans_by_seed"] != base["makespans_by_seed"]:
                raise AssertionError(
                    f"variant divergence at jobs={n_jobs} "
                    f"regions={n_regions}: {m['engine']}/{m['backend']} "
                    f"{m['makespans_by_seed']} != vectorized/numpy "
                    f"{base['makespans_by_seed']}"
                )
        if n_jobs > LEGACY_MAX_JOBS or n_regions > LEGACY_MAX_REGIONS:
            skipped.append(
                {
                    "jobs": n_jobs,
                    "regions": n_regions,
                    "engine": "legacy",
                    "reason": (
                        "legacy seed engine recomputes per pass "
                        "(quadratic-or-worse); intractable above "
                        f"{LEGACY_MAX_JOBS}x{LEGACY_MAX_REGIONS}"
                    ),
                }
            )
        cells.extend(measured)
        for m in measured:
            speedup = base["us_per_call"] / m["us_per_call"]
            rows.append(
                f"scheduler_scaling/j{n_jobs}xr{n_regions}"
                f"/{m['engine']}-{m['backend']},"
                f"{m['us_per_call']:.1f},"
                f"wall_s={m['wall_s']:.3f};vs_vec_numpy={speedup:.2f}"
            )
    # Plan-memo probe: the microplan timing backend selected end to end,
    # with the hit-rate floor asserted inside.
    probe_jobs, probe_regions = (
        PLAN_CACHE_PROBE_QUICK if quick else PLAN_CACHE_PROBE_FULL
    )
    cache_cell = _plan_cache_cell(probe_jobs, probe_regions)
    rows.append(
        f"scheduler_scaling/j{probe_jobs}xr{probe_regions}/plan-cache,"
        f"{1e6 * sum(cache_cell['wall_s_per_pass']) / (2 * probe_jobs):.1f},"
        f"hit_rate={cache_cell['hit_rate']:.3f};"
        f"topologies={cache_cell['distinct_topologies']};"
        f"floor={PLAN_CACHE_HIT_FLOOR}"
    )
    # Trace-overhead probe: recorder attached vs not, ceiling asserted
    # inside.
    trace_cell = _trace_overhead_cell(*TRACE_OVERHEAD_CELL)
    rows.append(
        f"scheduler_scaling/j{TRACE_OVERHEAD_CELL[0]}"
        f"xr{TRACE_OVERHEAD_CELL[1]}/trace-overhead,"
        f"{1e6 * trace_cell['traced_wall_s'] / TRACE_OVERHEAD_CELL[0]:.1f},"
        f"ratio={trace_cell['ratio']:.2f};"
        f"ceiling={TRACE_OVERHEAD_CEILING}"
    )

    if quick:
        # Quick mode is a smoke run: don't clobber the full-sweep baseline
        # that bench_compare gates against.
        rows.append(f"# quick mode: {BENCH_PATH.name} not written")
        return rows
    payload: Dict[str, object] = {
        "benchmark": "scheduler_scaling",
        "policy": "bace-pipe",
        "us_per_call_definition": (
            "1e6 * run_wall_s / n_jobs; wall clock covers Simulator.run() "
            "only (cluster/workload/Simulator construction excluded); "
            "mean over seeds"
        ),
        "arrival_gap_s": ARRIVAL_GAP_S,
        "seeds": n_seeds,
        "cells": cells,
        "skipped": skipped,
        # Not a timing cell: the microplan plan-memo probe (hit-rate floor
        # asserted in-process, recorded here for the paper trail).
        "plan_cache": cache_cell,
        # Likewise: the recorder overhead probe (ceiling asserted
        # in-process).
        "trace_overhead": trace_cell,
    }

    def _find(jobs: int, regions: int, engine: str, backend: str):
        for c in cells:
            if (c["jobs"], c["regions"], c["engine"], c["backend"]) == (
                jobs,
                regions,
                engine,
                backend,
            ):
                return c
        return None

    # Engine speedup at the biggest cell where legacy is still timed.
    leg = _find(LEGACY_MAX_JOBS, LEGACY_MAX_REGIONS, "legacy", "numpy")
    vec = _find(LEGACY_MAX_JOBS, LEGACY_MAX_REGIONS, "vectorized", "numpy")
    if leg and vec:
        payload["speedup_biggest_cell"] = (
            leg["us_per_call"] / vec["us_per_call"]
        )
    # Backend speedup at the large-regime cell (numpy / jax us_per_call).
    if have_jax:
        np_big = _find(*BIG_CELL, "vectorized", "numpy")
        jx_big = _find(*BIG_CELL, "vectorized", "jax")
        if np_big and jx_big:
            payload["jax_speedup_biggest_cell"] = (
                np_big["us_per_call"] / jx_big["us_per_call"]
            )
    BENCH_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    rows.append(f"# wrote {BENCH_PATH}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="small grid, no BENCH_scheduler.json rewrite (CI smoke)",
    )
    ap.add_argument(
        "--seeds",
        type=int,
        default=1,
        metavar="N",
        help="workload seeds 0..N-1 per cell; us_per_call is the mean",
    )
    args = ap.parse_args()
    if args.seeds < 1:
        ap.error("--seeds must be >= 1")
    print("name,us_per_call,derived")
    for row in run(quick=args.quick, n_seeds=args.seeds):
        print(row)


if __name__ == "__main__":
    main()
