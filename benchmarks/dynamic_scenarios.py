"""Dynamic-environment scenario sweep: every registered scenario × policy.

Runs the scenario registry (``repro.core.scenarios``) across all five
policies and emits the usual ``name,us_per_call,derived`` CSV rows, where
``derived`` carries avg JCT, total cost, migration count, and total stall
time.  Each cell is run twice with the same seed and asserted identical
(``SimulationResult.to_jsonable``) — the determinism contract the golden
traces pin — and the static-paper scenario is additionally asserted
bit-identical between the vectorized and legacy engines.

Usage:
    PYTHONPATH=src python -m benchmarks.dynamic_scenarios [--smoke] [--seed N]

``--smoke`` trims to 6-job scenarios for CI (~seconds).
"""

from __future__ import annotations

import argparse
import time
from typing import List

from repro.core import SCENARIOS, simulate

from .common import BENCH_GPU_FLOPS, POLICY_FACTORIES


def run(*, smoke: bool = False, seed: int = 0) -> List[str]:
    rows: List[str] = []
    pk = {"gpu_flops": BENCH_GPU_FLOPS}
    for scen_name, scenario in SCENARIOS.items():
        n_jobs = 6 if smoke else None
        for pol_name, factory in POLICY_FACTORIES.items():
            t0 = time.perf_counter()
            res = scenario.run(
                factory(), seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            lap = time.perf_counter() - t0
            rerun = scenario.run(
                factory(), seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            if res.to_jsonable() != rerun.to_jsonable():
                raise AssertionError(
                    f"non-deterministic result: {scen_name}/{pol_name} "
                    f"(seed={seed})"
                )
            rows.append(
                f"dynamic/{scen_name}/{pol_name},{1e6 * lap:.1f},"
                f"jct_h={res.average_jct / 3600:.3f};"
                f"cost=${res.total_cost:.2f};"
                f"migrations={res.total_migrations};"
                f"stall_h={res.total_stall_seconds / 3600:.3f}"
            )
        if not scenario.dynamic:
            # Static scenarios must stay bit-identical across engines.
            cluster, profiles, _ = scenario.build(
                seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            for pol_name, factory in POLICY_FACTORIES.items():
                vec = simulate(cluster, profiles, factory(), engine="vectorized")
                leg = simulate(cluster, profiles, factory(), engine="legacy")
                if vec.to_jsonable() != leg.to_jsonable():
                    raise AssertionError(
                        f"engine divergence: {scen_name}/{pol_name}"
                    )
            rows.append(f"# {scen_name}: engine parity OK (all policies)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke, seed=args.seed):
        print(row)


if __name__ == "__main__":
    main()
