"""Dynamic-environment scenario sweep: every registered scenario × policy.

Runs the scenario registry (``repro.core.scenarios``) across all five
policies and emits the usual ``name,us_per_call,derived`` CSV rows, where
``derived`` carries avg JCT, total cost, migration counts (voluntary broken
out), and total stall time.  Each cell is run twice with the same seed and
asserted identical (``SimulationResult.to_jsonable``) — the determinism
contract the golden traces pin — and the static-paper scenario is
additionally asserted bit-identical between the vectorized and legacy
engines.

Every cell also asserts the piecewise-accounting invariants (segment costs
non-negative and partitioning the per-job Eq. 4 totals), and the
price-spike scenario asserts that BACE-Pipe with voluntary migration (the
scenario default) lands strictly cheaper than the stay-put schedule —
both measured by the same breakpoint-accurate ledger.  These run in CI via
``--smoke``.

``--trace-out PATH`` additionally runs the observability acceptance cell —
mixed-stress × BACE-Pipe with voluntary migration on, a ``SimTraceRecorder``
attached — asserts the traced run bit-identical to an untraced twin, and
writes the JSONL trace to PATH (``python -m repro.obs report PATH --check``
renders it; ``--perfetto`` converts it for ``ui.perfetto.dev``).

Usage:
    PYTHONPATH=src python -m benchmarks.dynamic_scenarios [--smoke] [--seed N]
        [--trace-out PATH]

``--smoke`` trims to 6-job scenarios for CI (~seconds).
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import List

from repro.core import BACEPipePolicy, SCENARIOS, SimulationResult, simulate
from repro.core.scenarios import get_scenario
from repro.obs import SimTraceRecorder, write_jsonl

from .common import BENCH_GPU_FLOPS, POLICY_FACTORIES


def assert_cost_invariants(res: SimulationResult, cell: str) -> None:
    """Piecewise-ledger invariants every simulation must satisfy: settled
    segment costs are non-negative and partition the per-job totals, and
    voluntary migrations are a subset of all migrations."""
    by_job = {}
    for rec in res.records:
        if rec.cost < 0.0:
            raise AssertionError(f"negative segment cost in {cell}: {rec}")
        by_job.setdefault(rec.job_id, 0.0)
        by_job[rec.job_id] += rec.cost
    for job_id, total in by_job.items():
        ledger = res.costs[job_id]
        if ledger < 0.0 or abs(total - ledger) > 1e-6 + 1e-9 * abs(ledger):
            raise AssertionError(
                f"segment costs do not partition job {job_id} total in "
                f"{cell}: {total} vs {ledger}"
            )
    for job_id, n_vol in res.voluntary_migrations.items():
        if not 0 < n_vol <= res.migrations.get(job_id, 0):
            raise AssertionError(
                f"voluntary > total migrations for job {job_id} in {cell}"
            )


#: The traced cell ``--trace-out`` emits: mixed-stress at this seed with
#: voluntary migration always-on produces preempt→start migration pairs,
#: so the exported Perfetto trace carries flow arrows (seed 0 migrates
#: nothing there — the stay-put schedule is already cheapest).
TRACE_SCENARIO = "mixed-stress"
TRACE_SEED = 1
TRACE_MIGRATION_THRESHOLD = 0.0


def emit_trace(out: Path) -> str:
    """Run the traced acceptance cell, assert tracing parity, write JSONL."""
    rec = SimTraceRecorder()
    sc = get_scenario(TRACE_SCENARIO)
    kwargs = dict(
        seed=TRACE_SEED,
        voluntary_migration_threshold=TRACE_MIGRATION_THRESHOLD,
    )
    traced = sc.run(BACEPipePolicy(), recorder=rec, **kwargs)
    plain = sc.run(BACEPipePolicy(), **kwargs)
    if traced.to_jsonable() != plain.to_jsonable():
        raise AssertionError(
            f"tracing moved the {TRACE_SCENARIO} result (seed={TRACE_SEED}):"
            " the recorder mutated engine state or consumed RNG"
        )
    write_jsonl(
        out,
        rec,
        meta={
            "scenario": TRACE_SCENARIO,
            "policy": "bace-pipe",
            "seed": TRACE_SEED,
            "voluntary_migration_threshold": TRACE_MIGRATION_THRESHOLD,
        },
    )
    return (
        f"# trace: {TRACE_SCENARIO}/bace-pipe seed={TRACE_SEED} -> {out} "
        f"({len(rec.records)} records, "
        f"{traced.total_voluntary_migrations} voluntary migrations)"
    )


def run(*, smoke: bool = False, seed: int = 0) -> List[str]:
    rows: List[str] = []
    pk = {"gpu_flops": BENCH_GPU_FLOPS}
    for scen_name, scenario in SCENARIOS.items():
        if scenario.hetero:
            # Typed-pool scenarios have their own sweep + invariant gate
            # (benchmarks/hetero_scenarios.py); skipping them here keeps
            # this sweep's CI cells and its legacy-engine parity surface
            # exactly as before.
            continue
        n_jobs = 6 if smoke else None
        bace_res = None
        for pol_name, factory in POLICY_FACTORIES.items():
            t0 = time.perf_counter()
            res = scenario.run(
                factory(), seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            lap = time.perf_counter() - t0
            rerun = scenario.run(
                factory(), seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            if res.to_jsonable() != rerun.to_jsonable():
                raise AssertionError(
                    f"non-deterministic result: {scen_name}/{pol_name} "
                    f"(seed={seed})"
                )
            assert_cost_invariants(res, f"{scen_name}/{pol_name}")
            if pol_name == "bace-pipe":
                bace_res = res
            rows.append(
                f"dynamic/{scen_name}/{pol_name},{1e6 * lap:.1f},"
                f"jct_h={res.average_jct / 3600:.3f};"
                f"cost=${res.total_cost:.2f};"
                f"migrations={res.total_migrations};"
                f"voluntary={res.total_voluntary_migrations};"
                f"stall_h={res.total_stall_seconds / 3600:.3f}"
            )
        if scenario.voluntary_migration_threshold is not None:
            # A/B the voluntary pass against the stay-put schedule the
            # stale-price engine used to produce, on the same piecewise
            # ledger.  The BACE-Pipe cell above (determinism-asserted) *is*
            # the "on" run.  The greedy breakpoint-time decision is not
            # globally optimal — a later price reversion can make a migrated
            # schedule dearer — so the strict-saving gate applies only at
            # the registry's default seed, the acceptance surface the
            # scenario was tuned for; other seeds just report.
            on = bace_res
            off = scenario.run(
                BACEPipePolicy(),
                seed=seed,
                n_jobs=n_jobs,
                profile_kwargs=pk,
                voluntary_migration_threshold=None,
            )
            if seed == 0 and not on.total_cost < off.total_cost:
                raise AssertionError(
                    f"voluntary migration saved nothing on {scen_name} at "
                    f"the default seed: ${on.total_cost:.2f} vs "
                    f"${off.total_cost:.2f}"
                )
            rows.append(
                f"# {scen_name}: voluntary migration "
                f"${off.total_cost:.2f} -> ${on.total_cost:.2f} "
                f"({on.total_voluntary_migrations} moves)"
            )
        if not scenario.dynamic:
            # Static scenarios must stay bit-identical across engines.
            cluster, profiles, _ = scenario.build(
                seed=seed, n_jobs=n_jobs, profile_kwargs=pk
            )
            for pol_name, factory in POLICY_FACTORIES.items():
                vec = simulate(cluster, profiles, factory(), engine="vectorized")
                leg = simulate(cluster, profiles, factory(), engine="legacy")
                if vec.to_jsonable() != leg.to_jsonable():
                    raise AssertionError(
                        f"engine divergence: {scen_name}/{pol_name}"
                    )
            rows.append(f"# {scen_name}: engine parity OK (all policies)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--trace-out",
        type=Path,
        default=None,
        metavar="PATH",
        help="also run the traced acceptance cell and write its JSONL here",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke, seed=args.seed):
        print(row)
    if args.trace_out is not None:
        print(emit_trace(args.trace_out))


if __name__ == "__main__":
    main()
