"""Schedule ablation: microbatch schedule × policy × bandwidth tier.

For every cell the static-paper simulation runs under the default analytic
backend, the placements it actually produced are re-priced by the microplan
subsystem for each pipeline schedule, and the cell reports mean iteration
time, mean bubble fraction, and worst-case peak in-flight activations per
schedule.  Policies are the Pathfinder-based trio (BACE-Pipe and the two
ablations that keep Alg. 1's ``t_comm ≤ t_comp`` invariant) so every
placement is in the regime where the paper's claims live.

Each cell asserts the cross-backend invariants the microplan subsystem
guarantees:

* the ``gpipe`` plan reproduces Eq. (1) to ≤1e-9 relative on every placement
  (float association is the only slack — see DESIGN.md);
* ``1f1b`` and ``gpipe-overlap`` iteration times never exceed ``gpipe``;
* ``1f1b`` peak in-flight activations never exceed GPipe's.

One end-to-end row additionally runs the *whole simulation* with
``timing_model="microplan"`` threaded through the ``JobSpec``s: the
``gpipe`` schedule must land on the analytic avg JCT (≤1e-9 relative) and
``1f1b``/``gpipe-overlap`` must not exceed it.

Usage:
    PYTHONPATH=src python -m benchmarks.schedule_ablation [--smoke]
        [--seed N] [--out PATH]

The full sweep writes ``BENCH_schedules.json`` at the repo root (``--out``
overrides); ``--smoke`` trims the grid for CI and skips the file unless
``--out`` is given explicitly.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import (
    PIPELINE_SCHEDULES,
    BACEPipePolicy,
    SimulationResult,
    plan_schedule,
    simulate,
)
from repro.core.ablations import WithoutCostMin, WithoutPriority
from repro.core.timing import analytic_iteration_time
from repro.core.workloads import paper_cluster, paper_jobs, paper_profiles

from .common import BENCH_GPU_FLOPS

#: Pathfinder-based policies (placements keep ``t_comm <= t_comp``).
POLICIES = {
    "bace-pipe": BACEPipePolicy,
    "wo-priority": WithoutPriority,
    "wo-costmin": WithoutCostMin,
}

FULL_TIERS = (0.25, 1.0, 4.0)
SMOKE_TIERS = (0.25, 1.0)
REL_TOL = 1e-9

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_schedules.json"


def _run_sim(
    policy_name: str,
    tier: float,
    *,
    seed: int,
    n_jobs: int,
    timing_model: str = "analytic",
    pipeline_schedule: str = "gpipe",
):
    cluster = paper_cluster(bandwidth_factor=tier)
    jobs = paper_jobs(
        n_jobs=n_jobs,
        seed=seed,
        timing_model=timing_model,
        pipeline_schedule=pipeline_schedule,
    )
    profiles = paper_profiles(jobs, gpu_flops=BENCH_GPU_FLOPS)
    res: SimulationResult = simulate(
        cluster, profiles, POLICIES[policy_name]()
    )
    return res, profiles


def _cell(
    policy_name: str, tier: float, *, seed: int, n_jobs: int
) -> Dict[str, Dict[str, float]]:
    """Plan every schedule over the placements one simulation produced."""
    res, profiles = _run_sim(policy_name, tier, seed=seed, n_jobs=n_jobs)
    by_id = {p.spec.job_id: p for p in profiles}
    placements = [
        (by_id[r.job_id], r.placement) for r in res.completed_records
    ]
    cell: Dict[str, Dict[str, float]] = {}
    per_job: Dict[str, List[float]] = {s: [] for s in PIPELINE_SCHEDULES}
    for schedule in PIPELINE_SCHEDULES:
        iters, bubbles, peaks = [], [], []
        for prof, placement in placements:
            plan = plan_schedule(prof, placement, schedule)
            iters.append(plan.iteration_time)
            bubbles.append(plan.bubble_fraction)
            peaks.append(plan.peak_activations)
            per_job[schedule].append(plan.iteration_time)
            if schedule == "gpipe":
                eq1 = analytic_iteration_time(prof, placement)
                if abs(plan.iteration_time - eq1) > REL_TOL * eq1:
                    raise AssertionError(
                        f"gpipe plan diverged from Eq. (1) for job "
                        f"{prof.spec.job_id}: {plan.iteration_time} vs {eq1}"
                    )
            if schedule == "1f1b":
                gp = plan_schedule(prof, placement, "gpipe")
                if plan.peak_activations > gp.peak_activations:
                    raise AssertionError(
                        f"1f1b stashes more than gpipe for job "
                        f"{prof.spec.job_id}"
                    )
        n = len(iters)
        cell[schedule] = {
            "mean_iteration_s": sum(iters) / n,
            "mean_bubble": sum(bubbles) / n,
            "max_peak_activations": max(peaks),
        }
    for schedule in ("1f1b", "gpipe-overlap"):
        for t_sched, t_gpipe in zip(per_job[schedule], per_job["gpipe"]):
            if t_sched > t_gpipe * (1.0 + REL_TOL):
                raise AssertionError(
                    f"{schedule} slower than gpipe in cell "
                    f"{policy_name}/bw{tier}: {t_sched} vs {t_gpipe}"
                )
    return cell


def run(*, smoke: bool = False, seed: int = 0, out: Optional[str] = None):
    rows: List[str] = []
    tiers = SMOKE_TIERS if smoke else FULL_TIERS
    policies = ("bace-pipe",) if smoke else tuple(POLICIES)
    n_jobs = 6 if smoke else 8
    results: Dict[str, Dict] = {}
    for policy_name in policies:
        for tier in tiers:
            t0 = time.perf_counter()
            cell = _cell(policy_name, tier, seed=seed, n_jobs=n_jobs)
            lap = time.perf_counter() - t0
            key = f"{policy_name}/bw{tier:g}"
            results[key] = cell
            for schedule in PIPELINE_SCHEDULES:
                m = cell[schedule]
                rows.append(
                    f"schedules/{key}/{schedule},{1e6 * lap:.1f},"
                    f"iter_s={m['mean_iteration_s']:.4f};"
                    f"bubble={m['mean_bubble']:.4f};"
                    f"peak_acts={m['max_peak_activations']:.1f}"
                )
            rows.append(
                f"# {key}: 1f1b/gpipe-overlap <= gpipe on all "
                f"{n_jobs} placements, gpipe == Eq.(1)"
            )

    # End-to-end: the microplan backend threaded through the simulator.
    base, _ = _run_sim("bace-pipe", 1.0, seed=seed, n_jobs=n_jobs)
    e2e: Dict[str, float] = {"analytic": base.average_jct}
    for schedule in ("gpipe", "1f1b", "gpipe-overlap"):
        res, _ = _run_sim(
            "bace-pipe",
            1.0,
            seed=seed,
            n_jobs=n_jobs,
            timing_model="microplan",
            pipeline_schedule=schedule,
        )
        e2e[schedule] = res.average_jct
        rows.append(
            f"schedules/e2e/microplan-{schedule},0.0,"
            f"jct_h={res.average_jct / 3600:.4f};"
            f"jct_vs_analytic={res.average_jct / base.average_jct:.6f}"
        )
    if abs(e2e["gpipe"] - e2e["analytic"]) > REL_TOL * e2e["analytic"]:
        raise AssertionError(
            "microplan/gpipe end-to-end JCT diverged from analytic: "
            f"{e2e['gpipe']} vs {e2e['analytic']}"
        )
    for schedule in ("1f1b", "gpipe-overlap"):
        if e2e[schedule] > e2e["analytic"] * (1.0 + REL_TOL):
            raise AssertionError(
                f"microplan/{schedule} end-to-end JCT exceeds analytic: "
                f"{e2e[schedule]} vs {e2e['analytic']}"
            )
    rows.append(
        "# e2e: microplan/gpipe == analytic JCT, 1f1b and gpipe-overlap <= it"
    )

    out_path = out if out is not None else (None if smoke else _JSON_PATH)
    if out_path is not None:
        payload = {
            "seed": seed,
            "n_jobs": n_jobs,
            "gpu_flops": BENCH_GPU_FLOPS,
            "tiers": list(tiers),
            "policies": list(policies),
            "cells": results,
            "e2e_avg_jct_s": e2e,
        }
        Path(out_path).write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n"
        )
        rows.append(f"# wrote {out_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--out",
        default=None,
        help="JSON output path (default: BENCH_schedules.json at the repo "
        "root for the full sweep; no file in --smoke mode)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke, seed=args.seed, out=args.out):
        print(row)


if __name__ == "__main__":
    main()
