"""Schedule ablation: microbatch schedule × policy × bandwidth tier.

For every cell the static-paper simulation runs under the default analytic
backend, the placements it actually produced are re-priced by the microplan
subsystem for each pipeline schedule, and the cell reports mean iteration
time, mean bubble fraction, and worst-case peak in-flight activations per
schedule.  Policies are the Pathfinder-based trio (BACE-Pipe and the two
ablations that keep Alg. 1's ``t_comm ≤ t_comp`` invariant) so every
placement is in the regime where the paper's claims live.

Alongside each admission-regime cell the sweep re-plans the *cross-region*
placements under a degraded WAN (``topology_from_placement``'s
``wan_stretch``): Eq. (6)'s violation window, where a placement admitted
under ``t_comm ≤ t_comp`` runs comm-bound until the simulator migrates it.
These long-latency cells are where fixed templates leave bubble on the
table, and they carry the synthesizer's acceptance gate:

* on every cross-region (wan-stretched) cell, ``synthesized`` iteration time
  is ≤ the best template's, at equal or lower peak activations;
* across the sweep, ``synthesized`` is *strictly* better on at least one
  such cell (the full-duplex steady state the capped template warmups
  cannot reach — see ``core/microplan/planner.py``).

Each admission-regime cell also asserts the cross-backend invariants the
microplan subsystem guarantees:

* the ``gpipe`` plan reproduces Eq. (1) to ≤1e-9 relative on every placement
  (float association is the only slack — see DESIGN.md);
* ``1f1b`` and ``gpipe-overlap`` iteration times never exceed ``gpipe``;
* ``1f1b`` peak in-flight activations never exceed GPipe's;
* ``synthesized`` never exceeds the best op-graph template
  (gpipe/1f1b/interleaved) on any cell, stretched or not.

One end-to-end block additionally runs the *whole simulation* with
``timing_model="microplan"`` threaded through the ``JobSpec``s: the
``gpipe`` schedule must land on the analytic avg JCT (≤1e-9 relative) and
``1f1b``/``gpipe-overlap``/``synthesized`` must not exceed it.

Usage:
    PYTHONPATH=src python -m benchmarks.schedule_ablation [--smoke]
        [--seed N] [--out PATH]

The full sweep writes ``BENCH_schedules.json`` at the repo root (``--out``
overrides); ``--smoke`` trims the grid for CI and skips the file unless
``--out`` is given explicitly.  Cells are name-keyed
(``policy/bwT[/wanSx]/schedule``) so ``scripts/bench_compare.py --metrics``
can gate drift; the smoke grid is a strict subset of the full grid at the
same seed and job count, so smoke cells are bit-identical to their
checked-in counterparts.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core import (
    PIPELINE_SCHEDULES,
    BACEPipePolicy,
    SimulationResult,
    plan_from_topology,
    simulate,
    topology_from_placement,
)
from repro.core.ablations import WithoutCostMin, WithoutPriority
from repro.core.timing import analytic_iteration_time
from repro.core.workloads import paper_cluster, paper_jobs, paper_profiles

from .common import BENCH_GPU_FLOPS

#: Pathfinder-based policies (placements keep ``t_comm <= t_comp``).
POLICIES = {
    "bace-pipe": BACEPipePolicy,
    "wo-priority": WithoutPriority,
    "wo-costmin": WithoutCostMin,
}

FULL_TIERS = (0.25, 1.0, 4.0)
SMOKE_TIERS = (0.25, 1.0)
REL_TOL = 1e-9
#: Inter-region hop multiplier for the long-latency (violation-window)
#: cells: Eq. (6)'s post-placement bandwidth contraction, far outside the
#: ``t_comm <= t_comp`` admission envelope.
WAN_STRETCH = 4.0
#: Templates the synthesized schedule is gated against on the long-latency
#: cells (everything that is not itself the search).
TEMPLATES = tuple(s for s in PIPELINE_SCHEDULES if s != "synthesized")
#: The op-graph family: schedules whose timeline runs on the same `_OpSim`
#: resource model as the search (``gpipe-overlap`` is the lockstep
#: data-plane model, comparable on numbers but not on the op graph).
OP_GRAPH_TEMPLATES = ("gpipe", "1f1b", "interleaved")

_JSON_PATH = Path(__file__).resolve().parent.parent / "BENCH_schedules.json"


def _run_sim(
    policy_name: str,
    tier: float,
    *,
    seed: int,
    n_jobs: int,
    timing_model: str = "analytic",
    pipeline_schedule: str = "gpipe",
):
    cluster = paper_cluster(bandwidth_factor=tier)
    jobs = paper_jobs(
        n_jobs=n_jobs,
        seed=seed,
        timing_model=timing_model,
        pipeline_schedule=pipeline_schedule,
    )
    profiles = paper_profiles(jobs, gpu_flops=BENCH_GPU_FLOPS)
    res: SimulationResult = simulate(
        cluster, profiles, POLICIES[policy_name]()
    )
    return res, profiles


def _plan_grid(
    placements, *, wan_stretch: float
) -> Dict[str, List]:
    """Plan every schedule over every placement at the given WAN stretch.

    Returns per-schedule lists of ``SchedulePlan``s (index-aligned with
    ``placements``)."""
    plans: Dict[str, List] = {s: [] for s in PIPELINE_SCHEDULES}
    for prof, placement in placements:
        topo = topology_from_placement(
            prof, placement, wan_stretch=wan_stretch
        )
        for schedule in PIPELINE_SCHEDULES:
            plans[schedule].append(plan_from_topology(topo, schedule))
    return plans


def _summary(plans: List) -> Dict[str, float]:
    n = len(plans)
    return {
        "mean_iteration_s": sum(p.iteration_time for p in plans) / n,
        "mean_bubble": sum(p.bubble_fraction for p in plans) / n,
        "max_peak_activations": max(p.peak_activations for p in plans),
    }


def _check_admission_cell(key: str, placements, plans: Dict[str, List]):
    """The seed invariants on admission-regime (unstretched) placements."""
    for i, (prof, placement) in enumerate(placements):
        gp = plans["gpipe"][i]
        eq1 = analytic_iteration_time(prof, placement)
        if abs(gp.iteration_time - eq1) > REL_TOL * eq1:
            raise AssertionError(
                f"gpipe plan diverged from Eq. (1) for job "
                f"{prof.spec.job_id}: {gp.iteration_time} vs {eq1}"
            )
        if plans["1f1b"][i].peak_activations > gp.peak_activations:
            raise AssertionError(
                f"1f1b stashes more than gpipe for job {prof.spec.job_id}"
            )
    for schedule in ("1f1b", "gpipe-overlap"):
        for p, gp in zip(plans[schedule], plans["gpipe"]):
            if p.iteration_time > gp.iteration_time * (1.0 + REL_TOL):
                raise AssertionError(
                    f"{schedule} slower than gpipe in cell {key}: "
                    f"{p.iteration_time} vs {gp.iteration_time}"
                )


def _check_synth_vs_op_graph(key: str, plans: Dict[str, List]):
    """Synthesized never loses to a template on its own resource model."""
    for i, sp in enumerate(plans["synthesized"]):
        best = min(
            plans[s][i].iteration_time for s in OP_GRAPH_TEMPLATES
        )
        if sp.iteration_time > best * (1.0 + REL_TOL):
            raise AssertionError(
                f"synthesized loses to an op-graph template in cell "
                f"{key}: {sp.iteration_time} vs {best}"
            )


def _gate_long_latency_cell(
    key: str, summaries: Dict[str, Dict[str, float]]
) -> bool:
    """The acceptance gate on one cross-region (wan-stretched) cell.

    Synthesized must match or beat the *best template* on mean iteration
    time at equal-or-lower peak activations.  Returns True when the win is
    strict (the sweep requires at least one)."""
    synth = summaries["synthesized"]
    best_tmpl = min(
        TEMPLATES, key=lambda s: summaries[s]["mean_iteration_s"]
    )
    best = summaries[best_tmpl]
    if synth["mean_iteration_s"] > best["mean_iteration_s"] * (
        1.0 + REL_TOL
    ):
        raise AssertionError(
            f"synthesized loses to {best_tmpl} on long-latency cell "
            f"{key}: {synth['mean_iteration_s']} vs "
            f"{best['mean_iteration_s']}"
        )
    if synth["max_peak_activations"] > best["max_peak_activations"] + 1e-9:
        raise AssertionError(
            f"synthesized stashes more than {best_tmpl} on long-latency "
            f"cell {key}: {synth['max_peak_activations']} vs "
            f"{best['max_peak_activations']}"
        )
    return synth["mean_iteration_s"] < best["mean_iteration_s"] * (
        1.0 - REL_TOL
    )


def run(*, smoke: bool = False, seed: int = 0, out: Optional[str] = None):
    rows: List[str] = []
    tiers = SMOKE_TIERS if smoke else FULL_TIERS
    policies = ("bace-pipe",) if smoke else tuple(POLICIES)
    # Same job count in both modes: the smoke grid is a strict subset of the
    # full grid, so bench_compare can diff smoke cells against the
    # checked-in full baseline bit-for-bit.
    n_jobs = 8
    cells: List[Dict] = []
    strict_win_cells: List[str] = []
    for policy_name in policies:
        for tier in tiers:
            t0 = time.perf_counter()
            res, profiles = _run_sim(
                policy_name, tier, seed=seed, n_jobs=n_jobs
            )
            by_id = {p.spec.job_id: p for p in profiles}
            placements = [
                (by_id[r.job_id], r.placement)
                for r in res.completed_records
            ]
            key = f"{policy_name}/bw{tier:g}"
            plans = _plan_grid(placements, wan_stretch=1.0)
            _check_admission_cell(key, placements, plans)
            _check_synth_vs_op_graph(key, plans)
            summaries = {
                s: _summary(plans[s]) for s in PIPELINE_SCHEDULES
            }
            lap = time.perf_counter() - t0
            for schedule in PIPELINE_SCHEDULES:
                cells.append(
                    {"name": f"{key}/{schedule}", **summaries[schedule]}
                )
                m = summaries[schedule]
                rows.append(
                    f"schedules/{key}/{schedule},{1e6 * lap:.1f},"
                    f"iter_s={m['mean_iteration_s']:.4f};"
                    f"bubble={m['mean_bubble']:.4f};"
                    f"peak_acts={m['max_peak_activations']:.1f}"
                )
            rows.append(
                f"# {key}: 1f1b/gpipe-overlap <= gpipe on all "
                f"{len(placements)} placements, gpipe == Eq.(1)"
            )

            # Long-latency cells: the same placements, inter-region hops
            # stretched into Eq. (6)'s violation window.  Only placements
            # that actually cross regions belong here — an intra-region
            # placement is unchanged by the stretch.
            cross = [
                (prof, placement)
                for prof, placement in placements
                if len(set(placement.stage_regions())) > 1
            ]
            if not cross:
                rows.append(f"# {key}: no cross-region placements, "
                            "no long-latency cell")
                continue
            t0 = time.perf_counter()
            wkey = f"{key}/wan{WAN_STRETCH:g}x"
            wplans = _plan_grid(cross, wan_stretch=WAN_STRETCH)
            _check_synth_vs_op_graph(wkey, wplans)
            # The gate demands *domination* — match/beat the best template
            # at equal-or-lower peak — while the uncapped search is free to
            # trade stash for speed.  Re-plan the search under the best
            # template's own memory budget (OptPipe-style activation_cap):
            # that template's order is in the candidate pool, so the capped
            # search can never lose its time, and the cap bounds the peak
            # by construction.
            tsum = {s: _summary(wplans[s]) for s in TEMPLATES}
            budget_tmpl = min(
                TEMPLATES, key=lambda s: tsum[s]["mean_iteration_s"]
            )
            cap = tsum[budget_tmpl]["max_peak_activations"]
            wplans["synthesized"] = [
                plan_from_topology(
                    topology_from_placement(
                        prof, placement, wan_stretch=WAN_STRETCH
                    ),
                    "synthesized",
                    activation_cap=cap,
                )
                for prof, placement in cross
            ]
            wsummaries = {
                s: _summary(wplans[s]) for s in PIPELINE_SCHEDULES
            }
            if _gate_long_latency_cell(wkey, wsummaries):
                strict_win_cells.append(wkey)
            lap = time.perf_counter() - t0
            for schedule in PIPELINE_SCHEDULES:
                cells.append(
                    {
                        "name": f"{wkey}/{schedule}",
                        **wsummaries[schedule],
                    }
                )
                m = wsummaries[schedule]
                rows.append(
                    f"schedules/{wkey}/{schedule},{1e6 * lap:.1f},"
                    f"iter_s={m['mean_iteration_s']:.4f};"
                    f"bubble={m['mean_bubble']:.4f};"
                    f"peak_acts={m['max_peak_activations']:.1f}"
                )
            rows.append(
                f"# {wkey}: synthesized (capped at {budget_tmpl}'s peak "
                f"{cap:g}) <= best template at <= peak on "
                f"{len(cross)} cross-region placements"
            )
    if not strict_win_cells:
        raise AssertionError(
            "synthesized never strictly beat the best template on any "
            "long-latency cell — the search regressed to the templates"
        )
    rows.append(
        f"# synthesized strictly beats the best template on "
        f"{len(strict_win_cells)}/{len(cells)} cells: "
        + ", ".join(strict_win_cells)
    )

    # End-to-end: the microplan backend threaded through the simulator.
    base, _ = _run_sim("bace-pipe", 1.0, seed=seed, n_jobs=n_jobs)
    e2e: Dict[str, float] = {"analytic": base.average_jct}
    for schedule in ("gpipe", "1f1b", "gpipe-overlap", "synthesized"):
        res, _ = _run_sim(
            "bace-pipe",
            1.0,
            seed=seed,
            n_jobs=n_jobs,
            timing_model="microplan",
            pipeline_schedule=schedule,
        )
        e2e[schedule] = res.average_jct
        rows.append(
            f"schedules/e2e/microplan-{schedule},0.0,"
            f"jct_h={res.average_jct / 3600:.4f};"
            f"jct_vs_analytic={res.average_jct / base.average_jct:.6f}"
        )
    if abs(e2e["gpipe"] - e2e["analytic"]) > REL_TOL * e2e["analytic"]:
        raise AssertionError(
            "microplan/gpipe end-to-end JCT diverged from analytic: "
            f"{e2e['gpipe']} vs {e2e['analytic']}"
        )
    for schedule in ("1f1b", "gpipe-overlap", "synthesized"):
        if e2e[schedule] > e2e["analytic"] * (1.0 + REL_TOL):
            raise AssertionError(
                f"microplan/{schedule} end-to-end JCT exceeds analytic: "
                f"{e2e[schedule]} vs {e2e['analytic']}"
            )
    rows.append(
        "# e2e: microplan/gpipe == analytic JCT; 1f1b, gpipe-overlap and "
        "synthesized <= it"
    )
    for label, jct in e2e.items():
        cells.append({"name": f"e2e/{label}", "jct_s": jct})

    out_path = out if out is not None else (None if smoke else _JSON_PATH)
    if out_path is not None:
        payload = {
            "seed": seed,
            "n_jobs": n_jobs,
            "gpu_flops": BENCH_GPU_FLOPS,
            "tiers": list(tiers),
            "policies": list(policies),
            "wan_stretch": WAN_STRETCH,
            "cells": cells,
        }
        Path(out_path).write_text(
            json.dumps(payload, indent=2, sort_keys=True) + "\n"
        )
        rows.append(f"# wrote {out_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized quick run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--out",
        default=None,
        help="JSON output path (default: BENCH_schedules.json at the repo "
        "root for the full sweep; no file in --smoke mode)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for row in run(smoke=args.smoke, seed=args.seed, out=args.out):
        print(row)


if __name__ == "__main__":
    main()
