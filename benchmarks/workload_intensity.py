"""Fig. 7 workload intensity: scale the job count from 8 to 24.

Paper claims: BACE-Pipe keeps the lowest JCT at every intensity; gaps narrow
as the cluster saturates (CR-LDF overhead 64.7% @8 jobs -> 21.7% @24 jobs but
still 9.7–23.3% JCT improvement at 24 jobs); cost advantage shrinks to ~1%
at 20–24 jobs.
"""

from __future__ import annotations

from typing import List

from .common import POLICY_FACTORIES, check_claim, emit_rows, run_policy_suite


def run() -> List[str]:
    rows: List[str] = []
    best_everywhere = True
    for n_jobs in (8, 12, 16, 20, 24):
        suite = run_policy_suite(POLICY_FACTORIES, n_jobs=n_jobs)
        rows.extend(emit_rows(f"fig7/jobs{n_jobs}", suite))
        base_j = suite["bace-pipe"]["avg_jct_s"]
        if any(
            m["avg_jct_s"] < base_j for n, m in suite.items() if n != "bace-pipe"
        ):
            best_everywhere = False
        if n_jobs == 24:
            over = [
                100.0 * (m["avg_jct_s"] / base_j - 1.0)
                for n, m in suite.items()
                if n != "bace-pipe"
            ]
            rows.append(check_claim("24-job JCT improvements", min(over), 9.7, 23.3))
    rows.append(
        "# Fig.7 'BACE-Pipe lowest JCT at all intensities': "
        + ("MATCH" if best_everywhere else "MISMATCH")
    )
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
