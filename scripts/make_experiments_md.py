"""Regenerates the §Dry-run and §Roofline tables of EXPERIMENTS.md from
artifacts/dryrun/*.json.  Static sections (§Benchmarks, §Perf) live in
EXPERIMENTS.header.md / EXPERIMENTS.perf.md and are concatenated.

    PYTHONPATH=src python scripts/make_experiments_md.py
"""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import analyse_record  # noqa: E402
from repro.configs import ARCH_IDS, LONG_CONTEXT_ARCHS, SHAPES  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
OUT = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
HEADER = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.header.md")
PERF = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.perf.md")


def gb(x):
    return f"{x / 1e9:.2f}" if x is not None else "-"


def main() -> None:
    recs = []
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    by_key = {(r["arch"], r["shape"], r["multi_pod"]): r for r in recs}

    lines = []
    if os.path.exists(HEADER):
        lines.append(open(HEADER).read().rstrip())

    # ------------------------------------------------------------ dry-run
    lines.append("\n\n## §Dry-run\n")
    n_ok = sum(1 for r in recs if r.get("status") == "ok")
    n_fail = sum(1 for r in recs if r.get("status") != "ok")
    lines.append(
        f"`launch/dryrun.py` lowered + compiled **{n_ok} cells OK, "
        f"{n_fail} failed** across the single-pod (16x16 = 256 chips) and "
        "multi-pod (2x16x16 = 512 chips) meshes.  Cells marked `skip` are "
        "the documented long_500k skips for pure full-attention archs "
        "(DESIGN.md §long_500k).\n"
    )
    lines.append(
        "| arch | shape | mesh | status | FLOPs/dev | bytes/dev (GB) | "
        "collective bytes/dev (GB) | args/dev (GB) | temp/dev (GB) | "
        "compile (s) |"
    )
    lines.append("|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for cell in SHAPES:
            skip = cell.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS
            for mp in (False, True):
                mesh = "2x16x16" if mp else "16x16"
                if skip:
                    if not mp:
                        lines.append(
                            f"| {arch} | {cell.name} | both | skip "
                            f"(full-attention, see DESIGN.md) | | | | | | |"
                        )
                    continue
                r = by_key.get((arch, cell.name, mp))
                if r is None:
                    lines.append(
                        f"| {arch} | {cell.name} | {mesh} | missing | | | | | | |"
                    )
                    continue
                if r.get("status") != "ok":
                    err = r.get("error", "?")[:60].replace("|", "/")
                    lines.append(
                        f"| {arch} | {cell.name} | {mesh} | FAIL: {err} | | | | | | |"
                    )
                    continue
                coll = sum(r.get("collective_bytes", {}).values())
                lines.append(
                    f"| {arch} | {cell.name} | {mesh} | ok "
                    f"| {r.get('flops', 0):.3e} | {gb(r.get('bytes_accessed'))} "
                    f"| {gb(coll)} | {gb(r.get('argument_size_in_bytes'))} "
                    f"| {gb(r.get('temp_size_in_bytes'))} "
                    f"| {r.get('compile_s', '-')} |"
                )

    # collective schedule summary
    lines.append("\n**Collective mix per cell (bytes by op, single-pod):**\n")
    lines.append("| arch | shape | all-reduce | all-gather | reduce-scatter | all-to-all | collective-permute |")
    lines.append("|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for cell in SHAPES:
            r = by_key.get((arch, cell.name, False))
            if not r or r.get("status") != "ok":
                continue
            cb = r.get("collective_bytes", {})
            lines.append(
                f"| {arch} | {cell.name} | "
                + " | ".join(
                    gb(cb.get(k, 0.0))
                    for k in ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute")
                )
                + " |"
            )

    # ------------------------------------------------------------ roofline
    lines.append("\n\n## §Roofline\n")
    lines.append(
        "Hardware model: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, "
        "50 GB/s/link ICI.  Terms are seconds per step per device from the "
        "compiled artifact; `useful` = MODEL_FLOPS / HLO_FLOPs "
        "(6·N·D for train, 2·N·D prefill, 2·N·B decode; N_active for MoE); "
        "`frac` = useful-compute-time / dominant-term (the roofline "
        "fraction).\n"
    )
    for mp in (False, True):
        lines.append(f"\n### {'Multi-pod 2x16x16' if mp else 'Single-pod 16x16'}\n")
        lines.append(
            "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) "
            "| dominant | useful | frac | next lever |"
        )
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for arch in ARCH_IDS:
            for cell in SHAPES:
                r = by_key.get((arch, cell.name, mp))
                if not r or r.get("status") != "ok":
                    continue
                a = analyse_record(r)
                if a is None:
                    continue
                lines.append(
                    f"| {arch} | {cell.name} | {a['t_compute_s']:.4f} "
                    f"| {a['t_memory_s']:.4f} | {a['t_collective_s']:.4f} "
                    f"| **{a['dominant']}** | {a['useful_ratio']:.3f} "
                    f"| {a['roofline_frac']:.3f} | {a['next_lever']} |"
                )

    if os.path.exists(PERF):
        lines.append("\n\n" + open(PERF).read().rstrip())

    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({n_ok} ok / {n_fail} fail)")


if __name__ == "__main__":
    main()
