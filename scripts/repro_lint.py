#!/usr/bin/env python
"""Convenience launcher for reprolint that works without PYTHONPATH.

Equivalent to ``PYTHONPATH=src python -m repro.analysis.staticcheck``;
run from the repo root:

    python scripts/repro_lint.py src benchmarks scripts tests
"""

import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SRC = REPO_ROOT / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

from repro.analysis.staticcheck import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
