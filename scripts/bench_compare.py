#!/usr/bin/env python3
"""Diff two ``BENCH_scheduler.json`` files and gate on perf regression.

Usage:
    python scripts/bench_compare.py OLD.json NEW.json [--threshold 0.20]
                                    [--relative]

Matches cells by (jobs, regions, engine) and compares ``us_per_call``.  Any
matched cell in NEW that is more than ``threshold`` (default 20%) slower than
in OLD fails the gate: the script prints a per-cell table and exits nonzero,
so CI (or the next PR's driver) can refuse the change.  Cells present in only
one file are reported but do not fail the gate — sweeps are allowed to grow.

``--relative`` compares the per-(jobs, regions) *speedup* (legacy /
vectorized ``us_per_call``, both measured within the same run) instead of
absolute timings.  Speedup is machine-portable, so this is the mode for CI,
where NEW comes from a shared runner while the checked-in baseline was
measured elsewhere: the gate fails only when NEW's speedup falls more than
``threshold`` below OLD's on a matched cell.

``--metrics`` compares *named* cells (payloads whose cells carry a ``name``
key, e.g. ``BENCH_hetero.json``) on their simulation metrics (``jct_s``,
``cost``, ``migrations``) instead of timings.  The metrics are fully
deterministic, so the gate is a tight relative tolerance (``--metric-tol``,
default 1e-6): any drift is a semantic regression, not machine noise.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, Tuple

Key = Tuple[int, int, str]

#: Deterministic per-cell metrics the --metrics mode gates on (when present).
METRIC_FIELDS = ("jct_s", "cost", "migrations")


def load_cells(path: Path) -> Dict[Key, dict]:
    if not path.is_file():
        raise SystemExit(f"{path}: no such file")
    payload = json.loads(path.read_text())
    cells = payload.get("cells", [])
    out: Dict[Key, dict] = {}
    for c in cells:
        out[(int(c["jobs"]), int(c["regions"]), str(c["engine"]))] = c
    if not out:
        raise SystemExit(f"{path}: no cells found")
    return out


def load_named_cells(path: Path) -> Dict[str, dict]:
    """Cells keyed by their ``name`` field (metric-gated benchmarks)."""
    if not path.is_file():
        raise SystemExit(f"{path}: no such file")
    payload = json.loads(path.read_text())
    cells = payload.get("cells", [])
    out: Dict[str, dict] = {}
    for c in cells:
        if "name" not in c:
            raise SystemExit(f"{path}: cell without a name (not a metrics file)")
        out[str(c["name"])] = c
    if not out:
        raise SystemExit(f"{path}: no cells found")
    return out


def compare_metrics(
    old: Dict[str, dict], new: Dict[str, dict], tol: float
) -> int:
    """Unlike the timing modes (where sweeps may grow), the metric sweep's
    *cell population* is itself deterministic: a cell present on only one
    side means a scenario/policy vanished or appeared without the baseline
    being regenerated, which is exactly the silent drift this gate exists to
    catch — so asymmetric cells fail, not just metric drift."""
    regressions = []
    print(f"{'cell':42s} {'metric':>10s} {'old':>14s} {'new':>14s}")
    for name in sorted(set(old) & set(new)):
        for field in METRIC_FIELDS:
            if field not in old[name] or field not in new[name]:
                continue
            o, n = float(old[name][field]), float(new[name][field])
            drift = abs(n - o) > tol * max(abs(o), abs(n), 1e-12)
            tag = "  << DRIFT" if drift else ""
            if drift:
                regressions.append((name, field))
            print(f"{name:42s} {field:>10s} {o:14.6g} {n:14.6g}{tag}")
    missing = sorted(set(old) ^ set(new))
    for name in missing:
        side = "old only" if name in old else "new only"
        print(f"{name}: {side}  << CELL MISMATCH")
    if regressions or missing:
        print(
            f"FAIL: {len(regressions)} metric(s) drifted beyond {tol:g} "
            f"relative, {len(missing)} cell(s) unmatched (regenerate the "
            "baseline if the sweep population changed intentionally)"
        )
        return 1
    print(f"OK: all metric cells match within {tol:g} relative")
    return 0


def speedups(cells: Dict[Key, dict]) -> Dict[Tuple[int, int], float]:
    """legacy/vectorized us_per_call per (jobs, regions) cell, where both
    engines are present."""
    out: Dict[Tuple[int, int], float] = {}
    for (jobs, regions, engine), c in cells.items():
        if engine != "vectorized":
            continue
        leg = cells.get((jobs, regions, "legacy"))
        if leg and c["us_per_call"] > 0:
            out[(jobs, regions)] = leg["us_per_call"] / c["us_per_call"]
    return out


def compare_relative(old, new, threshold: float) -> int:
    old_s, new_s = speedups(old), speedups(new)
    regressions = []
    print(f"{'cell':16s} {'old x':>8s} {'new x':>8s} {'ratio':>7s}")
    for key in sorted(set(old_s) & set(new_s)):
        o, n = old_s[key], new_s[key]
        ratio = n / o
        tag = ""
        if ratio < 1.0 - threshold:
            regressions.append((key, ratio))
            tag = "  << REGRESSION"
        print(f"j{key[0]}xr{key[1]:<8d} {o:8.2f} {n:8.2f} {ratio:7.3f}{tag}")
    for key in sorted(set(old_s) ^ set(new_s)):
        side = "old only" if key in old_s else "new only"
        print(f"j{key[0]}xr{key[1]}: {side} (not compared)")
    if regressions:
        worst = min(r for _, r in regressions)
        print(
            f"FAIL: {len(regressions)} cell(s) lost more than "
            f"{threshold:.0%} of their engine speedup (worst {worst:.2f}x)"
        )
        return 1
    print(f"OK: no cell lost more than {threshold:.0%} of its speedup")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", type=Path, help="baseline BENCH_scheduler.json")
    ap.add_argument("new", type=Path, help="candidate BENCH_scheduler.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="allowed fractional us_per_call growth per cell (default 0.20)",
    )
    ap.add_argument(
        "--relative",
        action="store_true",
        help="gate on per-cell engine speedup (machine-portable) instead of "
        "absolute us_per_call",
    )
    ap.add_argument(
        "--metrics",
        action="store_true",
        help="gate name-keyed cells on deterministic simulation metrics "
        "(jct_s/cost/migrations) instead of timings",
    )
    ap.add_argument(
        "--metric-tol",
        type=float,
        default=1e-6,
        help="relative tolerance for --metrics drift (default 1e-6)",
    )
    args = ap.parse_args()

    if args.metrics:
        return compare_metrics(
            load_named_cells(args.old),
            load_named_cells(args.new),
            args.metric_tol,
        )

    old = load_cells(args.old)
    new = load_cells(args.new)

    if args.relative:
        return compare_relative(old, new, args.threshold)

    regressions = []
    print(f"{'cell':28s} {'old us':>10s} {'new us':>10s} {'ratio':>7s}")
    for key in sorted(set(old) & set(new)):
        jobs, regions, engine = key
        o, n = old[key]["us_per_call"], new[key]["us_per_call"]
        ratio = n / o if o > 0 else float("inf")
        tag = ""
        if ratio > 1.0 + args.threshold:
            regressions.append((key, ratio))
            tag = "  << REGRESSION"
        print(
            f"j{jobs}xr{regions}/{engine:10s} {o:10.1f} {n:10.1f} "
            f"{ratio:7.3f}{tag}"
        )
    for key in sorted(set(old) ^ set(new)):
        side = "old only" if key in old else "new only"
        print(f"j{key[0]}xr{key[1]}/{key[2]}: {side} (not compared)")

    if regressions:
        worst = max(r for _, r in regressions)
        print(
            f"FAIL: {len(regressions)} cell(s) regressed beyond "
            f"{args.threshold:.0%} (worst {worst:.2f}x)"
        )
        return 1
    print(f"OK: no cell regressed beyond {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
