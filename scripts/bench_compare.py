#!/usr/bin/env python3
"""Diff two ``BENCH_scheduler.json`` files and gate on perf regression.

Usage:
    python scripts/bench_compare.py OLD.json NEW.json [--threshold 0.20]
                                    [--relative] [--new-cells-ok]

Matches cells by (jobs, regions, engine, backend) and compares
``us_per_call``.  Cells written before the decision-backend seam carry no
``backend`` field and default to ``"numpy"``, so old baselines keep
matching.  Any matched cell in NEW that is more than ``threshold`` (default
20%) slower than in OLD fails the gate: the script prints a per-cell table
and exits nonzero, so CI (or the next PR's driver) can refuse the change.
Cells present in only one file are reported but do not fail the gate —
sweeps are allowed to grow.

``--relative`` compares machine-portable per-(jobs, regions) *speedups*
(both sides of each ratio measured within the same run) instead of absolute
timings: the ``engine`` family (legacy / vectorized ``us_per_call``, numpy
backend) and the ``backend`` family (vectorized numpy / vectorized jax).
This is the mode for CI, where NEW comes from a shared runner while the
checked-in baseline was measured elsewhere: the gate fails only when NEW's
speedup falls more than ``threshold`` below OLD's on a matched cell.

``--metrics`` compares *named* cells (payloads whose cells carry a ``name``
key, e.g. ``BENCH_hetero.json``) on their simulation metrics (``jct_s``,
``cost``, ``migrations``) instead of timings.  The metrics are fully
deterministic, so the gate is a tight relative tolerance (``--metric-tol``,
default 1e-6) and cells present on only one side fail too (a silently
vanished or appeared scenario is drift).  ``--new-cells-ok`` relaxes only
the *new-only* half of that: cells added since the baseline pass (a PR may
grow the sweep before regenerating it), while cells *removed* from the
baseline still fail.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, Tuple

Key = Tuple[int, int, str, str]

#: Deterministic per-cell metrics the --metrics mode gates on (when present).
#: Absent fields are skipped per cell, so files from different benchmarks
#: (hetero scenarios vs. the schedule ablation) share one gate.
METRIC_FIELDS = (
    "jct_s",
    "cost",
    "migrations",
    "mean_iteration_s",
    "mean_bubble",
    "max_peak_activations",
)


def _load_payload(path: Path) -> list:
    """Read a BENCH_*.json and return its cell list, exiting with a clear
    one-line error (not a traceback) on a missing, truncated, or malformed
    file — CI artifacts get cut off mid-write often enough that the gate
    must say *which* file is bad and why."""
    if not path.is_file():
        raise SystemExit(f"{path}: no such file")
    try:
        text = path.read_text()
    except (OSError, UnicodeDecodeError) as exc:
        raise SystemExit(f"{path}: unreadable ({exc})")
    try:
        payload = json.loads(text)
    except json.JSONDecodeError as exc:
        raise SystemExit(
            f"{path}: malformed JSON at line {exc.lineno} col {exc.colno} "
            f"({exc.msg}) — truncated benchmark artifact?"
        )
    if not isinstance(payload, dict):
        raise SystemExit(
            f"{path}: expected a JSON object with a 'cells' list, got "
            f"{type(payload).__name__}"
        )
    cells = payload.get("cells", [])
    if not isinstance(cells, list) or not all(
        isinstance(c, dict) for c in cells
    ):
        raise SystemExit(f"{path}: 'cells' must be a list of objects")
    if not cells:
        raise SystemExit(f"{path}: no cells found")
    return cells


def _cell_field(c: dict, field: str, path: Path, cast=float):
    try:
        return cast(c[field])
    except KeyError:
        raise SystemExit(
            f"{path}: cell {c.get('name') or c.get('jobs', '?')} is missing "
            f"required field '{field}'"
        )
    except (TypeError, ValueError):
        raise SystemExit(
            f"{path}: cell field '{field}' is not a "
            f"{cast.__name__}: {c[field]!r}"
        )


def load_cells(path: Path) -> Dict[Key, dict]:
    out: Dict[Key, dict] = {}
    for c in _load_payload(path):
        key = (
            _cell_field(c, "jobs", path, int),
            _cell_field(c, "regions", path, int),
            _cell_field(c, "engine", path, str),
            str(c.get("backend", "numpy")),
        )
        _cell_field(c, "us_per_call", path, float)
        out[key] = c
    return out


def load_named_cells(path: Path) -> Dict[str, dict]:
    """Cells keyed by their ``name`` field (metric-gated benchmarks)."""
    out: Dict[str, dict] = {}
    for c in _load_payload(path):
        if "name" not in c:
            raise SystemExit(f"{path}: cell without a name (not a metrics file)")
        for field in METRIC_FIELDS:
            if field in c:
                _cell_field(c, field, path, float)
        out[str(c["name"])] = c
    return out


def compare_metrics(
    old: Dict[str, dict],
    new: Dict[str, dict],
    tol: float,
    new_cells_ok: bool = False,
) -> int:
    """Unlike the timing modes (where sweeps may grow), the metric sweep's
    *cell population* is itself deterministic: a cell present on only one
    side means a scenario/policy vanished or appeared without the baseline
    being regenerated, which is exactly the silent drift this gate exists to
    catch — so asymmetric cells fail, not just metric drift.  With
    ``new_cells_ok`` the new-only half is waived (a PR may grow the sweep
    ahead of its baseline); removed cells always fail."""
    regressions = []
    print(f"{'cell':42s} {'metric':>10s} {'old':>14s} {'new':>14s}")
    for name in sorted(set(old) & set(new)):
        for field in METRIC_FIELDS:
            if field not in old[name] or field not in new[name]:
                continue
            o, n = float(old[name][field]), float(new[name][field])
            drift = abs(n - o) > tol * max(abs(o), abs(n), 1e-12)
            tag = "  << DRIFT" if drift else ""
            if drift:
                regressions.append((name, field))
            print(f"{name:42s} {field:>10s} {o:14.6g} {n:14.6g}{tag}")
    removed = sorted(set(old) - set(new))
    added = sorted(set(new) - set(old))
    for name in removed:
        print(f"{name}: old only  << CELL MISMATCH")
    for name in added:
        if new_cells_ok:
            print(f"{name}: new only (allowed by --new-cells-ok)")
        else:
            print(f"{name}: new only  << CELL MISMATCH")
    mismatched = len(removed) + (0 if new_cells_ok else len(added))
    if regressions or mismatched:
        print(
            f"FAIL: {len(regressions)} metric(s) drifted beyond {tol:g} "
            f"relative, {mismatched} cell(s) unmatched (regenerate the "
            "baseline if the sweep population changed intentionally)"
        )
        return 1
    print(f"OK: all metric cells match within {tol:g} relative")
    return 0


def speedups(cells: Dict[Key, dict]) -> Dict[Tuple[str, int, int], float]:
    """Machine-portable speedups per (jobs, regions) cell, both sides of
    each ratio measured within the same run:

    - ``("engine", jobs, regions)``  — legacy / vectorized ``us_per_call``
      on the numpy backend;
    - ``("backend", jobs, regions)`` — vectorized numpy / vectorized jax
      ``us_per_call``.

    Only cells where both sides are present contribute."""
    out: Dict[Tuple[str, int, int], float] = {}
    for (jobs, regions, engine, backend), c in cells.items():
        if engine != "vectorized" or backend != "numpy":
            continue
        if c["us_per_call"] <= 0:
            continue
        leg = cells.get((jobs, regions, "legacy", "numpy"))
        if leg:
            out[("engine", jobs, regions)] = (
                leg["us_per_call"] / c["us_per_call"]
            )
        jx = cells.get((jobs, regions, "vectorized", "jax"))
        if jx and jx["us_per_call"] > 0:
            out[("backend", jobs, regions)] = (
                c["us_per_call"] / jx["us_per_call"]
            )
    return out


def compare_relative(old, new, threshold: float) -> int:
    old_s, new_s = speedups(old), speedups(new)
    regressions = []
    print(f"{'cell':26s} {'old x':>8s} {'new x':>8s} {'ratio':>7s}")
    for key in sorted(set(old_s) & set(new_s)):
        o, n = old_s[key], new_s[key]
        ratio = n / o
        tag = ""
        if ratio < 1.0 - threshold:
            regressions.append((key, ratio))
            tag = "  << REGRESSION"
        label = f"j{key[1]}xr{key[2]}/{key[0]}"
        print(f"{label:26s} {o:8.2f} {n:8.2f} {ratio:7.3f}{tag}")
    for key in sorted(set(old_s) ^ set(new_s)):
        side = "old only" if key in old_s else "new only"
        print(f"j{key[1]}xr{key[2]}/{key[0]}: {side} (not compared)")
    if regressions:
        worst = min(r for _, r in regressions)
        print(
            f"FAIL: {len(regressions)} cell(s) lost more than "
            f"{threshold:.0%} of their speedup (worst {worst:.2f}x)"
        )
        return 1
    print(f"OK: no cell lost more than {threshold:.0%} of its speedup")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", type=Path, help="baseline BENCH_scheduler.json")
    ap.add_argument("new", type=Path, help="candidate BENCH_scheduler.json")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.20,
        help="allowed fractional us_per_call growth per cell (default 0.20)",
    )
    ap.add_argument(
        "--relative",
        action="store_true",
        help="gate on per-cell engine/backend speedups (machine-portable) "
        "instead of absolute us_per_call",
    )
    ap.add_argument(
        "--metrics",
        action="store_true",
        help="gate name-keyed cells on deterministic simulation metrics "
        "(jct_s/cost/migrations) instead of timings",
    )
    ap.add_argument(
        "--metric-tol",
        type=float,
        default=1e-6,
        help="relative tolerance for --metrics drift (default 1e-6)",
    )
    ap.add_argument(
        "--new-cells-ok",
        action="store_true",
        help="--metrics only: cells present only in NEW pass (sweep grew "
        "ahead of its baseline); cells removed from OLD still fail",
    )
    args = ap.parse_args()

    if args.new_cells_ok and not args.metrics:
        ap.error(
            "--new-cells-ok only applies to --metrics mode (the timing "
            "modes never fail on unmatched cells)"
        )

    if args.metrics:
        return compare_metrics(
            load_named_cells(args.old),
            load_named_cells(args.new),
            args.metric_tol,
            new_cells_ok=args.new_cells_ok,
        )

    old = load_cells(args.old)
    new = load_cells(args.new)

    if args.relative:
        return compare_relative(old, new, args.threshold)

    regressions = []
    print(f"{'cell':34s} {'old us':>10s} {'new us':>10s} {'ratio':>7s}")
    for key in sorted(set(old) & set(new)):
        jobs, regions, engine, backend = key
        o, n = old[key]["us_per_call"], new[key]["us_per_call"]
        ratio = n / o if o > 0 else float("inf")
        tag = ""
        if ratio > 1.0 + args.threshold:
            regressions.append((key, ratio))
            tag = "  << REGRESSION"
        label = f"j{jobs}xr{regions}/{engine}-{backend}"
        print(f"{label:34s} {o:10.1f} {n:10.1f} {ratio:7.3f}{tag}")
    for key in sorted(set(old) ^ set(new)):
        side = "old only" if key in old else "new only"
        print(f"j{key[0]}xr{key[1]}/{key[2]}-{key[3]}: {side} (not compared)")

    if regressions:
        worst = max(r for _, r in regressions)
        print(
            f"FAIL: {len(regressions)} cell(s) regressed beyond "
            f"{args.threshold:.0%} (worst {worst:.2f}x)"
        )
        return 1
    print(f"OK: no cell regressed beyond {args.threshold:.0%}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
