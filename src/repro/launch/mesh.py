"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod (16, 16) = 256 chips, multi-pod (2, 16, 16) =
512 chips.  The ``pod`` axis is the WAN/cross-region link of the paper; the
``data``/``model`` axes are the intra-pod fabric.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False):
    """Tiny mesh for CI-sized device counts (8 host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


#: TPU v5e-class hardware constants for the roofline analysis.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BANDWIDTH = 819e9          # bytes/s per chip
ICI_BANDWIDTH = 50e9           # bytes/s per link
