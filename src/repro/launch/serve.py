"""Batched serving driver: prefill-free decode loop over a request batch.

Runs for real on CPU with reduced configs:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 4 --prompt-len 16 --new-tokens 24
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import ModelCtx, build_model


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.model_axis == "pp":  # single-device serving path
        cfg = dataclasses.replace(cfg, model_axis="tp")
    api = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(key)
    ctx = ModelCtx()

    cache_len = args.prompt_len + args.new_tokens
    cache = api.init_cache(args.batch, cache_len)
    if cfg.family == "encdec":
        cache["memory"] = (
            jax.random.normal(key, (args.batch, cache_len, cfg.d_model)) * 0.02
        )

    decode = jax.jit(
        lambda p, c, b: api.decode_step(p, c, b, cfg, ctx)
    )

    # "prefill" by feeding prompt tokens through the decode path one by one
    # (keeps one compiled program; bulk prefill is the prefill_32k cell).
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.prompt_len - 1):
        _, cache = decode(params, cache, {"token": tok, "pos": jnp.int32(i)})
        tok = jnp.asarray(prompt[:, i + 1 : i + 2], jnp.int32)

    generated = []
    for i in range(args.new_tokens):
        pos = jnp.int32(args.prompt_len - 1 + i)
        logits, cache = decode(params, cache, {"token": tok, "pos": pos})
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, 0, :] / args.temperature)
        else:
            nxt = jnp.argmax(logits[:, 0, :], axis=-1)
        tok = nxt[:, None].astype(jnp.int32)
        generated.append(np.asarray(nxt))
    dt = time.perf_counter() - t0
    toks = np.stack(generated, 1)
    total = args.batch * (args.prompt_len + args.new_tokens - 1)
    print(f"[serve] {cfg.arch_id}: generated {toks.shape} tokens; "
          f"{total / dt:.1f} tok/s (batch {args.batch})")
    print("[serve] sample:", toks[0][:16].tolist())
    assert np.all(toks >= 0) and np.all(toks < cfg.padded_vocab)


if __name__ == "__main__":
    main()
