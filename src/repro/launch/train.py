"""End-to-end training driver (runs for real on CPU with reduced configs;
the same code path lowers the full configs on the production meshes).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-32b --reduced \
      --steps 30 --simulate-failure 12
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM, make_batch_iterator
from repro.distributed.compat import use_mesh
from repro.distributed.sharding import param_specs
from repro.ft import FailureInjector, resilient_train_loop
from repro.launch import steps as S
from repro.models.model import build_model
from repro.optim import adamw_init


def build_everything(cfg, mesh, *, batch, seq, multi_pod, dtype, seed=0):
    """Init real state + jitted train step for any strategy."""
    api = build_model(cfg)
    if cfg.model_axis == "pp":
        lay = S.pp_layout(cfg, mesh, multi_pod)
        step_fn, _, layout = S.build_pp_train(
            cfg, mesh, multi_pod=multi_pod, batch=batch, seq=seq, dtype=dtype
        )
        pspecs = S.pp_param_specs(cfg, mesh, lay[1])

        def init_params():
            from repro.pipeline import stack_pipeline_params

            p = api.init(jax.random.PRNGKey(seed), dtype)
            p = dict(p)
            p["blocks"] = stack_pipeline_params(p["blocks"], lay[0])
            return p
    else:
        step_fn, _, _ = S.build_auto_train(
            cfg, mesh, multi_pod=multi_pod, batch=batch
        )
        pspecs = param_specs(cfg, mesh)

        def init_params():
            return api.init(jax.random.PRNGKey(seed), dtype)

    params_abs = jax.eval_shape(init_params)
    sspecs = S.state_specs(cfg, mesh, params_abs, pspecs)
    state_ns = S.ns(mesh, sspecs)

    with use_mesh(mesh):
        params = jax.jit(init_params, out_shardings=S.ns(mesh, pspecs))()
        opt = jax.jit(adamw_init, out_shardings=state_ns.opt)(params)
    state = S.TrainState(params, opt)

    jit_step = jax.jit(
        step_fn,
        in_shardings=(state_ns, None),
        out_shardings=(state_ns, None),
        donate_argnums=(0,),
    )
    return state, jit_step, state_ns


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) twin of the arch")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", choices=["single", "debug", "debug-mp"], default="single")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--simulate-failure", type=int, default=None,
                    help="inject a region failure at this step")
    ap.add_argument("--dtype", choices=["f32", "bf16"], default="f32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # keep pipeline/scan divisibility on tiny runs
    if cfg.model_axis == "pp" and args.mesh == "single":
        cfg = dataclasses.replace(cfg, model_axis="tp")

    if args.mesh == "single":
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        multi_pod = False
    else:
        from repro.launch.mesh import make_debug_mesh

        multi_pod = args.mesh == "debug-mp"
        mesh = make_debug_mesh(multi_pod=multi_pod)

    dtype = jnp.float32 if args.dtype == "f32" else jnp.bfloat16
    state, jit_step, _ = build_everything(
        cfg, mesh, batch=args.batch, seq=args.seq, multi_pod=multi_pod,
        dtype=dtype,
    )
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"[train] arch={cfg.arch_id} family={cfg.family} params={n_params/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    source = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch)
    bspec = S.batch_axis_spec(mesh, multi_pod, args.batch)
    batches = make_batch_iterator(source, cfg, mesh, bspec)

    injector = None
    if args.simulate_failure is not None:
        injector = FailureInjector({args.simulate_failure: "pod-1"})

    def wrapped_step(state_, batch_):
        with use_mesh(mesh):
            return jit_step(state_, batch_)

    out = resilient_train_loop(
        train_step=wrapped_step,
        state=state,
        batches=batches,
        n_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        injector=injector,
    )
    first, last = out["losses"][0], out["losses"][-1]
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"(restarts={out['restarts']}, stragglers={len(out['stragglers'])})")
    if not np.isfinite(last):
        raise SystemExit("non-finite loss")


if __name__ == "__main__":
    main()
