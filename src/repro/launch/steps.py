"""Builds jittable train / prefill / serve steps for every (arch x mesh).

Strategy dispatch (cfg.model_axis):
  'tp' / 'ep'  — GSPMD auto-sharding with named-axis constraints; MoE FFN
                 runs its own manual all_to_all shard_map over `model`.
                 Multi-pod: per-pod DDP inside a shard_map over `pod` with
                 int8-compressed gradient exchange ('tp'), or GSPMD pod-DP
                 ('ep': the MoE shard_map cannot nest).
  'pp'         — GPipe pipeline over `model` (16 stages) inside a
                 partial-manual shard_map.  Multi-pod: when the layer count
                 divides 32, the pipeline extends over ('pod','model') — the
                 stage-15->16 hop is the cross-region WAN edge, exactly the
                 paper's geo-PP placement; otherwise the pod axis is plain
                 (auto) data parallelism.

The builders return step functions plus everything needed to jit/lower them
(abstract state, sharding specs, batch specs) so dryrun.py and train.py
share one code path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import constrain_auto_axes, shard_map

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.compression import compressed_pmean
from repro.distributed.sharding import (
    axis_size,
    make_shard_act,
    param_specs,
)
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    chunked_xent,
    dense_block_apply,
    embed,
    lm_logits,
    rms_norm,
    rope_angles,
)
from repro.models.model import ModelCtx, build_model
from repro.optim import adamw_init, adamw_update, cosine_schedule, opt_state_specs
from repro.pipeline import pipeline_decode, pipeline_forward, stack_pipeline_params

AUX_WEIGHT = 0.01


# ---------------------------------------------------------------- TrainState
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, c: TrainState(*c),
)


def ns(mesh: Mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def pp_layout(cfg: ArchConfig, mesh: Mesh, multi_pod: bool) -> Tuple[int, Tuple[str, ...]]:
    """(n_stages, pipeline axes).  Multi-pod extends the pipeline across the
    pod axis when the layer count divides 2*model; otherwise the pod axis
    stays auto data-parallel."""
    m = axis_size(mesh, "model")
    if multi_pod and cfg.n_layers % (2 * m) == 0:
        return 2 * m, ("pod", "model")
    return m, ("model",)


def dp_shards(mesh: Mesh, multi_pod: bool, pipe_axes=()) -> int:
    d = axis_size(mesh, "data")
    if multi_pod and "pod" not in pipe_axes:
        d *= axis_size(mesh, "pod")
    return d


def microbatch_count(batch: int, dp: int, cap: int = 32) -> int:
    per_shard = max(1, batch // max(1, dp))
    m = min(per_shard, cap)
    while batch % m:
        m -= 1
    return max(1, m)


def batch_axis_spec(mesh: Mesh, multi_pod: bool, batch: int, *, pipe_axes=()):
    """Batch-dim sharding.  When the pod axis carries pipeline stages it
    cannot also shard the batch."""
    if batch == 1:
        return None
    pod_free = multi_pod and "pod" not in pipe_axes
    if pod_free and batch % (axis_size(mesh, "pod") * axis_size(mesh, "data")) == 0:
        return ("pod", "data")
    if batch % axis_size(mesh, "data") == 0:
        return "data"
    return None


# ============================================================ input builders
def make_batch_specs(
    cfg: ArchConfig, mesh: Mesh, cell: ShapeCell, *, multi_pod: bool
) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, P]]:
    """ShapeDtypeStruct stand-ins + PartitionSpecs for one input-shape cell.
    The modality frontends are stubs: vlm gets precomputed patch embeddings,
    audio enc-dec gets precomputed frame embeddings.  Never allocates."""
    b, t = cell.global_batch, cell.seq_len
    pipe_axes = pp_layout(cfg, mesh, multi_pod)[1] if cfg.model_axis == "pp" else ()
    bspec = batch_axis_spec(mesh, multi_pod, b, pipe_axes=pipe_axes)
    i32, bf16 = jnp.int32, jnp.bfloat16
    sd = jax.ShapeDtypeStruct

    if cell.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            batch = {
                "src_embeds": sd((b, t, cfg.d_model), bf16),
                "tgt_tokens": sd((b, t), i32),
                "labels": sd((b, t), i32),
            }
            specs = {
                "src_embeds": P(bspec, None, None),
                "tgt_tokens": P(bspec, None),
                "labels": P(bspec, None),
            }
        elif cfg.family == "vlm":
            tv = int(t * cfg.vision_frac)
            tt = t - tv
            batch = {
                "tokens": sd((b, tt), i32),
                "vision_embeds": sd((b, tv, cfg.d_model), bf16),
                "positions3": sd((3, b, t), i32),
                "labels": sd((b, tt), i32),
            }
            specs = {
                "tokens": P(bspec, None),
                "vision_embeds": P(bspec, None, None),
                "positions3": P(None, bspec, None),
                "labels": P(bspec, None),
            }
        else:
            batch = {"tokens": sd((b, t), i32), "labels": sd((b, t), i32)}
            specs = {"tokens": P(bspec, None), "labels": P(bspec, None)}
        if cell.kind == "prefill":
            batch.pop("labels")
            specs.pop("labels")
        return batch, specs

    batch = {"token": sd((b, 1), i32), "pos": sd((), i32)}
    specs = {"token": P(bspec, None), "pos": P()}
    return batch, specs


# ================================================================= TP/EP path
def build_auto_train(
    cfg: ArchConfig,
    mesh: Mesh,
    *,
    multi_pod: bool,
    batch: int,
    compress_pod_grads: bool = True,
    use_kernel: bool = False,
    total_steps: int = 10_000,
):
    """train_step for 'tp'/'ep' archs."""
    api = build_model(cfg)
    shard_act = make_shard_act(cfg, mesh, batch=batch)
    ep = cfg.model_axis == "ep" and axis_size(mesh, "model") > 1
    ctx = ModelCtx(
        shard_act=shard_act,
        use_kernel=use_kernel,
        ep_axis="model" if ep else None,
        ep_size=axis_size(mesh, "model"),
        mesh=mesh,
    )

    def loss_fn(params, batch_):
        return api.loss(params, batch_, ctx, aux_weight=AUX_WEIGHT)

    # tp-archs across pods: manual DDP with int8-compressed WAN exchange.
    use_pod_ddp = (
        multi_pod and not ep and compress_pod_grads and batch % 2 == 0
    )

    def grads_fn(params, batch_):
        if not use_pod_ddp:
            return jax.value_and_grad(loss_fn)(params, batch_)

        def pod_fn(params_, batch__):
            loss, grads = jax.value_and_grad(loss_fn)(params_, batch__)
            grads = compressed_pmean(grads, "pod", axis_size(mesh, "pod"))
            return jax.lax.pmean(loss, "pod"), grads

        in_batch_specs = {
            k: (P(None, "pod") if k == "positions3" else P("pod"))
            for k in batch_
        }
        return shard_map(
            pod_fn,
            mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P(), params), in_batch_specs),
            out_specs=(P(), jax.tree.map(lambda _: P(), params)),
            axis_names={"pod"},
            check_vma=False,
        )(params, batch_)

    def train_step(state: TrainState, batch_):
        loss, grads = grads_fn(state.params, batch_)
        lr = cosine_schedule(
            state.opt.count, base_lr=3e-4, warmup=200, total=total_steps
        )
        new_params, new_opt = adamw_update(grads, state.opt, state.params, lr=lr)
        return TrainState(new_params, new_opt), loss

    return train_step, api, ctx


def build_auto_prefill(cfg: ArchConfig, mesh: Mesh, *, batch: int, multi_pod: bool):
    api = build_model(cfg)
    shard_act = make_shard_act(cfg, mesh, batch=batch)
    ep = cfg.model_axis == "ep" and axis_size(mesh, "model") > 1
    ctx = ModelCtx(
        shard_act=shard_act, ep_axis="model" if ep else None,
        ep_size=axis_size(mesh, "model"), mesh=mesh,
    )

    def prefill_step(params, batch_):
        h, _ = api.hidden(params, batch_, cfg, ctx)
        return lm_logits(params["embed"], h[:, -1:, :], cfg)

    return prefill_step, api, ctx


def build_auto_serve(cfg: ArchConfig, mesh: Mesh, *, batch: int):
    api = build_model(cfg)
    shard_act = make_shard_act(cfg, mesh, batch=batch)
    ctx = ModelCtx(shard_act=shard_act, mesh=mesh)

    def serve_step(params, cache, batch_):
        return api.decode_step(params, cache, batch_, cfg, ctx)

    return serve_step, api, ctx


def auto_cache_specs(cfg: ArchConfig, mesh: Mesh, cache_shapes, *, bspec):
    """Cache specs for the auto (tp/ep) path.  KV leaves [L, B, S, H, D]:
    batch over data(+pod); kv heads over model when divisible, else the
    sequence dim over model (GSPMD handles the distributed softmax)."""
    m = axis_size(mesh, "model")
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % m == 0

    def leaf_spec(x):
        nd = len(x.shape)
        if nd == 5:  # [L, B, S, H, D] kv cache
            if kv_ok:
                return P(None, bspec, None, "model", None)
            return P(None, bspec, "model", None, None)
        if nd == 6:  # gemma pairs [Lp, B, S, H, D] inside dict-of-2? no: [L,2?..]
            return P(None, None, bspec, None, None, None)
        if nd == 5 - 1:  # [L, B, K, C] conv history
            return P(None, bspec, None, None)
        if nd == 5 and False:
            pass
        if nd == 5 + 0:
            pass
        if nd == 5:
            pass
        if nd == 4:
            return P(None, bspec, None, None)
        if nd == 3:
            return P(None, bspec, None)
        return P(*([None] * nd))

    def ssm_leaf(x):
        nd = len(x.shape)
        ssm_ok = cfg.ssm_state and cfg.ssm_heads % m == 0
        if nd == 5:  # [L, B, H, P, N] state
            return P(None, bspec, "model" if ssm_ok else None, None, None)
        if nd == 4:  # [L, B, K-1, C] conv
            return P(None, bspec, None, None)
        return P(*([None] * nd))

    if cfg.family in ("ssm",):
        return jax.tree.map(ssm_leaf, cache_shapes)
    if cfg.family == "hybrid":
        def hybrid_leaf(x):
            nd = len(x.shape)
            # mamba leaves have 2 leading stack dims [G, A, B, ...]
            if nd == 6:  # [G, A, B, H, P, N]
                ssm_ok = cfg.ssm_heads % m == 0
                return P(None, None, bspec, "model" if ssm_ok else None, None, None)
            if nd == 5 and x.shape[-1] == cfg.head_dim_:  # shared kv [G,B,S,H,D]
                kvh_ok = cfg.n_kv_heads % m == 0
                if kvh_ok:
                    return P(None, bspec, None, "model", None)
                return P(None, bspec, "model", None, None)
            if nd == 5:  # [G, A, B, K, C] conv
                return P(None, None, bspec, None, None)
            return P(*([None] * nd))

        return jax.tree.map(hybrid_leaf, cache_shapes)
    if cfg.family == "encdec":
        def ed_leaf(x):
            nd = len(x.shape)
            if nd == 5:
                if kv_ok:
                    return P(None, bspec, None, "model", None)
                return P(None, bspec, "model", None, None)
            if nd == 3:  # memory [B, S, D]
                return P(bspec, None, None)
            return P(*([None] * nd))

        return jax.tree.map(ed_leaf, cache_shapes)
    return jax.tree.map(leaf_spec, cache_shapes)


# =================================================================== PP path
def _pp_batch_shard(x: jax.Array, name: str) -> jax.Array:
    """Inside the manual-model pipeline, pin every activation to stay
    batch-sharded over the (auto) data axis.  Without this GSPMD sometimes
    gathers activation-sized tensors over `data` to compute replicated
    weight grads — measured 1.8 TB/step per dot on qwen train_4k (SSPerf)."""
    return constrain_auto_axes(
        x, P("data", *([None] * (x.ndim - 1)))
    )


def _pp_stage_fn(cfg: ArchConfig, t: int, use_kernel: bool):
    cos, sin = (
        rope_angles(jnp.arange(t), cfg.head_dim_, cfg.rope_theta)
        if cfg.family != "ssm"
        else (None, None)
    )

    def stage_fn(blocks, x):
        dt = x.dtype

        if cfg.family == "ssm":
            def body(h, bp):
                h, _ = ssm_lib.mamba_block_apply(
                    bp, h, cfg, use_kernel=use_kernel,
                    shard_act=_pp_batch_shard,
                )
                return h.astype(dt), None
        else:
            def body(h, bp):
                h, _ = dense_block_apply(
                    bp, h, cos, sin, cfg, shard_act=_pp_batch_shard
                )
                return h.astype(dt), None

        # full block remat (see models/model.py)
        x, _ = jax.lax.scan(jax.checkpoint(body), x, blocks)
        return x

    return stage_fn


def _pp_decode_stage_fn(cfg: ArchConfig):
    def stage_fn(blocks, cache_mb, x, pos):
        if cfg.family == "ssm":
            def body(h, xs):
                bp, c = xs
                h, c2 = ssm_lib.mamba_block_apply(bp, h, cfg, cache=c)
                return h, c2
        else:
            cos, sin = rope_angles(pos[None], cfg.head_dim_, cfg.rope_theta)

            def body(h, xs):
                bp, c = xs
                h, c2 = dense_block_apply(
                    bp, h, cos, sin, cfg, cache=c, cache_pos=pos
                )
                return h, c2

        x, cache2 = jax.lax.scan(body, x, (blocks, cache_mb))
        return x, cache2

    return stage_fn


@dataclasses.dataclass(frozen=True)
class PPLayout:
    n_stages: int
    pipe_axes: Tuple[str, ...]
    m_ub: int
    mb: int


def _pp_common(cfg, mesh, multi_pod, batch):
    n_stages, pipe_axes = pp_layout(cfg, mesh, multi_pod)
    dp = dp_shards(mesh, multi_pod, pipe_axes)
    m_ub = microbatch_count(batch, dp)
    mb = batch // m_ub
    return PPLayout(n_stages, pipe_axes, m_ub, mb)


def _pp_forward_hidden(cfg, params, tokens, lay: PPLayout, mesh, seq,
                       use_kernel, dtype):
    """shard_map'd pipeline forward -> [B, T, D] hidden after ln_f."""

    def inner(blocks, emb_table, tokens_):
        mbs = tokens_.reshape(lay.m_ub, lay.mb, seq)
        first_fn = lambda tok: embed({"table": emb_table}, tok, cfg)
        stage_fn = _pp_stage_fn(cfg, seq, use_kernel)
        ys = pipeline_forward(
            blocks, mbs, axis=lay.pipe_axes, n_stages=lay.n_stages,
            first_fn=first_fn, stage_fn=stage_fn,
            act_shape=(lay.mb, seq, cfg.d_model), act_dtype=dtype,
        )
        return ys[None]

    blocks_spec = P(lay.pipe_axes)
    # NB: the table crosses the manual boundary in f32 so its gradient psum
    # (transpose of a replicated input) is a 32-bit all-reduce — XLA's CPU
    # AllReducePromotion pass crashes cloning 16-bit reducers that carry a
    # Shardy sharding_constraint (see DESIGN.md "hardware adaptation").
    hidden = shard_map(
        inner,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: blocks_spec, params["blocks"]),
            P(), P(),
        ),
        out_specs=P(lay.pipe_axes),
        axis_names=set(lay.pipe_axes),
        check_vma=False,
    )(params["blocks"], params["embed"]["table"].astype(jnp.float32), tokens)
    h = hidden[-1].reshape(-1, seq, cfg.d_model)
    h = jax.lax.with_sharding_constraint(
        h, NamedSharding(mesh, P("data", None, None))
    )
    return rms_norm(h, params["ln_f"], cfg.rms_eps)


def build_pp_train(
    cfg: ArchConfig, mesh: Mesh, *, multi_pod: bool, batch: int, seq: int,
    use_kernel: bool = False, total_steps: int = 10_000, dtype=jnp.bfloat16,
):
    api = build_model(cfg)
    lay = _pp_common(cfg, mesh, multi_pod, batch)

    def loss_fn(params, batch_):
        h = _pp_forward_hidden(
            cfg, params, batch_["tokens"], lay, mesh, seq, use_kernel, dtype
        )
        # microbatch-major row order: [M, mb] -> flat
        lbl = batch_["labels"].reshape(lay.m_ub, lay.mb, seq).reshape(-1, seq)
        return chunked_xent(params["embed"], h, lbl, cfg)

    def train_step(state: TrainState, batch_):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch_)
        lr = cosine_schedule(
            state.opt.count, base_lr=3e-4, warmup=200, total=total_steps
        )
        new_params, new_opt = adamw_update(grads, state.opt, state.params, lr=lr)
        return TrainState(new_params, new_opt), loss

    return train_step, api, lay


def build_pp_prefill(cfg, mesh, *, multi_pod, batch, seq, use_kernel=False,
                     dtype=jnp.bfloat16):
    api = build_model(cfg)
    lay = _pp_common(cfg, mesh, multi_pod, batch)

    def prefill_step(params, batch_):
        h = _pp_forward_hidden(
            cfg, params, batch_["tokens"], lay, mesh, seq, use_kernel, dtype
        )
        return lm_logits(params["embed"], h[:, -1:, :], cfg)

    return prefill_step, api, lay


def pp_make_cache_shapes(cfg, lay: PPLayout, cache_len, cache_dtype=jnp.bfloat16):
    """Abstract stage-major decode cache: leaves [S, L/S, M, mb, ...]."""
    lps = cfg.n_layers // lay.n_stages

    def stacked(shape, dtype):
        return jax.ShapeDtypeStruct(
            (lay.n_stages, lps, lay.m_ub, lay.mb) + shape, dtype
        )

    if cfg.family == "ssm":
        return {
            "conv_x": stacked((cfg.ssm_conv - 1, cfg.d_inner), cache_dtype),
            "conv_b": stacked((cfg.ssm_conv - 1, cfg.ssm_state), cache_dtype),
            "conv_c": stacked((cfg.ssm_conv - 1, cfg.ssm_state), cache_dtype),
            "state": stacked(
                (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), cache_dtype
            ),
        }
    return {
        "k": stacked((cache_len, cfg.n_kv_heads, cfg.head_dim_), cache_dtype),
        "v": stacked((cache_len, cfg.n_kv_heads, cfg.head_dim_), cache_dtype),
    }


def build_pp_serve(cfg, mesh, *, multi_pod, batch, cache_len,
                   dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    """Pipelined one-token decode.  Cache leaves [S, L/S, M, mb, ...]; the
    per-stage cache row for a microbatch is dynamically indexed as the
    microbatch wavefront passes through."""
    api = build_model(cfg)
    lay = _pp_common(cfg, mesh, multi_pod, batch)

    def serve_step(params, cache, batch_):
        pos = batch_["pos"]

        def inner(blocks, emb_table, cache_, token_):
            toks = token_.reshape(lay.m_ub, lay.mb, 1)
            first_fn = lambda tok: embed({"table": emb_table}, tok, cfg)
            base_stage = _pp_decode_stage_fn(cfg)

            def stage_cached(params_, cache_mb, x, pos_):
                if cfg.family == "ssm":
                    # mamba cache dict: leaves [L/S, mb, ...]
                    return base_stage(params_, cache_mb, x, pos_)
                return base_stage(params_, cache_mb, x, pos_)

            ys, cache_new = pipeline_decode(
                blocks, cache_, toks, pos,
                axis=lay.pipe_axes, n_stages=lay.n_stages,
                first_fn=first_fn, stage_fn=stage_cached,
                act_shape=(lay.mb, 1, cfg.d_model), act_dtype=dtype,
            )
            return ys[None], cache_new

        blocks_spec = P(lay.pipe_axes)
        cache_tree_spec = jax.tree.map(lambda _: P(lay.pipe_axes), cache)
        hidden, cache_new = shard_map(
            inner,
            mesh=mesh,
            in_specs=(
                jax.tree.map(lambda _: blocks_spec, params["blocks"]),
                P(),
                cache_tree_spec,
                P(),
            ),
            out_specs=(P(lay.pipe_axes), cache_tree_spec),
            axis_names=set(lay.pipe_axes),
        check_vma=False,
        )(params["blocks"], params["embed"]["table"], cache, batch_["token"])
        h = hidden[-1].reshape(-1, 1, cfg.d_model)
        h = jax.lax.with_sharding_constraint(
            h, NamedSharding(mesh, P("data", None, None))
        )
        h = rms_norm(h, params["ln_f"], cfg.rms_eps)
        return lm_logits(params["embed"], h, cfg), cache_new

    return serve_step, api, lay


def pp_cache_specs(cfg, mesh, lay: PPLayout, cache_shapes, *, bspec):
    """Stage dim over the pipe axes; microbatch row dim over data(+pod when
    the pod axis isn't part of the pipeline)."""
    def leaf(x):
        rest = [None] * (len(x.shape) - 4)
        return P(lay.pipe_axes, None, None, bspec, *rest)

    return jax.tree.map(leaf, cache_shapes)


# ====================================================== state/spec assembly
def pp_abstract_params(cfg: ArchConfig, n_stages: int, dtype=jnp.bfloat16):
    api = build_model(cfg)

    def build():
        p = api.init(jax.random.PRNGKey(0), dtype)
        out = dict(p)
        out["blocks"] = stack_pipeline_params(p["blocks"], n_stages)
        return out

    return jax.eval_shape(build)


def pp_param_specs(cfg: ArchConfig, mesh: Mesh, pipe_axes) -> Any:
    base = param_specs(cfg, mesh)
    out = dict(base)
    out["blocks"] = jax.tree.map(
        lambda s: P(tuple(pipe_axes), *list(s)),
        base["blocks"],
        is_leaf=lambda x: isinstance(x, P),
    )
    return out


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    api = build_model(cfg)
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0), dtype))


def abstract_state(params_shapes) -> TrainState:
    opt = jax.eval_shape(adamw_init, params_shapes)
    return TrainState(params=params_shapes, opt=opt)


def state_specs(cfg: ArchConfig, mesh: Mesh, params_shapes, pspecs) -> TrainState:
    opt_specs = opt_state_specs(pspecs, params_shapes, mesh)
    return TrainState(params=pspecs, opt=opt_specs)
