import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real train/prefill/serve step with its
production shardings, lowers it against ShapeDtypeStruct stand-ins (no
allocation), compiles it AOT, and records:
  * memory_analysis()  — per-device bytes (proves the placement fits),
  * cost_analysis()    — per-device FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the optimized HLO per collective kind.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-32b \
      --shape train_4k [--multi-pod] [--debug-mesh] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compat import use_mesh

from repro.configs import SHAPES_BY_NAME, get_config, runnable_cells, ARCH_IDS
from repro.configs.base import ArchConfig, ShapeCell
from repro.launch import steps as S
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.analysis import loop_aware_cost
from repro.models.model import build_model


# ----------------------------------------------------------- HLO collectives
_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in optimized HLO."""
    out: Dict[str, float] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        total = 0
        for dm in _SHAPE_RE.finditer(shape_str):
            dt, dims = dm.group(1), dm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + float(total)
    return out


# ------------------------------------------------------------- cell builders
def lower_cell(
    cfg: ArchConfig,
    cell: ShapeCell,
    mesh,
    *,
    multi_pod: bool,
    cache_dtype=jnp.bfloat16,
):
    """Returns (lowered, meta) for one (arch, shape, mesh) cell."""
    b, t = cell.global_batch, cell.seq_len
    batch_abs, batch_specs = S.make_batch_specs(cfg, mesh, cell, multi_pod=multi_pod)
    batch_ns = S.ns(mesh, batch_specs)
    pipe_axes = S.pp_layout(cfg, mesh, multi_pod)[1] if cfg.model_axis == "pp" else ()
    bspec = S.batch_axis_spec(mesh, multi_pod, b, pipe_axes=pipe_axes)
    meta: Dict[str, Any] = {}

    if cfg.model_axis == "pp":
        lay_probe = S.pp_layout(cfg, mesh, multi_pod)
        meta["pipeline"] = {"stages": lay_probe[0], "axes": lay_probe[1]}
        params_abs = S.pp_abstract_params(cfg, lay_probe[0])
        pspecs = S.pp_param_specs(cfg, mesh, lay_probe[1])
        if cell.kind == "train":
            step, _, lay = S.build_pp_train(
                cfg, mesh, multi_pod=multi_pod, batch=b, seq=t
            )
            state_abs = S.abstract_state(params_abs)
            sspecs = S.state_specs(cfg, mesh, params_abs, pspecs)
            fn = jax.jit(
                step,
                in_shardings=(S.ns(mesh, sspecs), batch_ns),
                out_shardings=(S.ns(mesh, sspecs), None),
                donate_argnums=(0,),
            )
            return fn.lower(state_abs, batch_abs), meta
        if cell.kind == "prefill":
            step, _, lay = S.build_pp_prefill(
                cfg, mesh, multi_pod=multi_pod, batch=b, seq=t
            )
            fn = jax.jit(step, in_shardings=(S.ns(mesh, pspecs), batch_ns))
            return fn.lower(params_abs, batch_abs), meta
        # decode
        step, _, lay = S.build_pp_serve(
            cfg, mesh, multi_pod=multi_pod, batch=b, cache_len=t,
            cache_dtype=cache_dtype,
        )
        cache_abs = S.pp_make_cache_shapes(cfg, lay, t, cache_dtype)
        cspecs = S.pp_cache_specs(cfg, mesh, lay, cache_abs, bspec=bspec)
        fn = jax.jit(
            step,
            in_shardings=(S.ns(mesh, pspecs), S.ns(mesh, cspecs), batch_ns),
            out_shardings=(None, S.ns(mesh, cspecs)),
            donate_argnums=(1,),
        )
        return fn.lower(params_abs, cache_abs, batch_abs), meta

    # ------------------------------------------------------------ tp/ep
    from repro.distributed.sharding import param_specs

    params_abs = S.abstract_params(cfg)
    pspecs = param_specs(cfg, mesh)
    if cell.kind == "train":
        step, _, _ = S.build_auto_train(cfg, mesh, multi_pod=multi_pod, batch=b)
        state_abs = S.abstract_state(params_abs)
        sspecs = S.state_specs(cfg, mesh, params_abs, pspecs)
        fn = jax.jit(
            step,
            in_shardings=(S.ns(mesh, sspecs), batch_ns),
            out_shardings=(S.ns(mesh, sspecs), None),
            donate_argnums=(0,),
        )
        return fn.lower(state_abs, batch_abs), meta
    if cell.kind == "prefill":
        step, _, _ = S.build_auto_prefill(cfg, mesh, batch=b, multi_pod=multi_pod)
        fn = jax.jit(step, in_shardings=(S.ns(mesh, pspecs), batch_ns))
        return fn.lower(params_abs, batch_abs), meta

    api = build_model(cfg)
    step, _, _ = S.build_auto_serve(cfg, mesh, batch=b)
    cache_abs = jax.eval_shape(lambda: api.init_cache(b, t, cache_dtype))
    cspecs = S.auto_cache_specs(cfg, mesh, cache_abs, bspec=bspec)
    fn = jax.jit(
        step,
        in_shardings=(S.ns(mesh, pspecs), S.ns(mesh, cspecs), batch_ns),
        out_shardings=(None, S.ns(mesh, cspecs)),
        donate_argnums=(1,),
    )
    return fn.lower(params_abs, cache_abs, batch_abs), meta


def run_cell(
    arch: str,
    shape: str,
    *,
    multi_pod: bool,
    debug: bool = False,
    out_dir: Optional[str] = None,
    cache_dtype: str = "bf16",
) -> Dict[str, Any]:
    cfg = get_config(arch)
    cell = SHAPES_BY_NAME[shape]
    mesh = make_debug_mesh(multi_pod=multi_pod) if debug else make_production_mesh(
        multi_pod=multi_pod
    )
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod, "cache_dtype": cache_dtype,
    }
    t0 = time.time()
    try:
        with use_mesh(mesh):
            lowered, meta = lower_cell(
                cfg, cell, mesh, multi_pod=multi_pod,
                cache_dtype={"bf16": jnp.bfloat16, "int8": jnp.int8}[cache_dtype],
            )
        record.update(meta)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
            ):
                record[k] = getattr(mem, k, None)
        cost = compiled.cost_analysis()
        if cost:
            # raw XLA numbers (while bodies counted once — kept for reference)
            record["flops_xla"] = cost.get("flops")
            record["bytes_accessed_xla"] = cost.get("bytes accessed")
        hlo = compiled.as_text()
        # loop-aware re-derivation: dot FLOPs / fusion-boundary bytes /
        # collective bytes scaled by while trip counts (analysis/hlo_cost.py)
        la = loop_aware_cost(hlo)
        record["flops"] = la["flops"]
        record["bytes_accessed"] = la["hbm_bytes"]
        record["collective_bytes"] = la["collective_bytes"]
        record["cost_warnings"] = la["n_warnings"]
        record["hlo_bytes"] = len(hlo)
        record["status"] = "ok"
        print(
            f"[dryrun] {arch:22s} {shape:12s} mesh={record['mesh']:9s} OK  "
            f"flops/dev={record.get('flops', 0):.3e}  "
            f"coll={sum(record['collective_bytes'].values()):.3e}B  "
            f"(lower {record['lower_s']}s, compile {record['compile_s']}s)"
        )
        print(f"  memory_analysis: { {k: record.get(k) for k in ('argument_size_in_bytes','output_size_in_bytes','temp_size_in_bytes')} }")
        print(f"  cost_analysis: flops={record.get('flops')}, bytes_accessed={record.get('bytes_accessed')}")
    except Exception as e:  # noqa: BLE001 — record and continue
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {arch:22s} {shape:12s} FAIL: {record['error'][:200]}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = "mp" if multi_pod else "sp"
        path = os.path.join(out_dir, f"{arch}__{shape}__{tag}.json")
        slim = {k: v for k, v in record.items() if k != "traceback"}
        with open(path, "w") as f:
            json.dump(slim, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--cache-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    jobs = []
    if args.all:
        for arch in ARCH_IDS:
            for cell in runnable_cells(get_config(arch)):
                jobs.append((arch, cell.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        jobs = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    n_fail = 0
    for arch, shape in jobs:
        for mp in meshes:
            rec = run_cell(
                arch, shape, multi_pod=mp, debug=args.debug_mesh,
                out_dir=args.out, cache_dtype=args.cache_dtype,
            )
            n_fail += rec["status"] != "ok"
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
