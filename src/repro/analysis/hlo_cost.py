"""Loop-aware cost extraction from optimized HLO text.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) counts a
while-loop body ONCE, so scanned-layer models under-report FLOPs/bytes by the
trip count.  This module re-derives the three roofline quantities directly
from ``compiled.as_text()`` with loop multipliers:

  * flops            — 2 * prod(dot output dims) * contraction size, summed
                       through nested whiles/fusions/calls;
  * hbm_bytes        — operand + result bytes at fusion/dot/collective/copy
                       boundaries (fusion internals stay in registers/VMEM);
  * collective_bytes — result bytes per collective kind, loop-scaled.

Trip counts come from each while condition's ``compare(iv, constant)``.
JAX-emitted scans always count 0..N with direction=LT; anything unparseable
falls back to multiplier 1 (recorded in ``warnings``).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+) .*?\{\s*$")
_INSTR = re.compile(
    r"^\s+(?:ROOT )?%([\w\.\-]+) = (.+?) ([\w\-]+)\((.*?)\)(.*)$"
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_ATTR = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_IN_COND = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[List[int], str]:
    m = _SHAPE.search(type_str)
    if not m:
        return [], ""
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims, m.group(1)


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    operands: List[str]
    attrs: str


def parse_hlo(text: str):
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line:
            m = _COMP_HEADER.match(line.strip("\n"))
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, out_type, op, args, attrs = m.groups()
        operands = [
            a.strip().lstrip("%").split(" ")[-1].lstrip("%")
            for a in args.split(",")
            if a.strip()
        ]
        comps[cur].append(Instr(name, out_type, op, operands, attrs))
    return comps, entry


def _trip_count(comps, cond_name: str) -> Optional[int]:
    """JAX scans lower to `while(iv < N)` with iv counting from 0; on CPU the
    compare is often wrapped in a kLoop fusion, so simply take the largest
    integer constant defined in the condition computation."""
    best: Optional[int] = None
    for ins in comps.get(cond_name, []):
        if ins.op == "constant" and ins.operands:
            try:
                val = int(ins.operands[0])
            except ValueError:
                continue
            if best is None or val > best:
                best = val
    return best


def _fusion_input_bytes(
    comps, fused_name: str, operand_types: List[str]
) -> float:
    """HBM bytes read by a fusion.  A parameter consumed *only* through
    dynamic-slice/gather reads just the slice, not the whole operand (the
    stacked-weights case: scanned layers slice one layer per step)."""
    body = comps.get(fused_name)
    if body is None:
        return float(sum(_shape_bytes(t) for t in operand_types))
    # parameter name -> index
    param_idx: Dict[str, int] = {}
    for ins in body:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", f"{ins.op}({ins.operands[0] if ins.operands else ''})")
            idx = int(ins.operands[0]) if ins.operands and ins.operands[0].isdigit() else len(param_idx)
            param_idx[ins.name] = idx
    total = 0.0
    for pname, idx in param_idx.items():
        if idx >= len(operand_types):
            continue
        full = _shape_bytes(operand_types[idx])
        users = [i for i in body if pname in i.operands]
        if users and all(u.op in ("dynamic-slice", "gather") for u in users):
            total += sum(_shape_bytes(u.out_type) for u in users)
        else:
            total += full
    return total


def _dot_flops(ins: Instr, symbols: Dict[str, str]) -> float:
    out_dims, _ = _shape_dims(ins.out_type)
    out_n = 1
    for d in out_dims:
        out_n *= d
    contract = 1
    m = _CONTRACT.search(ins.attrs)
    lhs_type = symbols.get(ins.operands[0] if ins.operands else "", "")
    lhs_dims, _ = _shape_dims(lhs_type)
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_n * contract


def loop_aware_cost(text: str) -> Dict:
    comps, entry = parse_hlo(text)
    warnings: List[str] = []

    # symbol table per computation: instr name -> out type (for dot operands)
    def _is_quadratic(type_str: str) -> bool:
        # attention-score-shaped: last two dims both attention-chunk sized
        # (512..2048 square-ish tiles) -- exactly the traffic a fused flash
        # kernel keeps in VMEM.  Excludes [T, d_ff]-shaped MLP tensors.
        dims, _ = _shape_dims(type_str)
        return (
            len(dims) >= 2
            and 512 <= dims[-1] <= 2048
            and 512 <= dims[-2] <= 2048
        )

    def analyse(comp: str, mult: float, seen: Tuple[str, ...]) -> Dict:
        flops = 0.0
        hbm = 0.0
        quad = 0.0
        coll: Dict[str, float] = {}
        if comp in seen:  # defensive: no recursion
            return {"flops": 0.0, "hbm": 0.0, "quad": 0.0, "coll": {}}
        symbols = {i.name: i.out_type for i in comps.get(comp, [])}
        for ins in comps.get(comp, []):
            op = ins.op
            out_b = _shape_bytes(ins.out_type)
            if op == "dot":
                flops += _dot_flops(ins, symbols) * mult
                in_b = sum(_shape_bytes(symbols.get(o, "")) for o in ins.operands)
                hbm += (out_b + in_b) * mult
                if _is_quadratic(ins.out_type):
                    quad += out_b * mult
                for o in ins.operands:
                    if _is_quadratic(symbols.get(o, "")):
                        quad += _shape_bytes(symbols.get(o, "")) * mult
            elif op == "fusion":
                m = _CALL_ATTR.search(ins.attrs)
                in_b = (
                    _fusion_input_bytes(
                        comps, m.group(1),
                        [symbols.get(o, "") for o in ins.operands],
                    )
                    if m
                    else sum(_shape_bytes(symbols.get(o, "")) for o in ins.operands)
                )
                hbm += (out_b + in_b) * mult
                if _is_quadratic(ins.out_type):
                    quad += out_b * mult
                for o in ins.operands:
                    if _is_quadratic(symbols.get(o, "")):
                        quad += _shape_bytes(symbols.get(o, "")) * mult
                if m:  # dots inside the fused computation still do FLOPs
                    sub = analyse(m.group(1), mult, seen + (comp,))
                    flops += sub["flops"]
                    quad += sub["quad"]
                    for k, v in sub["coll"].items():
                        coll[k] = coll.get(k, 0.0) + v
            elif op == "while":
                body = cond = None
                for am in _CALL_ATTR.finditer(ins.attrs):
                    pass
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(comps, cond) if cond else None
                if trips is None:
                    trips = 1
                    warnings.append(f"unparsed trip count for {ins.name}")
                sub = analyse(body, mult * trips, seen + (comp,)) if body else {
                    "flops": 0, "hbm": 0, "quad": 0, "coll": {}}
                flops += sub["flops"]
                hbm += sub["hbm"]
                quad += sub["quad"]
                for k, v in sub["coll"].items():
                    coll[k] = coll.get(k, 0.0) + v
            elif op == "conditional":
                m = _BRANCHES.search(ins.attrs)
                branches = (
                    [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    if m else []
                )
                subs = [analyse(b, mult, seen + (comp,)) for b in branches]
                if subs:  # conservative: the most expensive branch
                    best = max(subs, key=lambda s: s["flops"] + s["hbm"])
                    flops += best["flops"]
                    hbm += best["hbm"]
                    quad += best["quad"]
                    for k, v in best["coll"].items():
                        coll[k] = coll.get(k, 0.0) + v
            elif op in ("call", "custom-call", "async-start"):
                m = _CALL_ATTR.search(ins.attrs)
                if m and m.group(1) in comps:
                    sub = analyse(m.group(1), mult, seen + (comp,))
                    flops += sub["flops"]
                    hbm += sub["hbm"]
                    quad += sub["quad"]
                    for k, v in sub["coll"].items():
                        coll[k] = coll.get(k, 0.0) + v
                else:
                    hbm += out_b * mult
            elif any(op.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.startswith(c))
                if op.endswith("-done"):
                    continue  # counted at -start
                coll[kind] = coll.get(kind, 0.0) + out_b * mult
                hbm += out_b * mult
            elif op in ("dynamic-update-slice", "scatter"):
                # in-place update: only the update operand's bytes move
                upd_idx = 1 if op == "dynamic-update-slice" else 2
                upd = (
                    symbols.get(ins.operands[upd_idx], "")
                    if len(ins.operands) > upd_idx
                    else ins.out_type
                )
                hbm += 2 * _shape_bytes(upd) * mult  # read+write of the slice
            elif op == "reduce":
                in_b = sum(_shape_bytes(symbols.get(o, "")) for o in ins.operands)
                hbm += (in_b + out_b) * mult
            elif op in ("copy", "copy-start", "transpose", "reshape",
                        "dynamic-slice", "gather", "sort", "select"):
                # data-movement ops at the top level touch HBM
                hbm += out_b * mult
        return {"flops": flops, "hbm": hbm, "quad": quad, "coll": coll}

    if entry is None:
        raise ValueError("no ENTRY computation found")
    out = analyse(entry, 1.0, ())
    return {
        "flops": out["flops"],
        "hbm_bytes": out["hbm"],
        "attn_quadratic_bytes": out["quad"],
        "collective_bytes": out["coll"],
        "warnings": warnings[:20],
        "n_warnings": len(warnings),
    }
