from .hlo_cost import loop_aware_cost  # noqa: F401
