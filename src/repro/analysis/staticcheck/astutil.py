"""Shared AST helpers for reprolint rules.

Everything here is stdlib-``ast`` only.  The helpers cover the three
mechanics every rule needs: resolving dotted names through per-file import
aliases, walking a subtree without descending into nested function scopes,
and locating the enclosing function for a node.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Tuple


def import_aliases(tree: ast.Module) -> Dict[str, str]:
    """Map local names to the dotted module/object path they were imported
    as, for every top-level or nested import statement in the file.

    ``import numpy as np``            -> {"np": "numpy"}
    ``from numpy import random``      -> {"random": "numpy.random"}
    ``from datetime import datetime`` -> {"datetime": "datetime.datetime"}
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0]
                )
        elif isinstance(node, ast.ImportFrom):
            if node.module is None or node.level:  # relative imports: local
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted_name(node: ast.expr, aliases: Optional[Dict[str, str]] = None) -> Optional[str]:
    """Resolve an ``ast.Name``/``ast.Attribute`` chain to a dotted string,
    substituting the root through ``aliases`` when given.  Returns None for
    anything that is not a pure attribute chain (calls, subscripts, ...)."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    root = cur.id
    if aliases and root in aliases:
        root = aliases[root]
    parts.append(root)
    return ".".join(reversed(parts))


def call_name(node: ast.Call, aliases: Optional[Dict[str, str]] = None) -> Optional[str]:
    """Dotted name of the callee, or None when it is not a name chain."""
    return dotted_name(node.func, aliases)


def walk_shallow(node: ast.AST) -> Iterator[ast.AST]:
    """Yield descendants of ``node`` without entering nested function or
    class definitions (the node itself is not yielded)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(child))


def function_defs(tree: ast.AST) -> Iterator[Tuple[str, ast.AST]]:
    """Yield (qualname, def-node) for every function in the tree, including
    nested ones and methods.  Qualnames use ``Outer.inner`` dotted form."""

    def visit(node: ast.AST, prefix: str) -> Iterator[Tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, child
                yield from visit(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                yield from visit(child, f"{prefix}{child.name}.")
            else:
                yield from visit(child, prefix)

    yield from visit(tree, "")


def assigned_names(target: ast.expr) -> Iterator[str]:
    """Names bound by an assignment target (handles tuple unpacking and
    starred targets; attribute/subscript stores bind nothing new)."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from assigned_names(elt)
    elif isinstance(target, ast.Starred):
        yield from assigned_names(target.value)


def names_loaded(node: ast.AST) -> Iterator[str]:
    """All Name identifiers read anywhere under ``node``."""
    for child in ast.walk(node):
        if isinstance(child, ast.Name) and isinstance(child.ctx, ast.Load):
            yield child.id


def first_arg(call: ast.Call) -> Optional[ast.expr]:
    return call.args[0] if call.args else None


def literal_str(node: ast.expr) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def is_name_call(node: ast.AST, names: Sequence[str]) -> bool:
    """True when ``node`` is a call to one of the bare ``names``."""
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Name)
        and node.func.id in names
    )
