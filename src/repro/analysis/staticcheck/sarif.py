"""SARIF 2.1.0 export for reprolint findings.

Produces a single-run SARIF log so CI can upload findings to code-scanning
UIs (``github/codeql-action/upload-sarif``).  Only the schema subset those
consumers read is emitted: driver metadata, the rule catalog, and one
``result`` per diagnostic with a physical location.  New findings are
``error`` (they fail the run); baselined findings are included at ``note``
level with ``baselineState: "unchanged"`` so dashboards can show the
ratchet's remaining debt without failing the upload.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from .diagnostics import Diagnostic

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = (
    "https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/"
    "Schemata/sarif-schema-2.1.0.json"
)
TOOL_NAME = "reprolint"
TOOL_URI = "src/repro/analysis/staticcheck"


def _result(diag: Diagnostic, level: str, baselined: bool) -> Dict[str, object]:
    out: Dict[str, object] = {
        "ruleId": diag.code,
        "level": level,
        "message": {"text": diag.message},
        "locations": [
            {
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": diag.path,
                        "uriBaseId": "SRCROOT",
                    },
                    "region": {
                        "startLine": diag.line,
                        # SARIF columns are 1-based; ast's are 0-based.
                        "startColumn": diag.col + 1,
                    },
                }
            }
        ],
    }
    if baselined:
        out["baselineState"] = "unchanged"
    return out


def to_sarif(
    new: List[Diagnostic],
    baselined: List[Diagnostic],
    catalog: Dict[str, str],
) -> Dict[str, object]:
    """Build the SARIF log dict for one reprolint run."""
    rules = [
        {
            "id": code,
            "name": name,
            "shortDescription": {"text": name},
            "defaultConfiguration": {"level": "error"},
        }
        for code, name in sorted(catalog.items())
    ]
    results = [_result(d, "error", baselined=False) for d in new]
    results += [_result(d, "note", baselined=True) for d in baselined]
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [
            {
                "tool": {
                    "driver": {
                        "name": TOOL_NAME,
                        "informationUri": TOOL_URI,
                        "rules": rules,
                    }
                },
                "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
                "results": results,
            }
        ],
    }


def write_sarif(
    path: Path,
    new: List[Diagnostic],
    baselined: List[Diagnostic],
    catalog: Dict[str, str],
) -> None:
    payload = to_sarif(new, baselined, catalog)
    path.write_text(
        json.dumps(payload, indent=2, sort_keys=True) + "\n",
        encoding="utf-8",
    )
