"""reprolint — repo-specific static analysis for the repro engine.

AST visitors plus a lightweight intra-file call graph (stdlib ``ast`` only)
enforcing the contracts the runtime suites can only sample: determinism
(RPL1xx), ClusterState ledger encapsulation (RPL2xx), numpy/jax twin parity
(RPL3xx), jit hygiene (RPL4xx), and settle-before-release accounting
(RPL5xx).  Run with ``python -m repro.analysis.staticcheck`` or
``scripts/repro_lint.py``; see DESIGN.md "Static contracts".
"""

from .baseline import apply as apply_baseline, load as load_baseline, save as save_baseline
from .cli import main
from .diagnostics import Diagnostic
from .engine import Project, SourceFile, run_rules
from .rules import all_rules, rule_catalog

__all__ = [
    "Diagnostic",
    "Project",
    "SourceFile",
    "all_rules",
    "apply_baseline",
    "load_baseline",
    "main",
    "rule_catalog",
    "run_rules",
    "save_baseline",
]
