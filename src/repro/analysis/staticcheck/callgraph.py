"""Lightweight intra-file call graph.

Resolution is by bare callee name: a call ``settle(...)`` or ``x.settle(...)``
produces an edge to every function *named* ``settle`` known to the graph.
That over-approximation is exactly what a reachability contract wants — if
*any* plausible resolution reaches the target, the edge counts; a rename
that breaks all resolutions breaks reachability and fails loudly.

The graph is per-file because the settle-before-release contract is scoped
to ``core/scheduler.py``; cross-module callees that the file merely imports
(e.g. ``SegmentLedger.settle``) still appear as attribute-call *names*, so
name-level targets match without needing import resolution.
"""

from __future__ import annotations

import ast
from collections import deque
from typing import Dict, Iterator, List, Set, Tuple

from .astutil import function_defs, walk_shallow


class CallGraph:
    def __init__(self, tree: ast.Module) -> None:
        # bare function name -> def nodes (methods and nested defs included)
        self.defs: Dict[str, List[ast.AST]] = {}
        self.qualnames: Dict[int, str] = {}
        for qual, node in function_defs(tree):
            name = qual.rsplit(".", 1)[-1]
            self.defs.setdefault(name, []).append(node)
            self.qualnames[id(node)] = qual
        # bare function name -> bare callee names reachable in one hop
        self.edges: Dict[str, Set[str]] = {}
        for name, nodes in self.defs.items():
            callees: Set[str] = set()
            for node in nodes:
                callees |= set(self.callee_names(node))
            self.edges[name] = callees

    @staticmethod
    def callee_names(func_node: ast.AST) -> Iterator[str]:
        """Bare names of everything called directly inside ``func_node``
        (not inside its nested defs — those have their own graph entries)."""
        for child in walk_shallow(func_node):
            if not isinstance(child, ast.Call):
                continue
            fn = child.func
            if isinstance(fn, ast.Name):
                yield fn.id
            elif isinstance(fn, ast.Attribute):
                yield fn.attr

    def reaches(self, start: str, targets: Set[str]) -> bool:
        """True when a call chain starting from function name ``start`` can
        reach any function name in ``targets`` (including ``start`` itself
        calling a target directly)."""
        seen: Set[str] = set()
        queue = deque([start])
        while queue:
            cur = queue.popleft()
            if cur in seen:
                continue
            seen.add(cur)
            for callee in self.edges.get(cur, set()):
                if callee in targets:
                    return True
                if callee in self.edges and callee not in seen:
                    queue.append(callee)
        return False

    def call_reaches(self, callee_name: str, targets: Set[str]) -> bool:
        """True when a *call site* with bare name ``callee_name`` either is a
        target itself or resolves to a local def that reaches a target."""
        if callee_name in targets:
            return True
        return self.reaches(callee_name, targets)


def ordered_calls(func_node: ast.AST) -> List[Tuple[Tuple[int, int], str, ast.Call]]:
    """All direct call sites in ``func_node`` (nested defs excluded), as
    ``((line, col), bare_name, node)`` sorted in source order."""
    out: List[Tuple[Tuple[int, int], str, ast.Call]] = []
    for child in walk_shallow(func_node):
        if not isinstance(child, ast.Call):
            continue
        fn = child.func
        if isinstance(fn, ast.Name):
            name = fn.id
        elif isinstance(fn, ast.Attribute):
            name = fn.attr
        else:
            continue
        out.append(((child.lineno, child.col_offset), name, child))
    out.sort(key=lambda t: t[0])
    return out
