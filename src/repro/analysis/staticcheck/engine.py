"""File collection, suppression parsing, and rule dispatch for reprolint.

A ``SourceFile`` owns one parsed module plus its per-line suppression table;
a ``Project`` owns the set of files under analysis and the repo root used to
render relative paths.  Rules receive the whole project so cross-file rules
(ledger encapsulation, twin parity) and single-file rules share one pass.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

from .astutil import import_aliases
from .diagnostics import Diagnostic

SUPPRESS_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Z0-9,\s*]+)")

# Directory names never scanned: intentional-violation fixtures and
# third-party/cache trees.
EXCLUDED_DIR_NAMES = {
    "__pycache__",
    ".git",
    ".venv",
    "node_modules",
    "golden",
}
# Path fragments excluded anywhere they appear (posix, relative).
EXCLUDED_FRAGMENTS = ("fixtures/staticcheck",)


class SourceFileError(Exception):
    """Raised when a file under analysis cannot be parsed."""


@dataclasses.dataclass
class SourceFile:
    path: Path                 # absolute
    rel: str                   # posix path relative to project root
    text: str
    tree: ast.Module
    suppressions: Dict[int, Set[str]]  # line -> codes ("*" = all)
    aliases: Dict[str, str]

    @classmethod
    def load(cls, path: Path, root: Path) -> "SourceFile":
        text = path.read_text(encoding="utf-8")
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError as exc:  # surfaced as a hard error by the runner
            raise SourceFileError(f"{path}: {exc}") from exc
        suppressions: Dict[int, Set[str]] = {}
        for lineno, line in enumerate(text.splitlines(), start=1):
            m = SUPPRESS_RE.search(line)
            if m:
                codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
                suppressions[lineno] = codes
        try:
            rel = path.relative_to(root).as_posix()
        except ValueError:
            rel = path.as_posix()
        return cls(
            path=path,
            rel=rel,
            text=text,
            tree=tree,
            suppressions=suppressions,
            aliases=import_aliases(tree),
        )

    def suppressed(self, line: int, code: str) -> bool:
        codes = self.suppressions.get(line)
        if not codes:
            return False
        return "*" in codes or code in codes

    @property
    def parts(self) -> Sequence[str]:
        return Path(self.rel).parts

    def in_core(self) -> bool:
        return "core" in self.parts


@dataclasses.dataclass
class Project:
    root: Path
    files: List[SourceFile]

    @classmethod
    def collect(
        cls,
        paths: Iterable[Path],
        root: Optional[Path] = None,
        *,
        include_fixtures: bool = False,
    ) -> "Project":
        root = (root or Path.cwd()).resolve()
        seen: Set[Path] = set()
        files: List[SourceFile] = []
        for p in paths:
            p = Path(p).resolve()
            candidates = sorted(p.rglob("*.py")) if p.is_dir() else [p]
            for f in candidates:
                if f in seen or f.suffix != ".py":
                    continue
                if any(part in EXCLUDED_DIR_NAMES for part in f.parts):
                    continue
                posix = f.as_posix()
                if not include_fixtures and any(
                    frag in posix for frag in EXCLUDED_FRAGMENTS
                ):
                    continue
                seen.add(f)
                files.append(SourceFile.load(f, root))
        files.sort(key=lambda sf: sf.rel)
        return cls(root=root, files=files)

    def by_rel(self, suffix: str) -> List[SourceFile]:
        """Files whose relative path ends with ``suffix`` (posix)."""
        return [f for f in self.files if f.rel.endswith(suffix)]


def run_rules(project: Project, rules: Sequence[object]) -> List[Diagnostic]:
    """Run every rule over the project, apply per-line suppressions, and
    return the surviving diagnostics in deterministic order."""
    by_path = {f.rel: f for f in project.files}
    out: List[Diagnostic] = []
    for rule in rules:
        for diag in rule.check(project):  # type: ignore[attr-defined]
            sf = by_path.get(diag.path)
            if sf is not None and sf.suppressed(diag.line, diag.code):
                continue
            out.append(diag)
    out.sort(key=Diagnostic.sort_key)
    return out
