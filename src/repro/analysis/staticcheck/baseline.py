"""Checked-in baseline of grandfathered findings.

The baseline is a ratchet: findings recorded in it are reported as
"baselined" and do not fail the run; findings *not* in it fail; entries in
it that no longer occur are "stale" — celebrated in the summary, and a
failure under ``--strict-baseline`` (CI) so the file shrinks monotonically.

Entries match on ``(code, path, message)`` with a count, never on line
numbers, so unrelated edits don't churn the file.
"""

from __future__ import annotations

import dataclasses
import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Tuple

from .diagnostics import Diagnostic

BASELINE_VERSION = 1

Key = Tuple[str, str, str]


@dataclasses.dataclass
class BaselineResult:
    new: List[Diagnostic]
    baselined: List[Diagnostic]
    stale: List[Dict[str, object]]  # baseline entries with no matching finding


def load(path: Path) -> Counter:
    """Load a baseline file into a Counter over (code, path, message)."""
    data = json.loads(path.read_text(encoding="utf-8"))
    if not isinstance(data, dict) or data.get("version") != BASELINE_VERSION:
        raise ValueError(f"{path}: unsupported baseline format")
    counts: Counter = Counter()
    for entry in data.get("entries", []):
        key = (str(entry["code"]), str(entry["path"]), str(entry["message"]))
        counts[key] += int(entry.get("count", 1))
    return counts


def save(path: Path, diags: List[Diagnostic]) -> None:
    counts: Counter = Counter(d.baseline_key for d in diags)
    entries = [
        {"code": code, "path": p, "message": msg, "count": n}
        for (code, p, msg), n in sorted(counts.items())
    ]
    payload = {"version": BASELINE_VERSION, "entries": entries}
    path.write_text(json.dumps(payload, indent=2) + "\n", encoding="utf-8")


def apply(diags: List[Diagnostic], baseline: Counter) -> BaselineResult:
    remaining = Counter(baseline)
    new: List[Diagnostic] = []
    baselined: List[Diagnostic] = []
    for d in diags:
        if remaining.get(d.baseline_key, 0) > 0:
            remaining[d.baseline_key] -= 1
            baselined.append(d)
        else:
            new.append(d)
    stale = [
        {"code": code, "path": p, "message": msg, "count": n}
        for (code, p, msg), n in sorted(remaining.items())
        if n > 0
    ]
    return BaselineResult(new=new, baselined=baselined, stale=stale)
