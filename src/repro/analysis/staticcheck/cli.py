"""reprolint command line.

``python -m repro.analysis.staticcheck [paths...]`` runs every rule over
the given files/directories (default: ``src benchmarks scripts tests`` when
run from the repo root) and exits non-zero on findings not covered by the
baseline.

Exit codes: 0 clean (or fully baselined), 1 findings / stale strict
baseline, 2 usage or parse errors.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List, Optional

from . import baseline as baseline_mod
from . import cache as cache_mod
from .diagnostics import Diagnostic
from .engine import Project, SourceFileError, run_rules
from .rules import all_rules, rule_catalog, rule_codes
from .sarif import write_sarif

DEFAULT_PATHS = ("src", "benchmarks", "scripts", "tests")
DEFAULT_BASELINE = "reprolint_baseline.json"


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="reprolint",
        description="Repo-specific static analysis for the repro engine's "
        "determinism, ledger, twin-parity, jit, and accounting contracts.",
    )
    p.add_argument("paths", nargs="*", help="files or directories to check")
    p.add_argument(
        "--baseline", type=Path, default=None,
        help=f"baseline file (default: ./{DEFAULT_BASELINE} when present)",
    )
    p.add_argument(
        "--write-baseline", action="store_true",
        help="write the current findings to the baseline file and exit 0",
    )
    p.add_argument(
        "--strict-baseline", action="store_true",
        help="fail when the baseline contains stale entries (CI ratchet)",
    )
    p.add_argument(
        "--select", default=None,
        help="comma-separated rule codes to run (default: all)",
    )
    p.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    p.add_argument(
        "--include-fixtures", action="store_true",
        help="also scan tests/fixtures/staticcheck (intentional violations)",
    )
    p.add_argument(
        "--sarif", type=Path, default=None, metavar="OUT",
        help="also write findings as a SARIF 2.1.0 log to OUT",
    )
    p.add_argument(
        "--cache", type=Path, default=None, metavar="PATH",
        help=f"per-file result cache (default: ./{cache_mod.DEFAULT_CACHE})",
    )
    p.add_argument(
        "--no-cache", action="store_true",
        help="disable the result cache for this run",
    )
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_rules:
        for code, name in rule_catalog().items():
            print(f"{code}  {name}")
        return 0

    paths = [Path(p) for p in (args.paths or DEFAULT_PATHS)]
    paths = [p for p in paths if p.exists()]
    if not paths:
        print("reprolint: no matching paths", file=sys.stderr)
        return 2

    try:
        project = Project.collect(
            paths, include_fixtures=args.include_fixtures
        )
    except SourceFileError as exc:
        print(f"reprolint: {exc}", file=sys.stderr)
        return 2

    rules = all_rules()
    selected: List[str] = []
    if args.select:
        wanted = {c.strip() for c in args.select.split(",") if c.strip()}
        rules = [
            r for r in rules if wanted.intersection(rule_codes(r))
        ]
        selected = sorted(wanted)

    if args.no_cache:
        diags = run_rules(project, rules)
    else:
        cache_path = args.cache or Path(cache_mod.DEFAULT_CACHE)
        diags, _stats = cache_mod.run_rules_cached(
            project, rules, cache_path, extra_tokens=selected
        )

    baseline_path = args.baseline
    if baseline_path is None:
        candidate = Path(DEFAULT_BASELINE)
        baseline_path = candidate if candidate.exists() else None

    if args.write_baseline:
        target = args.baseline or Path(DEFAULT_BASELINE)
        baseline_mod.save(target, diags)
        print(f"reprolint: wrote {len(diags)} finding(s) to {target}")
        return 0

    if baseline_path is not None:
        result = baseline_mod.apply(diags, baseline_mod.load(baseline_path))
    else:
        result = baseline_mod.BaselineResult(
            new=diags, baselined=[], stale=[]
        )

    if args.sarif is not None:
        write_sarif(args.sarif, result.new, result.baselined, rule_catalog())

    for d in result.new:
        print(d.render())
    status = 0
    if result.new:
        print(
            f"reprolint: {len(result.new)} new finding(s)"
            + (f", {len(result.baselined)} baselined" if result.baselined else "")
        )
        status = 1
    elif result.baselined:
        print(f"reprolint: clean ({len(result.baselined)} baselined)")
    else:
        print(f"reprolint: clean ({len(project.files)} files)")
    if result.stale:
        print(
            f"reprolint: {len(result.stale)} baseline entr"
            f"{'y is' if len(result.stale) == 1 else 'ies are'} stale — "
            f"fixed findings! remove them from the baseline:"
        )
        for entry in result.stale:
            print(f"  - {entry['code']} {entry['path']}: {entry['message']}")
        if args.strict_baseline:
            status = max(status, 1)
    return status


def render_all(diags: List[Diagnostic]) -> str:
    return "\n".join(d.render() for d in diags)
