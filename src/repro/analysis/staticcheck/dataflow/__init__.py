"""Per-function dataflow for reprolint (stdlib ``ast`` only).

``cfg`` builds statement-granularity control-flow graphs with explicit
exception edges (try/except/finally, ``with`` unwinding, loop break/else,
early returns); ``framework`` runs forward join-lattice fixpoints over them
with widening on loop heads; ``summaries`` lifts the intra-file call graph
into parameter-indexed resource-effect summaries; ``units`` is the
units-of-measure algebra + annotation registry for the core signatures.

The two rule families built on top live in ``rules/typestate.py`` (RPL7xx)
and ``rules/units.py`` (RPL8xx); see DESIGN.md "Static contracts".
"""

from .cfg import CFG, Block, Edge, build_cfg, default_may_raise
from .framework import ForwardAnalysis, run_forward

__all__ = [
    "CFG",
    "Block",
    "Edge",
    "ForwardAnalysis",
    "build_cfg",
    "default_may_raise",
    "run_forward",
]
