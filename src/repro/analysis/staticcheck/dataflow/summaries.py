"""Parameter-indexed resource-effect summaries over the intra-file call graph.

The typestate rule needs to know, for a call like
``_release_placement(cluster, placement)``, that the *second argument*'s
GPU reservation is released — the primitive ``cluster.release_gpus_typed``
is buried one call deep.  A :class:`FunctionSummary` records, per local
function, which parameter indexes have reserve/release effects of which
resource kind, plus whether the function (transitively) reaches
``SegmentLedger.settle``.  Effects propagate through local call chains to a
fixpoint, reusing :class:`~..callgraph.CallGraph`'s name-based
over-approximation: a call resolves to every local def of that bare name.

Method calls (``x.f(a)``) offset argument positions by one when the matched
def's first parameter is ``self``/``cls``.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from ..callgraph import CallGraph
from .cfg import _calls_shallow, callee_bare_name

GPU = "gpus"
BANDWIDTH = "bandwidth"
LEDGER = "ledger"

RESERVE_PRIMS = {
    "reserve_gpus": GPU,
    "reserve_gpus_typed": GPU,
    "reserve_bandwidth": BANDWIDTH,
}
RELEASE_PRIMS = {
    "release_gpus": GPU,
    "release_gpus_typed": GPU,
    "release_bandwidth": BANDWIDTH,
}
SETTLE_NAMES = {"settle"}

Effect = Tuple[str, int]  # (kind, parameter index)


@dataclasses.dataclass
class FunctionSummary:
    name: str
    params: List[str]
    reserves: Set[Effect] = dataclasses.field(default_factory=set)
    releases: Set[Effect] = dataclasses.field(default_factory=set)
    settles: bool = False

    @property
    def has_resource_effects(self) -> bool:
        return bool(self.reserves or self.releases)


def expr_root(node: Optional[ast.AST]) -> Optional[str]:
    """Base ``Name`` of an attribute/subscript chain: ``run.placement.bw``
    and ``alloc[r]`` both root at the left-most name."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def primitive_resource_arg(call: ast.Call) -> Optional[ast.AST]:
    """The argument carrying the resource identity of a reserve/release
    primitive call.  Method style (``cluster.release_gpus(alloc)``) puts it
    first; fixture-style free functions (``release_gpus(cluster, alloc)``)
    lead with the cluster — skip leading ``cluster``/``self`` roots."""
    for arg in call.args:
        if expr_root(arg) not in ("cluster", "self"):
            return arg
    return call.args[0] if call.args else None


def _def_params(fdef: ast.AST) -> List[str]:
    a = fdef.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _arg_index_for_param(call: ast.Call, params: List[str], pidx: int) -> Optional[ast.AST]:
    """Call-site argument feeding def parameter ``pidx`` (positional or
    keyword), accounting for the bound-method offset on attribute calls."""
    if pidx < len(params):
        for kw in call.keywords:
            if kw.arg == params[pidx]:
                return kw.value
    offset = 0
    if (
        isinstance(call.func, ast.Attribute)
        and params
        and params[0] in ("self", "cls")
    ):
        offset = 1
    site = pidx - offset
    if 0 <= site < len(call.args):
        return call.args[site]
    return None


def build_summaries(graph: CallGraph) -> Dict[str, FunctionSummary]:
    """Fixpoint of per-function effect summaries over the file's defs.
    Same-name defs merge (the call graph cannot tell them apart anyway)."""
    summaries: Dict[str, FunctionSummary] = {}
    for name, nodes in graph.defs.items():
        params = _def_params(nodes[0])
        summaries[name] = FunctionSummary(
            name=name,
            params=params,
            settles=graph.reaches(name, SETTLE_NAMES) or name in SETTLE_NAMES,
        )

    def param_index(summary: FunctionSummary, root: Optional[str]) -> Optional[int]:
        if root is None:
            return None
        try:
            return summary.params.index(root)
        except ValueError:
            return None

    changed = True
    while changed:
        changed = False
        for name, nodes in graph.defs.items():
            summary = summaries[name]
            for node in nodes:
                for call in _calls_shallow(node):
                    bare = callee_bare_name(call)
                    if bare is None:
                        continue
                    if bare in RESERVE_PRIMS or bare in RELEASE_PRIMS:
                        kind = (RESERVE_PRIMS | RELEASE_PRIMS)[bare]
                        target = (
                            summary.reserves
                            if bare in RESERVE_PRIMS
                            else summary.releases
                        )
                        pidx = param_index(
                            summary, expr_root(primitive_resource_arg(call))
                        )
                        if pidx is not None and (kind, pidx) not in target:
                            target.add((kind, pidx))
                            changed = True
                        continue
                    callee = summaries.get(bare)
                    if callee is None or not callee.has_resource_effects:
                        continue
                    for effects, target in (
                        (callee.reserves, summary.reserves),
                        (callee.releases, summary.releases),
                    ):
                        for kind, cpidx in effects:
                            arg = _arg_index_for_param(call, callee.params, cpidx)
                            pidx = param_index(summary, expr_root(arg))
                            if pidx is not None and (kind, pidx) not in target:
                                target.add((kind, pidx))
                                changed = True
    return summaries
