"""Units-of-measure algebra + annotation registry for the core signatures.

A unit is a map ``base dimension -> integer exponent`` over the dimensions
the cost model actually mixes: seconds, dollars, GPUs, bytes, FLOPs and
kilowatts (hours fold into seconds — only ratios matter, and the ``/3600``
in ``power_cost_rate`` is a dimensionless literal).  Two non-unit lattice
points complete the picture:

* ``TOP`` — unknown/any (joins of unlike units, containers, foreign calls);
  every check involving TOP is vacuous, so the analysis under-approximates
  rather than guessing.
* ``POLY`` — numeric literals, which are unit-polymorphic: ``t + 1e-12``
  and ``0.95 * rate`` are fine, and a join with a concrete unit adopts it.

The annotation registry seeds inference at the ``core/`` API boundary:
function return units by bare callee name, attribute units by attribute
name, parameter/local fallbacks by exact name and by suffix convention
(``*_s``/``*_seconds`` are seconds, ``*_cost`` dollars, ...), and keyword-
argument slots for constructor checks (``SegmentLedger(rate=...)``).
Registry entries are asserted against the real signatures by the tests, so
a unit change in ``core/`` must update the registry loudly.
"""

from __future__ import annotations

from typing import Dict, Mapping, Optional, Tuple

Dims = Tuple[Tuple[str, int], ...]  # sorted (dimension, exponent), exp != 0


class Unit:
    """A concrete unit (possibly dimensionless) or a lattice point."""

    __slots__ = ("dims", "tag")

    def __init__(self, dims: Mapping[str, int] = (), tag: str = "unit") -> None:
        self.tag = tag  # "unit" | "top" | "poly"
        self.dims: Dims = tuple(
            sorted((d, e) for d, e in dict(dims).items() if e != 0)
        )

    def __eq__(self, other: object) -> bool:
        return (
            isinstance(other, Unit)
            and self.tag == other.tag
            and self.dims == other.dims
        )

    def __hash__(self) -> int:
        return hash((self.tag, self.dims))

    @property
    def is_top(self) -> bool:
        return self.tag == "top"

    @property
    def is_poly(self) -> bool:
        return self.tag == "poly"

    @property
    def is_concrete(self) -> bool:
        return self.tag == "unit"

    def __repr__(self) -> str:
        return f"Unit({self.render()})"

    def render(self) -> str:
        if self.is_top:
            return "?"
        if self.is_poly:
            return "literal"
        if not self.dims:
            return "dimensionless"
        pretty = _PRETTY.get(self.dims)
        if pretty:
            return pretty
        num = [
            f"{d}^{e}" if e != 1 else d for d, e in self.dims if e > 0
        ]
        den = [
            f"{d}^{-e}" if e != -1 else d for d, e in self.dims if e < 0
        ]
        if not num:
            return "1/" + "·".join(den)
        if den:
            return "·".join(num) + "/" + "·".join(den)
        return "·".join(num)


TOP = Unit(tag="top")
POLY = Unit(tag="poly")
DIMLESS = Unit()

S = Unit({"s": 1})
USD = Unit({"usd": 1})
RATE = Unit({"usd": 1, "s": -1})            # $/s
GPU = Unit({"gpu": 1})
BYTES = Unit({"byte": 1})
BPS = Unit({"byte": 1, "s": -1})            # bytes/s
FLOPS = Unit({"flop": 1, "s": -1})
KW = Unit({"kw": 1})
PRICE_KWH = Unit({"usd": 1, "kw": -1, "s": -1})  # $/kWh, hours as seconds

_PRETTY: Dict[Dims, str] = {
    S.dims: "s",
    USD.dims: "$",
    RATE.dims: "$/s",
    GPU.dims: "GPU",
    BYTES.dims: "bytes",
    BPS.dims: "bytes/s",
    FLOPS.dims: "FLOPS",
    KW.dims: "kW",
    PRICE_KWH.dims: "$/kWh",
}


def join(a: Unit, b: Unit) -> Unit:
    """Lattice join: POLY is absorbed by anything; unlike units go to TOP."""
    if a == b:
        return a
    if a.is_poly:
        return b
    if b.is_poly:
        return a
    return TOP


def multiply(a: Unit, b: Unit) -> Unit:
    if a.is_top or b.is_top:
        return TOP
    if a.is_poly:
        return b
    if b.is_poly:
        return a
    dims: Dict[str, int] = dict(a.dims)
    for d, e in b.dims:
        dims[d] = dims.get(d, 0) + e
    return Unit(dims)


def divide(a: Unit, b: Unit) -> Unit:
    return multiply(a, invert(b))


def invert(u: Unit) -> Unit:
    if not u.is_concrete:
        return u
    return Unit({d: -e for d, e in u.dims})


def addable(a: Unit, b: Unit) -> bool:
    """May ``a + b`` (or ``a - b``, or ``a < b``) be formed?  Only a
    *provable* mismatch — two unlike concrete units — is rejected."""
    if not (a.is_concrete and b.is_concrete):
        return True
    return a == b


# ----------------------------------------------------------------- registry
#: Return units by bare callee name (core/ function and method signatures).
FUNC_UNITS: Dict[str, Unit] = {
    # timing.py
    "iteration_time": S,
    "analytic_iteration_time": S,
    "execution_time": S,
    "bottleneck_delta": S,
    "placement_power_rate": RATE,
    "electricity_cost": USD,
    "average_price": TOP,  # deliberately unit-polymorphic (see its docstring)
    # job.py boundary
    "power_cost_rate": RATE,
    "t_comp": S,
    "t_comp_hw": S,
    "single_gpu_execution": S,
    "bandwidth_requirement": BPS,
    "bandwidth_requirement_hw": BPS,
    "demand_at_cap": BPS,
    "min_gpus_for_memory": GPU,
    "pipeline_depth": DIMLESS,
    # cluster.py boundary
    "price": PRICE_KWH,
    "available_bandwidth": BPS,
    "total_gpus": GPU,
    "total_free_gpus": GPU,
    "congestion_alpha": DIMLESS,
    # accounting.py
    "settle": USD,
    "completed_iterations": DIMLESS,
    "remaining_after_checkpoint": DIMLESS,
}

#: Attribute units by attribute name (dataclass fields + properties).
ATTR_UNITS: Dict[str, Unit] = {
    # times
    "submit_time": S,
    "submit": S,
    "start": S,
    "finish": S,
    "last_settle": S,
    "projected_finish": S,
    "iteration_seconds": S,
    "restore_s": S,
    "restart_penalty_s": S,
    "makespan": S,
    "wait": S,
    "execution": S,
    "jct": S,
    "average_jct": S,
    "average_hol_wait": S,
    "comm_times": S,          # container-of-seconds: elements carry the unit
    "iteration_time": S,
    # money
    "cost": USD,
    "projected_cost": USD,
    "accrued": USD,
    "total_cost": USD,
    "rate": RATE,
    # counts / hardware
    "total_gpus": GPU,
    "cluster_gpus": GPU,
    "min_gpus": GPU,
    "gpu_kw": KW,
    "activation_bytes": BYTES,
    "reserved_bw": BPS,
    "gpu_flops": FLOPS,
    "eff_flops": FLOPS,
    "microbatches": DIMLESS,
    "iterations": DIMLESS,
    "n_regions": DIMLESS,
    "price_mult": DIMLESS,
    "voluntary_migration_threshold": DIMLESS,
}

#: Fallback units for bare names (parameters and well-known locals) when
#: local inference has nothing better than TOP.
NAME_UNITS: Dict[str, Unit] = {
    "t": S,
    "now": S,
    "t_ev": S,
    "dt": S,
    "threshold": DIMLESS,
    "alpha": DIMLESS,
    "remaining": DIMLESS,
    "INTRA_REGION_BANDWIDTH": BPS,
    "DEFAULT_RESTART_PENALTY_S": S,
    "GBPS": BPS,
}

#: Suffix conventions, checked after NAME_UNITS (first match wins).
SUFFIX_UNITS: Tuple[Tuple[str, Unit], ...] = (
    ("_seconds", S),
    ("_s", S),
    ("_cost", USD),
    ("_rate", RATE),
    ("_bw", BPS),
    ("_gpus", GPU),
    ("_flops", FLOPS),
    ("_bytes", BYTES),
    ("_kw", KW),
)

#: Keyword-argument slots checked at every call (constructor wiring — the
#: classic transposition bug: a seconds value poured into a $ slot).
KW_UNITS: Dict[str, Unit] = {
    "start": S,
    "finish": S,
    "submit": S,
    "execution_seconds": S,
    "restore_s": S,
    "iteration_seconds": S,
    "restart_penalty_s": S,
    "projected_finish": S,
    "last_settle": S,
    "makespan": S,
    "projected_cost": USD,
    "accrued": USD,
    "cost": USD,
    "rate": RATE,
    "voluntary_migration_threshold": DIMLESS,
}


def lookup_name(name: str) -> Unit:
    u = NAME_UNITS.get(name)
    if u is not None:
        return u
    for suffix, unit in SUFFIX_UNITS:
        if name.endswith(suffix) and name != suffix:
            return unit
    return TOP


def lookup_attr(name: str) -> Unit:
    u = ATTR_UNITS.get(name)
    if u is not None:
        return u
    for suffix, unit in SUFFIX_UNITS:
        if name.endswith(suffix) and name != suffix:
            return unit
    return TOP


def lookup_func(name: Optional[str]) -> Unit:
    if name is None:
        return TOP
    return FUNC_UNITS.get(name, TOP)
