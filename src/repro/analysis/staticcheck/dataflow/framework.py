"""Forward abstract interpretation over a :class:`~.cfg.CFG`.

Worklist fixpoint with join over predecessor edges.  Normal edges carry the
post-state of :meth:`ForwardAnalysis.transfer`; exception edges carry
:meth:`transfer_exc` (default: the same post-state — a statement observed
mid-flight is approximated by its completed effects, which keeps the
exception lattice small; rules that care override it, e.g. the typestate
rule stamps the raising line there).

Termination: after ``widen_after`` visits to a loop head the join is
replaced by :meth:`widen`, whose contract is to make strictly ascending
chains finite (the units analysis drops still-changing bindings to ⊤; the
typestate analysis collapses its path disjunction).  A hard relaxation cap
turns a non-terminating lattice bug into a loud error instead of a hang.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Callable, Dict, Optional

from .cfg import CFG, EXC, Block

Report = Callable[..., None]


class ForwardAnalysis:
    """Override points for one forward dataflow problem.

    States must be immutable and support ``==``; ``transfer`` takes a block
    and its in-state and returns the out-state.  ``report`` is only passed
    during the post-fixpoint reporting pass, so transfer functions emit
    diagnostics exactly once, from converged states.
    """

    def initial(self) -> Any:
        raise NotImplementedError

    def transfer(self, block: Block, state: Any, report: Optional[Report] = None) -> Any:
        raise NotImplementedError

    def transfer_exc(
        self, block: Block, state: Any, note: str, report: Optional[Report] = None
    ) -> Any:
        return self.transfer(block, state)

    def join(self, a: Any, b: Any) -> Any:
        raise NotImplementedError

    def widen(self, old: Any, new: Any) -> Any:
        return self.join(old, new)


def run_forward(
    cfg: CFG,
    analysis: ForwardAnalysis,
    *,
    widen_after: int = 8,
    max_relaxations: int = 200_000,
) -> Dict[int, Any]:
    """Fixpoint ``block id -> in-state`` for every reachable block."""
    in_states: Dict[int, Any] = {cfg.entry: analysis.initial()}
    visits: Dict[int, int] = {}
    worklist = deque([cfg.entry])
    relaxations = 0
    while worklist:
        bid = worklist.popleft()
        state = in_states[bid]
        block = cfg.block(bid)
        normal_out = exc_out = None
        for edge in cfg.succ[bid]:
            if edge.kind == EXC:
                if exc_out is None:
                    exc_out = analysis.transfer_exc(block, state, edge.note)
                out = exc_out
            else:
                if normal_out is None:
                    normal_out = analysis.transfer(block, state)
                out = normal_out
            old = in_states.get(edge.dst)
            if old is None:
                merged = out
            else:
                merged = analysis.join(old, out)
                if (
                    edge.dst in cfg.loop_heads
                    and visits.get(edge.dst, 0) >= widen_after
                ):
                    merged = analysis.widen(old, merged)
            if old is None or merged != old:
                relaxations += 1
                if relaxations > max_relaxations:
                    raise RuntimeError(
                        "dataflow fixpoint did not converge "
                        f"(block line {block.line}); widening is broken"
                    )
                in_states[edge.dst] = merged
                visits[edge.dst] = visits.get(edge.dst, 0) + 1
                if edge.dst not in worklist:
                    worklist.append(edge.dst)
    return in_states


def reporting_pass(
    cfg: CFG,
    analysis: ForwardAnalysis,
    in_states: Dict[int, Any],
    report: Report,
) -> None:
    """Re-run transfer over every reachable block with converged in-states,
    this time with the ``report`` callback armed."""
    for block in cfg.blocks:
        state = in_states.get(block.id)
        if state is None:
            continue
        has_normal = any(e.kind != EXC for e in cfg.succ[block.id])
        exc_notes = [e.note for e in cfg.succ[block.id] if e.kind == EXC]
        if has_normal or not exc_notes:
            analysis.transfer(block, state, report=report)
        for note in exc_notes:
            analysis.transfer_exc(block, state, note, report=report)
