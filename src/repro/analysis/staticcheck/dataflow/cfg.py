"""Statement-granularity control-flow graphs for one function body.

Every statement (or compound-statement *header*: an ``if``/``while`` test,
a ``for`` iterable, a ``with`` context expression) becomes one block, so a
transfer function sees exactly one statement at a time and diagnostics can
name exact lines.  Three synthetic nodes frame the graph: ``entry``,
``exit`` (normal returns and fall-through) and ``raise_exit`` (exceptions
escaping the function).

Exception flow is explicit: a statement that may raise (per the caller's
``may_raise`` predicate — rules narrow it, e.g. the typestate rule treats
ledger primitives as atomic) gets an ``EXC`` edge to wherever an exception
raised *there* would land: the innermost enclosing handler dispatch, else
through every enclosing ``finally`` (each finally body is instantiated once
per continuation kind — normal / exceptional / each abrupt jump — the
classic finally-duplication encoding), else ``raise_exit``.  ``with`` is
modeled as try/finally whose finally is a synthetic ``with-exit`` block, so
unwinding through ``__exit__`` appears on exceptional paths too.

Abrupt jumps (``break``/``continue``/``return``/``raise``) unwind the
enclosing frame stack, instantiating crossed finally bodies on the way out.
Loop ``else`` clauses hang off the loop-head's false edge, which ``break``
bypasses — the real semantics, exercised by the CFG edge-case tests.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Set

NORMAL = "normal"
EXC = "exc"

#: Block roles: what the transfer function should evaluate for this block.
ROLE_STMT = "stmt"            # a full simple statement
ROLE_TEST = "test"            # an if/while test expression
ROLE_ITER = "iter"            # a for-loop iterable + target binding
ROLE_WITH_ENTER = "with-enter"  # with-items evaluation + optional-vars bind
ROLE_WITH_EXIT = "with-exit"    # synthetic __exit__ unwinding point
ROLE_DISPATCH = "dispatch"    # except-handler dispatch point
ROLE_ENTRY = "entry"
ROLE_EXIT = "exit"
ROLE_RAISE_EXIT = "raise-exit"


@dataclasses.dataclass
class Block:
    id: int
    role: str
    stmt: Optional[ast.AST] = None  # owning stmt (or header-owning compound)

    @property
    def line(self) -> int:
        return getattr(self.stmt, "lineno", 0) if self.stmt is not None else 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Block({self.id}, {self.role!r}, line={self.line})"


@dataclasses.dataclass(frozen=True)
class Edge:
    src: int
    dst: int
    kind: str = NORMAL
    note: str = ""  # "call" | "raise" | "assert" | "reraise" for EXC edges


class CFG:
    def __init__(self) -> None:
        self.blocks: List[Block] = []
        self.succ: Dict[int, List[Edge]] = {}
        self.pred: Dict[int, List[Edge]] = {}
        self.entry = -1
        self.exit = -1
        self.raise_exit = -1
        self.loop_heads: Set[int] = set()

    def block(self, bid: int) -> Block:
        return self.blocks[bid]

    def new_block(self, role: str, stmt: Optional[ast.AST] = None) -> int:
        b = Block(id=len(self.blocks), role=role, stmt=stmt)
        self.blocks.append(b)
        self.succ[b.id] = []
        self.pred[b.id] = []
        return b.id

    def add_edge(self, src: int, dst: int, kind: str = NORMAL, note: str = "") -> None:
        e = Edge(src=src, dst=dst, kind=kind, note=note)
        if e not in self.succ[src]:
            self.succ[src].append(e)
            self.pred[dst].append(e)


def _calls_shallow(node: ast.AST) -> List[ast.Call]:
    """Call nodes under ``node`` (inclusive), skipping nested function /
    class / lambda scopes, in (line, col) source order."""
    out: List[ast.Call] = []
    stack: List[ast.AST] = [node]
    while stack:
        cur = stack.pop()
        if cur is not node and isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)
        ):
            continue
        if isinstance(cur, ast.Call):
            out.append(cur)
        stack.extend(ast.iter_child_nodes(cur))
    out.sort(key=lambda c: (c.lineno, c.col_offset))
    return out


def callee_bare_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def default_may_raise(
    node: ast.AST, atomic_callees: FrozenSet[str] = frozenset()
) -> bool:
    """May evaluating ``node`` (a statement or header expression) raise?

    True for ``raise``/``assert`` and for any call whose bare callee name is
    not in ``atomic_callees`` (unresolvable callees count as raising).
    Attribute reads, subscripts and arithmetic are assumed non-raising — the
    rules care about *call* boundaries, not MemoryError-grade paranoia.
    """
    if isinstance(node, (ast.Raise, ast.Assert)):
        return True
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return False
    for call in _calls_shallow(node):
        name = callee_bare_name(call)
        if name is None or name not in atomic_callees:
            return True
    return False


# --------------------------------------------------------------- builder
class _LoopFrame:
    kind = "loop"

    def __init__(self, continue_target: int) -> None:
        self.continue_target = continue_target
        self.breaks: List[int] = []


class _FinallyFrame:
    kind = "finally"

    def __init__(self, body: Optional[Sequence[ast.stmt]], owner: ast.AST) -> None:
        self.body = body          # None => synthetic `with` exit
        self.owner = owner
        self.exc_entry: Optional[int] = None  # shared exceptional copy


class _HandlerFrame:
    kind = "handler"

    def __init__(self, dispatch: int) -> None:
        self.dispatch = dispatch


class _Builder:
    def __init__(self, fdef: ast.AST, may_raise: Callable[[ast.AST], bool]) -> None:
        self.cfg = CFG()
        self.fdef = fdef
        self.may_raise = may_raise
        self.frames: List[object] = []

    def build(self) -> CFG:
        cfg = self.cfg
        cfg.entry = cfg.new_block(ROLE_ENTRY)
        cfg.exit = cfg.new_block(ROLE_EXIT)
        cfg.raise_exit = cfg.new_block(ROLE_RAISE_EXIT)
        frontier = self._stmts(self.fdef.body, [cfg.entry])
        self._connect(frontier, cfg.exit)
        return cfg

    # -- plumbing -------------------------------------------------------
    def _connect(self, frontier: Sequence[int], dst: int) -> None:
        for src in frontier:
            self.cfg.add_edge(src, dst)

    def _exc_continuation(self, upto: Optional[int] = None) -> int:
        """Where an exception raised under the current frame stack lands.
        ``upto`` restricts the walk to ``frames[:upto]`` (used while building
        a finally frame's own exceptional copy)."""
        limit = len(self.frames) if upto is None else upto
        for i in range(limit - 1, -1, -1):
            frame = self.frames[i]
            if frame.kind == "handler":
                return frame.dispatch
            if frame.kind == "finally":
                return self._finally_exc_entry(frame, i)
        return self.cfg.raise_exit

    def _exc_edge(self, src: int, note: str) -> None:
        self.cfg.add_edge(src, self._exc_continuation(), EXC, note)

    def _finally_copy(self, frame: _FinallyFrame, frontier: List[int]) -> List[int]:
        """Instantiate one copy of the finally body, built as if the frame
        stack stopped just below ``frame`` (so nested aborts resolve
        outward, past this finally).  When the frame was already popped
        (the normal-continuation copy) the current stack *is* "below"."""
        saved = self.frames
        if frame in saved:
            self.frames = saved[: saved.index(frame)]
        try:
            if frame.body is None:
                exit_block = self.cfg.new_block(ROLE_WITH_EXIT, frame.owner)
                self._connect(frontier, exit_block)
                out = [exit_block]
            else:
                out = self._stmts(frame.body, frontier)
        finally:
            self.frames = saved
        return out

    def _finally_exc_entry(self, frame: _FinallyFrame, idx: int) -> int:
        """Shared exceptional copy of a finally body: built once per frame,
        its tail re-raises outward past the frame."""
        if frame.exc_entry is None:
            head = self.cfg.new_block(ROLE_DISPATCH, frame.owner)
            frame.exc_entry = head  # set first: finally bodies may raise
            out = self._finally_copy(frame, [head])
            tail = self._exc_continuation(upto=idx)
            for src in out:
                self.cfg.add_edge(src, tail, EXC, "reraise")
        return frame.exc_entry

    def _unwind_to_loop(self, frontier: List[int]) -> Optional[_LoopFrame]:
        """Cross finally frames down to the innermost loop, instantiating
        their bodies; mutates ``frontier`` in place.  None at top level."""
        for i in range(len(self.frames) - 1, -1, -1):
            frame = self.frames[i]
            if frame.kind == "loop":
                return frame
            if frame.kind == "finally":
                frontier[:] = self._finally_copy(frame, list(frontier))
        return None

    def _unwind_all(self, frontier: List[int]) -> List[int]:
        """Cross every enclosing finally (for ``return``)."""
        for i in range(len(self.frames) - 1, -1, -1):
            frame = self.frames[i]
            if frame.kind == "finally":
                frontier = self._finally_copy(frame, frontier)
        return frontier

    # -- statements -----------------------------------------------------
    def _stmts(self, body: Sequence[ast.stmt], frontier: List[int]) -> List[int]:
        for stmt in body:
            if not frontier:
                break  # unreachable code after return/raise/break
            frontier = self._stmt(stmt, frontier)
        return frontier

    def _stmt(self, stmt: ast.stmt, frontier: List[int]) -> List[int]:
        cfg = self.cfg
        if isinstance(stmt, ast.If):
            head = cfg.new_block(ROLE_TEST, stmt)
            self._connect(frontier, head)
            if self.may_raise(stmt.test):
                self._exc_edge(head, "call")
            body_out = self._stmts(stmt.body, [head])
            else_out = self._stmts(stmt.orelse, [head]) if stmt.orelse else [head]
            return body_out + else_out

        if isinstance(stmt, (ast.While, ast.For, ast.AsyncFor)):
            return self._loop(stmt, frontier)

        if isinstance(stmt, (ast.Try, *_TRY_STAR)):
            return self._try(stmt, frontier)

        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            return self._with(stmt, frontier)

        if isinstance(stmt, ast.Return):
            block = cfg.new_block(ROLE_STMT, stmt)
            self._connect(frontier, block)
            if self.may_raise(stmt):
                self._exc_edge(block, "call")
            out = self._unwind_all([block])
            self._connect(out, cfg.exit)
            return []

        if isinstance(stmt, ast.Raise):
            block = cfg.new_block(ROLE_STMT, stmt)
            self._connect(frontier, block)
            cfg.add_edge(block, self._exc_continuation(), EXC, "raise")
            return []

        if isinstance(stmt, ast.Break):
            block = cfg.new_block(ROLE_STMT, stmt)
            self._connect(frontier, block)
            out = [block]
            frame = self._unwind_to_loop(out)
            if frame is not None:
                frame.breaks.extend(out)
            return []

        if isinstance(stmt, ast.Continue):
            block = cfg.new_block(ROLE_STMT, stmt)
            self._connect(frontier, block)
            out = [block]
            frame = self._unwind_to_loop(out)
            if frame is not None:
                self._connect(out, frame.continue_target)
            return []

        # Simple statement (nested defs/classes included: binding only).
        block = cfg.new_block(ROLE_STMT, stmt)
        self._connect(frontier, block)
        if self.may_raise(stmt):
            self._exc_edge(block, "assert" if isinstance(stmt, ast.Assert) else "call")
        return [block]

    def _loop(self, stmt: ast.stmt, frontier: List[int]) -> List[int]:
        cfg = self.cfg
        role = ROLE_TEST if isinstance(stmt, ast.While) else ROLE_ITER
        head = cfg.new_block(role, stmt)
        cfg.loop_heads.add(head)
        self._connect(frontier, head)
        header_expr = stmt.test if isinstance(stmt, ast.While) else stmt.iter
        if self.may_raise(header_expr):
            self._exc_edge(head, "call")
        frame = _LoopFrame(continue_target=head)
        self.frames.append(frame)
        try:
            body_out = self._stmts(stmt.body, [head])
        finally:
            self.frames.pop()
        self._connect(body_out, head)  # back edge
        # Normal loop exit (condition false / iterator exhausted) runs the
        # else clause; break bypasses it.  ``while True`` has no false exit.
        infinite = (
            isinstance(stmt, ast.While)
            and isinstance(stmt.test, ast.Constant)
            and bool(stmt.test.value)
        )
        if infinite:
            no_break: List[int] = []
        elif stmt.orelse:
            no_break = self._stmts(stmt.orelse, [head])
        else:
            no_break = [head]
        return no_break + frame.breaks

    def _try(self, stmt: ast.AST, frontier: List[int]) -> List[int]:
        cfg = self.cfg
        fin_frame: Optional[_FinallyFrame] = None
        if stmt.finalbody:
            fin_frame = _FinallyFrame(stmt.finalbody, stmt)
            self.frames.append(fin_frame)
        try:
            if stmt.handlers:
                dispatch = cfg.new_block(ROLE_DISPATCH, stmt)
                self.frames.append(_HandlerFrame(dispatch))
                try:
                    body_out = self._stmts(stmt.body, frontier)
                finally:
                    self.frames.pop()
                else_out = (
                    self._stmts(stmt.orelse, body_out) if stmt.orelse else body_out
                )
                handler_outs: List[int] = []
                for handler in stmt.handlers:
                    handler_outs += self._stmts(handler.body, [dispatch])
                if not any(h.type is None for h in stmt.handlers):
                    # No bare except: an unmatched exception escapes.
                    cfg.add_edge(dispatch, self._exc_continuation(), EXC, "reraise")
                normal_out = else_out + handler_outs
            else:
                normal_out = self._stmts(stmt.body, frontier)
        finally:
            if fin_frame is not None:
                self.frames.pop()
        if fin_frame is not None:
            normal_out = self._finally_copy(fin_frame, normal_out)
        return normal_out

    def _with(self, stmt: ast.AST, frontier: List[int]) -> List[int]:
        cfg = self.cfg
        head = cfg.new_block(ROLE_WITH_ENTER, stmt)
        self._connect(frontier, head)
        if any(self.may_raise(item.context_expr) for item in stmt.items):
            # __enter__ failing skips __exit__: raise past the frame.
            self._exc_edge(head, "call")
        frame = _FinallyFrame(None, stmt)
        self.frames.append(frame)
        try:
            body_out = self._stmts(stmt.body, [head])
        finally:
            self.frames.pop()
        return self._finally_copy(frame, body_out)


_TRY_STAR = (ast.TryStar,) if hasattr(ast, "TryStar") else ()


def build_cfg(
    fdef: ast.AST, may_raise: Optional[Callable[[ast.AST], bool]] = None
) -> CFG:
    """Build the CFG of one ``ast.FunctionDef``/``AsyncFunctionDef`` body.
    Nested defs are opaque single statements (they get their own CFGs)."""
    return _Builder(fdef, may_raise or default_may_raise).build()
