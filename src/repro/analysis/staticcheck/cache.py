"""Content-hash result cache for reprolint.

Every rule in the suite is *file-local*: the diagnostics it emits for a
file depend only on that file's text (the twin differ diffs twins inside
one module; the typestate rule's call-graph summaries are intra-file).
That makes per-file caching sound: a file's post-suppression diagnostics
are keyed by the sha256 of its bytes, and the whole cache is invalidated
by a *rule-set fingerprint* — the sha256 of every ``staticcheck`` source
file plus the active rule selection — so editing any rule, the engine, or
the registries re-lints the world.

The cache lives in ``.reprolint_cache.json`` (gitignored) and turns the
second CI lint invocation into a hash-and-compare pass; CI asserts the
warm run stays inside a wall-clock budget.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict, Iterable, List, Sequence, Tuple

from .diagnostics import Diagnostic
from .engine import Project, run_rules

CACHE_VERSION = 1
DEFAULT_CACHE = ".reprolint_cache.json"

_PACKAGE_DIR = Path(__file__).resolve().parent


def ruleset_fingerprint(extra_tokens: Iterable[str] = ()) -> str:
    """sha256 over every staticcheck source file plus selection tokens."""
    h = hashlib.sha256()
    for src in sorted(_PACKAGE_DIR.rglob("*.py")):
        h.update(src.relative_to(_PACKAGE_DIR).as_posix().encode())
        h.update(b"\0")
        h.update(src.read_bytes())
        h.update(b"\0")
    for token in sorted(extra_tokens):
        h.update(token.encode())
        h.update(b"\0")
    return h.hexdigest()


def file_sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0


def _load(path: Path, fingerprint: str) -> Dict[str, dict]:
    try:
        data = json.loads(path.read_text(encoding="utf-8"))
    except (OSError, ValueError):
        return {}
    if (
        not isinstance(data, dict)
        or data.get("version") != CACHE_VERSION
        or data.get("fingerprint") != fingerprint
    ):
        return {}
    files = data.get("files")
    return files if isinstance(files, dict) else {}


def _decode(rel: str, rows: Sequence[Sequence[object]]) -> List[Diagnostic]:
    out: List[Diagnostic] = []
    for row in rows:
        code, path, line, col, message = row
        out.append(
            Diagnostic(str(code), str(path), int(line), int(col), str(message))
        )
    return out


def run_rules_cached(
    project: Project,
    rules: Sequence[object],
    cache_path: Path,
    *,
    extra_tokens: Iterable[str] = (),
) -> Tuple[List[Diagnostic], CacheStats]:
    """``run_rules`` with the per-file content cache around it.

    Files whose (sha, fingerprint) pair is cached contribute their stored
    diagnostics; the rest are re-linted as a sub-project and the cache is
    rewritten, pruned to the files seen this run.
    """
    fingerprint = ruleset_fingerprint(extra_tokens)
    cached = _load(cache_path, fingerprint)
    stats = CacheStats()

    shas = {sf.rel: file_sha(sf.text) for sf in project.files}
    diags: List[Diagnostic] = []
    missed = []
    for sf in project.files:
        entry = cached.get(sf.rel)
        if entry and entry.get("sha") == shas[sf.rel]:
            stats.hits += 1
            diags.extend(_decode(sf.rel, entry.get("diags", [])))
        else:
            stats.misses += 1
            missed.append(sf)

    fresh: Dict[str, List[Diagnostic]] = {sf.rel: [] for sf in missed}
    if missed:
        sub = Project(root=project.root, files=missed)
        for d in run_rules(sub, rules):
            fresh.setdefault(d.path, []).append(d)
            diags.append(d)

    files_out: Dict[str, dict] = {}
    for sf in project.files:
        if sf.rel in fresh:
            rows = [
                [d.code, d.path, d.line, d.col, d.message]
                for d in fresh[sf.rel]
            ]
            files_out[sf.rel] = {"sha": shas[sf.rel], "diags": rows}
        else:
            files_out[sf.rel] = cached[sf.rel]

    payload = {
        "version": CACHE_VERSION,
        "fingerprint": fingerprint,
        "files": files_out,
    }
    try:
        cache_path.write_text(
            json.dumps(payload, sort_keys=True) + "\n", encoding="utf-8"
        )
    except OSError:
        pass  # read-only checkouts still lint, just uncached

    diags.sort(key=Diagnostic.sort_key)
    return diags, stats
