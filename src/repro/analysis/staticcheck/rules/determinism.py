"""Determinism rules (RPL1xx).

RPL101 — calls into process-global RNG state (``random.random()``,
         ``np.random.rand()``, ...).  Seeded generator objects
         (``random.Random(seed)``, ``np.random.default_rng(seed)``) are the
         sanctioned idiom; module-level RNG makes trace replay depend on
         import order and global seeding side effects.

RPL102 — wall-clock reads inside ``core/``.  The engine is an event-driven
         simulator: simulated time comes from the event queue, and any
         ``time.time()``/``datetime.now()`` in core logic silently couples
         decisions to the host.

RPL103 — iteration over a set-valued expression without ``sorted()``.
         Set iteration order is hash-seed dependent; feeding it into loops,
         comprehensions, or reductions makes tie-breaks and float
         accumulation order non-deterministic across processes.

RPL104 — dict-order-sensitive reductions: ``sum()`` over ``.values()`` /
         ``.items()`` in ``core/`` files, and ``min()``/``max()`` with a
         ``key=`` over dict views anywhere.  Python dicts preserve
         *insertion* order, which is whatever history produced the dict —
         wrapping in ``sorted()`` pins the accumulation/tie-break order to
         the keys instead.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from ..astutil import dotted_name, first_arg, is_name_call
from ..diagnostics import Diagnostic
from ..engine import Project, SourceFile

# random.<fn> that touch the module-level generator.  Constructors of
# independent generators are fine.
_GLOBAL_RANDOM_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "triangular", "betavariate", "expovariate",
    "gammavariate", "gauss", "lognormvariate", "normalvariate",
    "vonmisesvariate", "paretovariate", "weibullvariate", "getrandbits",
    "randbytes", "seed", "setstate",
}
# numpy.random.<name> that do NOT touch global state.
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "RandomState",
                 "PCG64", "Philox", "MT19937", "SFC64", "BitGenerator"}

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns", "time.process_time",
    "time.localtime", "time.gmtime",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}

_DICT_VIEW_ATTRS = {"values", "items", "keys"}


def _is_set_expr(node: Optional[ast.expr]) -> bool:
    if node is None:
        return False
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return is_name_call(node, ("set", "frozenset"))


def _is_dict_view(node: Optional[ast.expr]) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr in _DICT_VIEW_ATTRS
        and not node.args
        and not node.keywords
    )


def _reduction_arg(node: ast.expr) -> ast.expr:
    """Look through a bare generator-expression argument to its source
    iterable: ``sum(v for v in d.values())`` reduces over ``d.values()``."""
    if isinstance(node, ast.GeneratorExp) and node.generators:
        return node.generators[0].iter
    return node


class UnseededRngRule:
    code = "RPL101"
    name = "unseeded-global-rng"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            yield from self._check_file(sf)

    def _check_file(self, sf: SourceFile) -> Iterator[Diagnostic]:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func, sf.aliases)
            if name is None:
                continue
            if name.startswith("random.") and name.count(".") == 1:
                fn = name.split(".", 1)[1]
                if fn in _GLOBAL_RANDOM_FNS:
                    yield Diagnostic(
                        self.code, sf.rel, node.lineno, node.col_offset,
                        f"call to process-global RNG 'random.{fn}'; "
                        f"use a seeded random.Random(seed) instance",
                    )
            elif ".random." in name and name.split(".", 1)[0] in ("numpy",):
                fn = name.rsplit(".", 1)[1]
                if fn not in _NP_RANDOM_OK:
                    yield Diagnostic(
                        self.code, sf.rel, node.lineno, node.col_offset,
                        f"call to numpy global RNG 'np.random.{fn}'; "
                        f"use np.random.default_rng(seed)",
                    )


class WallClockRule:
    code = "RPL102"
    name = "wall-clock-in-core"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if not sf.in_core():
                continue
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func, sf.aliases)
                if name in _WALL_CLOCK:
                    yield Diagnostic(
                        self.code, sf.rel, node.lineno, node.col_offset,
                        f"wall-clock read '{name}' inside core/; simulated "
                        f"time must come from the event queue",
                    )


class SetIterationRule:
    code = "RPL103"
    name = "unsorted-set-iteration"

    _REDUCERS = ("sum", "min", "max", "list", "tuple", "sorted")

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            yield from self._check_file(sf)

    def _check_file(self, sf: SourceFile) -> Iterator[Diagnostic]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.For):
                it = node.iter
                if is_name_call(it, ("enumerate",)):
                    it = first_arg(it)  # type: ignore[arg-type]
                if _is_set_expr(it):
                    yield self._diag(sf, node.iter)
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                for gen in node.generators:
                    if _is_set_expr(gen.iter):
                        yield self._diag(sf, gen.iter)
            elif isinstance(node, ast.Call) and is_name_call(
                node, ("sum", "min", "max", "list", "tuple")
            ):
                arg = first_arg(node)
                if arg is not None and _is_set_expr(_reduction_arg(arg)):
                    yield self._diag(sf, arg)

    def _diag(self, sf: SourceFile, node: ast.expr) -> Diagnostic:
        return Diagnostic(
            self.code, sf.rel, node.lineno, node.col_offset,
            "iteration over a set has hash-dependent order; wrap the set "
            "in sorted(...) before iterating",
        )


class DictReductionRule:
    code = "RPL104"
    name = "dict-order-sensitive-reduction"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            yield from self._check_file(sf)

    def _check_file(self, sf: SourceFile) -> Iterator[Diagnostic]:
        in_core = sf.in_core()
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            if in_core and is_name_call(node, ("sum",)):
                arg = first_arg(node)
                if arg is not None and _is_dict_view(_reduction_arg(arg)):
                    yield Diagnostic(
                        self.code, sf.rel, arg.lineno, arg.col_offset,
                        "sum() over a dict view accumulates in insertion "
                        "order; wrap in sorted(...) to pin the order",
                    )
            if is_name_call(node, ("min", "max")) and any(
                kw.arg == "key" for kw in node.keywords
            ):
                arg = first_arg(node)
                if arg is None:
                    continue
                src = _reduction_arg(arg)
                wrapped = is_name_call(src, ("sorted",))
                has_view = any(_is_dict_view(sub) for sub in ast.walk(src))
                if has_view and not wrapped:
                    yield Diagnostic(
                        self.code, sf.rel, arg.lineno, arg.col_offset,
                        "min/max with key= over a dict view breaks ties by "
                        "insertion order; wrap in sorted(...) to pin the "
                        "tie-break",
                    )
