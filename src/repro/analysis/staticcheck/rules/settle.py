"""Settle-before-release rule (RPL5xx).

RPL501 — in ``core/scheduler.py``, every code path that releases a running
segment's resources (``release_gpus*``/``release_bandwidth``/
``_release_placement``) must also reach ``SegmentLedger.settle`` — the
single sanctioned write path for ``costs`` (PR 3's settle-on-event
contract) — or immediately re-reserve (the voluntary-migration probe
pattern, which releases to price an alternative and re-reserves the
original when it declines to move).

Mechanics: within each function of the scheduler, every release call site
requires *some* call in the same function (order-agnostic — the settle-on-
preempt path deliberately settles the ledger before touching the cluster,
so source order proves nothing) whose callee reaches ``settle`` or a
``reserve``-family function through the intra-file call graph.  Path-
sensitive ordering — "every path from the release actually reaches a
settle" — is RPL703's job (``rules/typestate.py``); RPL501 remains the
cheap structural backstop.  Functions whose own name contains ``release``
are the release primitives/wrappers themselves and are exempt — their
callers carry the obligation.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..callgraph import CallGraph, ordered_calls
from ..astutil import function_defs
from ..diagnostics import Diagnostic
from ..engine import Project

TARGET_SUFFIX = "scheduler.py"

RELEASE_NAMES = {
    "release_gpus", "release_gpus_typed", "release_bandwidth",
    "_release_placement",
}
SETTLE_NAMES = {"settle"}
RESERVE_NAMES = {
    "reserve_gpus", "reserve_gpus_typed", "reserve_bandwidth",
    "_reserve_placement",
}


class SettleBeforeReleaseRule:
    code = "RPL501"
    name = "settle-before-release"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if not (
                sf.rel.endswith(TARGET_SUFFIX) and "core" in sf.parts
            ):
                continue
            graph = CallGraph(sf.tree)
            for qual, fdef in function_defs(sf.tree):
                name = qual.rsplit(".", 1)[-1]
                if "release" in name:
                    continue  # the release primitives themselves
                yield from self._check_fn(sf, graph, name, fdef)

    def _check_fn(
        self, sf, graph: CallGraph, fn_name: str, fdef: ast.AST
    ) -> Iterator[Diagnostic]:
        calls = ordered_calls(fdef)
        for _pos, name, node in calls:
            if name not in RELEASE_NAMES:
                continue
            settled = False
            for _pos2, other, _node2 in calls:
                if other in RELEASE_NAMES:
                    continue
                if graph.call_reaches(
                    other, SETTLE_NAMES
                ) or graph.call_reaches(other, RESERVE_NAMES):
                    settled = True
                    break
            if not settled:
                yield Diagnostic(
                    self.code, sf.rel, node.lineno, node.col_offset,
                    f"'{name}' in '{fn_name}' has no companion call "
                    f"reaching SegmentLedger.settle (or a re-reserve); "
                    f"releasing an unsettled segment drops accrued cost",
                )
