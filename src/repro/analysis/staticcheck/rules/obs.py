"""Observability import-boundary rule (RPL6xx).

RPL601 — a ``core/`` decision-path module importing ``repro.obs`` (any
submodule, absolute or relative) outside the sanctioned seam.  The engine's
tracing hooks are duck calls against the :class:`~repro.obs.protocol.
TraceRecorder` protocol, guarded by ``recorder is not None`` — core never
needs the recorder implementation, the metrics store, or the exporters, and
importing them would invert the dependency direction the observability
design rests on (obs observes core; core must stay runnable and
bit-identical with obs deleted).

The one exception is the protocol seam itself: ``repro.obs.protocol`` may
be imported for *typing* (in practice under ``if TYPE_CHECKING:``), so
signatures can name the protocol without a runtime edge.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from ..diagnostics import Diagnostic
from ..engine import Project

#: The sole core-importable obs module (the typing protocol seam).
ALLOWED_MODULE = "repro.obs.protocol"


def _obs_module(node: ast.AST) -> Optional[str]:
    """Normalized dotted module name when ``node`` imports from the obs
    package, else None.  Relative forms (``from ..obs.metrics import X``)
    normalize to their absolute ``repro.obs...`` spelling."""
    if isinstance(node, ast.Import):
        for alias in node.names:
            if alias.name == "repro.obs" or alias.name.startswith("repro.obs."):
                return alias.name
        return None
    if isinstance(node, ast.ImportFrom):
        mod = node.module or ""
        if node.level == 0:
            if mod == "repro.obs" or mod.startswith("repro.obs."):
                return mod
            return None
        # Relative import out of core/: ``..obs`` (or deeper) reaches the
        # sibling obs package; normalize for the message/allowlist check.
        if mod == "obs" or mod.startswith("obs."):
            return "repro." + mod
    return None


class ObsImportRule:
    code = "RPL601"
    name = "obs-import-boundary"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if not sf.in_core():
                continue
            for node in ast.walk(sf.tree):
                mod = _obs_module(node)
                if mod is None or mod == ALLOWED_MODULE:
                    continue
                yield Diagnostic(
                    self.code, sf.rel, node.lineno, node.col_offset,
                    f"core decision-path module imports '{mod}'; core may "
                    f"only see the '{ALLOWED_MODULE}' typing seam — tracing "
                    f"is duck-typed through the TraceRecorder protocol",
                )
