"""Units-of-measure checking over the cost/timing core (RPL8xx).

Forward dataflow over each function's CFG in the scoped core files
(``accounting.py``, ``timing.py``, ``priority.py``, ``placement.py``,
``scheduler.py``): the abstract state maps local names to
:class:`~..dataflow.units.Unit`, seeded from the annotation registry at the
API boundary (function returns, attribute names, parameter conventions) and
propagated through assignments, loops and branches.  Joins of unlike units
drop to ⊤ (unknown) so every reported mismatch is provable; numeric
literals are unit-polymorphic.

    RPL801 — unlike-unit addition/subtraction/comparison (``seconds +
             dollars``), a keyword argument whose value's unit contradicts
             the registered slot (``SegmentLedger(rate=<$>)``), an
             attribute store contradicting the field's unit, or a return
             contradicting the function's registered unit.
    RPL802 — a rate×rate product (``$/s × $/s``): no quantity in the cost
             model has unit $²/s², so this is always a transposed operand.

Loops terminate by widening: a binding still changing after ``widen_after``
visits of the loop head is dropped to ⊤ (e.g. ``x = x / dt`` inside a loop
ascends through ever-higher powers of 1/s until widening kills it).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from ..diagnostics import Diagnostic
from ..engine import Project, SourceFile
from ..astutil import function_defs
from ..dataflow.cfg import (
    ROLE_ITER,
    ROLE_STMT,
    ROLE_TEST,
    ROLE_WITH_ENTER,
    Block,
    build_cfg,
)
from ..dataflow.framework import ForwardAnalysis, reporting_pass, run_forward
from ..dataflow.units import (
    DIMLESS,
    KW_UNITS,
    POLY,
    RATE,
    TOP,
    Unit,
    addable,
    divide,
    join,
    lookup_attr,
    lookup_func,
    lookup_name,
    multiply,
)

SCOPED_BASENAMES = {
    "accounting.py",
    "timing.py",
    "priority.py",
    "placement.py",
    "scheduler.py",
}

#: Builtins transparent to units: result carries the argument's unit.
_PRESERVING_BUILTINS = {
    "abs", "float", "int", "round", "sorted", "tuple", "list", "sum",
}
_JOINING_BUILTINS = {"max", "min"}

Env = Tuple[Tuple[str, Unit], ...]  # sorted, only non-TOP entries


def _env_get(env: Dict[str, Unit], name: str) -> Unit:
    u = env.get(name)
    if u is None or u.is_top:
        return lookup_name(name)
    return u


def _env_set(env: Dict[str, Unit], name: str, u: Unit) -> None:
    if u.is_top:
        env.pop(name, None)
    else:
        env[name] = u


class UnitsAnalysis(ForwardAnalysis):
    def __init__(
        self, sf: SourceFile, fn_name: str, sink: Set[Tuple[str, int, str]]
    ) -> None:
        self.sf = sf
        self.fn_name = fn_name
        self.sink = sink

    # -- lattice --------------------------------------------------------
    def initial(self) -> Env:
        return ()

    def join(self, a: Env, b: Env) -> Env:
        da, db = dict(a), dict(b)
        out: Dict[str, Unit] = {}
        for k in da.keys() & db.keys():
            u = join(da[k], db[k])
            if not u.is_top:
                out[k] = u
        return tuple(sorted(out.items()))

    def widen(self, old: Env, new: Env) -> Env:
        do, dn = dict(old), dict(new)
        return tuple(
            sorted((k, u) for k, u in dn.items() if do.get(k) == u)
        )

    # -- reporting ------------------------------------------------------
    def _report(self, report, code: str, line: int, message: str) -> None:
        if report is not None:
            key = (code, line, message)
            if key not in self.sink:
                self.sink.add(key)
                report(code, line, message)

    # -- expression evaluation -----------------------------------------
    def eval(self, node: Optional[ast.AST], env: Dict[str, Unit], report) -> Unit:
        if node is None:
            return TOP
        if isinstance(node, ast.Constant):
            v = node.value
            if isinstance(v, bool) or not isinstance(v, (int, float, complex)):
                return TOP
            return POLY
        if isinstance(node, ast.Name):
            return _env_get(env, node.id)
        if isinstance(node, ast.Attribute):
            return lookup_attr(node.attr)
        if isinstance(node, ast.BinOp):
            return self._binop(node, env, report)
        if isinstance(node, ast.UnaryOp):
            u = self.eval(node.operand, env, report)
            if isinstance(node.op, (ast.USub, ast.UAdd)):
                return u
            if isinstance(node.op, ast.Not):
                return DIMLESS
            return TOP
        if isinstance(node, ast.BoolOp):
            out = POLY
            for v in node.values:
                out = join(out, self.eval(v, env, report))
            return out
        if isinstance(node, ast.Compare):
            left = self.eval(node.left, env, report)
            for op, comp in zip(node.ops, node.comparators):
                right = self.eval(comp, env, report)
                if isinstance(
                    op, (ast.Eq, ast.NotEq, ast.Lt, ast.LtE, ast.Gt, ast.GtE)
                ) and not addable(left, right):
                    self._report(
                        report,
                        "RPL801",
                        node.lineno,
                        f"comparing {left.render()} with {right.render()} "
                        f"in '{self.fn_name}': unlike units never order "
                        f"meaningfully",
                    )
                left = right
            return DIMLESS
        if isinstance(node, ast.IfExp):
            self.eval(node.test, env, report)
            return join(
                self.eval(node.body, env, report),
                self.eval(node.orelse, env, report),
            )
        if isinstance(node, ast.Call):
            return self._call(node, env, report)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            return self._comprehension(node, env, report)
        if isinstance(node, ast.DictComp):
            inner = dict(env)
            for gen in node.generators:
                self.eval(gen.iter, inner, report)
                for n in ast.walk(gen.target):
                    if isinstance(n, ast.Name):
                        inner.pop(n.id, None)
                        _env_set(inner, n.id, TOP)
            self.eval(node.key, inner, report)
            self.eval(node.value, inner, report)
            return TOP
        if isinstance(node, ast.Subscript):
            u = self.eval(node.value, env, report)
            self.eval(node.slice, env, report)
            # Containers are transparent: a tuple-of-seconds indexes to
            # seconds (comm_times[0]); unknown containers stay unknown.
            return u if u.is_concrete else TOP
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            for elt in node.elts:
                self.eval(elt, env, report)
            return TOP
        if isinstance(node, ast.Dict):
            for part in (*node.keys, *node.values):
                self.eval(part, env, report)
            return TOP
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                self.eval(v, env, report)
            return TOP
        if isinstance(node, ast.FormattedValue):
            self.eval(node.value, env, report)
            return TOP
        if isinstance(node, ast.Starred):
            return self.eval(node.value, env, report)
        if isinstance(node, ast.NamedExpr):
            u = self.eval(node.value, env, report)
            if isinstance(node.target, ast.Name):
                _env_set(env, node.target.id, u)
            return u
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.eval(part, env, report)
            return TOP
        if isinstance(node, ast.Lambda):
            return TOP  # unit-opaque; its body is not this function's flow
        return TOP

    def _binop(self, node: ast.BinOp, env: Dict[str, Unit], report) -> Unit:
        left = self.eval(node.left, env, report)
        right = self.eval(node.right, env, report)
        op = node.op
        if isinstance(op, (ast.Add, ast.Sub)):
            if not addable(left, right):
                verb = "add" if isinstance(op, ast.Add) else "subtract"
                self._report(
                    report,
                    "RPL801",
                    node.lineno,
                    f"cannot {verb} {right.render()} "
                    f"{'to' if verb == 'add' else 'from'} {left.render()} "
                    f"in '{self.fn_name}'",
                )
                return TOP
            return join(left, right)
        if isinstance(op, ast.Mult):
            if left == RATE and right == RATE:
                self._report(
                    report,
                    "RPL802",
                    node.lineno,
                    f"rate×rate product in '{self.fn_name}': $/s × $/s "
                    f"has unit $²/s², which no quantity in the cost model "
                    f"carries — one operand is transposed",
                )
                return TOP
            return multiply(left, right)
        if isinstance(op, (ast.Div, ast.FloorDiv)):
            return divide(left, right)
        if isinstance(op, ast.Mod):
            if (
                left.is_concrete
                and right.is_concrete
                and not addable(left, right)
            ):
                self._report(
                    report,
                    "RPL801",
                    node.lineno,
                    f"{left.render()} %% {right.render()} in "
                    f"'{self.fn_name}' mixes unlike units",
                )
            return left if left.is_concrete else TOP
        if isinstance(op, ast.Pow):
            if isinstance(node.right, ast.Constant) and isinstance(
                node.right.value, int
            ):
                if left.is_concrete:
                    return Unit(
                        {d: e * node.right.value for d, e in left.dims}
                    )
            if left.is_poly or left == DIMLESS:
                return left
            return TOP
        return TOP

    def _call(self, node: ast.Call, env: Dict[str, Unit], report) -> Unit:
        arg_units = [self.eval(a, env, report) for a in node.args]
        kw_units: Dict[str, Unit] = {}
        for kw in node.keywords:
            u = self.eval(kw.value, env, report)
            if kw.arg is not None:
                kw_units[kw.arg] = u
        for kw in node.keywords:
            if kw.arg is None:
                continue
            expected = KW_UNITS.get(kw.arg)
            actual = kw_units.get(kw.arg, TOP)
            if (
                expected is not None
                and expected.is_concrete
                and actual.is_concrete
                and actual != expected
            ):
                self._report(
                    report,
                    "RPL801",
                    node.lineno,
                    f"keyword '{kw.arg}' of call in '{self.fn_name}' "
                    f"expects {expected.render()} but receives "
                    f"{actual.render()}",
                )
        fn = node.func
        if isinstance(fn, ast.Name):
            if fn.id == "len":
                return DIMLESS
            if fn.id in _JOINING_BUILTINS:
                out = POLY
                for u in (*arg_units, *kw_units.values()):
                    out = join(out, u)
                return out
            if fn.id in _PRESERVING_BUILTINS:
                return arg_units[0] if arg_units else TOP
            return lookup_func(fn.id)
        if isinstance(fn, ast.Attribute):
            self.eval(fn.value, env, report)
            return lookup_func(fn.attr)
        self.eval(fn, env, report)
        return TOP

    def _comprehension(self, node: ast.AST, env: Dict[str, Unit], report) -> Unit:
        inner = dict(env)
        for gen in node.generators:
            it = self.eval(gen.iter, inner, report)
            names = [
                n.id for n in ast.walk(gen.target) if isinstance(n, ast.Name)
            ]
            # Iterating a unit-carrying container binds the element unit
            # (single target only; tuple unpacking is opaque).
            if len(names) == 1 and it.is_concrete:
                _env_set(inner, names[0], it)
            else:
                for name in names:
                    inner.pop(name, None)
            for cond in gen.ifs:
                self.eval(cond, inner, report)
        return self.eval(node.elt, inner, report)

    # -- statement transfer --------------------------------------------
    def transfer(self, block: Block, state: Env, report=None) -> Env:
        stmt = block.stmt
        if stmt is None or block.role not in (
            ROLE_STMT, ROLE_TEST, ROLE_ITER, ROLE_WITH_ENTER
        ):
            return state
        env = dict(state)
        if block.role == ROLE_TEST:
            self.eval(stmt.test, env, report)
        elif block.role == ROLE_ITER:
            it = self.eval(stmt.iter, env, report)
            names = [
                n.id
                for n in ast.walk(stmt.target)
                if isinstance(n, ast.Name)
            ]
            if len(names) == 1 and it.is_concrete:
                _env_set(env, names[0], it)
            else:
                for name in names:
                    env.pop(name, None)
        elif block.role == ROLE_WITH_ENTER:
            for item in stmt.items:
                self.eval(item.context_expr, env, report)
                if item.optional_vars is not None:
                    for n in ast.walk(item.optional_vars):
                        if isinstance(n, ast.Name):
                            env.pop(n.id, None)
        else:
            self._stmt(stmt, env, report)
        return tuple(sorted(env.items()))

    def _stmt(self, stmt: ast.AST, env: Dict[str, Unit], report) -> None:
        if isinstance(
            stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            env.pop(stmt.name, None)
            return
        if isinstance(stmt, ast.Assign):
            u = self.eval(stmt.value, env, report)
            for target in stmt.targets:
                self._bind(target, u, stmt.value, env, report)
            return
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is None:
                return
            u = self.eval(stmt.value, env, report)
            self._bind(stmt.target, u, stmt.value, env, report)
            return
        if isinstance(stmt, ast.AugAssign):
            current = self._load_target(stmt.target, env, report)
            value = self.eval(stmt.value, env, report)
            if isinstance(stmt.op, (ast.Add, ast.Sub)):
                if not addable(current, value):
                    self._report(
                        report,
                        "RPL801",
                        stmt.lineno,
                        f"augmented {'addition' if isinstance(stmt.op, ast.Add) else 'subtraction'} "
                        f"of {value.render()} onto {current.render()} in "
                        f"'{self.fn_name}'",
                    )
                    result = TOP
                else:
                    result = join(current, value)
            elif isinstance(stmt.op, ast.Mult):
                result = multiply(current, value)
            elif isinstance(stmt.op, (ast.Div, ast.FloorDiv)):
                result = divide(current, value)
            else:
                result = TOP
            if isinstance(stmt.target, ast.Name):
                _env_set(env, stmt.target.id, result)
            return
        if isinstance(stmt, ast.Return):
            u = self.eval(stmt.value, env, report)
            expected = lookup_func(self.fn_name)
            if (
                stmt.value is not None
                and expected.is_concrete
                and u.is_concrete
                and u != expected
            ):
                self._report(
                    report,
                    "RPL801",
                    stmt.lineno,
                    f"'{self.fn_name}' is registered to return "
                    f"{expected.render()} but this path returns "
                    f"{u.render()}",
                )
            return
        if isinstance(stmt, ast.Expr):
            self.eval(stmt.value, env, report)
            return
        if isinstance(stmt, ast.Assert):
            self.eval(stmt.test, env, report)
            if stmt.msg is not None:
                self.eval(stmt.msg, env, report)
            return
        if isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self.eval(stmt.exc, env, report)
            return
        if isinstance(stmt, ast.Delete):
            for target in stmt.targets:
                if isinstance(target, ast.Name):
                    env.pop(target.id, None)
            return

    def _load_target(self, target: ast.AST, env: Dict[str, Unit], report) -> Unit:
        if isinstance(target, ast.Name):
            return _env_get(env, target.id)
        if isinstance(target, ast.Attribute):
            return lookup_attr(target.attr)
        if isinstance(target, ast.Subscript):
            u = self.eval(target.value, env, report)
            return u if u.is_concrete else TOP
        return TOP

    def _bind(
        self,
        target: ast.AST,
        u: Unit,
        value: ast.AST,
        env: Dict[str, Unit],
        report,
    ) -> None:
        if isinstance(target, ast.Name):
            _env_set(env, target.id, u)
            return
        if isinstance(target, ast.Attribute):
            expected = lookup_attr(target.attr)
            if expected.is_concrete and u.is_concrete and u != expected:
                self._report(
                    report,
                    "RPL801",
                    target.lineno,
                    f"storing {u.render()} into attribute "
                    f"'{target.attr}' ({expected.render()}) in "
                    f"'{self.fn_name}'",
                )
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            elts = target.elts
            if isinstance(value, (ast.Tuple, ast.List)) and len(
                value.elts
            ) == len(elts):
                for t, v in zip(elts, value.elts):
                    self._bind(t, self.eval(v, env, None), v, env, report)
            else:
                for t in elts:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            env.pop(n.id, None)
            return
        # Subscript / starred stores: no binding, no check.


class UnitsRule:
    code = "RPL801"
    codes = ("RPL801", "RPL802")
    name = "units-of-measure"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if not sf.in_core():
                continue
            if sf.parts[-1] not in SCOPED_BASENAMES:
                continue
            yield from self._check_file(sf)

    def _check_file(self, sf: SourceFile) -> Iterator[Diagnostic]:
        diags: List[Diagnostic] = []
        for qual, fdef in function_defs(sf.tree):
            fn_name = qual.rsplit(".", 1)[-1]
            sink: Set[Tuple[str, int, str]] = set()
            analysis = UnitsAnalysis(sf, fn_name, sink)
            cfg = build_cfg(fdef)
            in_states = run_forward(cfg, analysis)

            def report(code: str, line: int, message: str) -> None:
                diags.append(Diagnostic(code, sf.rel, line, 0, message))

            reporting_pass(cfg, analysis, in_states, report)
        yield from sorted(diags, key=Diagnostic.sort_key)
