"""Resource typestate over the cluster ledger APIs (RPL7xx).

Path-sensitive upgrade of RPL501: per function in ``core/``, an abstract
interpretation over the statement CFG tracks, per *root value* (the base
name of the attribute chain handed to a primitive — ``run`` in
``cluster.release_bandwidth(run.placement.reserved_bw)``), which resource
kinds are currently **released-pending** (released here, neither settled
nor re-reserved yet), **fresh** (reserved here and not yet escaped to a
caller-visible structure), and **ever-released**, plus a per-path "a settle
happened" flag.  Primitive knowledge flows through
:mod:`..dataflow.summaries`, so wrappers like ``_release_placement`` carry
their effects to call sites.

    RPL701 — a leak: an exception edge escapes the function while a root is
             released-but-unsettled or reserved-but-unreleased, or a path
             settles after releasing only *some* of the resource kinds this
             file reserves (e.g. GPUs released, bandwidth not).
    RPL702 — double release: a kind released again with no intervening
             re-reserve on some path.
    RPL703 — release-without-settle: a path reaches function exit (or
             rebinds the root) with released-pending state and no settle;
             also an opened ``SegmentLedger`` dropped without settle.

States are disjunctions of paths (capped, then merged conservatively), so
"release then settle on every branch" proves clean while "settle only on
the happy branch" names the unhandled edge.  Calls to the primitives, to
local functions with known summaries, and to settle-reaching callees are
atomic (no exception edge); everything else may raise.  Passing a tracked
root to an unknown callee *escapes* its fresh reservations — ownership
moved — but cannot discharge released-pending state: only settle or
re-reserve rebalances the ledger.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, FrozenSet, Iterator, List, Optional, Set, Tuple

from ..callgraph import CallGraph
from ..diagnostics import Diagnostic
from ..engine import Project, SourceFile
from ..astutil import function_defs
from ..dataflow.cfg import (
    ROLE_ITER,
    ROLE_STMT,
    ROLE_TEST,
    ROLE_WITH_ENTER,
    Block,
    _calls_shallow,
    build_cfg,
    callee_bare_name,
    default_may_raise,
)
from ..dataflow.framework import ForwardAnalysis, reporting_pass, run_forward
from ..dataflow.summaries import (
    LEDGER,
    RELEASE_PRIMS,
    RESERVE_PRIMS,
    SETTLE_NAMES,
    FunctionSummary,
    _arg_index_for_param,
    build_summaries,
    expr_root,
    primitive_resource_arg,
)

PATH_CAP = 64

EXEMPT_NAME_FRAGMENTS = ("release", "reserve")
EXEMPT_NAMES = {"settle", "open", "reprice", "telemetry"}

_EMPTY: FrozenSet[str] = frozenset()


@dataclasses.dataclass(frozen=True)
class RootState:
    pending: FrozenSet[str] = _EMPTY   # released, not yet settled/re-reserved
    ever: FrozenSet[str] = _EMPTY      # kinds ever released through this root
    fresh: FrozenSet[str] = _EMPTY     # reserved/opened here, not yet escaped
    release_line: int = 0
    reserve_line: int = 0

    def is_empty(self) -> bool:
        return not (self.pending or self.ever or self.fresh)


@dataclasses.dataclass(frozen=True)
class Path:
    roots: Tuple[Tuple[str, RootState], ...] = ()
    settled: bool = False
    exc_line: int = 0

    def get(self, root: str) -> RootState:
        for name, st in self.roots:
            if name == root:
                return st
        return RootState()

    def set(self, root: str, st: RootState) -> "Path":
        rest = tuple((n, s) for n, s in self.roots if n != root)
        if not st.is_empty():
            rest = tuple(sorted(rest + ((root, st),)))
        return dataclasses.replace(self, roots=rest)

    def fragile_roots(self) -> List[Tuple[str, RootState]]:
        out = []
        for name, st in self.roots:
            if (st.pending and not self.settled) or st.fresh:
                out.append((name, st))
        return out


State = FrozenSet[Path]


def _merge_paths(paths: State) -> Path:
    """Conservative single-path collapse (cap overflow / widening)."""
    roots: Dict[str, RootState] = {}
    settled = True
    exc_line = 0
    for p in paths:
        settled = settled and p.settled
        exc_line = exc_line or p.exc_line
        for name, st in p.roots:
            cur = roots.get(name, RootState())
            roots[name] = RootState(
                pending=cur.pending | st.pending,
                ever=cur.ever | st.ever,
                fresh=cur.fresh | st.fresh,
                release_line=min(
                    x for x in (cur.release_line, st.release_line, 1 << 30) if x
                )
                if (cur.release_line or st.release_line)
                else 0,
                reserve_line=min(
                    x for x in (cur.reserve_line, st.reserve_line, 1 << 30) if x
                )
                if (cur.reserve_line or st.reserve_line)
                else 0,
            )
    return Path(
        roots=tuple(sorted(roots.items())), settled=settled, exc_line=exc_line
    )


class _Event:
    __slots__ = ("op", "kind", "root", "line")

    def __init__(self, op: str, kind: str = "", root: str = "", line: int = 0):
        self.op = op      # reserve | release | settle | open | escape
        self.kind = kind
        self.root = root
        self.line = line


class TypestateAnalysis(ForwardAnalysis):
    def __init__(
        self,
        sf: SourceFile,
        fn_name: str,
        graph: CallGraph,
        summaries: Dict[str, FunctionSummary],
        acquired: FrozenSet[str],
        sink: Set[Tuple[str, int, str]],
    ) -> None:
        self.sf = sf
        self.fn_name = fn_name
        self.graph = graph
        self.summaries = summaries
        self.acquired = acquired
        self.sink = sink

    # -- lattice --------------------------------------------------------
    def initial(self) -> State:
        return frozenset({Path()})

    def join(self, a: State, b: State) -> State:
        merged = a | b
        if len(merged) > PATH_CAP:
            return frozenset({_merge_paths(merged)})
        return merged

    def widen(self, old: State, new: State) -> State:
        merged = old | new
        if len(merged) > 1 and merged != old:
            return frozenset({_merge_paths(merged)})
        return merged

    # -- reporting ------------------------------------------------------
    def _report(self, report, code: str, line: int, message: str) -> None:
        if report is not None:
            key = (code, line, message)
            if key not in self.sink:
                self.sink.add(key)
                report(code, line, message)

    # -- event extraction ----------------------------------------------
    def _events_for_calls(self, node: ast.AST) -> Iterator[_Event]:
        for call in _calls_shallow(node):
            bare = callee_bare_name(call)
            line = call.lineno
            if bare in RELEASE_PRIMS or bare in RESERVE_PRIMS:
                prims = RELEASE_PRIMS if bare in RELEASE_PRIMS else RESERVE_PRIMS
                op = "release" if bare in RELEASE_PRIMS else "reserve"
                root = expr_root(primitive_resource_arg(call))
                if root is not None:
                    yield _Event(op, prims[bare], root, line)
                continue
            if bare == "open" and isinstance(call.func, ast.Attribute):
                recv = expr_root(call.func.value)
                if recv is not None and recv.endswith("Ledger"):
                    yield _Event("open", LEDGER, "", line)  # root set by Assign
                    continue
            summary = self.summaries.get(bare) if bare else None
            if summary is not None and (
                summary.has_resource_effects or summary.settles
            ):
                for effects, op in (
                    (summary.releases, "release"),
                    (summary.reserves, "reserve"),
                ):
                    for kind, pidx in sorted(effects):
                        arg = _arg_index_for_param(call, summary.params, pidx)
                        root = expr_root(arg)
                        if root is not None:
                            yield _Event(op, kind, root, line)
                if summary.settles:
                    yield self._settle_event(call, line)
                continue
            if bare is not None and (
                bare in SETTLE_NAMES
                or self.graph.call_reaches(bare, SETTLE_NAMES)
            ):
                yield self._settle_event(call, line)
                continue
            # Unknown call: tracked roots passed to it escape.
            roots = set()
            if isinstance(call.func, ast.Attribute):
                r = expr_root(call.func.value)
                if r:
                    roots.add(r)
            for arg in (*call.args, *[kw.value for kw in call.keywords]):
                r = expr_root(arg)
                if r:
                    roots.add(r)
            for r in sorted(roots):
                yield _Event("escape", "", r, line)

    def _settle_event(self, call: ast.Call, line: int) -> _Event:
        recv = (
            expr_root(call.func.value)
            if isinstance(call.func, ast.Attribute)
            else None
        )
        return _Event("settle", "", recv or "", line)

    # -- transfer -------------------------------------------------------
    def transfer(self, block: Block, state: State, report=None) -> State:
        return self._apply(block, state, report=report, resets=True)

    def transfer_exc(self, block: Block, state: State, note: str, report=None) -> State:
        out = self._apply(block, state, report=None, resets=False)
        line = block.line
        stamped = set()
        for p in out:
            if p.fragile_roots():
                stamped.add(
                    dataclasses.replace(p, exc_line=p.exc_line or line)
                )
            else:
                stamped.add(dataclasses.replace(p, exc_line=0))
        return frozenset(stamped)

    def _apply(self, block: Block, state: State, report, resets: bool) -> State:
        stmt = block.stmt
        if stmt is None:
            if block.role == "exit":
                self._check_exit(state, report)
            elif block.role == "raise-exit":
                self._check_raise_exit(state, report)
            return state
        if block.role == "exit":
            return state
        if isinstance(
            stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
        ):
            return state  # a nested def only binds a name; its body has its own CFG
        events: List[_Event] = []
        open_target: Optional[str] = None
        if block.role in (ROLE_STMT, ROLE_TEST, ROLE_ITER, ROLE_WITH_ENTER):
            if block.role == ROLE_TEST:
                events = list(self._events_for_calls(stmt.test))
            elif block.role == ROLE_ITER:
                events = list(self._events_for_calls(stmt.iter))
            elif block.role == ROLE_WITH_ENTER:
                for item in stmt.items:
                    events.extend(self._events_for_calls(item.context_expr))
                    if resets and item.optional_vars is not None:
                        for node in ast.walk(item.optional_vars):
                            if isinstance(node, ast.Name):
                                events.append(
                                    _Event("reset", "", node.id, stmt.lineno)
                                )
            else:
                events = list(self._events_for_calls(stmt))
            if block.role == ROLE_STMT and isinstance(
                stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)
            ):
                targets = (
                    stmt.targets
                    if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                # An opened ledger binds its obligation to the target name.
                if any(e.op == "open" for e in events):
                    for t in targets:
                        if isinstance(t, ast.Name):
                            open_target = t.id
                value = getattr(stmt, "value", None)
                if value is not None:
                    for t in targets:
                        if isinstance(t, ast.Name) and isinstance(
                            value, ast.Name
                        ):
                            # Pure alias: obligations visible through both.
                            events.append(
                                _Event("escape", "", value.id, stmt.lineno)
                            )
                if resets:
                    for t in targets:
                        if isinstance(t, ast.Name) and not isinstance(
                            stmt, ast.AugAssign
                        ):
                            events.append(
                                _Event("reset", "", t.id, stmt.lineno)
                            )
            if block.role == ROLE_STMT and isinstance(stmt, ast.Delete) and resets:
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        events.append(_Event("reset", "", t.id, stmt.lineno))
            if block.role == ROLE_ITER and resets:
                for name in _loop_target_names(stmt):
                    events.append(_Event("reset", "", name, stmt.lineno))
            if block.role == ROLE_STMT and isinstance(stmt, ast.Return):
                if stmt.value is not None:
                    r = expr_root(stmt.value)
                    if r:
                        events.append(_Event("escape", "", r, stmt.lineno))
        # An Assign's target reset checks the *old* binding being
        # overwritten; the RHS's freshly-opened ledger binds afterwards.
        events.sort(key=lambda e: e.op == "open")
        # On an exception edge (resets=False) the statement did not complete:
        # an open that raised never created a ledger, so the binding's
        # obligation must not be charged to the target on that edge.
        if not resets:
            events = [e for e in events if e.op != "open"]
            open_target = None
        out: Set[Path] = set()
        for p in state:
            out.add(self._apply_events(p, events, open_target, report))
        result: State = frozenset(out)
        if len(result) > PATH_CAP:
            result = frozenset({_merge_paths(result)})
        return result

    def _apply_events(
        self,
        path: Path,
        events: List[_Event],
        open_target: Optional[str],
        report,
    ) -> Path:
        for ev in events:
            if ev.op == "reserve":
                st = path.get(ev.root)
                if ev.kind in st.pending:
                    st = dataclasses.replace(st, pending=st.pending - {ev.kind})
                else:
                    st = dataclasses.replace(
                        st, fresh=st.fresh | {ev.kind}, reserve_line=ev.line
                    )
                path = path.set(ev.root, st)
            elif ev.op == "release":
                st = path.get(ev.root)
                if ev.kind in st.pending:
                    self._report(
                        report,
                        "RPL702",
                        ev.line,
                        f"'{ev.root}' double-releases {ev.kind} (already "
                        f"released at line {st.release_line} with no "
                        f"re-reserve in between); ClusterState raises on "
                        f"double release at runtime",
                    )
                elif ev.kind in st.fresh:
                    st = dataclasses.replace(st, fresh=st.fresh - {ev.kind})
                    path = path.set(ev.root, st)
                else:
                    st = dataclasses.replace(
                        st,
                        pending=st.pending | {ev.kind},
                        ever=st.ever | {ev.kind},
                        release_line=st.release_line or ev.line,
                    )
                    path = path.set(ev.root, st)
            elif ev.op == "settle":
                path = dataclasses.replace(path, settled=True)
                if ev.root:
                    st = path.get(ev.root)
                    if LEDGER in st.fresh:
                        path = path.set(
                            ev.root,
                            dataclasses.replace(st, fresh=st.fresh - {LEDGER}),
                        )
            elif ev.op == "open":
                if open_target is not None:
                    st = path.get(open_target)
                    path = path.set(
                        open_target,
                        dataclasses.replace(
                            st,
                            fresh=st.fresh | {LEDGER},
                            reserve_line=ev.line,
                        ),
                    )
            elif ev.op == "escape":
                st = path.get(ev.root)
                if st.fresh:
                    path = path.set(
                        ev.root, dataclasses.replace(st, fresh=_EMPTY)
                    )
            elif ev.op == "reset":
                st = path.get(ev.root)
                if not st.is_empty():
                    self._check_root(
                        ev.root,
                        st,
                        path.settled,
                        report,
                        where=f"rebinding of '{ev.root}' at line {ev.line}",
                    )
                    path = path.set(ev.root, RootState())
        return path

    # -- end-of-path checks --------------------------------------------
    def _check_root(
        self, name: str, st: RootState, settled: bool, report, *, where: str
    ) -> None:
        if st.pending and not settled:
            kinds = "+".join(sorted(st.pending))
            self._report(
                report,
                "RPL703",
                st.release_line,
                f"'{name}' releases {kinds} at line {st.release_line} in "
                f"'{self.fn_name}' but no path from there settles the "
                f"segment ledger (or re-reserves) before {where}; the "
                f"accrued segment cost is dropped",
            )
        if LEDGER in st.fresh:
            self._report(
                report,
                "RPL703",
                st.reserve_line,
                f"segment ledger opened at line {st.reserve_line} into "
                f"'{name}' is dropped without settle before {where}",
            )
        hard = st.fresh - {LEDGER}
        if hard:
            kinds = "+".join(sorted(hard))
            self._report(
                report,
                "RPL701",
                st.reserve_line,
                f"'{name}' reserves {kinds} at line {st.reserve_line} in "
                f"'{self.fn_name}' but neither releases it nor hands it "
                f"off before {where}; the ledger never recovers the "
                f"capacity",
            )
        if settled and st.ever:
            missing = self.acquired - st.ever
            if missing and st.ever <= self.acquired:
                self._report(
                    report,
                    "RPL701",
                    st.release_line,
                    f"partial teardown of '{name}' in '{self.fn_name}': "
                    f"settles after releasing only "
                    f"{'+'.join(sorted(st.ever))} — "
                    f"{'+'.join(sorted(missing))} reserved in this file is "
                    f"never released on this path",
                )

    def _check_exit(self, state: State, report) -> None:
        for p in state:
            for name, st in p.roots:
                self._check_root(
                    name, st, p.settled, report, where="function exit"
                )

    def _check_raise_exit(self, state: State, report) -> None:
        for p in state:
            if not p.exc_line:
                continue
            for name, st in p.fragile_roots():
                if st.pending and not p.settled:
                    kinds = "+".join(sorted(st.pending))
                    self._report(
                        report,
                        "RPL701",
                        p.exc_line,
                        f"exception path from line {p.exc_line} escapes "
                        f"'{self.fn_name}' with '{name}' "
                        f"released-but-unsettled ({kinds} released at line "
                        f"{st.release_line}); the accrued segment cost is "
                        f"dropped on this edge",
                    )
                if st.fresh:
                    kinds = "+".join(sorted(st.fresh))
                    self._report(
                        report,
                        "RPL701",
                        p.exc_line,
                        f"exception path from line {p.exc_line} leaks the "
                        f"{kinds} acquired by '{name}' at line "
                        f"{st.reserve_line} in '{self.fn_name}' — no "
                        f"release, settle, or escape on this edge",
                    )


def _loop_target_names(stmt: ast.AST) -> List[str]:
    out: List[str] = []
    target = getattr(stmt, "target", None)
    if target is None:
        return out
    for node in ast.walk(target):
        if isinstance(node, ast.Name):
            out.append(node.id)
    return out


def _acquired_kinds(tree: ast.Module) -> FrozenSet[str]:
    kinds: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            bare = callee_bare_name(node)
            if bare in RESERVE_PRIMS:
                kinds.add(RESERVE_PRIMS[bare])
    return frozenset(kinds)


def _exempt(name: str) -> bool:
    return name in EXEMPT_NAMES or any(
        frag in name for frag in EXEMPT_NAME_FRAGMENTS
    )


class ResourceTypestateRule:
    code = "RPL701"
    codes = ("RPL701", "RPL702", "RPL703")
    name = "resource-typestate"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if not sf.in_core():
                continue
            yield from self._check_file(sf)

    def _check_file(self, sf: SourceFile) -> Iterator[Diagnostic]:
        graph = CallGraph(sf.tree)
        summaries = build_summaries(graph)
        acquired = _acquired_kinds(sf.tree)
        atomic = frozenset(
            set(RESERVE_PRIMS)
            | set(RELEASE_PRIMS)
            | SETTLE_NAMES
            | {
                n
                for n, s in summaries.items()
                if s.has_resource_effects or s.settles
            }
        )
        diags: List[Diagnostic] = []
        for qual, fdef in function_defs(sf.tree):
            fn_name = qual.rsplit(".", 1)[-1]
            if _exempt(fn_name):
                continue
            sink: Set[Tuple[str, int, str]] = set()
            analysis = TypestateAnalysis(
                sf, fn_name, graph, summaries, acquired, sink
            )
            cfg = build_cfg(
                fdef, lambda node: default_may_raise(node, atomic)
            )
            in_states = run_forward(cfg, analysis)

            def report(code: str, line: int, message: str) -> None:
                diags.append(
                    Diagnostic(code, sf.rel, line, 0, message)
                )

            reporting_pass(cfg, analysis, in_states, report)
        yield from sorted(diags, key=Diagnostic.sort_key)
