"""Ledger encapsulation rule (RPL2xx).

RPL201 — any attribute access to a ``ClusterState`` private ledger field
outside ``core/cluster.py``.  The ledgers (capacity/usage planes, price and
bandwidth matrices, free-GPU vectors, rank/index tables) have exactly one
sanctioned mutation path — the reserve/release API — and memoized upkeep
(``available_matrix``) that a direct poke silently bypasses.  Reads must go
through the public accessors so the representation can keep evolving.

Scoping: only non-``self``/``cls`` receivers are checked, so an unrelated
class using a generic private name (e.g. a ``_cap`` counter of its own) is
not confused with ClusterState's field of the same name.  Every offending
site in practice reads ``cluster._free``-style attributes off a ClusterState
instance, which is precisely the non-self-receiver shape.
"""

from __future__ import annotations

import ast
from typing import Iterator

from ..diagnostics import Diagnostic
from ..engine import Project

# The private ledger surface of ClusterState (core/cluster.py).  Keep in
# sync with the dataclass; the staticcheck self-test cross-checks this set
# against the real class attributes.
PRIVATE_LEDGER_FIELDS = frozenset({
    "_names", "_idx", "_name_rank",
    "_cap", "_cap_total", "_cap_t", "_cap_t_base",
    "_price", "_price_base", "_spot_mult",
    "_hetero", "_gpu_types", "_tidx", "_pools", "_region_cells",
    "_used_t", "_flops_t", "_cell_exists",
    "_free", "_free_total",
    "_bw_mat", "_link_idx", "_bw_total", "_bw_base", "_bw_dict_base",
    "_res_mat", "_res_extra", "_res_total",
    "_avail_base", "_avail_view", "_avail_touch",
})

OWNER_FILE_SUFFIX = "core/cluster.py"


class LedgerEncapsulationRule:
    code = "RPL201"
    name = "cluster-ledger-encapsulation"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if sf.rel.endswith(OWNER_FILE_SUFFIX):
                continue
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Attribute):
                    continue
                if node.attr not in PRIVATE_LEDGER_FIELDS:
                    continue
                recv = node.value
                if isinstance(recv, ast.Name) and recv.id in ("self", "cls"):
                    continue
                verb = "write to" if isinstance(
                    node.ctx, (ast.Store, ast.Del)
                ) else "read of"
                yield Diagnostic(
                    self.code, sf.rel, node.lineno, node.col_offset,
                    f"direct {verb} ClusterState private ledger "
                    f"'{node.attr}' outside core/cluster.py; use the "
                    f"public accessors",
                )
