"""Twin-parity rule (RPL3xx): structural AST diff of the numpy/jax decision
kernels in ``core/kernels_decide.py``.

The engine's bit-exactness contract says the two backends run *the same
array program*.  This rule checks that statically: both twins are lowered to
a canonical symbolic form — module roots ``np``/``jnp`` unify to ``X``,
method calls (``e.any()``) unify with function calls (``X.any(e)``),
``x.at[i].set(v)`` / ``x[i] = v`` / ``x = X.where(m, v, x)`` all lower to one
``maskset`` node, ``.copy()`` is identity, trailing digits on names are
stripped (``g0`` ≡ ``g``), and single-assignment temporaries are inlined —
and then the loop-carried state of the numpy ``while`` loop is compared
variable-by-variable (init expression, per-step update, loop condition,
outputs) against the ``lax.while_loop`` state tuple.

Codes:

RPL301 — the twins parse into the expected shape but diverge (different
         loop-carried variables, different init/update/condition for some
         variable, different outputs).
RPL302 — a twin is missing or no longer matches the structural conventions
         the differ understands (so parity can't be proven); treat this as
         "restore the convention or extend the differ", never ignore it.

Structural conventions (enforced as RPL302):
* numpy twin = ``_prim_expand_numpy`` (init region) tail-calling
  ``_prim_steps_numpy`` (one ``while`` loop + return), passing its locals
  positionally under the same names;
* jax twin = ``_prim`` nested in ``_load_jax``: init region, ``cond``/
  ``body`` defs, one ``lax.while_loop`` whose state tuple carries the loop
  variables, unpack + return.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from ..astutil import function_defs
from ..diagnostics import Diagnostic
from ..engine import Project, SourceFile

TARGET_BASENAME = "kernels_decide.py"
NUMPY_EXPAND = "_prim_expand_numpy"
NUMPY_STEPS = "_prim_steps_numpy"
JAX_FN = "_prim"

# Module roots unified to the symbol X.
_MODULE_ALIASES = {"np": "X", "jnp": "X", "numpy": "X"}
# Methods rewritten to X.<name>(receiver, ...) so `e.any()` == `X.any(e)`.
_METHOD_FNS = {
    "any", "all", "max", "min", "argmax", "argmin", "sum", "astype",
    "reshape", "isfinite",
}

Sig = Tuple  # canonical signatures are nested tuples


class TwinStructureError(Exception):
    def __init__(self, msg: str, lineno: int) -> None:
        super().__init__(msg)
        self.lineno = lineno


def _strip(name: str) -> str:
    stripped = name.rstrip("0123456789")
    return stripped if stripped else name


def _var(name: str) -> Sig:
    return ("var", _strip(name))


@dataclasses.dataclass
class TwinProgram:
    params: Tuple[str, ...]
    loop_vars: Tuple[str, ...]          # canonical names (jax: state order)
    init_sigs: Dict[str, Sig]
    init_lines: Dict[str, int]
    cond_sig: Sig
    cond_line: int
    step_sigs: Dict[str, Sig]
    step_lines: Dict[str, int]
    outputs: Tuple[str, ...]
    fn_line: int


class _Canon:
    """Expression canonicalizer over a symbolic environment.

    ``env`` maps *stripped* names to their canonical values; names absent
    from the env are free symbols.  ``state_map`` resolves ``state[i]``
    subscripts inside the jax ``cond`` to the i-th loop variable.
    """

    def __init__(
        self,
        env: Dict[str, Sig],
        state_map: Optional[Tuple[str, Sequence[str]]] = None,
    ) -> None:
        self.env = env
        self.state_map = state_map

    def canon(self, node: ast.expr) -> Sig:
        c = self.canon
        if isinstance(node, ast.Constant):
            return ("const", repr(node.value))
        if isinstance(node, ast.Name):
            if node.id in _MODULE_ALIASES:
                return ("mod", "X")
            key = _strip(node.id)
            return self.env.get(key, ("var", key))
        if isinstance(node, ast.Attribute):
            return ("attr", c(node.value), node.attr)
        if isinstance(node, ast.Subscript):
            if (
                self.state_map is not None
                and isinstance(node.value, ast.Name)
                and node.value.id == self.state_map[0]
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, int)
            ):
                return ("var", self.state_map[1][node.slice.value])
            return ("sub", c(node.value), c(node.slice))
        if isinstance(node, ast.Slice):
            return (
                "slice",
                c(node.lower) if node.lower else ("none",),
                c(node.upper) if node.upper else ("none",),
                c(node.step) if node.step else ("none",),
            )
        if isinstance(node, ast.Tuple):
            return ("tuple",) + tuple(c(e) for e in node.elts)
        if isinstance(node, ast.List):
            return ("list",) + tuple(c(e) for e in node.elts)
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, ast.USub) and isinstance(
                node.operand, ast.Constant
            ) and isinstance(node.operand.value, (int, float)):
                return ("const", repr(-node.operand.value))
            return ("unary", type(node.op).__name__, c(node.operand))
        if isinstance(node, ast.BinOp):
            return ("bin", type(node.op).__name__, c(node.left), c(node.right))
        if isinstance(node, ast.BoolOp):
            return ("bool", type(node.op).__name__) + tuple(
                c(v) for v in node.values
            )
        if isinstance(node, ast.Compare):
            return (
                "cmp",
                c(node.left),
                tuple(type(op).__name__ for op in node.ops),
                tuple(c(v) for v in node.comparators),
            )
        if isinstance(node, ast.Call):
            return self._canon_call(node)
        if isinstance(node, ast.IfExp):
            return ("ifexp", c(node.test), c(node.body), c(node.orelse))
        raise TwinStructureError(
            f"unsupported expression {type(node).__name__}",
            getattr(node, "lineno", 0),
        )

    def _canon_call(self, node: ast.Call) -> Sig:
        c = self.canon
        func = node.func
        # x.at[idx].set(v)  ->  maskset(idx, v, x)
        if (
            isinstance(func, ast.Attribute)
            and func.attr == "set"
            and isinstance(func.value, ast.Subscript)
            and isinstance(func.value.value, ast.Attribute)
            and func.value.value.attr == "at"
            and len(node.args) == 1
        ):
            base = func.value.value.value
            return (
                "maskset",
                c(func.value.slice),
                c(node.args[0]),
                c(base),
            )
        if isinstance(func, ast.Attribute):
            recv = func.value
            is_module = isinstance(recv, ast.Name) and recv.id in _MODULE_ALIASES
            if not is_module:
                if func.attr == "copy" and not node.args and not node.keywords:
                    return c(recv)
                if func.attr in _METHOD_FNS:
                    return (
                        "call",
                        ("attr", ("mod", "X"), func.attr),
                        (c(recv),) + tuple(c(a) for a in node.args),
                        self._kwargs(node),
                    )
        return (
            "call",
            c(func),
            tuple(c(a) for a in node.args),
            self._kwargs(node),
        )

    def _kwargs(self, node: ast.Call) -> Sig:
        items = sorted(
            (kw.arg or "**", self.canon(kw.value)) for kw in node.keywords
        )
        return tuple(items)


def _is_where_call(node: ast.expr) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "where"
        and isinstance(node.func.value, ast.Name)
        and node.func.value.id in _MODULE_ALIASES
        and len(node.args) == 3
    )


class _Region:
    """Sequential symbolic interpreter for one straight-line region."""

    def __init__(self, env: Dict[str, Sig]) -> None:
        self.env = env
        self.lines: Dict[str, int] = {}
        self.returned: Optional[ast.Return] = None

    def run(self, stmts: Sequence[ast.stmt], canon: _Canon) -> None:
        for stmt in stmts:
            if self.returned is not None:
                raise TwinStructureError("code after return", stmt.lineno)
            self._exec(stmt, canon)

    def _exec(self, stmt: ast.stmt, canon: _Canon) -> None:
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            return  # docstring
        if isinstance(stmt, ast.With):
            self.run(stmt.body, canon)
            return
        if isinstance(stmt, ast.If):
            if not stmt.orelse and all(
                isinstance(s, (ast.Break, ast.Continue, ast.Pass))
                for s in stmt.body
            ):
                return  # early-exit optimization, semantics-preserving
            raise TwinStructureError("unsupported branch in twin", stmt.lineno)
        if isinstance(stmt, ast.Return):
            self.returned = stmt
            return
        if isinstance(stmt, ast.AugAssign):
            if not isinstance(stmt.target, ast.Name):
                raise TwinStructureError(
                    "unsupported augmented target", stmt.lineno
                )
            key = _strip(stmt.target.id)
            cur = self.env.get(key, ("var", key))
            self.env[key] = (
                "bin", type(stmt.op).__name__, cur, canon.canon(stmt.value)
            )
            self.lines[key] = stmt.lineno
            return
        if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            if isinstance(stmt, ast.AnnAssign):
                targets = [stmt.target]
                value = stmt.value
                if value is None:
                    return
            else:
                targets = stmt.targets
                value = stmt.value
            if len(targets) != 1:
                raise TwinStructureError("chained assignment", stmt.lineno)
            target = targets[0]
            if isinstance(target, ast.Name):
                key = _strip(target.id)
                if _is_where_call(value):
                    third = canon.canon(value.args[2])  # type: ignore[union-attr]
                    if third == self.env.get(key):
                        # x = X.where(m, v, x)  ->  maskset(m, v, x)
                        self.env[key] = (
                            "maskset",
                            canon.canon(value.args[0]),  # type: ignore[union-attr]
                            canon.canon(value.args[1]),  # type: ignore[union-attr]
                            third,
                        )
                        self.lines[key] = stmt.lineno
                        return
                self.env[key] = canon.canon(value)
                self.lines[key] = stmt.lineno
                return
            if isinstance(target, ast.Subscript) and isinstance(
                target.value, ast.Name
            ):
                key = _strip(target.value.id)
                cur = self.env.get(key, ("var", key))
                self.env[key] = (
                    "maskset",
                    canon.canon(target.slice),
                    canon.canon(value),
                    cur,
                )
                self.lines[key] = stmt.lineno
                return
            raise TwinStructureError(
                "unsupported assignment target", stmt.lineno
            )
        raise TwinStructureError(
            f"unsupported statement {type(stmt).__name__}", stmt.lineno
        )


def _find_def(sf: SourceFile, name: str) -> Optional[ast.FunctionDef]:
    for qual, node in function_defs(sf.tree):
        if qual.rsplit(".", 1)[-1] == name and isinstance(node, ast.FunctionDef):
            return node
    return None


def _return_names(ret: ast.Return) -> Tuple[str, ...]:
    if ret.value is None:
        raise TwinStructureError("bare return in twin", ret.lineno)
    if isinstance(ret.value, ast.Tuple):
        elts = ret.value.elts
    else:
        elts = [ret.value]
    names = []
    for e in elts:
        if not isinstance(e, ast.Name):
            raise TwinStructureError(
                "twin must return plain names", ret.lineno
            )
        names.append(_strip(e.id))
    return tuple(names)


# --------------------------------------------------------------- numpy twin
def extract_numpy(sf: SourceFile) -> TwinProgram:
    expand = _find_def(sf, NUMPY_EXPAND)
    steps = _find_def(sf, NUMPY_STEPS)
    if expand is None:
        raise TwinStructureError(f"numpy twin '{NUMPY_EXPAND}' not found", 1)
    if steps is None:
        raise TwinStructureError(f"numpy twin '{NUMPY_STEPS}' not found", 1)

    init_env: Dict[str, Sig] = {}
    canon = _Canon(init_env)
    region = _Region(init_env)
    region.run(expand.body, canon)
    if region.returned is None:
        raise TwinStructureError(
            f"{NUMPY_EXPAND} must end in 'return {NUMPY_STEPS}(...)'",
            expand.lineno,
        )
    glue = region.returned.value
    if not (
        isinstance(glue, ast.Call)
        and isinstance(glue.func, ast.Name)
        and glue.func.id == NUMPY_STEPS
    ):
        raise TwinStructureError(
            f"{NUMPY_EXPAND} must tail-call {NUMPY_STEPS}",
            region.returned.lineno,
        )
    step_params = [a.arg for a in steps.args.args]
    arg_names = []
    for a in glue.args:
        if not isinstance(a, ast.Name):
            raise TwinStructureError(
                "glue call must pass plain names", glue.lineno
            )
        arg_names.append(a.id)
    if arg_names != step_params:
        raise TwinStructureError(
            "glue call must pass init locals positionally under the same "
            "names as the step function's parameters",
            glue.lineno,
        )

    # Split the steps body around its single while loop.
    pre: List[ast.stmt] = []
    while_node: Optional[ast.While] = None
    post: List[ast.stmt] = []
    for stmt in steps.body:
        if isinstance(stmt, ast.While):
            if while_node is not None:
                raise TwinStructureError("multiple loops in twin", stmt.lineno)
            while_node = stmt
        elif while_node is None:
            pre.append(stmt)
        else:
            post.append(stmt)
    if while_node is None:
        raise TwinStructureError(
            f"{NUMPY_STEPS} must contain a while loop", steps.lineno
        )

    # Loop-carried = names rebound in the loop body that were already bound
    # (as a parameter or pre-loop local) when the loop was entered.
    bound_before = set(step_params)
    for stmt in pre:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    bound_before.add(t.id)
    rebound: Set[str] = set()
    for stmt in while_node.body:
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name) and t.id in bound_before:
                    rebound.add(t.id)
                elif isinstance(t, ast.Subscript) and isinstance(
                    t.value, ast.Name
                ) and t.value.id in bound_before:
                    rebound.add(t.value.id)
        elif isinstance(stmt, ast.AugAssign) and isinstance(
            stmt.target, ast.Name
        ) and stmt.target.id in bound_before:
            rebound.add(stmt.target.id)
    loop_vars = tuple(sorted(_strip(n) for n in rebound))

    # Step-entry environment: loop-carried names are free symbols; every
    # other parameter resolves to its init expression (same name, per the
    # glue convention); pre-loop locals evaluate on top.
    step_env: Dict[str, Sig] = {}
    init_sigs: Dict[str, Sig] = {}
    for p in step_params:
        key = _strip(p)
        if key in loop_vars:
            step_env[key] = ("var", key)
            if key not in init_env:
                raise TwinStructureError(
                    f"loop variable '{key}' has no init in {NUMPY_EXPAND}",
                    steps.lineno,
                )
            init_sigs[key] = init_env[key]
        else:
            step_env[key] = init_env.get(key, ("var", key))
    step_canon = _Canon(step_env)
    pre_region = _Region(step_env)
    pre_region.run(pre, step_canon)

    cond_sig = step_canon.canon(while_node.test)
    body_region = _Region(step_env)
    body_region.run(while_node.body, step_canon)
    step_sigs = {v: step_env[v] for v in loop_vars}

    post_region = _Region(step_env)
    post_region.run(post, step_canon)
    if post_region.returned is None:
        raise TwinStructureError(
            f"{NUMPY_STEPS} must return after the loop", steps.lineno
        )
    outputs = _return_names(post_region.returned)

    return TwinProgram(
        params=tuple(_strip(a.arg) for a in expand.args.args),
        loop_vars=loop_vars,
        init_sigs=init_sigs,
        init_lines={v: region.lines.get(v, expand.lineno) for v in loop_vars},
        cond_sig=cond_sig,
        cond_line=while_node.lineno,
        step_sigs=step_sigs,
        step_lines={
            v: body_region.lines.get(v, while_node.lineno) for v in loop_vars
        },
        outputs=outputs,
        fn_line=expand.lineno,
    )


# ----------------------------------------------------------------- jax twin
def extract_jax(sf: SourceFile) -> TwinProgram:
    prim = _find_def(sf, JAX_FN)
    if prim is None:
        raise TwinStructureError(f"jax twin '{JAX_FN}' not found", 1)

    init_env: Dict[str, Sig] = {}
    canon = _Canon(init_env)
    raw_env: Dict[str, ast.expr] = {}
    cond_def: Optional[ast.FunctionDef] = None
    body_def: Optional[ast.FunctionDef] = None
    while_assign: Optional[ast.Assign] = None
    ret: Optional[ast.Return] = None

    def is_while_loop_call(node: ast.expr) -> bool:
        return (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "while_loop"
        ) or (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "while_loop"
        )

    region = _Region(init_env)
    for stmt in prim.body:
        if isinstance(stmt, ast.FunctionDef):
            if cond_def is None:
                cond_def = stmt
            elif body_def is None:
                body_def = stmt
            else:
                raise TwinStructureError(
                    "more than two nested defs in jax twin", stmt.lineno
                )
            continue
        if isinstance(stmt, ast.Assign) and is_while_loop_call(stmt.value):
            while_assign = stmt
            continue
        if isinstance(stmt, ast.Return):
            ret = stmt
            continue
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 and (
            isinstance(stmt.targets[0], ast.Name)
        ):
            raw_env[_strip(stmt.targets[0].id)] = stmt.value
        region._exec(stmt, canon)

    if cond_def is None or body_def is None:
        raise TwinStructureError(
            "jax twin must define cond and body", prim.lineno
        )
    if while_assign is None or ret is None:
        raise TwinStructureError(
            "jax twin must unpack a lax.while_loop and return", prim.lineno
        )

    while_call = while_assign.value
    assert isinstance(while_call, ast.Call)
    if len(while_call.args) != 3:
        raise TwinStructureError(
            "while_loop must take (cond, body, state0)", while_call.lineno
        )
    state0_expr = while_call.args[2]
    if isinstance(state0_expr, ast.Name):
        state0_expr = raw_env.get(_strip(state0_expr.id), state0_expr)
    if not isinstance(state0_expr, ast.Tuple):
        raise TwinStructureError(
            "while_loop state must be a tuple literal", while_call.lineno
        )

    # Loop-carried order from the body's state unpack.
    if not body_def.body or not isinstance(body_def.body[0], ast.Assign):
        raise TwinStructureError(
            "body must start by unpacking the state", body_def.lineno
        )
    unpack = body_def.body[0]
    target = unpack.targets[0]
    if not isinstance(target, ast.Tuple):
        raise TwinStructureError(
            "body must tuple-unpack the state", unpack.lineno
        )
    loop_order: List[str] = []
    for e in target.elts:
        if not isinstance(e, ast.Name):
            raise TwinStructureError(
                "state unpack must bind plain names", unpack.lineno
            )
        loop_order.append(_strip(e.id))
    if len(state0_expr.elts) != len(loop_order):
        raise TwinStructureError(
            "state tuple and body unpack disagree on length",
            while_call.lineno,
        )

    init_sigs: Dict[str, Sig] = {}
    init_lines: Dict[str, int] = {}
    for name, elt in zip(loop_order, state0_expr.elts):
        init_sigs[name] = canon.canon(elt)
        init_lines[name] = elt.lineno

    # cond: single return over state[i] subscripts.
    if len(cond_def.args.args) != 1:
        raise TwinStructureError("cond must take one argument", cond_def.lineno)
    cond_param = cond_def.args.args[0].arg
    cond_body = [
        s for s in cond_def.body
        if not (isinstance(s, ast.Expr) and isinstance(s.value, ast.Constant))
    ]
    if len(cond_body) != 1 or not isinstance(cond_body[0], ast.Return):
        raise TwinStructureError(
            "cond must be a single return", cond_def.lineno
        )
    cond_ret = cond_body[0]
    assert cond_ret.value is not None
    cond_canon = _Canon(dict(init_env), state_map=(cond_param, loop_order))
    cond_sig = cond_canon.canon(cond_ret.value)

    # body: env = init env + loop vars as free symbols.
    body_env: Dict[str, Sig] = dict(init_env)
    for v in loop_order:
        body_env[v] = ("var", v)
    body_canon = _Canon(body_env)
    body_region = _Region(body_env)
    body_region.run(body_def.body[1:], body_canon)
    if body_region.returned is None:
        raise TwinStructureError(
            "body must return the updated state", body_def.lineno
        )
    returned = _return_names(body_region.returned)
    if list(returned) != loop_order:
        raise TwinStructureError(
            "body must return the state variables in unpack order",
            body_region.returned.lineno,
        )
    step_sigs = {v: body_env[v] for v in loop_order}

    # Outer unpack: non-underscore names must sit at their state position.
    out_target = while_assign.targets[0]
    if not isinstance(out_target, ast.Tuple):
        raise TwinStructureError(
            "while_loop result must be tuple-unpacked", while_assign.lineno
        )
    if len(out_target.elts) != len(loop_order):
        raise TwinStructureError(
            "while_loop unpack length must match the state tuple",
            while_assign.lineno,
        )
    for i, e in enumerate(out_target.elts):
        if isinstance(e, ast.Name) and e.id != "_" and (
            _strip(e.id) != loop_order[i]
        ):
            raise TwinStructureError(
                f"while_loop unpack renames state variable "
                f"'{loop_order[i]}'",
                while_assign.lineno,
            )
    outputs = _return_names(ret)

    return TwinProgram(
        params=tuple(_strip(a.arg) for a in prim.args.args),
        loop_vars=tuple(sorted(loop_order)),
        init_sigs=init_sigs,
        init_lines=init_lines,
        cond_sig=cond_sig,
        cond_line=cond_def.lineno,
        step_sigs=step_sigs,
        step_lines={
            v: body_region.lines.get(v, body_def.lineno) for v in loop_order
        },
        outputs=outputs,
        fn_line=prim.lineno,
    )


# ------------------------------------------------------------------ the rule
class TwinParityRule:
    code = "RPL301"
    name = "twin-parity"
    structure_code = "RPL302"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            if not sf.rel.endswith(TARGET_BASENAME):
                continue
            yield from self.check_file(sf)

    def check_file(self, sf: SourceFile) -> Iterator[Diagnostic]:
        try:
            np_prog = extract_numpy(sf)
        except TwinStructureError as exc:
            yield Diagnostic(
                self.structure_code, sf.rel, exc.lineno, 0,
                f"numpy twin structure not recognized: {exc}",
            )
            return
        try:
            jx_prog = extract_jax(sf)
        except TwinStructureError as exc:
            yield Diagnostic(
                self.structure_code, sf.rel, exc.lineno, 0,
                f"jax twin structure not recognized: {exc}",
            )
            return
        yield from self.compare(sf, np_prog, jx_prog)

    def compare(
        self, sf: SourceFile, np_prog: TwinProgram, jx_prog: TwinProgram
    ) -> Iterator[Diagnostic]:
        def diag(line: int, msg: str) -> Diagnostic:
            return Diagnostic(self.code, sf.rel, line, 0, msg)

        if np_prog.params != jx_prog.params:
            yield diag(
                np_prog.fn_line,
                f"twins disagree on parameters: numpy {np_prog.params} vs "
                f"jax {jx_prog.params}",
            )
            return
        if set(np_prog.loop_vars) != set(jx_prog.loop_vars):
            only_np = sorted(set(np_prog.loop_vars) - set(jx_prog.loop_vars))
            only_jx = sorted(set(jx_prog.loop_vars) - set(np_prog.loop_vars))
            yield diag(
                np_prog.fn_line,
                f"twins disagree on loop-carried state: only-numpy "
                f"{only_np}, only-jax {only_jx}",
            )
            return
        if np_prog.cond_sig != jx_prog.cond_sig:
            yield diag(
                np_prog.cond_line,
                "twins disagree on the loop condition",
            )
        for v in sorted(np_prog.loop_vars):
            if np_prog.init_sigs[v] != jx_prog.init_sigs[v]:
                yield diag(
                    np_prog.init_lines[v],
                    f"twins disagree on the init of loop variable '{v}'",
                )
            if np_prog.step_sigs[v] != jx_prog.step_sigs[v]:
                yield diag(
                    np_prog.step_lines[v],
                    f"twins disagree on the per-step update of loop "
                    f"variable '{v}'",
                )
        if np_prog.outputs != jx_prog.outputs:
            yield diag(
                np_prog.fn_line,
                f"twins disagree on outputs: numpy {np_prog.outputs} vs "
                f"jax {jx_prog.outputs}",
            )
