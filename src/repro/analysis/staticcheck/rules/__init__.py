"""Rule registry for reprolint."""

from __future__ import annotations

from typing import Dict, List

from .determinism import (
    DictReductionRule,
    SetIterationRule,
    UnseededRngRule,
    WallClockRule,
)
from .jit import JitClosureRule, TracedBranchRule, X64ScopeRule
from .ledger import LedgerEncapsulationRule
from .obs import ObsImportRule
from .settle import SettleBeforeReleaseRule
from .twins import TwinParityRule


def all_rules() -> List[object]:
    return [
        UnseededRngRule(),
        WallClockRule(),
        SetIterationRule(),
        DictReductionRule(),
        LedgerEncapsulationRule(),
        TwinParityRule(),
        JitClosureRule(),
        TracedBranchRule(),
        X64ScopeRule(),
        SettleBeforeReleaseRule(),
        ObsImportRule(),
    ]


def rule_catalog() -> Dict[str, str]:
    """code -> rule name, including secondary codes."""
    catalog = {r.code: r.name for r in all_rules()}  # type: ignore[attr-defined]
    catalog["RPL302"] = "twin-structure"
    return dict(sorted(catalog.items()))
