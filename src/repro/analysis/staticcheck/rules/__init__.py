"""Rule registry for reprolint."""

from __future__ import annotations

from typing import Dict, List

from .determinism import (
    DictReductionRule,
    SetIterationRule,
    UnseededRngRule,
    WallClockRule,
)
from .jit import JitClosureRule, TracedBranchRule, X64ScopeRule
from .ledger import LedgerEncapsulationRule
from .obs import ObsImportRule
from .settle import SettleBeforeReleaseRule
from .twins import TwinParityRule
from .typestate import ResourceTypestateRule
from .units import UnitsRule


def all_rules() -> List[object]:
    return [
        UnseededRngRule(),
        WallClockRule(),
        SetIterationRule(),
        DictReductionRule(),
        LedgerEncapsulationRule(),
        TwinParityRule(),
        JitClosureRule(),
        TracedBranchRule(),
        X64ScopeRule(),
        SettleBeforeReleaseRule(),
        ObsImportRule(),
        ResourceTypestateRule(),
        UnitsRule(),
    ]


def rule_codes(rule: object) -> tuple:
    """Every code a rule can emit (``codes`` tuple, else the primary
    ``code`` plus any legacy ``structure_code``)."""
    codes = getattr(rule, "codes", None)
    if codes:
        return tuple(codes)
    out = [rule.code]  # type: ignore[attr-defined]
    structure = getattr(rule, "structure_code", None)
    if structure:
        out.append(structure)
    return tuple(out)


def rule_catalog() -> Dict[str, str]:
    """code -> rule name, including secondary codes."""
    catalog: Dict[str, str] = {}
    for r in all_rules():
        for code in rule_codes(r):
            catalog[code] = r.name  # type: ignore[attr-defined]
    catalog["RPL302"] = "twin-structure"
    return dict(sorted(catalog.items()))
