"""jit hygiene rules (RPL4xx).

RPL401 — a jitted function closes over state that is rebound after
         definition.  ``jax.jit`` captures closed-over values at trace
         time; rebinding the name later silently keeps the traced value.
         Read-only closures (imported modules, once-bound config) are fine.

RPL402 — Python ``if``/``while`` on traced values inside a jitted
         function.  Python control flow runs at trace time; branching on a
         tracer raises ``ConcretizationTypeError`` at best and bakes in one
         branch at worst.  Values derived only from ``.shape``/``.ndim``/
         ``.dtype``/``len()`` and parameters declared static via
         ``static_argnums``/``static_argnames`` are concrete and exempt.

RPL403 — x64 precision flipped globally: ``config.update("jax_enable_x64")``
         or a call to ``enable_x64`` outside a ``with`` context.  The
         decision kernels' contract is a *scoped* x64 region
         (``with enable_x64():``) so the float32 data plane is untouched.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from ..astutil import (
    assigned_names,
    dotted_name,
    function_defs,
    literal_str,
    walk_shallow,
)
from ..diagnostics import Diagnostic
from ..engine import Project, SourceFile

_SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}
_SHAPE_CALLS = {"len", "isinstance", "int", "bool", "float", "str", "type",
                "hasattr", "getattr"}


def _jit_static_names(call: ast.Call, func_def: ast.AST) -> Set[str]:
    """Parameter names declared static in a jit(...) call."""
    params = [a.arg for a in func_def.args.args]  # type: ignore[attr-defined]
    static: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            vals = kw.value.elts if isinstance(
                kw.value, (ast.Tuple, ast.List)
            ) else [kw.value]
            for v in vals:
                s = literal_str(v)
                if s:
                    static.add(s)
        elif kw.arg == "static_argnums":
            vals = kw.value.elts if isinstance(
                kw.value, (ast.Tuple, ast.List)
            ) else [kw.value]
            for v in vals:
                if isinstance(v, ast.Constant) and isinstance(v.value, int):
                    if 0 <= v.value < len(params):
                        static.add(params[v.value])
    return static


def _is_jit_expr(node: ast.expr, aliases: Dict[str, str]) -> Optional[ast.Call]:
    """Return the configuring Call when ``node`` is ``jax.jit(...)`` or
    ``partial(jax.jit, ...)`` (the call carrying static args), else None."""
    if not isinstance(node, ast.Call):
        return None
    name = dotted_name(node.func, aliases)
    if name in ("jax.jit", "jit", "jax.api.jit"):
        return node
    if name in ("functools.partial", "partial") and node.args:
        inner = dotted_name(node.args[0], aliases)
        if inner in ("jax.jit", "jit"):
            return node
    return None


def _jitted_functions(
    sf: SourceFile,
) -> Iterator[Tuple[ast.AST, ast.Call]]:
    """Yield (function def, jit call) for every function jitted in this file
    — via decorator or via a ``jax.jit(f, ...)`` call on a local def."""
    defs_by_name: Dict[str, List[ast.AST]] = {}
    for qual, node in function_defs(sf.tree):
        defs_by_name.setdefault(qual.rsplit(".", 1)[-1], []).append(node)

    seen: Set[int] = set()
    for qual, node in function_defs(sf.tree):
        for dec in node.decorator_list:  # type: ignore[attr-defined]
            call = _is_jit_expr(dec, sf.aliases)
            if call is None and dotted_name(dec, sf.aliases) in (
                "jax.jit", "jit"
            ):
                call = ast.Call(func=dec, args=[], keywords=[])
            if call is not None and id(node) not in seen:
                seen.add(id(node))
                yield node, call

    for n in ast.walk(sf.tree):
        if not isinstance(n, ast.Call):
            continue
        name = dotted_name(n.func, sf.aliases)
        if name not in ("jax.jit", "jit"):
            continue
        if not n.args or not isinstance(n.args[0], ast.Name):
            continue
        for fdef in defs_by_name.get(n.args[0].id, []):
            if id(fdef) not in seen:
                seen.add(id(fdef))
                yield fdef, n


def _enclosing_scopes(
    tree: ast.Module, target: ast.AST
) -> List[ast.AST]:
    """Module plus every function/class scope containing ``target``."""
    path: List[ast.AST] = []

    def visit(node: ast.AST, chain: List[ast.AST]) -> bool:
        if node is target:
            path.extend(chain)
            return True
        for child in ast.iter_child_nodes(node):
            nxt = chain + [child] if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ) else chain
            if visit(child, nxt):
                return True
        return False

    visit(tree, [tree])
    return [s for s in path if s is not target] or [tree]


def _bindings_outside(
    scopes: Sequence[ast.AST], target: ast.AST, name: str
) -> int:
    """Count Store bindings of ``name`` in the given scopes, excluding
    anything inside ``target`` itself."""
    count = 0
    for scope in scopes:
        for node in walk_shallow(scope):
            if node is target:
                continue
            if isinstance(node, ast.Name) and isinstance(
                node.ctx, ast.Store
            ) and node.id == name:
                count += 1
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for a in node.names:
                    if (a.asname or a.name.split(".")[0]) == name:
                        count += 1
            elif isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ) and node.name == name:
                count += 1
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                if name in node.names:
                    count += 2  # declared for rebinding elsewhere
    return count


class JitClosureRule:
    code = "RPL401"
    name = "jit-mutable-closure"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            for fdef, _call in _jitted_functions(sf):
                yield from self._check_fn(sf, fdef)

    def _check_fn(self, sf: SourceFile, fdef: ast.AST) -> Iterator[Diagnostic]:
        params = {a.arg for a in fdef.args.args}  # type: ignore[attr-defined]
        bound: Set[str] = set(params)
        for node in ast.walk(fdef):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                bound.add(node.id)
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                for a in node.names:
                    bound.add(a.asname or a.name.split(".")[0])
            elif isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                bound.add(node.name)
                for a in node.args.args:  # type: ignore[attr-defined]
                    bound.add(a.arg)
        free = {
            n.id
            for n in ast.walk(fdef)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
            and n.id not in bound
        }
        if not free:
            return
        scopes = _enclosing_scopes(sf.tree, fdef)
        for name in sorted(free):
            if _bindings_outside(scopes, fdef, name) > 1:
                yield Diagnostic(
                    self.code, sf.rel,
                    fdef.lineno, fdef.col_offset,  # type: ignore[attr-defined]
                    f"jitted function '{fdef.name}' closes over "  # type: ignore[attr-defined]
                    f"'{name}', which is rebound elsewhere; jit captures "
                    f"the traced-time value — pass it as an argument",
                )


class TracedBranchRule:
    code = "RPL402"
    name = "traced-python-branch"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            for fdef, call in _jitted_functions(sf):
                static = _jit_static_names(call, fdef)
                yield from self._check_fn(sf, fdef, static)

    def _refs_traced(self, node: ast.expr, traced: Set[str]) -> bool:
        """True when ``node`` references a traced name outside a shape/len
        projection."""

        def scan(n: ast.AST) -> bool:
            if isinstance(n, ast.Attribute) and n.attr in _SHAPE_ATTRS:
                return False
            if isinstance(n, ast.Call):
                fn = n.func
                if isinstance(fn, ast.Name) and fn.id in _SHAPE_CALLS:
                    return False
                return any(scan(c) for c in ast.iter_child_nodes(n))
            if isinstance(n, ast.Name):
                return isinstance(n.ctx, ast.Load) and n.id in traced
            return any(scan(c) for c in ast.iter_child_nodes(n))

        return scan(node)

    def _check_fn(
        self, sf: SourceFile, fdef: ast.AST, static: Set[str]
    ) -> Iterator[Diagnostic]:
        traced: Set[str] = {
            a.arg
            for a in fdef.args.args  # type: ignore[attr-defined]
            if a.arg not in static and a.arg not in ("self", "cls")
        }

        def visit(stmts: Sequence[ast.stmt]) -> Iterator[Diagnostic]:
            for stmt in stmts:
                if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    value = getattr(stmt, "value", None)
                    targets = (
                        stmt.targets
                        if isinstance(stmt, ast.Assign)
                        else [stmt.target]
                    )
                    if value is not None:
                        tainted = self._refs_traced(value, traced)
                        if isinstance(stmt, ast.AugAssign):
                            tainted = tainted or any(
                                n in traced
                                for n in assigned_names(stmt.target)
                            )
                        for t in targets:
                            for name in assigned_names(t):
                                if tainted:
                                    traced.add(name)
                                else:
                                    traced.discard(name)
                elif isinstance(stmt, (ast.If, ast.While)):
                    if self._refs_traced(stmt.test, traced):
                        kind = "if" if isinstance(stmt, ast.If) else "while"
                        yield Diagnostic(
                            self.code, sf.rel,
                            stmt.lineno, stmt.col_offset,
                            f"Python '{kind}' on a traced value inside "
                            f"jitted '{fdef.name}'; use lax.cond/"  # type: ignore[attr-defined]
                            f"lax.while_loop or jnp.where",
                        )
                    yield from visit(stmt.body)
                    yield from visit(stmt.orelse)
                elif isinstance(stmt, (ast.For, ast.With)):
                    yield from visit(stmt.body)
                elif isinstance(
                    stmt, (ast.FunctionDef, ast.AsyncFunctionDef)
                ):
                    # Nested defs handed to lax control flow receive traced
                    # operands; treat their params as traced.
                    inner_traced = traced | {
                        a.arg for a in stmt.args.args
                    }
                    saved = set(traced)
                    traced.clear()
                    traced.update(inner_traced)
                    yield from visit(stmt.body)
                    traced.clear()
                    traced.update(saved)

        yield from visit(fdef.body)  # type: ignore[attr-defined]


class X64ScopeRule:
    code = "RPL403"
    name = "unscoped-x64"

    def check(self, project: Project) -> Iterator[Diagnostic]:
        for sf in project.files:
            with_item_calls: Set[int] = set()
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.With):
                    for item in node.items:
                        with_item_calls.add(id(item.context_expr))
            for node in ast.walk(sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = dotted_name(node.func, sf.aliases)
                if name is None:
                    continue
                if name.endswith("config.update") and node.args:
                    key = literal_str(node.args[0])
                    if key == "jax_enable_x64":
                        yield Diagnostic(
                            self.code, sf.rel, node.lineno, node.col_offset,
                            "global x64 flip via config.update("
                            "'jax_enable_x64'); use the scoped "
                            "jax.experimental.enable_x64 context",
                        )
                elif name.split(".")[-1] == "enable_x64":
                    if id(node) not in with_item_calls:
                        yield Diagnostic(
                            self.code, sf.rel, node.lineno, node.col_offset,
                            "enable_x64 outside a 'with' context; x64 must "
                            "be scoped so the float32 data plane is "
                            "untouched",
                        )
