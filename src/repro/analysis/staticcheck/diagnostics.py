"""Diagnostic records emitted by reprolint rules.

A diagnostic pins a rule code to a file/line/column plus a human message.
Baseline matching deliberately ignores line numbers (they churn on every
unrelated edit); the identity of a grandfathered finding is
``(code, path, message)``, counted with multiplicity.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str          # e.g. "RPL104"
    path: str          # repo-relative posix path
    line: int          # 1-based
    col: int           # 0-based (ast convention)
    message: str

    @property
    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.code, self.path, self.message)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.code} {self.message}"

    def sort_key(self) -> Tuple[str, int, int, str]:
        return (self.path, self.line, self.col, self.code)
