"""Checkpoint save/restore with sharding metadata and elastic resharding.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json (tree structure, dtypes,
step, data cursor).  Restore places every leaf under the *target* mesh's
NamedSharding — restoring onto a different mesh shape (elastic rescale after
a region loss) is therefore just a different `specs` argument.

``AsyncCheckpointer`` overlaps serialization with training (background
thread) — the fault-tolerance loop in ``repro.ft`` uses it so the step time
is not blocked on disk.
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flatten(tree: Any) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(
    directory: str,
    state: Any,
    *,
    step: int,
    extra: Optional[Dict[str, Any]] = None,
) -> str:
    """Blocking save.  Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    arrays = {}
    manifest = {"step": step, "extra": extra or {}, "keys": []}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        name = f"a{i}"
        arrays[name] = np.asarray(jax.device_get(leaf))
        manifest["keys"].append({"key": key, "name": name})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    abstract_state: Any,
    *,
    step: Optional[int] = None,
    mesh: Optional[Mesh] = None,
    specs: Any = None,
) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore onto the target mesh/sharding (elastic-safe)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    by_key = {e["key"]: data[e["name"]] for e in manifest["keys"]}

    flat_abs, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
    spec_leaves = (
        [None] * len(flat_abs)
        if specs is None
        else [
            s
            for _, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, (P, NamedSharding))
            )[0]
        ]
    )
    leaves = []
    for (pathk, leaf), spec in zip(flat_abs, spec_leaves):
        arr = by_key[jax.tree_util.keystr(pathk)]
        if mesh is not None and spec is not None:
            sh = spec if isinstance(spec, NamedSharding) else NamedSharding(mesh, spec)
            leaves.append(jax.device_put(arr.astype(leaf.dtype), sh))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state, manifest["step"], manifest["extra"]


class AsyncCheckpointer:
    """Background-thread checkpoint writer (one in flight at a time)."""

    def __init__(self, directory: str):
        self.directory = directory
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self.last_error: Optional[Exception] = None

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            state_host, step, extra = item
            try:
                save_checkpoint(self.directory, state_host, step=step, extra=extra)
            except Exception as e:  # pragma: no cover
                self.last_error = e

    def save(self, state: Any, *, step: int, extra=None) -> None:
        # materialize on host *now* (cheap copy) so training can proceed
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._q.put((host, step, extra))

    def wait(self) -> None:
        self._q.join() if False else self._drain()

    def _drain(self) -> None:
        while not self._q.empty():
            import time

            time.sleep(0.05)

    def close(self) -> None:
        self._drain()
        self._q.put(None)
        self._worker.join(timeout=10)
