from .gpipe import (  # noqa: F401
    pipeline_decode,
    pipeline_forward,
    stack_pipeline_params,
)
