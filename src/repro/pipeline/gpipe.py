"""GPipe microbatch pipelining as differentiable jax.lax control flow.

The forward pipeline is a ``lax.scan`` over schedule ticks inside a
partial-manual ``shard_map``: every tick each stage (a) reads its input —
fresh microbatch on stage 0, the ``ppermute``'d activation elsewhere —
(b) runs its layer slice, (c) forwards the activation one stage down the
(possibly multi-axis) pipeline.  ``jax.grad`` through the scan + ppermute
yields the exact reverse (backward) pipeline — this is GPipe's fill/steady/
drain schedule expressed to XLA, with activation transfer of microbatch i
overlapping compute of microbatch i+1 by construction.

The pipeline axis may be a *tuple* of mesh axes, e.g. ``("pod", "model")``:
stages are laid out pod-major, so the stage-15 -> stage-16 edge is exactly
the low-bandwidth cross-pod (cross-region) link — the placement the paper's
Pathfinder produces.

Geo/BACE mapping: one pipeline stage group per region, ``n_{j,r}`` stages per
region (contiguous), WAN edge = pod-axis ppermute.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple, Union

import jax
import jax.numpy as jnp

from repro.distributed.compat import axis_size as _lax_axis_size

Axis = Union[str, Tuple[str, ...]]


def _axis_tuple(axis: Axis) -> Tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


def linear_stage_index(axis: Axis) -> jax.Array:
    """Linearized stage id over the (possibly tuple) pipeline axis."""
    names = _axis_tuple(axis)
    idx = jnp.int32(0)
    for name in names:
        idx = idx * _lax_axis_size(name) + jax.lax.axis_index(name)
    return idx


def pipeline_size(axis: Axis) -> int:
    names = _axis_tuple(axis)
    out = 1
    for name in names:
        out *= _lax_axis_size(name)
    return out


def _shift_perm(n: int):
    return [(i, i + 1) for i in range(n - 1)]


def schedule_ticks(n_microbatches: int, n_stages: int) -> int:
    """Tick count of the lockstep GPipe schedule this data plane executes:
    fill + steady = ``M + S - 1`` scan steps per direction.  The control
    plane's microplan ``gpipe-overlap`` plan must report the same count —
    ``tests/test_microplan_parity.py`` pins the two together so the
    schedule the scheduler prices can't drift from the one XLA runs."""
    return n_microbatches + n_stages - 1


def stack_pipeline_params(blocks: Any, n_stages: int) -> Any:
    """[L, ...]-stacked block params -> [S, L/S, ...] stage-major stacking."""
    def reshape(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])

    return jax.tree.map(reshape, blocks)


def pipeline_forward(
    stage_params: Any,            # per-device slice: [1, L/S, ...] leaves
    microbatches: jax.Array,      # [M, mb, T] tokens (auto-sharded on mb)
    *,
    axis: Axis,
    n_stages: int,
    first_fn: Callable[[jax.Array], jax.Array],   # tokens -> embeddings
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    act_shape: Tuple[int, ...],   # (mb, T, D) activation shape
    act_dtype=jnp.bfloat16,
) -> jax.Array:
    """Runs the microbatched pipeline; returns last-stage activations
    [M, mb, T, D] (garbage on other stages — select by stage outside)."""
    m = microbatches.shape[0]
    names = _axis_tuple(axis)
    stage = linear_stage_index(axis)
    perm = _shift_perm(n_stages)
    n_ticks = schedule_ticks(m, n_stages)

    params_local = jax.tree.map(lambda x: x[0], stage_params)

    def tick(carry, t):
        state = carry
        mb_idx = jnp.clip(t, 0, m - 1)
        tok = jax.lax.dynamic_index_in_dim(microbatches, mb_idx, 0, keepdims=False)
        x0 = first_fn(tok).astype(act_dtype)
        x_in = jnp.where(stage == 0, x0, state)
        y = stage_fn(params_local, x_in).astype(act_dtype)
        state_next = jax.lax.ppermute(y, axis_name=names, perm=perm)
        return state_next, y

    state0 = jnp.zeros(act_shape, act_dtype)
    _, ys = jax.lax.scan(tick, state0, jnp.arange(n_ticks))
    # last stage emits microbatch m at tick m + n_stages - 1
    return ys[n_stages - 1 :]


def pipeline_decode(
    stage_params: Any,
    caches: Any,                  # leaves [1, L/S, M, mb, ...] per device
    tokens: jax.Array,            # [M, mb, 1]
    pos: jax.Array,               # scalar int32
    *,
    axis: Axis,
    n_stages: int,
    first_fn: Callable[[jax.Array], jax.Array],
    stage_fn: Callable[[Any, Any, jax.Array, jax.Array], Tuple[jax.Array, Any]],
    act_shape: Tuple[int, ...],
    act_dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Any]:
    """One pipelined decode step over M batch-microbatches.

    ``stage_fn(params, cache_mb, x, pos) -> (y, new_cache_mb)`` where
    ``cache_mb`` is the cache slice of one microbatch.  Returns last-stage
    hidden [M, mb, 1, D] and updated caches.
    """
    m = tokens.shape[0]
    names = _axis_tuple(axis)
    stage = linear_stage_index(axis)
    perm = _shift_perm(n_stages)
    n_ticks = schedule_ticks(m, n_stages)
    params_local = jax.tree.map(lambda x: x[0], stage_params)
    caches_local = jax.tree.map(lambda x: x[0], caches)

    def tick(carry, t):
        state, cache = carry
        in_idx = jnp.clip(t, 0, m - 1)
        # the microbatch THIS stage works on this tick
        my_idx = jnp.clip(t - stage, 0, m - 1)
        tok = jax.lax.dynamic_index_in_dim(tokens, in_idx, 0, keepdims=False)
        x0 = first_fn(tok).astype(act_dtype)
        x_in = jnp.where(stage == 0, x0, state)
        cache_mb = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, my_idx, 1, keepdims=False),
            cache,
        )
        y, cache_mb2 = stage_fn(params_local, cache_mb, x_in, pos)
        y = y.astype(act_dtype)
        active = (t >= stage) & (t - stage <= m - 1)
        cache = jax.tree.map(
            lambda c, c2: jax.lax.dynamic_update_index_in_dim(
                c,
                jnp.where(active, c2, jax.lax.dynamic_index_in_dim(c, my_idx, 1, keepdims=False)).astype(c.dtype),
                my_idx,
                1,
            ),
            cache,
            cache_mb2,
        )
        state_next = jax.lax.ppermute(y, axis_name=names, perm=perm)
        return (state_next, cache), y

    state0 = jnp.zeros(act_shape, act_dtype)
    (_, caches_new), ys = jax.lax.scan(
        tick, (state0, caches_local), jnp.arange(n_ticks)
    )
    caches_new = jax.tree.map(lambda x: x[None], caches_new)
    return ys[n_stages - 1 :], caches_new
