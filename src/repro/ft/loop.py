"""Resilient training loop: checkpoint/restart + failure recovery + straggler
monitoring, wired to the BACE-Pipe control plane.

On an injected region failure the loop (1) stops, (2) asks the control plane
for a new placement on the surviving capacity (the paper's Pathfinder re-runs
with the region's GPUs zeroed), (3) restores the last checkpoint onto the new
mesh sharding, and (4) continues — the full geo-failover path, executed for
real in tests/examples on reduced configs.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from .monitor import FailureInjector, StragglerDetector


def resilient_train_loop(
    *,
    train_step: Callable,
    state: Any,
    batches: Iterator[Dict[str, jax.Array]],
    n_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    injector: Optional[FailureInjector] = None,
    on_failure: Optional[Callable[[str, Any], Any]] = None,
    log_every: int = 10,
    log: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Runs ``n_steps``; returns {'state', 'losses', 'restarts', 'stragglers'}."""
    ckpt = AsyncCheckpointer(ckpt_dir)
    detector = StragglerDetector()
    losses = []
    restarts = 0
    step = 0
    while step < n_steps:
        victim = injector.check(step) if injector else None
        if victim is not None:
            log(f"[ft] step {step}: lost {victim}; recovering from checkpoint")
            restarts += 1
            if on_failure is not None:
                state = on_failure(victim, state)
            last = latest_step(ckpt_dir)
            if last is not None:
                state, step, extra = restore_checkpoint(
                    ckpt_dir, jax.eval_shape(lambda s: s, state)
                )[0], last, None
                log(f"[ft] resumed from step {last}")
            # else: restart from current in-memory state (step unchanged)

        batch = next(batches)
        t0 = time.perf_counter()
        state, loss = train_step(state, batch)
        loss = float(loss)
        dt = time.perf_counter() - t0
        if detector.observe(step, dt):
            log(f"[ft] straggler at step {step}: {dt:.3f}s vs ema {detector.ema:.3f}s")
        losses.append(loss)
        if step % log_every == 0:
            log(f"[train] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if step and step % ckpt_every == 0:
            ckpt.save(state, step=step, extra={"loss": loss})
        step += 1

    ckpt.save(state, step=n_steps, extra={"final": True})
    ckpt.close()
    return {
        "state": state,
        "losses": losses,
        "restarts": restarts,
        "stragglers": detector.events,
    }
