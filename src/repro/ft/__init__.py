from .monitor import FailureInjector, HeartbeatMonitor, StragglerDetector  # noqa: F401
from .loop import resilient_train_loop  # noqa: F401
