"""Fault-tolerance primitives: heartbeats, failure injection, stragglers.

On real hardware these wrap the runtime's device-health API; in this
container they are driven by the simulator/injector so the *control flow*
(detect -> checkpoint-restore -> reschedule) is fully exercised in tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-worker heartbeats; a worker is dead after ``timeout_s``."""

    timeout_s: float = 30.0
    _last: Dict[str, float] = dataclasses.field(default_factory=dict)

    def beat(self, worker: str, now: Optional[float] = None) -> None:
        self._last[worker] = time.monotonic() if now is None else now

    def dead_workers(self, now: Optional[float] = None) -> List[str]:
        t = time.monotonic() if now is None else now
        return [w for w, last in self._last.items() if t - last > self.timeout_s]

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_workers(now)


class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail at given
    steps; each failure 'kills' a named region/pod."""

    def __init__(self, fail_at: Dict[int, str]):
        self.fail_at = dict(fail_at)
        self.log: List[str] = []

    def check(self, step: int) -> Optional[str]:
        victim = self.fail_at.pop(step, None)
        if victim is not None:
            self.log.append(f"step {step}: injected failure of {victim}")
        return victim


class StragglerDetector:
    """EMA-based step-time monitor.  A step slower than ``factor`` x EMA
    flags a straggler; the runtime's mitigation (pipeline stage re-balance,
    or data re-shard) is invoked via callback."""

    def __init__(self, factor: float = 2.5, alpha: float = 0.2,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.factor, self.alpha = factor, alpha
        self.ema: Optional[float] = None
        self.events: List[int] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = dt > self.factor * self.ema
        if is_straggler:
            self.events.append(step)
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
        # EMA excludes straggler spikes so one hiccup doesn't mask the next
        if not is_straggler:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler
