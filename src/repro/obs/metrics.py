"""Time-series telemetry: gauges, histograms, counters, and fleet health.

``MetricsLog`` is the storage half of the obs subsystem: named time series
sampled at simulated-event timestamps (link utilization, GPU occupancy,
spend rate, queue depth, plan-cache hit rate), wall-clock histograms for
per-decision latency, and monotonic counters.  It is engine-agnostic — the
``SimTraceRecorder`` feeds it from the protocol hooks, and the exporters /
report consume it read-only.

``FleetHealth`` wires the fault-tolerance monitors (``repro.ft.monitor``:
``HeartbeatMonitor`` + ``StragglerDetector``) into this surface: regions
hosting running jobs heartbeat at every sampled timestamp (sim time), and
each placement decision's wall latency feeds the straggler EMA — a
control-plane decision much slower than its recent history is flagged and
counted, exactly the detect-path those monitors exist for.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

from repro.ft.monitor import HeartbeatMonitor, StragglerDetector


@dataclasses.dataclass
class MetricsLog:
    """Named time series + histograms + counters.

    ``series[name]`` is a list of ``(t, value)`` samples in sampling order
    (the simulator visits timestamps monotonically, so each series is
    time-sorted by construction); ``histograms[name]`` is a list of raw
    observations; ``counters[name]`` a running total.
    """

    series: Dict[str, List[Tuple[float, float]]] = dataclasses.field(
        default_factory=dict
    )
    histograms: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict
    )
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)

    def sample(self, name: str, t: float, value: float) -> None:
        self.series.setdefault(name, []).append((float(t), float(value)))

    def observe(self, name: str, value: float) -> None:
        self.histograms.setdefault(name, []).append(float(value))

    def incr(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def latest(self, name: str) -> Optional[float]:
        pts = self.series.get(name)
        return pts[-1][1] if pts else None

    def percentile(self, name: str, q: float) -> Optional[float]:
        """Nearest-rank percentile of a histogram (q in [0, 100])."""
        obs = self.histograms.get(name)
        if not obs:
            return None
        ordered = sorted(obs)
        rank = max(0, min(len(ordered) - 1, int(round(q / 100.0 * (len(ordered) - 1)))))
        return ordered[rank]

    def to_jsonable(self) -> Dict[str, object]:
        return {
            "series": {
                name: [[t, v] for t, v in pts]
                for name, pts in sorted(self.series.items())
            },
            "histograms": {
                name: list(obs)
                for name, obs in sorted(self.histograms.items())
            },
            "counters": dict(sorted(self.counters.items())),
        }

    @classmethod
    def from_jsonable(cls, data: Dict[str, object]) -> "MetricsLog":
        log = cls()
        for name, pts in data.get("series", {}).items():  # type: ignore[union-attr]
            log.series[name] = [(float(t), float(v)) for t, v in pts]
        for name, obs in data.get("histograms", {}).items():  # type: ignore[union-attr]
            log.histograms[name] = [float(v) for v in obs]
        for name, n in data.get("counters", {}).items():  # type: ignore[union-attr]
            log.counters[name] = int(n)
        return log


class FleetHealth:
    """Heartbeat + straggler signals bridged onto a ``MetricsLog``.

    ``heartbeat_timeout_s`` is *simulated* seconds: a region that hosted
    running work and then goes quiet for longer than the timeout while the
    simulation is still advancing shows up in the ``dead_regions`` gauge.
    ``observe_decision`` feeds per-decision *wall* latencies (seconds) to
    the EMA straggler detector; flagged decisions increment the
    ``straggler_decisions`` counter and are listed in ``detector.events``.
    """

    def __init__(
        self,
        metrics: MetricsLog,
        *,
        heartbeat_timeout_s: float = 6 * 3600.0,
        straggler_factor: float = 2.5,
    ) -> None:
        self.metrics = metrics
        self.monitor = HeartbeatMonitor(timeout_s=heartbeat_timeout_s)
        self.detector = StragglerDetector(
            factor=straggler_factor, on_straggler=self._on_straggler
        )
        self._step = 0

    def _on_straggler(self, step: int, dt: float, ema: float) -> None:
        self.metrics.incr("straggler_decisions")

    def beat_regions(self, t: float, regions: Iterable[str]) -> None:
        for r in regions:
            self.monitor.beat(r, now=t)

    def sample(self, t: float) -> None:
        self.metrics.sample(
            "dead_regions", t, float(len(self.monitor.dead_workers(now=t)))
        )

    def observe_decision(self, wall_s: float) -> bool:
        self._step += 1
        return self.detector.observe(self._step, wall_s)
