"""Trace exporters: Chrome trace-event JSON (Perfetto) and JSONL.

``to_perfetto`` lowers a recorded trace to the Chrome trace-event format
(the JSON array flavor Perfetto's legacy importer loads directly):

* pid 1 "regions"  — one thread track per region.  Job run segments render
  as complete slices (``ph="X"``) on the track of their first path region;
  region GPU-occupancy gauges render as counter tracks (``ph="C"``).
* pid 2 "links"    — one counter track per inter-region link carrying
  utilization and residual-Gbps series.
* pid 3 "scheduler" — queue depth / spend-rate counters plus instant
  events (``ph="i"``) for env breakpoints and preemptions.
* migrations       — flow arrows (``ph="s"``/``ph="f"``) from the end of a
  preempted segment's slice to the start of the job's next segment, so a
  job hopping regions draws a visible arc across tracks.

Timestamps are simulated seconds scaled to trace microseconds; wall-clock
never enters the export (it only appears inside histogram *values*).

``write_jsonl``/``load_jsonl`` round-trip the raw trace: one JSON object
per line (``meta``, ``record``, ``series``, ``hist``, ``counter``,
``hol``), enough to rebuild the terminal report and the Perfetto export
bit-for-bit from disk.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from .metrics import MetricsLog

_PID_REGIONS = 1
_PID_LINKS = 2
_PID_SCHED = 3

#: trace microseconds per simulated second.
_US = 1e6


@dataclasses.dataclass
class LoadedTrace:
    """A trace reloaded from JSONL: duck-compatible with the recorder for
    every consumer in ``obs`` (``records`` + ``metrics`` + ``hol_wait``)."""

    records: List[Dict[str, object]]
    metrics: MetricsLog
    hol_wait: Dict[int, float]
    meta: Dict[str, object]


def _region_tid(order: Dict[str, int], region: str) -> int:
    if region not in order:
        order[region] = len(order) + 1
    return order[region]


def to_perfetto(trace) -> Dict[str, object]:
    """Lower a trace (recorder or ``LoadedTrace``) to trace-event JSON."""
    events: List[Dict[str, object]] = []
    region_tid: Dict[str, int] = {}

    def meta_event(pid: int, name: str, tid: int = 0, what: str = "process_name"):
        events.append(
            {
                "ph": "M",
                "pid": pid,
                "tid": tid,
                "name": what,
                "args": {"name": name},
            }
        )

    meta_event(_PID_REGIONS, "regions")
    meta_event(_PID_LINKS, "links")
    meta_event(_PID_SCHED, "scheduler")

    # ---------------------------------------------------- job segment slices
    # Pair each "start" record with the first terminal event (complete /
    # preempt / migrate) for that job strictly after it.
    starts = [r for r in trace.records if r["kind"] == "start"]
    terminals: Dict[int, List[Tuple[float, str]]] = {}
    for r in trace.records:
        if r["kind"] == "event" and r["event"] in (
            "complete",
            "preempt",
            "migrate",
        ):
            terminals.setdefault(int(r["id"]), []).append(
                (float(r["t"]), str(r["event"]))
            )
    for ts_list in terminals.values():
        ts_list.sort()

    #: (job, end_t, end_region, end_tid) of preempted segments awaiting the
    #: job's next start — each pair becomes one flow arrow.
    open_flows: Dict[int, Tuple[float, int]] = {}
    flow_id = 0
    for rec in starts:
        job = int(rec["job"])
        t0 = float(rec["t"])
        path = list(rec["path"])
        tid = _region_tid(region_tid, path[0])
        cand = [
            (t, ev) for t, ev in terminals.get(job, []) if t > t0
        ]
        end_t, end_ev = cand[0] if cand else (t0, "unterminated")
        events.append(
            {
                "ph": "X",
                "pid": _PID_REGIONS,
                "tid": tid,
                "ts": t0 * _US,
                "dur": max(0.0, end_t - t0) * _US,
                "name": f"job {job}",
                "cat": "segment",
                "args": {
                    "path": path,
                    "alloc": rec["alloc"],
                    "gpus": rec["gpus"],
                    "rate_per_s": rec["rate_per_s"],
                    "end": end_ev,
                },
            }
        )
        # Close an outstanding migration flow into this segment's start.
        if job in open_flows:
            fid_t, fid = open_flows.pop(job)
            events.append(
                {
                    "ph": "f",
                    "bp": "e",
                    "pid": _PID_REGIONS,
                    "tid": tid,
                    "ts": max(t0, fid_t) * _US,
                    "id": fid,
                    "name": "migration",
                    "cat": "migration",
                }
            )
        if end_ev in ("preempt", "migrate"):
            flow_id += 1
            events.append(
                {
                    "ph": "s",
                    "pid": _PID_REGIONS,
                    "tid": tid,
                    "ts": end_t * _US,
                    "id": flow_id,
                    "name": "migration",
                    "cat": "migration",
                }
            )
            open_flows[job] = (end_t, flow_id)

    for region, tid in sorted(region_tid.items(), key=lambda kv: kv[1]):
        meta_event(_PID_REGIONS, region, tid=tid, what="thread_name")

    # -------------------------------------------------------- counter tracks
    def counters(prefix: str, pid: int, rename=lambda s: s) -> None:
        for name, pts in sorted(trace.metrics.series.items()):
            if not name.startswith(prefix):
                continue
            track = rename(name)
            for t, v in pts:
                events.append(
                    {
                        "ph": "C",
                        "pid": pid,
                        "ts": t * _US,
                        "name": track,
                        "args": {"value": v},
                    }
                )

    counters("gpu_occupancy/", _PID_REGIONS)
    counters("link_util/", _PID_LINKS)
    counters("link_residual_gbps/", _PID_LINKS)
    counters("pending_depth", _PID_SCHED)
    counters("spend_rate_per_s", _PID_SCHED)
    counters("dead_regions", _PID_SCHED)
    counters("plan_cache_hit_rate", _PID_SCHED)

    # ------------------------------------------------------- instant markers
    for r in trace.records:
        if r["kind"] == "event" and r["event"] in ("env", "preempt", "migrate"):
            events.append(
                {
                    "ph": "i",
                    "pid": _PID_SCHED,
                    "tid": 0,
                    "ts": float(r["t"]) * _US,
                    "name": str(r["event"]),
                    "s": "g",
                    "cat": "event",
                    "args": {"id": r["id"]},
                }
            )

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(path, trace) -> Path:
    path = Path(path)
    path.write_text(json.dumps(to_perfetto(trace)) + "\n", encoding="utf-8")
    return path


# ------------------------------------------------------------------- JSONL
def write_jsonl(path, trace, *, meta: Optional[Dict[str, object]] = None) -> Path:
    """One JSON object per line; replays through ``load_jsonl``."""
    path = Path(path)
    with path.open("w", encoding="utf-8") as fh:
        header = {"type": "meta", "schema": 1}
        header.update(meta or getattr(trace, "meta", None) or {})
        fh.write(json.dumps(header) + "\n")
        for rec in trace.records:
            fh.write(json.dumps({"type": "record", **rec}) + "\n")
        for name, pts in sorted(trace.metrics.series.items()):
            fh.write(
                json.dumps(
                    {"type": "series", "name": name, "points": [[t, v] for t, v in pts]}
                )
                + "\n"
            )
        for name, obs in sorted(trace.metrics.histograms.items()):
            fh.write(
                json.dumps({"type": "hist", "name": name, "values": list(obs)})
                + "\n"
            )
        for name, n in sorted(trace.metrics.counters.items()):
            fh.write(
                json.dumps({"type": "counter", "name": name, "value": n}) + "\n"
            )
        hol = getattr(trace, "hol_wait", None) or {}
        for job, secs in sorted(hol.items()):
            fh.write(
                json.dumps({"type": "hol", "job": int(job), "wait_s": secs})
                + "\n"
            )
    return path


def load_jsonl(path) -> LoadedTrace:
    records: List[Dict[str, object]] = []
    metrics = MetricsLog()
    hol: Dict[int, float] = {}
    meta: Dict[str, object] = {}
    with Path(path).open("r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            typ = obj.pop("type", None)
            if typ == "meta":
                meta = obj
            elif typ == "record":
                records.append(obj)
            elif typ == "series":
                metrics.series[obj["name"]] = [
                    (float(t), float(v)) for t, v in obj["points"]
                ]
            elif typ == "hist":
                metrics.histograms[obj["name"]] = [
                    float(v) for v in obj["values"]
                ]
            elif typ == "counter":
                metrics.counters[obj["name"]] = int(obj["value"])
            elif typ == "hol":
                hol[int(obj["job"])] = float(obj["wait_s"])
            else:
                raise ValueError(f"unknown JSONL line type {typ!r} in {path}")
    return LoadedTrace(records=records, metrics=metrics, hol_wait=hol, meta=meta)
