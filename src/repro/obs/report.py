"""Terminal summary report + structural trace validation (``--check``).

``render_report`` digests a trace (live recorder or ``load_jsonl`` result)
into a short human-readable account of what the scheduler did and why:
event counts, admission outcomes split by their binding constraint (Eq. 5
GPU vs Eq. 6 bandwidth), head-of-line wait attribution, migration probes,
plan-cache hit rate, per-backend decision wall-clock percentiles, and fleet
health.  ``check_trace`` validates the structural invariants a well-formed
trace must satisfy — CI runs it as a smoke gate over the benchmark trace
artifact.
"""

from __future__ import annotations

from typing import Dict, List

from .export import to_perfetto


def _fmt_h(seconds: float) -> str:
    return f"{seconds / 3600.0:.3f} h"


def render_report(trace) -> str:
    records = trace.records
    metrics = trace.metrics
    by_kind: Dict[str, int] = {}
    for r in records:
        by_kind[str(r["kind"])] = by_kind.get(str(r["kind"]), 0) + 1

    lines: List[str] = []
    lines.append("== obs trace report ==")
    meta = getattr(trace, "meta", None) or {}
    ctx = ", ".join(
        f"{k}={meta[k]}" for k in sorted(meta) if k not in ("schema",)
    )
    if ctx:
        lines.append(f"context: {ctx}")
    span = [float(r["t"]) for r in records if "t" in r]
    if span:
        lines.append(
            f"sim span: {_fmt_h(min(span))} .. {_fmt_h(max(span))}, "
            f"{len(records)} records"
        )
    lines.append(
        "records: "
        + ", ".join(f"{k}={n}" for k, n in sorted(by_kind.items()))
    )

    # Sim events.
    ev = {
        k.split("/", 1)[1]: n
        for k, n in metrics.counters.items()
        if k.startswith("events/")
    }
    if ev:
        lines.append(
            "events: " + ", ".join(f"{k}={n}" for k, n in sorted(ev.items()))
        )

    # Admission outcomes and binding constraints.
    outcomes = {
        k.split("/", 1)[1]: n
        for k, n in metrics.counters.items()
        if k.startswith("candidates/")
    }
    if outcomes:
        lines.append(
            "admission: "
            + ", ".join(f"{k}={n}" for k, n in sorted(outcomes.items()))
        )
    binding = {
        k.split("/", 1)[1]: n
        for k, n in metrics.counters.items()
        if k.startswith("binding/")
    }
    if binding:
        lines.append(
            "binding constraint: "
            + ", ".join(
                f"{k}(Eq.{'5' if k == 'gpu' else '6'})={n}"
                for k, n in sorted(binding.items())
            )
        )

    # Head-of-line wait attribution.
    hol = getattr(trace, "hol_wait", None) or {}
    if hol:
        total = sum(hol[j] for j in sorted(hol))
        worst = max(sorted(hol), key=lambda j: (hol[j], j))
        lines.append(
            f"HoL wait: {len(hol)} jobs blocked, total {_fmt_h(total)}, "
            f"worst job {worst} at {_fmt_h(hol[worst])}"
        )

    # Migration probes.
    moved = metrics.counters.get("probes/moved", 0)
    stayed = metrics.counters.get("probes/stayed", 0)
    if moved or stayed:
        lines.append(f"migration probes: {moved} moved, {stayed} stayed")

    # Gauges: final queue depth / spend rate / plan cache.
    for name, label in (
        ("pending_depth", "final queue depth"),
        ("spend_rate_per_s", "final spend rate ($/s)"),
        ("plan_cache_hit_rate", "plan-cache hit rate"),
    ):
        v = metrics.latest(name)
        if v is not None:
            lines.append(f"{label}: {v:.6g}")

    # Decision wall-clock histograms per backend.
    for name in sorted(metrics.histograms):
        if not name.startswith("decide_wall_us/"):
            continue
        backend = name.split("/", 1)[1]
        obs = metrics.histograms[name]
        mean = sum(obs) / len(obs)
        lines.append(
            f"decide wall ({backend}): n={len(obs)}, mean={mean:.1f} us, "
            f"p50={metrics.percentile(name, 50):.1f} us, "
            f"p99={metrics.percentile(name, 99):.1f} us"
        )

    # Fleet health.
    stragglers = metrics.counters.get("straggler_decisions", 0)
    dead = metrics.latest("dead_regions")
    if stragglers or dead is not None:
        lines.append(
            f"fleet health: straggler_decisions={stragglers}, "
            f"dead_regions={0 if dead is None else int(dead)}"
        )
    return "\n".join(lines)


def check_trace(trace) -> List[str]:
    """Structural invariants; returns a list of problems (empty = healthy)."""
    problems: List[str] = []
    records = trace.records
    if not records:
        problems.append("trace has no records")
        return problems

    last_t = None
    for i, r in enumerate(records):
        t = r.get("t")
        if t is None:
            problems.append(f"record {i} has no timestamp: {r}")
            continue
        if float(t) < 0.0:
            problems.append(f"record {i} has negative sim time {t}")
        if last_t is not None and float(t) < last_t - 1e-9:
            problems.append(
                f"record {i} goes backwards in sim time: {t} < {last_t}"
            )
        last_t = float(t)

    # Every start must eventually terminate (complete / preempt / migrate).
    started = [int(r["job"]) for r in records if r["kind"] == "start"]
    terminal: Dict[int, int] = {}
    for r in records:
        if r["kind"] == "event" and r["event"] in (
            "complete",
            "preempt",
            "migrate",
        ):
            j = int(r["id"])
            terminal[j] = terminal.get(j, 0) + 1
    for j in sorted(set(started)):
        n_started = started.count(j)
        if terminal.get(j, 0) < n_started:
            problems.append(
                f"job {j}: {n_started} segment starts but only "
                f"{terminal.get(j, 0)} terminal events"
            )

    # Series must be time-sorted.
    for name, pts in sorted(trace.metrics.series.items()):
        ts = [t for t, _ in pts]
        if ts != sorted(ts):
            problems.append(f"series {name!r} is not time-sorted")

    # The Perfetto lowering must succeed and every event must carry the
    # mandatory trace-event keys.
    try:
        pf = to_perfetto(trace)
    except Exception as exc:  # pragma: no cover - defensive
        problems.append(f"perfetto export failed: {exc!r}")
        return problems
    for ev in pf["traceEvents"]:
        if "ph" not in ev or "pid" not in ev:
            problems.append(f"trace event missing ph/pid: {ev}")
            break
        if ev["ph"] in ("X", "C", "i", "s", "f") and "ts" not in ev:
            problems.append(f"trace event missing ts: {ev}")
            break
        if ev["ph"] == "X" and "dur" not in ev:
            problems.append(f"complete slice missing dur: {ev}")
            break
    return problems
