"""The TraceRecorder protocol seam between ``core/`` and ``repro.obs``.

This module is the *only* piece of ``repro.obs`` that core decision-path
modules may import (reprolint RPL601 enforces it), and it imports nothing
from the rest of ``obs`` or from ``core`` — it is a pure typing surface.
Core modules accept an ``Optional[TraceRecorder]`` and guard every hook with
``if recorder is not None``; with the default ``None`` the traced branches
never execute and the engine's decisions, float accumulation order, and
event logs are untouched (the tracing on/off bit-identity test pins this
for every registered scenario on both decision backends).

Sim-time vs wall-time: every ``t`` below is *simulated* seconds from the
event queue.  Wall-clock may only be read inside ``obs/`` implementations
(e.g. ``SimTraceRecorder`` timing a ``place()`` span between
``on_place_begin``/``on_place_end``) — core itself never touches a clock
(reprolint RPL102).
"""

from __future__ import annotations

from typing import Mapping, Optional, Protocol, Sequence, Tuple, runtime_checkable


@runtime_checkable
class TraceRecorder(Protocol):
    """Structured decision + telemetry hooks the engine calls out-of-band.

    Implementations must be strictly observational: no mutation of the
    cluster, profiles, or any engine state, and no RNG consumption.
    """

    # ------------------------------------------------------------ sim events
    def on_sim_event(self, t: float, kind: str, ident: int) -> None:
        """Mirror of every ``SimulationResult.events`` log append."""

    def on_timestamp(
        self,
        t: float,
        cluster: object,
        pending: int,
        running: Mapping[int, object],
    ) -> None:
        """End of one event-timestamp iteration: sample time-series gauges."""

    # ------------------------------------------------------- queue decisions
    def on_queue_order(
        self, t: float, ordered: Sequence[object], cluster: object
    ) -> None:
        """Policy-ordered pending queue (list of ``JobProfile``) at ``t``."""

    # --------------------------------------------------- placement decisions
    def on_place_begin(self, t: float, job_id: int, *, probe: bool = False) -> None:
        """A ``place()`` decision span opens (wall clock read obs-side)."""

    def on_place_end(
        self,
        t: float,
        job_id: int,
        placement: Optional[object],
        backend: str,
        *,
        probe: bool = False,
    ) -> None:
        """The span closes; ``placement is None`` means the job stays queued."""

    def on_candidate(
        self,
        job_id: int,
        stage: str,
        path: Tuple[str, ...],
        gpus: int,
        outcome: str,
        binding: Optional[str],
        avg_price: Optional[float] = None,
    ) -> None:
        """One Pathfinder candidate: ``stage`` in {"reject", "phase1",
        "phase2"}, ``outcome`` the admission result, ``binding`` the
        constraint that decided it ("gpu" = Eq. 5, "bandwidth" = Eq. 6, or
        None when admitted)."""

    def on_alloc(
        self, path: Sequence[str], gpus: int, alloc: Mapping[str, int]
    ) -> None:
        """A successful Cost-Min (Alg. 2) pour along ``path``."""

    # ----------------------------------------------------- lifecycle records
    def on_start(
        self,
        t: float,
        job_id: int,
        placement: object,
        rate: float,
        iteration_seconds: float,
        finish: float,
        restore_s: float,
    ) -> None:
        """A segment starts: chosen placement with its billed $/s ``rate``."""

    def on_settle(
        self, t: float, job_id: int, cost: float, ledger: Mapping[str, object]
    ) -> None:
        """A segment's ledger settles (completion or preemption)."""

    def on_preempt(self, t: float, job_id: int, voluntary: bool) -> None:
        """A running segment is evicted (forced) or checkpoints (voluntary)."""

    def on_migration_probe(
        self,
        t: float,
        job_id: int,
        stay_cost: float,
        move_cost: Optional[float],
        moved: bool,
    ) -> None:
        """A price-aware stay-vs-move probe and its verdict."""
