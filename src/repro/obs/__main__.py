"""``python -m repro.obs`` — trace inspection CLI.

Subcommands:

* ``report TRACE.jsonl``           — print the terminal summary.
* ``report TRACE.jsonl --check``   — additionally validate the structural
  invariants; exit 1 when any fail (the CI smoke gate).
* ``report TRACE.jsonl --perfetto OUT.json`` — also write the Chrome
  trace-event export for https://ui.perfetto.dev.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .export import load_jsonl, write_perfetto
from .report import check_trace, render_report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.obs", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="summarize a JSONL trace")
    rep.add_argument("trace", help="path to a trace written by write_jsonl")
    rep.add_argument(
        "--check",
        action="store_true",
        help="validate structural invariants; exit 1 on any failure",
    )
    rep.add_argument(
        "--perfetto",
        metavar="OUT",
        help="also write the Chrome trace-event JSON export to OUT",
    )
    args = ap.parse_args(argv)

    try:
        trace = load_jsonl(args.trace)
    except (OSError, ValueError) as exc:
        print(f"error: cannot load {args.trace}: {exc}", file=sys.stderr)
        return 2
    print(render_report(trace))
    if args.perfetto:
        out = write_perfetto(args.perfetto, trace)
        print(f"wrote perfetto trace: {out}")
    if args.check:
        problems = check_trace(trace)
        if problems:
            print(f"check: {len(problems)} problem(s)", file=sys.stderr)
            for p in problems:
                print(f"  - {p}", file=sys.stderr)
            return 1
        print("check: trace OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
