"""``SimTraceRecorder`` — the reference ``TraceRecorder`` implementation.

Collects structured *decision records* (ordered-queue snapshots with Eq. 12
priority scores, per-candidate Pathfinder admission outcomes with their
binding constraint, chosen placements with the typed grant and billed rate,
migration stay-vs-move probes) plus a ``MetricsLog`` of time-series gauges
sampled at event timestamps.  Everything here is observational: the
recorder never mutates engine state and never consumes RNG, which is what
the tracing on/off bit-identity test relies on.

Wall clock lives *only* here (and in ``FleetHealth``): core calls
``on_place_begin``/``on_place_end`` and the recorder reads
``time.perf_counter`` on its side of the seam, so reprolint's RPL102
(no wall clock in ``core/``) stays clean by construction.

Record-volume bounds: a saturated cluster re-probes every queued job at
every event, so the recorder suppresses *repeat* failure records (and their
candidate sub-records) for a job already marked head-of-line blocked — the
first failure per queue episode is kept, later identical ones only update
the HoL wait attribution.  Decision wall-clock histograms are never
suppressed.
"""

from __future__ import annotations

import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.cluster import GBPS
from repro.core.microplan import plan_cache_info
from repro.core.priority import priority_scores

from .metrics import FleetHealth, MetricsLog


class SimTraceRecorder:
    """Reference recorder: decision records + ``MetricsLog`` + fleet health.

    ``queue_top`` caps how many entries of each ordered-queue snapshot are
    stored (the snapshot records the full queue depth either way).
    ``gauge_stride`` decimates the *expensive* gauges (per-region occupancy,
    per-link utilization/residual, spend rate, plan cache, fleet health) and
    queue-snapshot scoring to every Nth drained timestamp — the cheap
    scheduler gauges (queue depth, running jobs) still sample at every one.
    The default keeps traced runs within the benchmark's overhead ceiling
    (``TRACE_OVERHEAD_CEILING`` in ``benchmarks/scheduler_scaling.py``);
    set 1 for full resolution.
    """

    def __init__(
        self,
        *,
        queue_top: int = 16,
        gauge_stride: int = 16,
        heartbeat_timeout_s: float = 6 * 3600.0,
        straggler_factor: float = 2.5,
    ) -> None:
        if gauge_stride < 1:
            raise ValueError("gauge_stride must be >= 1")
        self.queue_top = queue_top
        self.gauge_stride = gauge_stride
        self.records: List[Dict[str, object]] = []
        self.metrics = MetricsLog()
        self.health = FleetHealth(
            self.metrics,
            heartbeat_timeout_s=heartbeat_timeout_s,
            straggler_factor=straggler_factor,
        )
        #: Per-job head-of-line wait attribution: simulated seconds spent
        #: queued *after* a failed placement attempt (i.e. blocked on
        #: resources, not merely not-yet-visited).
        self.hol_wait: Dict[int, float] = {}
        self._blocked: Dict[int, float] = {}
        self._now = 0.0
        self._queue_t: Optional[float] = None
        self._span_t0 = 0.0
        self._span_suppress = False
        self._gauge_tick = 0
        self._queue_tick = 0
        # Hot-path memos: pre-bound series lists and counter names keep
        # f-string construction and dict setdefault churn off the
        # per-timestamp / per-candidate paths (the overhead-ceiling
        # benchmark is sensitive to both).
        series = self.metrics.series
        self._pending_series = series.setdefault("pending_depth", [])
        self._running_series = series.setdefault("running_jobs", [])
        self._occ_series: Dict[str, List[Tuple[float, float]]] = {}
        self._link_series: Dict[
            Tuple[str, str],
            Tuple[List[Tuple[float, float]], List[Tuple[float, float]]],
        ] = {}
        self._event_counters: Dict[str, str] = {}
        self._cand_counters: Dict[str, str] = {}
        self._bind_counters: Dict[str, str] = {}
        self._wall_hists: Dict[str, str] = {}

    # ------------------------------------------------------------ sim events
    def on_sim_event(self, t: float, kind: str, ident: int) -> None:
        self._now = t
        self.records.append({"t": t, "kind": "event", "event": kind, "id": ident})
        name = self._event_counters.get(kind)
        if name is None:
            name = self._event_counters[kind] = f"events/{kind}"
        self.metrics.incr(name)

    def on_timestamp(
        self,
        t: float,
        cluster: object,
        pending: int,
        running: Mapping[int, object],
    ) -> None:
        self._now = t
        m = self.metrics
        self._pending_series.append((t, float(pending)))
        self._running_series.append((t, float(len(running))))

        # Everything below iterates running placements or the cluster
        # ledgers; decimate to every ``gauge_stride``-th timestamp.
        tick = self._gauge_tick
        self._gauge_tick = tick + 1
        if tick % self.gauge_stride:
            return

        # $/s spend rate: per running segment ledger, cluster-wide total.
        total_rate = 0.0
        active_regions: set = set()
        link_reserved: Dict[Tuple[str, str], float] = {}
        for job_id in sorted(running):
            run = running[job_id]
            total_rate += run.acct.rate
            active_regions.update(run.placement.path)
            for link, share in run.placement.reserved_bw.items():
                link_reserved[link] = link_reserved.get(link, 0.0) + share
        m.sample("spend_rate_per_s", t, total_rate)

        # Per-region GPU occupancy (1 − free/capacity), live spot capacity.
        names = cluster.region_names()
        free = cluster.free_vector()
        caps = cluster.capacity_vector()
        occ_series = self._occ_series
        for i, name in enumerate(names):
            pts = occ_series.get(name)
            if pts is None:
                pts = occ_series[name] = m.series.setdefault(
                    f"gpu_occupancy/{name}", []
                )
            cap = int(caps[i])
            occ = 1.0 - (float(free[i]) / cap) if cap > 0 else 0.0
            pts.append((t, occ))

        # Per-link utilization/residual — only links carrying reservations
        # are sampled (absent ⇒ utilization 0, residual = capacity).
        link_series = self._link_series
        for link in sorted(link_reserved):
            pair = link_series.get(link)
            if pair is None:
                u, v = link
                pair = link_series[link] = (
                    m.series.setdefault(f"link_util/{u}->{v}", []),
                    m.series.setdefault(f"link_residual_gbps/{u}->{v}", []),
                )
            u, v = link
            cap = cluster.link_bandwidth(u, v)
            util = link_reserved[link] / cap if cap > 0 else 1.0
            pair[0].append((t, util))
            pair[1].append((t, cluster.available_bandwidth(u, v) / GBPS))

        # Plan-cache hit rate of the microplan memo (process-wide).
        info = plan_cache_info()
        if info.hits or info.misses:
            m.sample("plan_cache_hit_rate", t, info.hit_rate)

        # Fleet health: occupied regions heartbeat at sim time.
        if active_regions:
            self.health.beat_regions(t, sorted(active_regions))
        self.health.sample(t)

    # ------------------------------------------------------- queue decisions
    def on_queue_order(
        self, t: float, ordered: Sequence[object], cluster: object
    ) -> None:
        self._now = t
        if t == self._queue_t:
            return  # one snapshot per timestamp: re-ranks within a pass churn
        self._queue_t = t
        # Scoring the full queue is O(depth); decimate like the gauges.
        tick = self._queue_tick
        self._queue_tick = tick + 1
        if tick % self.gauge_stride:
            return
        scores = priority_scores(ordered, cluster)
        self.records.append(
            {
                "t": t,
                "kind": "queue",
                "depth": len(ordered),
                "head": [
                    {"job": p.spec.job_id, "score": scores[p.spec.job_id]}
                    for p in ordered[: self.queue_top]
                ],
            }
        )

    # --------------------------------------------------- placement decisions
    def on_place_begin(self, t: float, job_id: int, *, probe: bool = False) -> None:
        self._now = t
        self._span_suppress = (not probe) and job_id in self._blocked
        self._span_t0 = time.perf_counter()

    def on_place_end(
        self,
        t: float,
        job_id: int,
        placement: Optional[object],
        backend: str,
        *,
        probe: bool = False,
    ) -> None:
        wall_s = time.perf_counter() - self._span_t0
        hist = self._wall_hists.get(backend)
        if hist is None:
            hist = self._wall_hists[backend] = f"decide_wall_us/{backend}"
        self.metrics.observe(hist, wall_s * 1e6)
        self.health.observe_decision(wall_s)
        ok = placement is not None
        if not ok and not probe:
            self._blocked.setdefault(job_id, t)
        if self._span_suppress and not ok:
            self._span_suppress = False
            return
        self._span_suppress = False
        rec: Dict[str, object] = {
            "t": t,
            "kind": "place",
            "job": job_id,
            "ok": ok,
            "backend": backend,
            "wall_us": wall_s * 1e6,
        }
        if probe:
            rec["probe"] = True
        self.records.append(rec)

    def on_candidate(
        self,
        job_id: int,
        stage: str,
        path: Tuple[str, ...],
        gpus: int,
        outcome: str,
        binding: Optional[str],
        avg_price: Optional[float] = None,
    ) -> None:
        if self._span_suppress:
            return
        rec: Dict[str, object] = {
            "t": self._now,
            "kind": "candidate",
            "job": job_id,
            "stage": stage,
            "path": list(path),
            "gpus": gpus,
            "outcome": outcome,
            "binding": binding,
        }
        if avg_price is not None:
            rec["avg_price"] = avg_price
        self.records.append(rec)
        name = self._cand_counters.get(outcome)
        if name is None:
            name = self._cand_counters[outcome] = f"candidates/{outcome}"
        self.metrics.incr(name)
        if binding is not None:
            name = self._bind_counters.get(binding)
            if name is None:
                name = self._bind_counters[binding] = f"binding/{binding}"
            self.metrics.incr(name)

    def on_alloc(
        self, path: Sequence[str], gpus: int, alloc: Mapping[str, int]
    ) -> None:
        if self._span_suppress:
            return
        self.records.append(
            {
                "t": self._now,
                "kind": "alloc",
                "path": list(path),
                "gpus": gpus,
                "alloc": {r: int(n) for r, n in sorted(alloc.items())},
            }
        )

    # ----------------------------------------------------- lifecycle records
    def on_start(
        self,
        t: float,
        job_id: int,
        placement: object,
        rate: float,
        iteration_seconds: float,
        finish: float,
        restore_s: float,
    ) -> None:
        self._now = t
        blocked_at = self._blocked.pop(job_id, None)
        if blocked_at is not None:
            self.hol_wait[job_id] = self.hol_wait.get(job_id, 0.0) + (
                t - blocked_at
            )
        rec: Dict[str, object] = {
            "t": t,
            "kind": "start",
            "job": job_id,
            "path": list(placement.path),
            "alloc": {r: int(n) for r, n in sorted(placement.alloc.items())},
            "gpus": placement.total_gpus,
            "rate_per_s": rate,
            "iteration_s": iteration_seconds,
            "finish": finish,
            "restore_s": restore_s,
        }
        if placement.typed_alloc:
            rec["typed_alloc"] = {
                r: {g: int(n) for g, n in sorted(types.items())}
                for r, types in sorted(placement.typed_alloc.items())
            }
        self.records.append(rec)

    def on_settle(
        self, t: float, job_id: int, cost: float, ledger: Mapping[str, object]
    ) -> None:
        self._now = t
        self.records.append(
            {
                "t": t,
                "kind": "settle",
                "job": job_id,
                "cost": cost,
                "ledger": dict(ledger),
            }
        )

    def on_preempt(self, t: float, job_id: int, voluntary: bool) -> None:
        self._now = t
        self.records.append(
            {"t": t, "kind": "preempt", "job": job_id, "voluntary": voluntary}
        )

    def on_migration_probe(
        self,
        t: float,
        job_id: int,
        stay_cost: float,
        move_cost: Optional[float],
        moved: bool,
    ) -> None:
        self._now = t
        self.records.append(
            {
                "t": t,
                "kind": "probe",
                "job": job_id,
                "stay_cost": stay_cost,
                "move_cost": move_cost,
                "moved": moved,
            }
        )
        self.metrics.incr("probes/moved" if moved else "probes/stayed")
