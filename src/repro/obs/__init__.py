"""Observability for the geo-scheduler: decision tracing, telemetry, export.

Public surface:

* :class:`~repro.obs.recorder.SimTraceRecorder` — pass as
  ``simulate(..., recorder=...)`` (or ``Scenario.run(recorder=...)``) to
  collect decision records and time-series telemetry out-of-band.
* :class:`~repro.obs.metrics.MetricsLog` / :class:`~repro.obs.metrics.FleetHealth`
  — the gauge/histogram store and the ft-monitor bridge.
* :mod:`~repro.obs.export` — ``write_perfetto`` (Chrome trace-event JSON,
  loads at https://ui.perfetto.dev), ``write_jsonl``/``load_jsonl``.
* :mod:`~repro.obs.report` — ``render_report``/``check_trace``; also the
  ``python -m repro.obs report`` CLI.

Core decision-path modules never import this package (reprolint RPL601);
they see only the :class:`~repro.obs.protocol.TraceRecorder` protocol.
"""

from .export import LoadedTrace, load_jsonl, to_perfetto, write_jsonl, write_perfetto
from .metrics import FleetHealth, MetricsLog
from .protocol import TraceRecorder
from .recorder import SimTraceRecorder
from .report import check_trace, render_report

__all__ = [
    "FleetHealth",
    "LoadedTrace",
    "MetricsLog",
    "SimTraceRecorder",
    "TraceRecorder",
    "check_trace",
    "load_jsonl",
    "render_report",
    "to_perfetto",
    "write_jsonl",
    "write_perfetto",
]
