"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, *, base_lr: float, warmup: int):
    s = step.astype(jnp.float32)
    return base_lr * jnp.minimum(1.0, (s + 1.0) / max(1, warmup))


def cosine_schedule(
    step, *, base_lr: float, warmup: int, total: int, min_frac: float = 0.1
):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(1, warmup))
    prog = jnp.clip((s - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = min_frac + (1.0 - min_frac) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
