from .adamw import AdamWState, adamw_init, adamw_update, opt_state_specs  # noqa: F401
from .schedule import cosine_schedule, linear_warmup  # noqa: F401
