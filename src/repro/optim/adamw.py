"""AdamW with fp32 master weights and ZeRO-1 optimizer-state sharding.

Params train in bf16; the fp32 master copy + Adam moments are sharded over
the ``data`` axis (ZeRO-1).  Under GSPMD the sharding specs alone induce the
classic ZeRO dataflow: grads reduce-scatter onto the state shards, the update
runs shard-local, and the bf16 params all-gather back.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass
class AdamWState:
    count: jax.Array
    mu: Any
    nu: Any
    master: Any  # fp32 master params

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.count, self.mu, self.nu, self.master), None


jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: ((s.count, s.mu, s.nu, s.master), None),
    lambda _, c: AdamWState(*c),
)


def adamw_init(params: Any) -> AdamWState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=zeros(params),
        nu=zeros(params),
        master=f32(params),
    )


def zero1_spec(spec: P, shape: Tuple[int, ...], data_size: int) -> P:
    """Add 'data' sharding on the first divisible, unsharded dim (ZeRO-1)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % data_size == 0 and dim >= data_size:
            parts[i] = "data"
            return P(*parts)
    return P(*parts)  # too small / indivisible: replicate (tiny leaves only)


def opt_state_specs(param_spec_tree: Any, param_shapes: Any, mesh: Mesh) -> Any:
    """Specs for AdamWState given param specs/shapes."""
    data = mesh.shape.get("data", 1)

    def per_leaf(spec, shape):
        return zero1_spec(spec, shape.shape, data)

    sharded = jax.tree.map(
        per_leaf, param_spec_tree, param_shapes,
        is_leaf=lambda x: isinstance(x, P),
    )
    return AdamWState(count=P(), mu=sharded, nu=sharded, master=sharded)


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState]:
    count = state.count + 1
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    if clip_norm is not None:
        gn = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(g32))
        )
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
        g32 = jax.tree.map(lambda g: g * scale, g32)

    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, w):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        w2 = w - lr * (step + weight_decay * w)
        return m2, v2, w2

    updated = jax.tree.map(upd, g32, state.mu, state.nu, state.master)
    is_triple = lambda x: isinstance(x, tuple)
    m_new = jax.tree.map(lambda t: t[0], updated, is_leaf=is_triple)
    v_new = jax.tree.map(lambda t: t[1], updated, is_leaf=is_triple)
    w_new = jax.tree.map(lambda t: t[2], updated, is_leaf=is_triple)

    new_params = jax.tree.map(
        lambda w, old: w.astype(old.dtype), w_new, params
    )
    return new_params, AdamWState(count=count, mu=m_new, nu=v_new, master=w_new)
