"""Cross-pod gradient compression (the WAN-analogue link is the pod axis).

``compressed_pmean``: int8 quantization with per-slice fp32 scales around a
reduce-scatter / all-gather pair over the pod axis — 2 pods exchange int8
shards instead of bf16 full tensors (~4x fewer WAN bytes).  Runs inside a
shard_map whose manual axes include ``axis``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_pmean_leaf(g: jax.Array, axis: str, size: int) -> jax.Array:
    """Mean-reduce one gradient leaf across ``axis`` with int8 transport."""
    if size <= 1:
        return g
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shards = flat.reshape(size, -1)

    # reduce-scatter with int8 payload: quantize my contribution per shard,
    # all_to_all so shard i lands on pod i, dequantize + sum locally.
    q, scale = _quantize(shards)                       # [size, n]
    scales = jnp.broadcast_to(scale, (size, 1))
    q_recv = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0, tiled=True)
    s_recv = jax.lax.all_to_all(
        scales, axis, split_axis=0, concat_axis=0, tiled=True
    )
    local_sum = jnp.sum(
        _dequantize(q_recv.reshape(size, -1), s_recv), axis=0
    ) / size                                            # [n] my shard's mean

    # all-gather the reduced shards back, int8 again.
    q2, scale2 = _quantize(local_sum[None, :])
    q_all = jax.lax.all_gather(q2[0], axis, tiled=False)       # [size, n]
    s_all = jax.lax.all_gather(scale2[None], axis, tiled=False)
    out = _dequantize(q_all, s_all.reshape(size, 1)).reshape(-1)
    if pad:
        out = out[:-pad]
    return out.reshape(g.shape).astype(g.dtype)


def compressed_pmean(grads: Any, axis: str, size: int) -> Any:
    return jax.tree.map(lambda g: compressed_pmean_leaf(g, axis, size), grads)
