"""Named-axis sharding rules per architecture and mesh.

Axes: ``data`` (batch DP + ZeRO-1 shards), ``model`` (TP / EP / PP stages),
``pod`` (multi-pod: geo pipeline stage or compressed-DP replica).

``param_specs(cfg, mesh)`` returns a PartitionSpec pytree matching the model
parameter tree; ``make_shard_act`` returns the activation-constraint hook the
models call.  PP-strategy stage stacking is handled by ``repro.pipeline``;
here PP-arch params outside the pipeline (embed, ln_f) are replicated.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax

from repro.distributed.compat import constrain_auto_axes
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def heads_shardable(cfg: ArchConfig, mesh: Mesh) -> bool:
    m = axis_size(mesh, "model")
    return (
        cfg.n_heads > 0
        and cfg.n_heads % m == 0
        and cfg.n_kv_heads % m == 0
    )


def ssm_heads_shardable(cfg: ArchConfig, mesh: Mesh) -> bool:
    m = axis_size(mesh, "model")
    return cfg.ssm_state > 0 and cfg.ssm_heads % m == 0 and cfg.d_inner % m == 0


def vocab_shardable(cfg: ArchConfig, mesh: Mesh) -> bool:
    return cfg.padded_vocab % axis_size(mesh, "model") == 0


# ------------------------------------------------------------- param rules
def _attn_specs(cfg: ArchConfig, mesh: Mesh, tp: bool) -> Dict[str, P]:
    m = "model" if tp and heads_shardable(cfg, mesh) else None
    s: Dict[str, P] = {
        "wq": P(None, m),
        "wk": P(None, m),
        "wv": P(None, m),
        "wo": P(m, None),
    }
    if cfg.qkv_bias:
        s.update({"bq": P(m), "bk": P(m), "bv": P(m)})
    return s


def _mlp_specs(cfg: ArchConfig, mesh: Mesh, tp: bool) -> Dict[str, P]:
    m = "model" if tp and cfg.d_ff % max(1, axis_size(mesh, "model")) == 0 else None
    s = {"w_up": P(None, m), "w_down": P(m, None)}
    if cfg.act != "gelu_plain":
        s["w_gate"] = P(None, m)
    return s


def _moe_specs(cfg: ArchConfig, mesh: Mesh, tp: bool) -> Dict[str, Any]:
    e = "model" if tp and cfg.n_experts % max(1, axis_size(mesh, "model")) == 0 else None
    s: Dict[str, Any] = {
        "router": P(None, None),
        "w_gate": P(e, None, None),
        "w_up": P(e, None, None),
        "w_down": P(e, None, None),
    }
    if cfg.n_shared_experts:
        s["shared"] = _mlp_specs(cfg, mesh, tp)
    return s


def _ssm_specs(cfg: ArchConfig, mesh: Mesh, tp: bool) -> Dict[str, Any]:
    ok = tp and ssm_heads_shardable(cfg, mesh)
    m = "model" if ok else None
    return {
        "ln": {"scale": P(None)},
        "w_z": P(None, m),
        "w_x": P(None, m),
        "w_b": P(None, None),
        "w_c": P(None, None),
        "w_dt": P(None, m),
        "conv_x": P(None, m),
        "conv_b": P(None, None),
        "conv_c": P(None, None),
        "conv_x_bias": P(m),
        "conv_b_bias": P(None),
        "conv_c_bias": P(None),
        "a_log": P(m),
        "d_skip": P(m),
        "dt_bias": P(m),
        "norm": {"scale": P(m)},
        "out_proj": P(m, None),
    }


def _dense_block_specs(cfg: ArchConfig, mesh: Mesh, tp: bool) -> Dict[str, Any]:
    return {
        "ln_attn": {"scale": P(None)},
        "attn": _attn_specs(cfg, mesh, tp),
        "ln_mlp": {"scale": P(None)},
        "mlp": _mlp_specs(cfg, mesh, tp),
    }


def _embed_specs(cfg: ArchConfig, mesh: Mesh, tp: bool) -> Dict[str, P]:
    # vocab-parallel embedding for ALL strategies (PP included): the loss
    # head computes under GSPMD auto, so sharded-vocab logits avoid the
    # logits-sized loss all-reduce (measured 4.4 TB/step on qwen train_4k).
    v = "model" if vocab_shardable(cfg, mesh) else None
    s = {"table": P(v, None)}
    if not cfg.tie_embeddings:
        s["head"] = P(None, v)
    return s


def _prepend(spec_tree, n: int):
    """Stacked (scanned) leaves get ``n`` leading None dims."""
    def fix(s: P) -> P:
        return P(*([None] * n + list(s)))

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def param_specs(cfg: ArchConfig, mesh: Mesh) -> Any:
    """PartitionSpec tree matching build_model(cfg).init(...)'s structure.

    For ``model_axis='pp'`` archs the per-block params are replicated here —
    the pipeline runtime re-shards them over stages (see pipeline/gpipe.py);
    this function still drives embed / final-norm placement.
    """
    tp = cfg.model_axis in ("tp", "ep")
    if cfg.family == "encdec":
        return {
            "embed": _embed_specs(cfg, mesh, tp),
            "enc_blocks": _prepend(_dense_block_specs(cfg, mesh, tp), 1),
            "dec_blocks": _prepend(
                {
                    "ln_self": {"scale": P(None)},
                    "self": _attn_specs(cfg, mesh, tp),
                    "ln_cross": {"scale": P(None)},
                    "cross": _attn_specs(cfg, mesh, tp),
                    "ln_mlp": {"scale": P(None)},
                    "mlp": _mlp_specs(cfg, mesh, tp),
                },
                1,
            ),
            "ln_enc": {"scale": P(None)},
            "ln_f": {"scale": P(None)},
        }

    out: Dict[str, Any] = {
        "embed": _embed_specs(cfg, mesh, tp),
        "ln_f": {"scale": P(None)},
    }
    if cfg.family in ("dense", "vlm"):
        blk = _dense_block_specs(cfg, mesh, tp)
        if cfg.alternate_local_global:
            blk = {"local": blk, "global": _dense_block_specs(cfg, mesh, tp)}
        out["blocks"] = _prepend(blk, 1)
    elif cfg.family == "moe":
        out["blocks"] = _prepend(
            {
                "ln_attn": {"scale": P(None)},
                "attn": _attn_specs(cfg, mesh, tp),
                "ln_mlp": {"scale": P(None)},
                "moe": _moe_specs(cfg, mesh, tp),
            },
            1,
        )
    elif cfg.family == "ssm":
        out["blocks"] = _prepend(_ssm_specs(cfg, mesh, tp), 1)
    elif cfg.family == "hybrid":
        out["blocks"] = _prepend(_ssm_specs(cfg, mesh, tp), 2)
        out["shared_attn"] = _dense_block_specs(cfg, mesh, tp)
    else:
        raise ValueError(cfg.family)
    return out


# -------------------------------------------------------- activation rules
def make_shard_act(
    cfg: ArchConfig,
    mesh: Mesh,
    *,
    batch: int,
    enable: bool = True,
) -> Optional[Callable[[jax.Array, str], jax.Array]]:
    """Activation-constraint hook.

    Attention activations: heads sharded over `model` when divisible;
    otherwise batch is co-sharded over (data, model) when it divides, else
    the sequence dim is sharded over `model` (KV gets gathered by GSPMD).
    """
    if not enable or mesh is None:
        return None
    d = axis_size(mesh, "data")
    m = axis_size(mesh, "model")
    heads_ok = heads_shardable(cfg, mesh)
    ssm_ok = ssm_heads_shardable(cfg, mesh)
    batch_ok = batch % (d * m) == 0

    ff_ok = cfg.d_ff > 0 and cfg.d_ff % m == 0

    def spec_for(name: str, ndim: int) -> Optional[P]:
        if name == "residual":
            return P("data", *([None] * (ndim - 1)))
        if name == "mlp_hidden":
            return P("data", None, "model") if ff_ok else None
        if name in ("attn_q", "attn_kv"):
            if heads_ok:
                return P("data", None, "model", None)
            if batch_ok:
                return P(("data", "model"), None, None, None)
            return P("data", "model", None, None)  # seq-sharded
        if name == "ssm_x":
            if ssm_ok:
                return P("data", None, "model", None)
            return P("data", *([None] * (ndim - 1)))
        if name == "logits":
            v = "model" if vocab_shardable(cfg, mesh) else None
            return P("data", None, v)
        return None

    def shard(x: jax.Array, name: str) -> jax.Array:
        s = spec_for(name, x.ndim)
        if s is None:
            return x
        # bare PartitionSpec: resolves against the context mesh, so the same
        # hook works inside pod-manual shard_map regions (abstract mesh with
        # Manual pod axis) and in plain auto regions alike.
        return constrain_auto_axes(x, s)

    return shard
