"""Version-tolerant wrappers over fast-moving jax mesh/shard_map APIs.

The repo targets the current jax API (``jax.set_mesh``, ``jax.shard_map`` with
``axis_names=``/``check_vma=``), but must also run on older installs where the
mesh context is ``jax.sharding.use_mesh`` or the ``Mesh`` object itself, and
where shard_map lives in ``jax.experimental.shard_map`` with the
``auto=``/``check_rep=`` spelling.  Everything that needs either API imports it
from here instead of probing ``jax`` directly.
"""

from __future__ import annotations

import jax


def use_mesh(mesh):
    """Context manager making ``mesh`` ambient for jit/shard_map.

    Resolution order: ``jax.set_mesh`` (current), ``jax.sharding.use_mesh``
    (transitional), then the ``Mesh`` object itself (older jax, where ``with
    mesh:`` enters the resource environment).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    sharding_use_mesh = getattr(jax.sharding, "use_mesh", None)
    if sharding_use_mesh is not None:
        return sharding_use_mesh(mesh)
    return mesh


_manual_region_depth = 0


def constrain_auto_axes(x, spec):
    """``with_sharding_constraint`` for constraints naming would-be-auto axes
    inside a shard_map body.  Under the full-manual fallback (old jax, see
    ``shard_map`` below) every mesh axis is manual, so such a constraint
    fails at lowering; it is a GSPMD performance hint, not semantics, and is
    skipped there.  On jax with native partial-auto shard_map (and in plain
    auto regions on any jax) it always applies."""
    if _manual_region_depth > 0:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def axis_size(name):
    """``jax.lax.axis_size`` inside a manual (shard_map) region, on any jax.
    Older versions lack it; ``psum(1, name)`` constant-folds to the same
    concrete int there."""
    native = getattr(jax.lax, "axis_size", None)
    if native is not None:
        return native(name)
    return jax.lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=True):
    """``jax.shard_map`` with the current keyword surface, on any jax.

    ``axis_names`` names the *manual* mesh axes (all axes when None).  On jax
    versions without ``jax.shard_map`` the fallback ignores ``axis_names``
    and runs the region *full-manual* (partial-auto there rejects
    ``axis_index``/``ppermute`` at SPMD partitioning) — numerically identical
    since specs that omit an axis replicate over it, at the cost of redundant
    compute on the would-be-auto axes; ``check_vma`` maps onto ``check_rep``.
    """
    native = getattr(jax, "shard_map", None)
    if native is not None:
        kwargs = {} if axis_names is None else {"axis_names": axis_names}
        return native(
            f,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=check_vma,
            **kwargs,
        )
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    # Old jax: run full-manual instead of partial-auto.  ``axis_index`` and
    # ``ppermute`` under partial-auto lower to instructions the SPMD
    # partitioner rejects there; full-manual is numerically identical (specs
    # that omit an axis replicate over it) at the cost of redundant compute
    # on the would-be-auto axes.  While the body traces, a flag tells
    # ``constrain_auto_axes`` to drop auto-axis sharding hints that would be
    # illegal in a fully-manual region.
    def body(*args, **body_kwargs):
        global _manual_region_depth
        _manual_region_depth += 1
        try:
            return f(*args, **body_kwargs)
        finally:
            _manual_region_depth -= 1

    return _experimental_shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_rep=check_vma,
    )
