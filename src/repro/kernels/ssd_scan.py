"""Mamba2 SSD chunked scan for TPU (Pallas).

The grid walks (batch, head-block, chunk) with the chunk axis innermost and
sequential; the inter-chunk recurrent state lives in VMEM scratch and is
carried across chunk steps — exactly the SSD decomposition: MXU-friendly
within-chunk matmuls + an O(T/Q) recurrence.  All matmuls are expressed as
2-operand ``dot_general`` so Mosaic can map them onto the MXU.

Layout contract (see ops.py): x [B, T, H, P], dt [B, T, H], a [H],
b/c [B, T, N].  Validated with interpret=True against kernels.ref.ssd_ref.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_HEAD_BLOCK = 8


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,   # inputs
    y_ref, s_out_ref,                     # outputs
    state_ref,                            # scratch: [bh, P, N] carried state
    *,
    chunk: int,
):
    ci = pl.program_id(2)
    n_chunks = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)        # [Q, bh, P]
    dt = dt_ref[0].astype(jnp.float32)      # [Q, bh]
    a = a_ref[...].astype(jnp.float32)      # [bh]
    bmat = b_ref[0].astype(jnp.float32)     # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)     # [Q, N]

    da = dt * a[None, :]                    # [Q, bh]
    da_cs = jnp.cumsum(da, axis=0)          # [Q, bh]

    # ---- within-chunk (quadratic) part
    seg = da_cs.T[:, :, None] - da_cs.T[:, None, :]          # [bh, Q, K]
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(q_idx >= k_idx, jnp.exp(seg), 0.0)      # [bh, Q, K]
    cb = jax.lax.dot_general(                                # [Q, K]
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w = cb[None, :, :] * lmat                                # [bh, Q, K]
    xdt = x * dt[:, :, None]                                 # [Q, bh, P]
    xdt_h = jnp.swapaxes(xdt, 0, 1)                          # [bh, K, P]
    y_diag = jax.lax.dot_general(                            # [bh, Q, P]
        w, xdt_h, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )

    # ---- contribution from the carried state
    in_decay = jnp.exp(da_cs)                                # [Q, bh]
    y_off = jax.lax.dot_general(                             # [Q, bh, P]
        cmat, state_ref[...], (((1,), (2,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                        # [Q, bh, P]
    y = jnp.swapaxes(y_diag, 0, 1) + y_off * in_decay[:, :, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # ---- state update for the next chunk
    decay_last = jnp.exp(da_cs[-1:, :] - da_cs)              # [Q, bh]
    xdt_w = xdt * decay_last[:, :, None]                     # [K, bh, P]
    states_new = jax.lax.dot_general(                        # [bh, P, N]
        jnp.swapaxes(xdt_w, 0, 1), bmat, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    chunk_decay = jnp.exp(jnp.sum(da, axis=0))               # [bh]
    state_ref[...] = state_ref[...] * chunk_decay[:, None, None] + states_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_out_ref[0] = state_ref[...].astype(s_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "head_block", "interpret")
)
def ssd_scan_pallas(
    x: jax.Array,   # [B, T, H, P]
    dt: jax.Array,  # [B, T, H]
    a: jax.Array,   # [H]
    b_: jax.Array,  # [B, T, N]
    c_: jax.Array,  # [B, T, N]
    *,
    chunk: int = 256,
    head_block: int = DEFAULT_HEAD_BLOCK,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    bsz, t, h, p = x.shape
    n = b_.shape[-1]
    assert t % chunk == 0, (t, chunk)
    bh = min(head_block, h)
    assert h % bh == 0, (h, bh)
    grid = (bsz, h // bh, t // chunk)

    y, s_final = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bh, p), lambda b, hb, c: (b, c, hb, 0)),
            pl.BlockSpec((1, chunk, bh), lambda b, hb, c: (b, c, hb)),
            pl.BlockSpec((bh,), lambda b, hb, c: (hb,)),
            pl.BlockSpec((1, chunk, n), lambda b, hb, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, hb, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bh, p), lambda b, hb, c: (b, c, hb, 0)),
            pl.BlockSpec((1, bh, p, n), lambda b, hb, c: (b, hb, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bh, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_, c_)
    return y, s_final
