"""Pure-jnp oracles for every Pallas kernel in this package.

Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import attention_ref as _attention_btHD
from repro.models.ssm import ssd_chunked_ref as _ssd_chunked


def attention_ref(
    q: jax.Array,  # [B, Hq, Tq, D]
    k: jax.Array,  # [B, Hkv, Tk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Oracle in the kernel's [B, H, T, D] layout."""
    out = _attention_btHD(
        q.swapaxes(1, 2),
        k.swapaxes(1, 2),
        v.swapaxes(1, 2),
        causal=causal,
        window=window,
        softcap=softcap,
    )
    return out.swapaxes(1, 2)


def ssd_ref(
    x: jax.Array,   # [B, T, H, P]
    dt: jax.Array,  # [B, T, H]
    a: jax.Array,   # [H]
    b_: jax.Array,  # [B, T, N]
    c_: jax.Array,  # [B, T, N]
    *,
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    return _ssd_chunked(x, dt, a, b_, c_, chunk=chunk)
