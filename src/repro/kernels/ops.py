"""jit'd public wrappers around the Pallas kernels.

On TPU the fused kernels run natively; on CPU (this container) they execute
under ``interpret=True``.  Training gradients flow through a ``custom_vjp``
whose backward pass recomputes with the pure-jnp oracle — identical numerics,
and the forward hot path still uses the fused kernel.  (A fused backward
kernel is a recorded follow-up in EXPERIMENTS.md §Perf.)
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from . import ref
from .flash_attention import flash_attention_bhtd
from .ssd_scan import ssd_scan_pallas


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ------------------------------------------------------------ flash attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_core(q, k, v, causal, window, softcap):
    return flash_attention_bhtd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=_on_cpu(),
    )


def _flash_fwd(q, k, v, causal, window, softcap):
    return _flash_core(q, k, v, causal, window, softcap), (q, k, v)


def _flash_bwd(causal, window, softcap, res, g):
    q, k, v = res

    def f(q, k, v):
        return ref.attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap
        )

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # [B, T, Hq, D]  (model layout)
    k: jax.Array,  # [B, S, Hkv, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    out = _flash_core(
        q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
        causal, window, softcap,
    )
    return out.swapaxes(1, 2)


# ------------------------------------------------------------------- SSD scan
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_core(x, dt, a, b_, c_, chunk):
    return ssd_scan_pallas(x, dt, a, b_, c_, chunk=chunk, interpret=_on_cpu())


def _ssd_fwd(x, dt, a, b_, c_, chunk):
    return _ssd_core(x, dt, a, b_, c_, chunk), (x, dt, a, b_, c_)


def _ssd_bwd(chunk, res, g):
    x, dt, a, b_, c_ = res

    def f(x, dt, a, b_, c_):
        return ref.ssd_ref(x, dt, a, b_, c_, chunk=chunk)

    _, vjp = jax.vjp(f, x, dt, a, b_, c_)
    return vjp(g)


_ssd_core.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_: jax.Array,
    c_: jax.Array,
    *,
    chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    return _ssd_core(x, dt, a, b_, c_, chunk)
