"""Fused flash attention for TPU (Pallas).

TPU-native tiling: the grid's innermost axis walks KV blocks *sequentially*
(TPU grids execute in order), carrying the online-softmax statistics and the
output accumulator in VMEM scratch.  Block shapes are MXU-aligned
(block_q x head_dim and block_k x head_dim tiles, multiples of 128 on the
lane dimension).  Supports GQA (kv-head broadcast via index_map), causal
masking, sliding windows (gemma2 local layers), and logit soft-capping.

Layout contract (see ops.py): q [B, Hq, T, D], k/v [B, Hkv, S, D].
Validated on CPU with interpret=True against kernels.ref.attention_ref.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,      # VMEM tiles
    o_ref,                    # output tile
    acc_ref, m_ref, l_ref,    # VMEM scratch: [bq, D], [bq, 1], [bq, 1]
    *,
    block_q: int,
    block_k: int,
    seq_k: int,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
):
    qi = pl.program_id(2)      # query-block index
    ki = pl.program_id(3)      # kv-block index (sequential innermost)
    n_k = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)           # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)           # [bk, D]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # [bq, bk]
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                # [bq, 1]
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_bhtd(
    q: jax.Array,  # [B, Hq, Tq, D]
    k: jax.Array,  # [B, Hkv, Tk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pad_q = (-tq) % block_q
    pad_k = (-tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    tq_p, tk_p = tq + pad_q, tk + pad_k

    grid = (b, hq, tq_p // block_q, tk_p // block_k)
    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_k=block_k,
        seq_k=tk,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=d**-0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_q, d), lambda bb, h, qq, kk: (bb, h, qq, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda bb, h, qq, kk, rep=rep: (bb, h // rep, kk, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda bb, h, qq, kk, rep=rep: (bb, h // rep, kk, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bb, h, qq, kk: (bb, h, qq, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hq, tq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :tq, :]
