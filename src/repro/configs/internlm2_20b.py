"""InternLM2-20B [dense]: 48L, d_model 6144, 48 heads (GQA kv=8),
d_ff 16384, vocab 92544.  [arXiv:2403.17297]

Parallelism: PP=16 over `model` (48 layers -> 3 per stage).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    rope_theta=1_000_000.0,
    act="silu",
    model_axis="pp",
    pp_stages=16,
)
