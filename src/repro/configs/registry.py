"""--arch <id> registry for all assigned architectures."""

from __future__ import annotations

from typing import Dict

from .base import ArchConfig
from . import (
    deepseek_moe_16b,
    gemma2_2b,
    internlm2_20b,
    mamba2_2_7b,
    moonshot_v1_16b_a3b,
    qwen1_5_32b,
    qwen2_vl_2b,
    seamless_m4t_medium,
    starcoder2_3b,
    zamba2_2_7b,
)

_MODULES = (
    qwen1_5_32b,
    gemma2_2b,
    internlm2_20b,
    starcoder2_3b,
    moonshot_v1_16b_a3b,
    deepseek_moe_16b,
    zamba2_2_7b,
    seamless_m4t_medium,
    mamba2_2_7b,
    qwen2_vl_2b,
)

CONFIGS: Dict[str, ArchConfig] = {m.CONFIG.arch_id: m.CONFIG for m in _MODULES}

ARCH_IDS = tuple(CONFIGS)


def get_config(arch_id: str) -> ArchConfig:
    try:
        return CONFIGS[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch '{arch_id}'; available: {', '.join(ARCH_IDS)}"
        ) from None
