"""Mamba2-2.7B [ssm]: 64L, d_model 2560 (attn-free), ssm_state 128,
vocab 50280 — SSD (state-space duality) blocks.  [arXiv:2405.21060]

Parallelism: PP=16 over `model` (64 layers -> 4 per stage); decode carries
the recurrent state (80 heads x 64 head_dim x 128 state) instead of a KV
cache, so long_500k runs natively.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    model_axis="pp",
    pp_stages=16,
)
