"""SeamlessM4T-medium backbone [audio enc-dec]: 12L encoder + 12L decoder,
d_model 1024, 16 heads (kv=16), d_ff 4096, vocab 256206.  [arXiv:2308.11596]

The speech frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings [B, T_src, d_model]; the backbone is the transformer enc-dec.

Parallelism: full TP over `model` (16 heads/16, d_ff 4096/16 = 256,
vocab 256206 -> padded 256256/16).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="seamless-m4t-medium",
    family="encdec",
    n_layers=12,          # decoder depth
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=256206,
    act="gelu_plain",
    model_axis="tp",
)
