"""Qwen1.5-32B [dense]: 64L, d_model 5120, 40 heads (GQA kv=40, i.e. MHA),
d_ff 27392, vocab 152064, QKV bias.  [hf:Qwen/Qwen1.5-32B]

Parallelism: flagship pipeline arch — PP=16 over the `model` axis
(64 layers -> 4 per stage), DP over `data`, geo-PP over `pod`.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
    model_axis="pp",
    pp_stages=16,
)
