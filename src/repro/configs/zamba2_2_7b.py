"""Zamba2-2.7B [hybrid]: 54 Mamba2 blocks, d_model 2560, ssm_state 64,
plus a SHARED attention+MLP block (32 heads, d_ff 10240, vocab 32000)
invoked every 6 mamba blocks.  [arXiv:2411.15242]

Parallelism: TP over `model` — mamba heads (80/16=5), shared-attn heads
(32/16=2), d_ff (10240/16).  Runs long_500k (recurrent state decode; the
shared block's KV cache is sequence-sharded).
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
    act="gelu",
    model_axis="tp",
)
