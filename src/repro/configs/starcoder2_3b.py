"""StarCoder2-3B [dense]: 30L, d_model 3072, 24 heads (GQA kv=2),
d_ff 12288, vocab 49152, RoPE, plain-GELU MLP, biases.  [arXiv:2402.19173]

Parallelism: TP over `model` (d_ff 12288/16 = 768); 24 heads don't divide
16 — attention batch/seq-sharded like gemma2.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab=49152,
    qkv_bias=True,
    rope_theta=999_999.4,
    act="gelu_plain",
    model_axis="tp",
)
