"""Architecture configs: one dataclass drives models, sharding, and dry-run.

Each assigned architecture gets a module in this package exporting ``CONFIG``
(the exact published shape) — the registry maps ``--arch <id>`` to it.  Every
config can produce a ``reduced()`` twin: same family/wiring, tiny dims, for
CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Family = str  # 'dense' | 'moe' | 'hybrid' | 'ssm' | 'encdec' | 'vlm'


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # ------------------------------------------------------------- identity
    arch_id: str
    family: Family
    # ------------------------------------------------------------ transformer
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                  # qwen1.5
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    act: str = "silu"                       # 'silu' (swiglu) | 'gelu' (geglu)
    tie_embeddings: bool = False
    # ----------------------------------------------------- gemma2-style extras
    sliding_window: Optional[int] = None    # local-attention window
    alternate_local_global: bool = False    # odd layers local, even global
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    # ------------------------------------------------------------------- moe
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    # ------------------------------------------------------------------- ssm
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # ---------------------------------------------------------------- hybrid
    attn_every: int = 0                     # zamba2: shared attn block period
    # ---------------------------------------------------------------- encdec
    n_enc_layers: int = 0                   # seamless: encoder depth
    # ------------------------------------------------------------------- vlm
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w head_dim split
    vision_frac: float = 0.25               # stub frontend: fraction of seq
    # -------------------------------------------------- distribution strategy
    #: how the 'model' mesh axis is used on the single-pod mesh:
    #:   'pp' — pipeline stages; 'tp' — tensor parallel; 'ep' — expert
    #:   parallel; 'dp' — pure extra data parallelism
    model_axis: str = "tp"
    pp_stages: int = 0                      # for 'pp': stages on model axis

    # ---------------------------------------------------------------- helpers
    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded so the vocab dim shards over 16-way meshes
        (padded logit columns are masked to -inf in lm_logits)."""
        return ((self.vocab + 15) // 16) * 16

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def ssm_heads(self) -> int:
        return (self.ssm_expand * self.d_model) // self.ssm_head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> float:
        """Analytic parameter count (drives MODEL_FLOPS and the scheduler)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hq = self.n_heads * self.head_dim_
        hkv = self.n_kv_heads * self.head_dim_
        attn = d * hq + 2 * d * hkv + hq * d
        mlp = 3 * d * f
        if self.family == "moe":
            mlp = 3 * d * self.expert_d_ff * (
                self.n_experts + self.n_shared_experts
            ) + d * self.n_experts  # router
        ssm = 0.0
        if self.family in ("ssm", "hybrid"):
            di = self.d_inner
            # in_proj (z,x,B,C,dt), conv, A/D/dt_bias, norm, out_proj
            ssm = d * (2 * di + 2 * self.ssm_state + self.ssm_heads) + di * d + 4 * di
        per_layer = {
            "dense": attn + mlp,
            "moe": attn + mlp,
            "vlm": attn + mlp,
            "encdec": attn + mlp,
            "ssm": ssm,
            "hybrid": ssm,
        }[self.family]
        n = self.n_layers * per_layer
        if self.family == "encdec":
            # n_layers = decoder depth; encoder adds self-attn-only layers and
            # decoder adds cross-attention.
            n += self.n_enc_layers * (attn + mlp) + self.n_layers * attn
        if self.family == "hybrid" and self.attn_every:
            shared_blocks = 1
            n += shared_blocks * (attn + mlp)
        n += v * d * (1 if self.tie_embeddings else 2)
        n += self.n_layers * 2 * d  # norms
        return float(n)

    def active_param_count(self) -> float:
        """MoE: parameters touched per token (for 6·N_active·D)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * 3 * d * self.expert_d_ff * (
            self.n_experts + self.n_shared_experts
        )
        active_mlp = 3 * d * self.expert_d_ff * (self.top_k + self.n_shared_experts)
        return float(dense + self.n_layers * active_mlp)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family twin for CPU smoke tests."""
        scale = {
            "n_layers": min(self.n_layers, 4 if self.family != "hybrid" else 4),
            "d_model": 64,
            "n_heads": min(self.n_heads, 4),
            "n_kv_heads": min(self.n_kv_heads, 2),
            "head_dim": 16,
            "d_ff": 128,
            "vocab": 256,
            "n_experts": min(self.n_experts, 4),
            "n_shared_experts": min(self.n_shared_experts, 1),
            "top_k": min(self.top_k, 2),
            "expert_d_ff": 64 if self.expert_d_ff else 0,
            "ssm_state": min(self.ssm_state, 16),
            "ssm_head_dim": 16,
            "ssm_chunk": 16,
            "sliding_window": 32 if self.sliding_window else None,
            "attn_every": min(self.attn_every, 2) if self.attn_every else 0,
            "n_enc_layers": min(self.n_enc_layers, 2),
            "pp_stages": min(self.pp_stages, 2) if self.pp_stages else 0,
            "mrope_sections": (2, 3, 3),  # sums to reduced head_dim // 2
        }
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}

#: archs that run the sub-quadratic long_500k cell (see DESIGN.md)
LONG_CONTEXT_ARCHS = ("mamba2-2.7b", "zamba2-2.7b", "gemma2-2b")


def runnable_cells(cfg: ArchConfig):
    for s in SHAPES:
        if s.name == "long_500k" and cfg.arch_id not in LONG_CONTEXT_ARCHS:
            continue
        yield s
