"""Qwen2-VL-2B backbone [vlm]: 28L, d_model 1536, 12 heads (GQA kv=2),
d_ff 8960, vocab 151936 — M-RoPE (t/h/w sections), dynamic resolution.
[arXiv:2409.12191]

The vision frontend is a STUB: ``input_specs()`` supplies precomputed patch
embeddings [B, T_vis, d_model] and 3D M-RoPE position ids; the backbone is
the text decoder consuming the multimodal sequence.

Parallelism: TP over `model` (d_ff 8960/16 = 560); 12 heads don't divide 16
— attention batch/seq-sharded.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    vision_frac=0.25,
    model_axis="tp",
)
