"""Gemma2-2B [dense]: 26L, d_model 2304, 8 heads (GQA kv=4, head_dim 256),
d_ff 9216, vocab 256000 — alternating local(4096)/global attention, logit
softcaps, GeGLU, tied embeddings.  [arXiv:2408.00118]

Parallelism: TP over `model` (d_ff 9216/16, vocab 256000/16); attention
heads (8) don't divide 16 — attention runs batch-sharded over `model` for
train and seq-sharded (distributed flash decode) for decode.  Runs the
long_500k cell: local layers are sliding-window (sub-quadratic); global
layers sequence-shard their KV.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    act="gelu",
    tie_embeddings=True,
    sliding_window=4096,
    alternate_local_global=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    model_axis="tp",
)
