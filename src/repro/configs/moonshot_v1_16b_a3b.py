"""Moonlight-16B-A3B (moonshot-v1-16b-a3b) [moe]: 48L, d_model 2048,
16 heads (kv=16), expert d_ff 1408, vocab 163840, 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B]

Parallelism: EP=16 over `model` (64 experts -> 4 per device), GShard-style
dispatch/combine einsums.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    act="silu",
    model_axis="ep",
)
