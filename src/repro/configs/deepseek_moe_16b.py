"""DeepSeekMoE-16B [moe]: 28L, d_model 2048, 16 heads (kv=16), expert
d_ff 1408, vocab 102400 — 2 shared + 64 routed experts, top-6, fine-grained.
[arXiv:2401.06066]

Parallelism: EP=16 over `model`; shared experts replicated (computed by all
devices on their token shard).  Deviation noted in DESIGN.md: the published
model's layer 0 uses a dense FFN; we keep a uniform MoE stack for the
scanned-layer representation.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    expert_d_ff=1408,
    act="silu",
    model_axis="ep",
)
