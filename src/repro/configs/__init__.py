from .base import (  # noqa: F401
    ArchConfig,
    LONG_CONTEXT_ARCHS,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeCell,
    runnable_cells,
)
from .registry import ARCH_IDS, CONFIGS, get_config  # noqa: F401
