"""Job model: LLM training job specs and analytic execution profiles.

The scheduler consumes a job as the paper does (§III-A): stage compute time
``t_comp^j(k)`` under ``k`` pipeline stages, micro-batch count ``M_j``,
inter-stage activation size ``A_j``, iteration count ``I_j`` and the derived
minimum bandwidth requirement ``b_j = A_j / t_comp^j(L_j)``.

Profiles are *analytic* (no hardware in the loop): FLOPs per micro-batch are
``2 · N_params · tokens`` for the forward pass, stage time divides by the
stage count with a linear efficiency-decay term modelling the diminishing
returns the paper attributes to skinny stages (§III-B2), plus a fixed
per-stage overhead.  The same model powers ``K* = argmin_k t_iter(k)``
(Eq. 13).  The data-plane cross-check of this analytic model against XLA's
``cost_analysis()`` lives in ``repro.models.profile``.
"""

from __future__ import annotations

import dataclasses
import math
from functools import cached_property, lru_cache
from typing import Optional

import numpy as np

#: Effective per-GPU throughput (FLOP/s) used by the simulator's timing model.
#: The paper's Fig. 1 arithmetic (50 ms/μbatch for Llama-70B stages) implies
#: A100-class effective throughput; see DESIGN.md "assumptions changed".
DEFAULT_GPU_FLOPS = 140e12
#: Per-stage fixed overhead per micro-batch (s): launch/norm/pipeline glue.
DEFAULT_STAGE_OVERHEAD = 4e-3
#: Linear efficiency decay per extra stage (skinnier stages run less
#: efficiently on the MXU/SM — the paper's "diminishing returns").
DEFAULT_EFFICIENCY_DECAY = 0.003
#: Slowdown at memory-starved allocations: as k approaches the memory floor,
#: activation recomputation / offloading inflates stage time by up to this
#: fraction (k = min_gpus => 1 + penalty; k >= comfort => 1).  At the floor
#: the optimizer states barely fit, so full remat + host offload ~ 2.5x.
DEFAULT_REMAT_PENALTY = 1.5
#: Memory comfort multiple: allocations above ``comfort * min_gpus`` hold all
#: activations resident (no remat penalty).
DEFAULT_MEMORY_COMFORT = 3.0
#: Hybrid PP x TP: a pipeline stage may span up to this many GPUs
#: (tensor-parallel within the stage), so a job can use up to
#: ``tp_max * n_layers`` GPUs — the regime where large jobs outgrow any
#: single region and must pipeline across the WAN (the paper's premise).
DEFAULT_TP_MAX = 2
#: Per-GPU efficiency loss per extra tensor-parallel way (all-reduce tax).
DEFAULT_TP_PENALTY = 0.10
#: Accelerator board power draw (kW) for electricity-cost accounting.
DEFAULT_GPU_KW = 0.30
#: Usable accelerator memory (bytes) for the minimum-stage-count bound.
DEFAULT_GPU_MEMORY = 44e9
#: Bytes of state per parameter: bf16 weights+grads (4) + fp32 Adam m/v (8)
#: + fp32 master copy (4).
BYTES_PER_PARAM = 16.0

#: Process-wide memo tables for invariants that are pure functions of the
#: model architecture + hardware knobs (``JobProfile._timing_key``), not of
#: job identity: the ``K*`` argmin scan and the decision-kernel decay
#: tables.  Workloads cycle a handful of model templates across thousands
#: of jobs, so sharing these turns O(jobs) scalar scans into O(templates).
_KSTAR_CACHE: dict = {}
_DECAY_TAB_CACHE: dict = {}

#: Timing backends a ``JobSpec`` may select (the ``TimingModel`` seam in
#: ``core/timing.py``): the closed-form Eq. (1) model, or the discrete
#: microbatch-level planner (``core/microplan``).
TIMING_MODELS = ("analytic", "microplan")
#: Pipeline schedules the microplan backend can price (``core/microplan``).
#: ``synthesized`` is not a fixed template: the planner searches for a
#: per-topology schedule (see ``core/microplan/planner.py``).
PIPELINE_SCHEDULES = (
    "gpipe", "1f1b", "interleaved", "gpipe-overlap", "synthesized"
)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Minimal architecture descriptor the timing model needs (Table III)."""

    name: str
    n_params: float
    n_layers: int
    hidden: int
    batch_size: int
    seq_len: int = 2048
    microbatch_seqs: int = 1  # sequences per micro-batch (GPipe grain)

    def __post_init__(self) -> None:
        if self.batch_size < 1:
            raise ValueError("batch_size must be positive")
        if self.microbatch_seqs < 1:
            raise ValueError("microbatch_seqs must be positive")
        if self.batch_size % self.microbatch_seqs:
            raise ValueError(
                f"batch_size={self.batch_size} is not divisible by "
                f"microbatch_seqs={self.microbatch_seqs}: "
                f"{self.batch_size % self.microbatch_seqs} sequences per "
                "iteration would be silently dropped"
            )

    @property
    def microbatches(self) -> int:
        """``M_j``: micro-batches per iteration (exact — divisibility is
        validated at construction)."""
        return self.batch_size // self.microbatch_seqs

    @property
    def tokens_per_microbatch(self) -> int:
        return self.microbatch_seqs * self.seq_len

    @property
    def activation_bytes(self) -> float:
        """``A_j``: bf16 activation tensor crossing a stage boundary."""
        return float(self.microbatch_seqs * self.seq_len * self.hidden * 2)


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """A training job: model + dataset scale (+ submission time).

    ``timing_model`` selects the backend that prices this job's placements
    (the ``TimingModel`` seam, ``core/timing.py``); ``pipeline_schedule``
    picks the microbatch schedule the ``microplan`` backend plans.  The
    defaults reproduce the seed's closed-form Eq. (1) behavior bit-exactly.
    """

    job_id: int
    model: ModelSpec
    iterations: int
    submit_time: float = 0.0
    timing_model: str = "analytic"
    pipeline_schedule: str = "gpipe"

    def __post_init__(self) -> None:
        if self.iterations <= 0:
            raise ValueError("iterations must be positive")
        if self.timing_model not in TIMING_MODELS:
            raise ValueError(
                f"unknown timing model {self.timing_model!r} "
                f"(have: {TIMING_MODELS})"
            )
        if self.pipeline_schedule not in PIPELINE_SCHEDULES:
            raise ValueError(
                f"unknown pipeline schedule {self.pipeline_schedule!r} "
                f"(have: {PIPELINE_SCHEDULES})"
            )


class JobProfile:
    """Analytic ``t_comp``/``t_iter`` model for one job (Eqs. 1, 13).

    Scheduling invariants — ``E_j(1)``, ``b_j`` at a given ``k``, and every
    ``t_comp(k)`` lookup — are pure functions of the construction parameters,
    so they are memoized on the profile: the priority ranker and Pathfinder
    hit them thousands of times per simulation (see DESIGN.md).  The
    ``*_uncached`` variants recompute from scratch and exist so the legacy
    reference engine can reproduce the seed engine's per-call cost profile.

    Parameters
    ----------
    gpu_flops: effective sustained FLOP/s of one GPU.
    stage_overhead: fixed seconds per stage per micro-batch.
    efficiency_decay: fractional slowdown per extra stage.
    """

    def __init__(
        self,
        spec: JobSpec,
        *,
        gpu_flops: float = DEFAULT_GPU_FLOPS,
        stage_overhead: float = DEFAULT_STAGE_OVERHEAD,
        efficiency_decay: float = DEFAULT_EFFICIENCY_DECAY,
        remat_penalty: float = DEFAULT_REMAT_PENALTY,
        memory_comfort: float = DEFAULT_MEMORY_COMFORT,
        tp_max: int = DEFAULT_TP_MAX,
        tp_penalty: float = DEFAULT_TP_PENALTY,
        gpu_memory: float = DEFAULT_GPU_MEMORY,
        gpu_kw: float = DEFAULT_GPU_KW,
    ) -> None:
        self.spec = spec
        self.gpu_flops = gpu_flops
        self.stage_overhead = stage_overhead
        self.efficiency_decay = efficiency_decay
        self.remat_penalty = remat_penalty
        self.memory_comfort = memory_comfort
        self.tp_max = tp_max
        self.tp_penalty = tp_penalty
        self.gpu_memory = gpu_memory
        self.gpu_kw = gpu_kw
        # Memo tables for the per-job scheduling invariants (see class doc).
        self._t_comp_cache: dict = {}
        self._bw_req_cache: dict = {}
        self._single_exec: Optional[float] = None
        # Hardware-override memo tables: heterogeneous placements evaluate
        # t_comp / b_j / the memory floor against the accelerator type
        # actually granted (keyed by the override value).
        self._t_comp_hw_cache: dict = {}
        self._min_gpus_hw_cache: dict = {}
        # Decay-factor tables for the batched decision kernels, keyed by
        # table length (``core/kernels_decide`` pads lengths to buckets so
        # the jitted kernels compile once per bucket, not once per K*).
        self._decay_tab_cache: dict = {}

    @cached_property
    def _timing_key(self) -> tuple:
        """Everything the placement-agnostic timing invariants (``t_comp``,
        ``t_iter_ideal``, ``K*``, the decay table) depend on — the model
        architecture plus the hardware/efficiency knobs, *not* the job
        identity (submit time, iterations, dataset).  Workloads cycle a
        handful of model templates across thousands of jobs, so these
        invariants are shared process-wide under this key."""
        m = self.spec.model
        return (
            m.n_params,
            m.n_layers,
            m.hidden,
            m.batch_size,
            m.seq_len,
            m.microbatch_seqs,
            self.gpu_flops,
            self.stage_overhead,
            self.efficiency_decay,
            self.remat_penalty,
            self.memory_comfort,
            self.tp_max,
            self.tp_penalty,
            self.gpu_memory,
        )

    # ------------------------------------------------------------- primitives
    @property
    def fwd_flops_per_microbatch(self) -> float:
        m = self.spec.model
        return 2.0 * m.n_params * m.tokens_per_microbatch

    def _memory_pressure(self, k: int) -> float:
        """Remat/offload slowdown for memory-tight allocations.  Ramps from
        ``1 + remat_penalty`` at the memory floor down to 1.0 once the job has
        twice the floor (comfortable activation headroom)."""
        floor = self.min_gpus
        comfort = min(
            max(floor + 1, int(round(self.memory_comfort * floor))),
            self.max_stages,
        )
        if k >= comfort or comfort == floor:
            return 1.0
        frac = (comfort - k) / (comfort - floor)
        return 1.0 + self.remat_penalty * max(0.0, min(1.0, frac))

    def pipeline_depth(self, k: int) -> int:
        """Stages used by ``k`` GPUs: capped at one layer per stage; beyond
        that extra GPUs widen stages tensor-parallel-wise."""
        return min(k, self.max_stages)

    def t_comp(self, k: int) -> float:
        """Memoized ``t_comp(k)`` — see ``_t_comp_raw`` for the model."""
        cached = self._t_comp_cache.get(k)
        if cached is None:
            cached = self._t_comp_raw(k)
            self._t_comp_cache[k] = cached
        return cached

    def _t_comp_raw(self, k: int, gpu_flops: Optional[float] = None) -> float:
        """Per-stage forward time of one micro-batch with ``k`` GPUs total.

        The trailing ``·2`` of Eq. (1) accounts for the (symmetric) backward
        pass, so ``t_comp`` here is forward-only, as in the paper.  Three
        efficiency terms bracket the useful regime: a linear decay for many
        skinny stages (diminishing returns, §III-B2), a memory-pressure ramp
        near the floor (remat/offload), and a tensor-parallel tax once stages
        widen past one GPU.  ``gpu_flops`` overrides the profile's reference
        throughput (heterogeneous placements evaluate against the granted
        accelerator type); ``None`` keeps the reference hardware.
        """
        if k < 1:
            raise ValueError("GPU count must be >= 1")
        flops = self.gpu_flops if gpu_flops is None else gpu_flops
        return (
            self.fwd_flops_per_microbatch / (k * flops)
        ) * self._decay_factor(k) + self.stage_overhead

    def _decay_factor(self, k: int) -> float:
        """Combined efficiency multiplier of ``t_comp`` at ``k`` GPUs: linear
        skinny-stage decay × memory-pressure ramp × tensor-parallel tax.
        Factored out of ``_t_comp_raw`` (identical float operations) so the
        batched decision kernels can evaluate ``t_comp`` at any (k, FLOPS)
        pair from a per-job table built by this scalar code — the
        bit-exactness anchor for ``core/kernels_decide``."""
        depth = self.pipeline_depth(k)
        decay = 1.0 + self.efficiency_decay * (depth - 1)
        decay *= self._memory_pressure(k)
        if k > depth:  # tensor-parallel widening
            decay *= 1.0 + self.tp_penalty * (k / depth - 1.0)
        return decay

    def decay_table(self, length: int) -> np.ndarray:
        """Read-only vector of ``_decay_factor(g)`` for ``g`` in
        ``[1, length)`` (entry 0 is an unused placeholder: allocations are
        never empty).  Memoized per length — the decision kernels request
        bucket-padded lengths, so a profile typically builds one table ever."""
        tab = self._decay_tab_cache.get(length)
        if tab is None:
            key = (self._timing_key, length)
            tab = _DECAY_TAB_CACHE.get(key)
            if tab is None:
                tab = np.empty(length, dtype=np.float64)
                tab[0] = 1.0
                for g in range(1, length):
                    tab[g] = self._decay_factor(g)
                tab.setflags(write=False)
                _DECAY_TAB_CACHE[key] = tab
            self._decay_tab_cache[length] = tab
        return tab

    def t_comp_hw(self, k: int, gpu_flops: Optional[float] = None) -> float:
        """``t_comp(k)`` under an accelerator-type FLOPS override; ``None``
        (or the reference value itself) takes the memoized default path
        bit-exactly — the homogeneous-parity guarantee."""
        if gpu_flops is None or gpu_flops == self.gpu_flops:
            return self.t_comp(k)
        key = (k, gpu_flops)
        cached = self._t_comp_hw_cache.get(key)
        if cached is None:
            cached = self._t_comp_raw(k, gpu_flops)
            self._t_comp_hw_cache[key] = cached
        return cached

    def bandwidth_requirement_hw(
        self, k: int, gpu_flops: Optional[float] = None
    ) -> float:
        """``b_j = A_j / t_comp^j(k)`` against the granted hardware."""
        if gpu_flops is None or gpu_flops == self.gpu_flops:
            return self.bandwidth_requirement(k)
        return self.spec.model.activation_bytes / self.t_comp_hw(k, gpu_flops)

    def min_gpus_for_memory(self, gpu_memory: Optional[float] = None) -> int:
        """Memory floor against a granted accelerator type's usable memory;
        ``None`` (or the reference value) is the memoized ``min_gpus``."""
        if gpu_memory is None or gpu_memory == self.gpu_memory:
            return self.min_gpus
        cached = self._min_gpus_hw_cache.get(gpu_memory)
        if cached is None:
            need = self.spec.model.n_params * BYTES_PER_PARAM
            cached = max(
                1, min(self.max_stages, math.ceil(need / gpu_memory))
            )
            self._min_gpus_hw_cache[gpu_memory] = cached
        return cached

    def t_iter_ideal(self, k: int) -> float:
        """Eq. (1) with zero inter-stage communication (placement-agnostic)."""
        m = self.spec.model
        tc = self.t_comp(k)
        return (self.pipeline_depth(k) * tc + (m.microbatches - 1) * tc) * 2.0

    @cached_property
    def max_stages(self) -> int:
        """At most one transformer layer per pipeline stage."""
        return self.spec.model.n_layers

    @cached_property
    def max_gpus(self) -> int:
        """Widest useful allocation (tp_max-way stages on every layer)."""
        return self.tp_max * self.max_stages

    @cached_property
    def min_gpus(self) -> int:
        """Memory floor: the model state must fit across the stages."""
        need = self.spec.model.n_params * BYTES_PER_PARAM
        return max(1, min(self.max_stages, math.ceil(need / self.gpu_memory)))

    @lru_cache(maxsize=None)
    def optimal_gpus(self, cluster_cap: Optional[int] = None) -> int:
        """``K* = argmin_k t_iter(k)`` (Eq. 13), capped by ``max_gpus`` and,
        optionally, total cluster size.  The scan is shared process-wide
        across profiles with the same model/hardware invariants
        (``_timing_key``): ``t_iter_ideal`` never reads job identity, so
        ten thousand jobs cycling eight model templates pay eight scans."""
        hi = self.max_gpus if cluster_cap is None else min(
            self.max_gpus, max(1, cluster_cap)
        )
        lo = self.min_gpus
        if lo >= hi:
            return hi
        key = (self._timing_key, lo, hi)
        cached = _KSTAR_CACHE.get(key)
        if cached is None:
            best_k, best_t = lo, self.t_iter_ideal(lo)
            for k in range(lo + 1, hi + 1):
                t = self.t_iter_ideal(k)
                if t < best_t:
                    best_k, best_t = k, t
            cached = _KSTAR_CACHE[key] = best_k
        return cached

    def bandwidth_requirement(self, k: int) -> float:
        """``b_j = A_j / t_comp^j(k)`` (bytes/s) — the minimum per-link rate at
        which inter-stage traffic keeps up with compute (§III-A)."""
        cached = self._bw_req_cache.get(k)
        if cached is None:
            cached = self.spec.model.activation_bytes / self.t_comp(k)
            self._bw_req_cache[k] = cached
        return cached

    def demand_at_cap(self, cluster_cap: int) -> float:
        """``b_j`` evaluated at ``K*(cluster_cap)`` — the quantity Eq. (10)
        normalizes over the pending queue; memoized via the two caches."""
        return self.bandwidth_requirement(self.optimal_gpus(cluster_cap))

    # -------------------------------------------------------------- estimates
    def single_gpu_execution(self) -> float:
        """``E_j(1)`` for the computation-intensity metric (Eq. 9)."""
        if self._single_exec is None:
            self._single_exec = self.single_gpu_execution_uncached()
        return self._single_exec

    # ---------------------------------------------------- uncached reference
    def single_gpu_execution_uncached(self) -> float:
        """``E_j(1)`` recomputed from scratch (legacy-engine cost profile)."""
        return self.spec.iterations * (
            (self.pipeline_depth(1) * self._t_comp_raw(1)
             + (self.spec.model.microbatches - 1) * self._t_comp_raw(1)) * 2.0
        )

    def bandwidth_requirement_uncached(self, k: int) -> float:
        """``b_j`` recomputed from scratch (legacy-engine cost profile)."""
        return self.spec.model.activation_bytes / self._t_comp_raw(k)

    def power_cost_rate(
        self,
        price_kwh: float,
        n_gpus: int,
        gpu_kw: Optional[float] = None,
    ) -> float:
        """$/second of ``n_gpus`` drawing board power at ``price_kwh``;
        ``gpu_kw`` overrides the reference board power (per-type draw on
        heterogeneous placements)."""
        kw = self.gpu_kw if gpu_kw is None else gpu_kw
        return price_kwh * kw * n_gpus / 3600.0
