"""Baseline schedulers from the paper's evaluation (§IV-A).

- **LCF**   (industrial, cost-first):  FCFS; whole job in the single cheapest
  region with enough free GPUs (capped at ``K*``).
- **LDF**   (industrial, delay-first): FCFS; whole job in the region with the
  most free GPUs.
- **CR-LCF** (cross-region cost-first, TanGo-style): FCFS; chains regions in
  ascending-price order, filling each before moving on, until ``K*``.
- **CR-LDF** (cross-region delay-first, decentralized-training-style): FCFS;
  seeds at the largest free region and greedily follows the
  highest-residual-bandwidth link, filling regions along the way.

The CR baselines honour the hard bandwidth ledger (Eq. 6) — an edge with no
residual bandwidth is unusable, and an edge whose residual cannot even reach
``bubble_tolerance × t_comp`` worth of transfer rate is rejected — but unlike
BACE-Pipe's Pathfinder they do *not* insist on ``t_comm ≤ t_comp``, so their
pipelines can come out communication-bound ("throttled by suboptimal
inter-region links", §IV-B).

Under a non-default timing backend (``JobSpec.timing_model``), the
per-edge heuristic gains a schedule-aware companion: the finished chain is
priced by the active ``TimingModel`` and rejected when the modeled iteration
exceeds ``(1 + bubble_tolerance) ×`` the zero-communication ideal — the same
tolerance, applied to the *planned* bubble instead of a per-edge proxy.
With the default ``analytic`` backend behavior is unchanged (golden/parity
surface).

The cross-region baselines model the *rigid* job abstraction the paper
ascribes to them (§II-A, on TanGo-style schedulers: "fixed resource
requirements per job... prevents schedulers from dynamically leveraging
additional available resources"): a CR job demands its full ``K*`` GPUs and
waits otherwise.  The industrial single-region baselines are
capacity-flexible but region-bound (Fig. 1 semantics).  BACE-Pipe's flexible
``[min, K*]`` multi-region allocation is part of the paper's contribution.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .cluster import ClusterState
from .job import JobProfile
from .placement import Placement, build_placement
from .scheduler import SchedulingPolicy, fcfs_order
from .timing import iteration_time

#: A naive scheduler still refuses edges slower than this many compute slots.
DEFAULT_BUBBLE_TOLERANCE = 8.0


def _single_region(
    profile: JobProfile,
    cluster: ClusterState,
    *,
    by_price: bool,
) -> Optional[Placement]:
    k = max(
        profile.optimal_gpus(cluster.total_gpus()),
        profile.min_gpus,
    )
    # Industrial single-region policies are capacity-flexible (Fig. 1: LCF
    # hands job P whatever the cheapest region holds) but *region-bound*:
    # parallelism is capped by one region's free pool.
    feasible = [
        r for r, free in cluster.free_gpus.items() if free >= profile.min_gpus
    ]
    if not feasible:
        return None
    if by_price:
        region = min(feasible, key=lambda r: (cluster.price(r), r))
    else:
        region = max(feasible, key=lambda r: (cluster.free_gpus[r], r))
    n = min(cluster.free_gpus[region], k)
    return build_placement(profile, cluster, [region], {region: n})


class LCFPolicy(SchedulingPolicy):
    name = "lcf"
    strict_fcfs = True
    ordering_kind = "fcfs"

    def order(self, pending, cluster, now):
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        return _single_region(profile, cluster, by_price=True)


class LDFPolicy(SchedulingPolicy):
    name = "ldf"
    strict_fcfs = True
    ordering_kind = "fcfs"

    def order(self, pending, cluster, now):
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        return _single_region(profile, cluster, by_price=False)


def _chain_placement(
    profile: JobProfile,
    cluster: ClusterState,
    ordered_regions: List[str],
    *,
    bubble_tolerance: float = DEFAULT_BUBBLE_TOLERANCE,
) -> Optional[Placement]:
    """Greedy fill along a fixed region order; edges must carry *some* usable
    bandwidth but need not keep communication off the critical path."""
    k = max(profile.optimal_gpus(cluster.total_gpus()), profile.min_gpus)
    k = min(k, cluster.total_gpus())  # rigid sizing at submission
    act = profile.spec.model.activation_bytes
    path: List[str] = []
    alloc: Dict[str, int] = {}
    g = 0
    for r in ordered_regions:
        if g >= k:
            break
        free = cluster.free_gpus.get(r, 0)
        if free < 1:
            continue
        if path:
            avail = cluster.available_bandwidth(path[-1], r)
            # usable iff the edge can move one activation within the
            # tolerance window (a naive-but-not-insane scheduler's check).
            if avail <= 0.0 or act / avail > bubble_tolerance * profile.t_comp(
                min(k, g + free)
            ):
                continue
        take = min(free, k - g)
        path.append(r)
        alloc[r] = take
        g += take
    if g < k:
        return None  # rigid demand: the chain must reach the full K*
    try:
        placement = build_placement(profile, cluster, path, alloc)
    except ValueError:
        return None
    if profile.spec.timing_model != "analytic":
        # Schedule-aware bubble gate (see module docstring): the active
        # timing backend prices the whole chain; a pipeline whose planned
        # iteration blows past the tolerance-scaled zero-comm ideal is as
        # unusable as a chain the per-edge heuristic would have refused.
        if iteration_time(profile, placement) > (
            1.0 + bubble_tolerance
        ) * profile.t_iter_ideal(g):
            return None
    return placement


class CRLCFPolicy(SchedulingPolicy):
    """Cross-region LCF: ascending electricity price defines the chain."""

    name = "cr-lcf"
    strict_fcfs = True
    ordering_kind = "fcfs"

    def __init__(self, bubble_tolerance: float = DEFAULT_BUBBLE_TOLERANCE):
        self.bubble_tolerance = bubble_tolerance

    def order(self, pending, cluster, now):
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        by_price = sorted(
            cluster.region_names(), key=lambda r: (cluster.price(r), r)
        )
        return _chain_placement(
            profile, cluster, by_price, bubble_tolerance=self.bubble_tolerance
        )


class CRLDFPolicy(SchedulingPolicy):
    """Cross-region LDF: largest region seeds, highest-bandwidth expansion."""

    name = "cr-ldf"
    strict_fcfs = True
    ordering_kind = "fcfs"

    def __init__(self, bubble_tolerance: float = DEFAULT_BUBBLE_TOLERANCE):
        self.bubble_tolerance = bubble_tolerance

    def order(self, pending, cluster, now):
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        names = [r for r in cluster.region_names() if cluster.free_gpus[r] > 0]
        if not names:
            return None
        seed = max(names, key=lambda r: (cluster.free_gpus[r], r))
        order = [seed]
        tail = seed
        while len(order) < len(names):
            rest = [
                r
                for r in names
                if r not in order and cluster.available_bandwidth(tail, r) > 0.0
            ]
            if not rest:
                break
            nxt = max(
                rest, key=lambda r: (cluster.available_bandwidth(tail, r), r)
            )
            order.append(nxt)
            tail = nxt
        return _chain_placement(
            profile, cluster, order, bubble_tolerance=self.bubble_tolerance
        )


ALL_BASELINES = (LCFPolicy, LDFPolicy, CRLCFPolicy, CRLDFPolicy)
