"""Batched decision kernels for the scheduling hot path.

The Pathfinder (Alg. 1) makes two kinds of decisions thousands of times per
simulated second of control-plane time: *candidate scoring* (which regions
can host a job at all — free-GPU / FLOPS / memory feasibility, electricity
pricing) and the *Prim frontier walk* (grow a pipeline path from every seed
region along the highest-residual-bandwidth links while Eq. 6 admission
``A / b_tmp <= t_comp`` holds).  PR 1 vectorized the per-seed walk's inner
lookups but kept one Python loop per seed; this module batches the walk
itself — **all seed regions advance one hop per step** against the dense
R×R residual matrix, so a full Alg. 1 Phase 2 is a handful of array steps
instead of O(R) Python walks.

Two interchangeable backends implement the same kernels:

* ``numpy`` (default) — plain float64 array programs, no dependencies.
* ``jax``  — the identical program staged through ``jax.jit`` so the whole
  frontier loop runs as one fused XLA call per placement decision.  Kernels
  trace under ``jax.experimental.enable_x64`` (scoped, never the global
  flag — the data-plane tests rely on jax's float32 default), so every
  arithmetic op is the same IEEE float64 op the numpy twin executes, and
  decisions — including all tie-breaks — are bit-identical.  When jax is
  missing the backend degrades gracefully to numpy (one warning).

Bit-exactness contract (enforced by ``tests/test_decision_backend.py`` and
the engine-parity suite): for any inputs, both backends return identical
arrays, and the Pathfinder built on them makes the exact decisions of the
seed reference in ``legacy.py``.  To that end the kernels reproduce the
scalar code's operation *order*: ``t_comp`` is evaluated as
``fwd / (g · flops) · decay(g) + overhead`` (the expression in
``JobProfile._t_comp_raw``) with ``decay(g)`` read from a per-job table the
profile computes with the scalar code itself.
"""

from __future__ import annotations

import warnings
from typing import Optional, Tuple

import numpy as np

#: Decision backends the Pathfinder/scheduler seam accepts.
DECISION_BACKENDS = ("numpy", "jax")
DEFAULT_DECISION_BACKEND = "numpy"

#: Pad per-job decay tables to multiples of this many entries so the jax
#: kernels compile once per (region count, table bucket) instead of once per
#: distinct ``K*``.
TABLE_BUCKET = 64


def decay_table_len(k: int) -> int:
    """Bucket-padded decay-table length covering GPU counts ``0..k``."""
    return (k // TABLE_BUCKET + 1) * TABLE_BUCKET

_jax_state: Optional[tuple] = None  # (prim_jit, jnp, enable_x64) or ()
_warned_no_jax = False


def jax_available() -> bool:
    """True when the jax decision kernels can be used in this process."""
    return _load_jax() is not None


def resolve_backend(name: str) -> str:
    """Validate a backend name; ``"jax"`` degrades to ``"numpy"`` (with a
    one-time warning) when jax is not importable."""
    if name not in DECISION_BACKENDS:
        raise ValueError(
            f"unknown decision backend {name!r} (have: {DECISION_BACKENDS})"
        )
    if name == "jax" and _load_jax() is None:
        global _warned_no_jax
        if not _warned_no_jax:
            warnings.warn(
                'decision_backend="jax" requested but jax is not '
                "installed; falling back to the numpy kernels",
                RuntimeWarning,
                stacklevel=2,
            )
            _warned_no_jax = True
        return "numpy"
    return name


# ------------------------------------------------------------- phase 1 score
def phase1_pick(
    free: np.ndarray, prices: np.ndarray, name_rank: np.ndarray, k: int
) -> int:
    """Fused single-region scoring (Alg. 1 Phase 1): among regions with
    ``free >= k`` pick the cheapest, ties broken by smallest region name.
    Returns the region index, or -1 when no single region fits.

    One masked argmin over the region axis; already a single fused array
    program on the numpy backend, and cheaper than a device dispatch at
    control-plane sizes — both backends share it.
    """
    mask = free >= k
    if not mask.any():
        return -1
    idxs = np.flatnonzero(mask)
    p = prices[idxs]
    cheapest = idxs[p == p.min()]
    return int(cheapest[np.argmin(name_rank[cheapest])])


# -------------------------------------------------------- prim frontier walk
def _prim_expand_numpy(
    avail: np.ndarray,
    free: np.ndarray,
    name_rank: np.ndarray,
    flops_vec: np.ndarray,
    decay_tab: np.ndarray,
    fwd: float,
    overhead: float,
    act: float,
    k: int,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All-seeds Prim expansion, numpy backend.  See ``prim_expand``."""
    n = avail.shape[0]
    seeds = np.arange(n)
    has_free = free > 0

    visited = np.eye(n, dtype=bool)
    tail = seeds.copy()
    g = np.minimum(free, k)
    b_min = np.full(n, np.inf)
    f_min = flops_vec.copy()
    path_len = np.where(has_free, 1, 0).astype(np.int64)
    paths = np.full((n, n), -1, dtype=np.int64)
    paths[:, 0] = seeds
    # A seed keeps expanding while it has free GPUs, still wants more than it
    # aggregated, and has room for another hop (the scalar loop's condition
    # ``len(path) < n_regions and g < k``).
    active = has_free & (g < k) & (n > 1)

    col = seeds[None, :]
    # Lanes without a candidate this step compute garbage (nxt=0, b_tmp=0,
    # g_new=g) that the ``adv`` mask discards; silence the float warnings
    # those masked divisions would emit.
    with np.errstate(divide="ignore", invalid="ignore"):
        return _prim_steps_numpy(
            avail, free, name_rank, flops_vec, decay_tab, fwd, overhead, act,
            k, has_free, visited, tail, g, b_min, f_min, path_len, paths,
            active, seeds, col,
        )


def _prim_steps_numpy(
    avail, free, name_rank, flops_vec, decay_tab, fwd, overhead, act, k,
    has_free, visited, tail, g, b_min, f_min, path_len, paths, active, seeds,
    col,
):
    n = avail.shape[0]
    while active.any():
        rows = avail[tail]  # (S, R) residual bandwidth out of each tail
        cand = has_free[None, :] & ~visited & (rows > 0.0)
        vals = np.where(cand, rows, -np.inf)
        vmax = vals.max(axis=1)
        has_cand = np.isfinite(vmax)
        # max by (bandwidth, name): equal-bandwidth ties take the largest name
        tie = cand & (vals == vmax[:, None])
        nxt = np.where(tie, name_rank[None, :], -1).argmax(axis=1)
        b_tmp = np.minimum(b_min, rows[seeds, nxt])
        g_new = np.minimum(g + free[nxt], k)
        f_new = np.minimum(f_min, flops_vec[nxt])
        # Scalar op order (JobProfile._t_comp_raw): fwd/(g·f) · decay + ovh.
        t_cmp = fwd / (g_new * f_new) * decay_tab[g_new] + overhead
        # Alg. 1 line 13: communication must keep up with compute.
        admit = ~(act / b_tmp > t_cmp)
        adv = active & has_cand & admit
        if not adv.any():
            break
        sel = adv[:, None] & (col == nxt[:, None])
        visited |= sel
        paths = np.where(adv[:, None] & (col == path_len[:, None]),
                         nxt[:, None], paths)
        tail = np.where(adv, nxt, tail)
        b_min = np.where(adv, b_tmp, b_min)
        g = np.where(adv, g_new, g)
        f_min = np.where(adv, f_new, f_min)
        path_len = path_len + adv
        active = adv & (g < k) & (path_len < n)
    return g, path_len, paths


def _load_jax():
    """Lazy jax import + jit construction; caches (prim_jit, helpers)."""
    global _jax_state
    if _jax_state is not None:
        return _jax_state or None
    try:
        import jax
        import jax.numpy as jnp
        from jax import lax
        from jax.experimental import enable_x64
    except Exception:  # pragma: no cover - exercised on jax-less installs
        _jax_state = ()
        return None

    def _prim(avail, free, name_rank, flops_vec, decay_tab, fwd, overhead,
              act, k):
        n = avail.shape[0]
        seeds = jnp.arange(n)
        has_free = free > 0

        visited0 = jnp.eye(n, dtype=bool)
        g0 = jnp.minimum(free, k)
        path_len0 = jnp.where(has_free, 1, 0).astype(jnp.int64)
        paths0 = jnp.full((n, n), -1, dtype=jnp.int64).at[:, 0].set(seeds)
        active0 = has_free & (g0 < k) & (n > 1)
        state0 = (
            active0, visited0, seeds, g0, jnp.full(n, jnp.inf),
            flops_vec, path_len0, paths0,
        )

        def cond(state):
            return jnp.any(state[0])

        def body(state):
            active, visited, tail, g, b_min, f_min, path_len, paths = state
            rows = avail[tail]
            cand = has_free[None, :] & ~visited & (rows > 0.0)
            vals = jnp.where(cand, rows, -jnp.inf)
            vmax = vals.max(axis=1)
            has_cand = jnp.isfinite(vmax)
            tie = cand & (vals == vmax[:, None])
            nxt = jnp.where(tie, name_rank[None, :], -1).argmax(axis=1)
            b_tmp = jnp.minimum(b_min, rows[seeds, nxt])
            g_new = jnp.minimum(g + free[nxt], k)
            f_new = jnp.minimum(f_min, flops_vec[nxt])
            t_cmp = fwd / (g_new * f_new) * decay_tab[g_new] + overhead
            admit = ~(act / b_tmp > t_cmp)
            adv = active & has_cand & admit
            col = seeds[None, :]
            visited = visited | (adv[:, None] & (col == nxt[:, None]))
            paths = jnp.where(
                adv[:, None] & (col == path_len[:, None]),
                nxt[:, None], paths,
            )
            tail = jnp.where(adv, nxt, tail)
            b_min = jnp.where(adv, b_tmp, b_min)
            g = jnp.where(adv, g_new, g)
            f_min = jnp.where(adv, f_new, f_min)
            path_len = path_len + adv
            active = adv & (g < k) & (path_len < n)
            return (
                active, visited, tail, g, b_min, f_min, path_len, paths,
            )

        _, _, _, g, _, _, path_len, paths = lax.while_loop(
            cond, body, state0
        )
        return g, path_len, paths

    prim_jit = jax.jit(_prim)
    _jax_state = (prim_jit, jnp, enable_x64)
    return _jax_state


def _prim_expand_jax(avail, free, name_rank, flops_vec, decay_tab, fwd,
                     overhead, act, k):
    prim_jit, jnp, enable_x64 = _load_jax()
    # The x64 scope is per-call (it participates in the jit cache key), so
    # the kernels run in IEEE float64 without flipping jax's process-global
    # default dtype out from under the float32 data plane.
    with enable_x64():
        g, path_len, paths = prim_jit(
            avail, free, name_rank, flops_vec, decay_tab,
            float(fwd), float(overhead), float(act), int(k),
        )
        return (
            np.asarray(g, dtype=np.int64),
            np.asarray(path_len, dtype=np.int64),
            np.asarray(paths, dtype=np.int64),
        )


def prim_expand(
    avail: np.ndarray,
    free: np.ndarray,
    name_rank: np.ndarray,
    flops_vec: np.ndarray,
    decay_tab: np.ndarray,
    fwd: float,
    overhead: float,
    act: float,
    k: int,
    *,
    backend: str = DEFAULT_DECISION_BACKEND,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized Prim frontier: advance **every** seed region one hop per
    step via masked argmax on the residual R×R bandwidth matrix ``avail``.

    Per step and per seed: among unvisited regions with free GPUs and a
    positive-residual link out of the seed's current tail, follow the
    highest-bandwidth link (ties to the largest region name — the reference
    tie-break), provisionally extend the path, and admit the hop only while
    Eq. 6 holds: ``act / b_tmp <= t_comp(g_new)`` with ``b_tmp`` the running
    path-bottleneck bandwidth and ``t_comp`` evaluated at the running
    most-conservative granted FLOPS ``f_min`` (``flops_vec`` is constant =
    reference FLOPS on homogeneous clusters, making this exactly the
    homogeneous admission).  Seeds stop independently (masked updates); the
    walk ends when every seed has stopped or aggregated ``k`` GPUs.

    Returns ``(g, path_len, paths)`` aligned with the region axis: aggregated
    GPUs per seed, the seed's path length, and the visited region indices in
    hop order (``paths[s, :path_len[s]]``; -1 padding).  Seeds without free
    GPUs have ``path_len == 0`` and must be ignored by the caller.

    Decision-identical to the per-seed scalar walk in ``legacy.py`` — same
    float ops in the same order, same tie-breaks.  PR 1's per-seed early-exit
    bound (skip seeds that cannot beat the incumbent) is superseded by the
    caller masking finished candidates on their exact ``g`` — batching makes
    the *bound* obsolete but the *mask* exact.
    """
    if backend == "jax":
        return _prim_expand_jax(
            avail, free, name_rank, flops_vec, decay_tab, fwd, overhead, act,
            k,
        )
    return _prim_expand_numpy(
        avail, free, name_rank, flops_vec, decay_tab, fwd, overhead, act, k
    )


# ------------------------------------------------------- allocator cell order
def cheapest_fill_order(
    rates: np.ndarray, region_rank: np.ndarray, type_rank: np.ndarray
) -> np.ndarray:
    """Index permutation ordering allocator cells by (kW-inclusive $/s rate,
    region name, type name) — the deterministic pour order Alg. 2 shares with
    ``ClusterState.assign_types``.  Exact float compares, so the order is
    identical to the scalar ``sorted(..., key=(rate, region, type))``."""
    return np.lexsort((type_rank, region_rank, rates))
