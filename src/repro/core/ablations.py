"""Ablation variants of BACE-Pipe (paper §IV-E, Fig. 8).

- **w/o Priority**:   FCFS ordering, full Pathfinder + Cost-Min placement.
- **w/o Pathfinder**: dynamic priority ordering, CR-LDF placement.
- **w/o Cost-Min**:   dynamic priority + Pathfinder, uniform GPU spreading.
"""

from __future__ import annotations

from .allocator import uniform_allocate
from .baselines import CRLDFPolicy
from .legacy import legacy_find_placement, legacy_order_by_priority
from .pathfinder import find_placement
from .priority import order_by_priority
from .scheduler import BACEPipePolicy, SchedulingPolicy


class WithoutPriority(BACEPipePolicy):
    name = "bace-pipe-wo-priority"
    strict_fcfs = True  # FCFS without re-ordering blocks at the head

    def __init__(self) -> None:
        super().__init__(use_priority=False)


class WithoutPathfinder(SchedulingPolicy):
    name = "bace-pipe-wo-pathfinder"
    ordering_kind = "priority"

    def __init__(self) -> None:
        self._placer = CRLDFPolicy()

    def order(self, pending, cluster, now):
        return order_by_priority(pending, cluster)

    def place(self, profile, cluster):
        return self._placer.place(profile, cluster)

    def legacy_order(self, pending, cluster, now):
        return legacy_order_by_priority(pending, cluster)


class WithoutCostMin(SchedulingPolicy):
    name = "bace-pipe-wo-costmin"
    ordering_kind = "priority"

    def order(self, pending, cluster, now):
        return order_by_priority(pending, cluster)

    def place(self, profile, cluster):
        return find_placement(
            profile,
            cluster,
            allocator=uniform_allocate,
            backend=self.decision_backend,
        )

    def legacy_order(self, pending, cluster, now):
        return legacy_order_by_priority(pending, cluster)

    def legacy_place(self, profile, cluster):
        return legacy_find_placement(
            profile, cluster, allocator=uniform_allocate
        )


ALL_ABLATIONS = (WithoutPriority, WithoutPathfinder, WithoutCostMin)
