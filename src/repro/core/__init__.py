"""BACE-Pipe control plane: the paper's scheduling contribution.

Public API:
    ClusterState / Region          — geo-distributed infrastructure model
    ModelSpec / JobSpec / JobProfile — job + analytic timing profile
    Placement                      — a scheduling decision ``S_j``
    find_placement                 — Alg. 1 Pathfinder (+ Alg. 2 allocator)
    cost_min_allocate              — Alg. 2
    priority_scores                — Eqs. (9)–(12)
    BACEPipePolicy / baselines / ablations — pluggable policies
    simulate                       — event-driven multi-job simulator
    TimingModel / plan_schedule    — pluggable timing backends: closed-form
                                     Eq. (1) (``analytic``) or the discrete
                                     microbatch schedule planner
                                     (``microplan``, ``core/microplan``)
"""

from .accounting import SegmentLedger  # noqa: F401
from .ablations import (  # noqa: F401
    ALL_ABLATIONS,
    WithoutCostMin,
    WithoutPathfinder,
    WithoutPriority,
)
from .allocator import allocation_cost_rate, cost_min_allocate, uniform_allocate  # noqa: F401
from .baselines import (  # noqa: F401
    ALL_BASELINES,
    CRLCFPolicy,
    CRLDFPolicy,
    LCFPolicy,
    LDFPolicy,
)
from .cluster import (  # noqa: F401
    DEFAULT_GPU_TYPE,
    GBPS,
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    GpuPool,
    Region,
)
from .kernels_decide import (  # noqa: F401
    DECISION_BACKENDS,
    DEFAULT_DECISION_BACKEND,
    jax_available,
    resolve_backend,
)
from .job import (  # noqa: F401
    PIPELINE_SCHEDULES,
    TIMING_MODELS as TIMING_MODEL_NAMES,
    JobProfile,
    JobSpec,
    ModelSpec,
)
from .microplan import (  # noqa: F401
    PipelineTopology,
    PlanCacheInfo,
    PlanEvent,
    SchedulePlan,
    clear_plan_cache,
    plan_cache_info,
    plan_from_topology,
    plan_schedule,
    topology_from_placement,
)
from .legacy import (  # noqa: F401
    legacy_find_placement,
    legacy_order_by_priority,
    legacy_priority_scores,
)
from .pathfinder import find_placement, placement_feasible  # noqa: F401
from .placement import Placement, build_placement  # noqa: F401
from .priority import (  # noqa: F401
    bandwidth_sensitivity,
    computation_intensity,
    order_by_priority,
    priority_scores,
    score_array,
)
from .scheduler import (  # noqa: F401
    DEFAULT_RESTART_PENALTY_S,
    ENGINES,
    BACEPipePolicy,
    JobRecord,
    SchedulingPolicy,
    SimulationResult,
    Simulator,
    simulate,
)
from .timing import (  # noqa: F401
    TIMING_MODELS,
    AnalyticTimingModel,
    MicroplanTimingModel,
    TimingModel,
    analytic_iteration_time,
    average_price,
    bottleneck_delta,
    electricity_cost,
    execution_time,
    get_timing_model,
    iteration_time,
    placement_power_rate,
)
from .workloads import (  # noqa: F401
    DATASETS,
    GPU_CATALOG,
    TABLE_II_REGIONS,
    TABLE_III_MODELS,
    bursty_submit_times,
    diurnal_trace,
    hetero_fleet_cluster,
    link_flap_trace,
    motivation_cluster,
    motivation_profiles,
    paper_cluster,
    paper_jobs,
    paper_profiles,
    poisson_submit_times,
    price_spike_trace,
    random_fluctuation_trace,
    spot_fleet_cluster,
    spot_reclaim_trace,
)
from .scenarios import (  # noqa: F401
    SCENARIOS,
    Scenario,
    get_scenario,
    scenario_names,
)
