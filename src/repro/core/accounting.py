"""Piecewise segment accounting: the Eq. (4) cost ledger under env changes.

PR 2 froze a segment's $/s rate at placement time, so a mid-segment
electricity-price breakpoint never repriced running jobs — exactly wrong in
the dynamic regimes (price-spike, diurnal, mixed-stress) the scenario
registry exists to exercise.  This module replaces "project at start, back
out at preemption" with *piecewise integration over env breakpoints*:

* Every live run segment owns a :class:`SegmentLedger`.
* At each ``EnvUpdate`` that moves a price of a region the segment occupies,
  the simulator calls :meth:`SegmentLedger.reprice`, which closes the
  sub-interval ``[last_settle, t)`` at the then-current rate and opens a new
  one at the post-update rate.
* Completion and preemption call :meth:`SegmentLedger.settle`, which returns
  the exact accrued cost up to the event time — a sum of non-negative
  ``duration × rate`` terms, so a segment's cost can never go negative (the
  old back-out ``cost -= (finish - t) * rate`` could, when the restore window
  dominated a short segment).

Progress derives from the same ledger (:meth:`completed_iterations`): the
elapsed active time minus the leading restore window, floored to whole
checkpointed iterations — identical semantics to PR 2, now owned by the
accounting layer instead of being re-derived inline in ``preempt()``.

Static-parity contract (bit-identical): a segment that is never repriced
settles, at its projected finish, to the *placement-time projection*
``electricity_cost(..., execution_seconds=e)`` — the exact float the seed
engine charged — so static scenarios (and the legacy engine, which shares
this event loop) produce byte-identical costs and golden traces.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Union

from .cluster import ClusterState
from .job import JobProfile
from .placement import Placement
from .timing import electricity_cost, placement_power_rate


@dataclasses.dataclass
class SegmentLedger:
    """Cost + progress accounting for one live run segment.

    The ledger is a piecewise-constant rate integral: ``accrued`` holds the
    closed sub-intervals, ``[last_settle, now)`` accrues at ``rate`` (the
    live $/s of the placement, re-read from the cluster at every price
    breakpoint touching an occupied region).  ``projected_cost`` /
    ``projected_finish`` keep the placement-time projection so a
    never-repriced segment settles to the seed engine's exact float (see
    module docstring).
    """

    profile: JobProfile
    placement: Placement
    start: float
    #: Leading checkpoint-restore window (s) of a restarted segment: not
    #: training time, but GPUs are held, so Eq. 4 cost accrues for it.
    restore_s: float
    iteration_seconds: float
    projected_finish: float
    projected_cost: float
    rate: float
    accrued: float = 0.0
    last_settle: float = 0.0
    repriced: bool = False

    @classmethod
    def open(
        cls,
        profile: JobProfile,
        placement: Placement,
        cluster: ClusterState,
        *,
        start: float,
        restore_s: float,
        iteration_seconds: float,
        execution_seconds: float,
    ) -> "SegmentLedger":
        """Open a ledger at placement time, pricing the projection at the
        cluster's *current* (live-multiplier) prices."""
        return cls(
            profile=profile,
            placement=placement,
            start=start,
            restore_s=restore_s,
            iteration_seconds=iteration_seconds,
            projected_finish=start + execution_seconds,
            projected_cost=electricity_cost(
                profile, placement, cluster,
                execution_seconds=execution_seconds,
            ),
            rate=placement_power_rate(profile, placement, cluster),
            last_settle=start,
        )

    def reprice(
        self, t: float, cluster: ClusterState, regions: Iterable[str]
    ) -> bool:
        """Split the segment at breakpoint ``t`` if the price change touches
        an occupied region *and* actually moves the placement's $/s rate.

        Returns True when a new sub-interval was opened.  A breakpoint that
        leaves the rate bitwise unchanged (multiplier back to the same value,
        or only foreign regions listed) is skipped, so the accrual stays the
        single placement-time projection and settles bit-exactly.
        """
        if not any(r in self.placement.alloc for r in regions):
            return False
        new_rate = placement_power_rate(self.profile, self.placement, cluster)
        if new_rate == self.rate:
            return False
        self.accrued += (t - self.last_settle) * self.rate
        self.last_settle = t
        self.rate = new_rate
        self.repriced = True
        return True

    def settle(self, t: float) -> float:
        """Total accrued cost of this segment over ``[start, t)``.

        Never repriced + settled at the projected finish ⇒ the exact
        placement-time projection (static-parity contract).  Otherwise the
        piecewise sum, whose every term is ``duration ≥ 0 × rate ≥ 0`` — the
        ``cost >= 0`` simulator invariant follows structurally.
        """
        if not self.repriced and t == self.projected_finish:
            return self.projected_cost
        return self.accrued + (t - self.last_settle) * self.rate

    def telemetry(self) -> Dict[str, Union[float, bool]]:
        """Observational snapshot for the ``repro.obs`` settle record: the
        ledger's scalar state after :meth:`settle` ran.  Read-only — never
        feeds back into accounting."""
        return {
            "start": self.start,
            "restore_s": self.restore_s,
            "iteration_s": self.iteration_seconds,
            "projected_finish": self.projected_finish,
            "projected_cost": self.projected_cost,
            "rate_per_s": self.rate,
            "accrued": self.accrued,
            "last_settle": self.last_settle,
            "repriced": self.repriced,
        }

    def completed_iterations(self, t: float) -> int:
        """Whole checkpointed iterations trained by time ``t``: elapsed
        active time minus the leading restore window, floored."""
        trained = max(0.0, (t - self.start) - self.restore_s)
        return max(0, int(trained // self.iteration_seconds))

    def remaining_after_checkpoint(self, t: float, remaining: int) -> int:
        """Iterations still owed if the segment checkpoints at ``t``; never
        below 1 (a checkpoint mid-iteration discards the partial work) and
        never above ``remaining`` — migration cannot increase owed work."""
        return max(1, remaining - self.completed_iterations(t))
