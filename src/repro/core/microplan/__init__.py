"""Microbatch-level schedule planner (pluggable timing backend).

See ``planner.py`` for the event model and ``core/timing.py`` for the
``TimingModel`` seam that selects between the closed-form Eq. (1) backend
(``analytic``, the default) and this planner (``microplan``).
"""

from .planner import (  # noqa: F401
    DEFAULT_VIRTUAL_STAGES,
    PipelineTopology,
    PlanCacheInfo,
    PlanEvent,
    SchedulePlan,
    clear_plan_cache,
    plan_cache_info,
    plan_from_topology,
    plan_schedule,
    topology_from_placement,
)
