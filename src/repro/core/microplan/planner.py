"""Microbatch-level schedule planner: bubble-accurate pipeline timelines.

Where ``core/timing.py`` prices a placement with the closed-form GPipe
fill-drain formula of Eq. (1), this module *executes* the schedule: every
(stage, microbatch) compute slot and every stage-boundary transfer becomes an
operation on a resource, and iteration time is the makespan of the resulting
dependency graph.  That turns the paper's central quantity — the pipeline
bubble under heterogeneous per-hop WAN bandwidth — from an analytic scalar
into an inspectable event timeline, and opens schedule-level questions
(CrossPipe-style comm-overlapped cross-DC schedules, OptPipe-style
memory/schedule trade-offs) that a closed form cannot express.

Resource model
--------------
A placement maps to a :class:`PipelineTopology`:

* ``L`` pipeline stages (``JobProfile.pipeline_depth``), each a serially
  reused compute resource with per-microbatch forward/backward times;
* ``L-1`` stage boundaries, each an ordered group of *serial hop* resources —
  one hop per GPU boundary it covers (store-and-forward: hop ``h`` can carry
  microbatch ``i+1`` while hop ``h+1`` carries ``i``).  Intra-region hops
  ride the intra-region fabric; region crossings ride the WAN share the
  placement reserved.  Tensor-parallel-widened placements (``g > L``) fold
  their surplus per-GPU hops into the last boundary group, so the planner
  pays exactly the ``g-1`` transfers Eq. (1)'s fill term pays.
* Links are full duplex: forward activations and backward gradients on the
  same boundary use independent per-direction resources.

Schedules
---------
``gpipe``          fill/steady/drain, all forwards then all backwards; the
                   deterministic-tandem makespan reproduces Eq. (1)
                   (``analytic_iteration_time``) up to float association.
``1f1b``           one-forward-one-backward with the standard per-stage
                   warmup of ``min(M, L-1-s)``; same bubble as GPipe but the
                   per-stage activation stash drops from ``M`` to ``~L-s``.
``interleaved``    virtual stages: each physical stage runs ``v`` chunks of
                   ``1/v`` of its layers, microbatches group-cycled
                   (Megatron-style groups of ``L``); chunk wrap-around
                   transfers traverse a dedicated store-and-forward return
                   path over every hop (the WAN cost that makes interleaving
                   unattractive cross-region).
``gpipe-overlap``  the lockstep tick schedule the jax data plane
                   (``pipeline/gpipe.py``) executes by construction: ticks of
                   length ``Δ = max(t_comp, max hop)``, transfer of
                   microbatch ``i`` overlapping compute of ``i+1``;
                   ``M + L - 1`` ticks per direction (the data-plane parity
                   surface).

The op-level simulator is deterministic: per-resource FIFO order is fixed by
the schedule, an op starts at ``max(resource free, dependency finishes)``,
and an unexecutable schedule (a FIFO/dependency cycle) raises instead of
hanging.
"""

from __future__ import annotations

import dataclasses
import math
from functools import lru_cache
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

from ..cluster import INTRA_REGION_BANDWIDTH
from ..job import PIPELINE_SCHEDULES, JobProfile
from ..placement import Placement

#: Default virtual-stage (chunk) count for the ``interleaved`` schedule.
DEFAULT_VIRTUAL_STAGES = 2


class PlanEvent(NamedTuple):
    """One timeline slot: a compute op or a single-hop transfer."""

    kind: str        # fwd | bwd | fwd_comm | bwd_comm | wrap_fwd | wrap_bwd
    stage: int       # compute stage; boundary index for *_comm; -1 for wrap
    microbatch: int
    chunk: int       # virtual-stage chunk (0 outside `interleaved`)
    hop: int         # serial hop index within the boundary (-1 for compute)
    start: float
    end: float


@dataclasses.dataclass(frozen=True)
class PipelineTopology:
    """Schedule-independent description of one placed pipeline.

    ``boundaries[s]`` is the ordered tuple of serial hop times between stage
    ``s`` and ``s+1``.  ``egress`` is only populated for the degenerate
    single-stage-with-hops case (``max_stages == 1`` but several GPUs): the
    hops trail the stage so the tandem total still pays them, as Eq. (1)
    does.
    """

    n_microbatches: int
    stage_time_fwd: Tuple[float, ...]
    stage_time_bwd: Tuple[float, ...]
    boundaries: Tuple[Tuple[float, ...], ...]
    stage_overhead: float = 0.0
    egress: Tuple[float, ...] = ()

    def __post_init__(self) -> None:
        if self.n_microbatches < 1:
            raise ValueError("need at least one microbatch")
        if not self.stage_time_fwd:
            raise ValueError("need at least one stage")
        if len(self.stage_time_bwd) != len(self.stage_time_fwd):
            raise ValueError("fwd/bwd stage-time length mismatch")
        if len(self.boundaries) != max(0, len(self.stage_time_fwd) - 1):
            raise ValueError("need exactly n_stages - 1 boundary groups")
        if self.egress and len(self.stage_time_fwd) != 1:
            raise ValueError("egress hops only model the single-stage case")

    @property
    def n_stages(self) -> int:
        return len(self.stage_time_fwd)

    @property
    def all_hops(self) -> Tuple[float, ...]:
        """Every serial hop time, boundary-major (the Eq. (1) fill multiset)."""
        flat: List[float] = []
        for group in self.boundaries:
            flat.extend(group)
        flat.extend(self.egress)
        return tuple(flat)

    @property
    def bottleneck(self) -> float:
        """Slowest slot (compute or single hop) — Eq. (1)'s Δ with symmetric
        backward."""
        slots = list(self.stage_time_fwd) + list(self.stage_time_bwd)
        slots.extend(self.all_hops)
        return max(slots)


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """Materialized timeline + the summary the scheduler consumes."""

    schedule: str
    n_stages: int
    n_microbatches: int
    iteration_time: float
    #: Busy seconds per stage (compute only), forward + backward.
    stage_busy: Tuple[float, ...]
    #: Per-stage bubble fraction: 1 - busy / makespan.
    stage_bubble: Tuple[float, ...]
    #: Peak concurrently-stashed activations per stage, in units of one full
    #: per-stage microbatch activation (interleaved chunks count 1/v each).
    peak_activations_per_stage: Tuple[float, ...]
    #: Lockstep schedules only: ticks per direction (gpipe-overlap), matching
    #: the data plane's ``M + S - 1``.
    n_ticks: Optional[int] = None
    #: Materialized timeline (empty unless planned with keep_events=True).
    events: Tuple[PlanEvent, ...] = ()
    #: Dependency edges as (producer, consumer) indices into ``events``.
    #: Lockstep plans (``gpipe-overlap``) have no explicit edges: the global
    #: tick barrier is their entire dependency structure.
    edges: Tuple[Tuple[int, int], ...] = ()

    @property
    def bubble_fraction(self) -> float:
        """Aggregate bubble: idle fraction of all stage-seconds."""
        total = self.n_stages * self.iteration_time
        return 1.0 - sum(self.stage_busy) / total if total > 0.0 else 0.0

    @property
    def peak_activations(self) -> float:
        return max(self.peak_activations_per_stage)

    def summary(self) -> str:
        return (
            f"{self.schedule}: t_iter={self.iteration_time:.3f}s, "
            f"bubble={self.bubble_fraction:.3f}, "
            f"peak_acts={self.peak_activations:.1f}"
        )


# ---------------------------------------------------------------- op machine
class _OpSim:
    """Deterministic resource/dependency simulator.

    Ops are appended in per-resource FIFO order (the *schedule*); deps may be
    filled in later (``set_deps``) because cross-stage producers are built in
    a different pass.  ``run`` computes start/finish in O(ops + edges): an op
    executes once it is at the head of its resource queue and all its deps
    have finished, starting at ``max(resource free, dep finishes)``.
    """

    def __init__(self) -> None:
        self.dur: List[float] = []
        self.deps: List[Tuple[int, ...]] = []
        self.meta: List[Tuple[str, int, int, int, int]] = []
        self._res: List[int] = []
        self._res_ids: Dict[object, int] = {}
        self._queues: List[List[int]] = []

    def add(
        self,
        resource: object,
        duration: float,
        deps: Sequence[int],
        meta: Tuple[str, int, int, int, int],
    ) -> int:
        rid = self._res_ids.get(resource)
        if rid is None:
            rid = len(self._queues)
            self._res_ids[resource] = rid
            self._queues.append([])
        i = len(self.dur)
        self.dur.append(duration)
        self.deps.append(tuple(deps))
        self.meta.append(meta)
        self._res.append(rid)
        self._queues[rid].append(i)
        return i

    def set_deps(self, op: int, deps: Sequence[int]) -> None:
        self.deps[op] = tuple(deps)

    def run(self) -> Tuple[List[float], List[float]]:
        n = len(self.dur)
        dur, deps, res_of = self.dur, self.deps, self._res
        n_unmet = [len(d) for d in deps]
        dependents: List[List[int]] = [[] for _ in range(n)]
        for i, ds in enumerate(deps):
            for d in ds:
                dependents[d].append(i)
        pos = [0] * n
        for q in self._queues:
            for idx, i in enumerate(q):
                pos[i] = idx
        head = [0] * len(self._queues)
        res_free = [0.0] * len(self._queues)
        start = [0.0] * n
        finish = [0.0] * n
        stack = [q[0] for q in self._queues if q and n_unmet[q[0]] == 0]
        done = 0
        while stack:
            i = stack.pop()
            r = res_of[i]
            s = res_free[r]
            for d in deps[i]:
                f = finish[d]
                if f > s:
                    s = f
            start[i] = s
            f = s + dur[i]
            finish[i] = f
            res_free[r] = f
            done += 1
            head[r] += 1
            q = self._queues[r]
            if head[r] < len(q):
                j = q[head[r]]
                if n_unmet[j] == 0:
                    stack.append(j)
            for k in dependents[i]:
                n_unmet[k] -= 1
                if n_unmet[k] == 0 and pos[k] == head[res_of[k]]:
                    stack.append(k)
        if done != n:
            raise RuntimeError(
                f"unexecutable schedule: {n - done} of {n} ops deadlocked "
                "(FIFO order inconsistent with dependencies)"
            )
        return start, finish


# ----------------------------------------------------------- topology mapping
def topology_from_placement(
    profile: JobProfile, placement: Placement
) -> PipelineTopology:
    """Derive the planner topology from a concrete placement.

    Per-GPU boundary hops are reconstructed in *stage order* from
    ``Placement.stage_regions()`` (``Placement.comm_times`` is an unordered
    multiset); the multisets are identical, which is what keeps the gpipe
    plan on Eq. (1).  GPU slot ``i`` belongs to stage ``min(i, L-1)``, so a
    tensor-parallel-widened placement folds its surplus hops into the last
    boundary group.
    """
    g = placement.total_gpus
    depth = profile.pipeline_depth(g)
    # Typed grants price stages at the bottleneck granted hardware (None on
    # single-type clusters: the bit-exact reference path).
    t_comp = profile.t_comp_hw(g, placement.eff_flops)
    act = profile.spec.model.activation_bytes
    regions = placement.stage_regions()
    intra_hop = act / INTRA_REGION_BANDWIDTH
    hops: List[float] = []
    for i in range(g - 1):
        u, v = regions[i], regions[i + 1]
        hops.append(
            intra_hop if u == v else act / placement.reserved_bw[(u, v)]
        )
    if depth == 1:
        boundaries: Tuple[Tuple[float, ...], ...] = ()
        egress = tuple(hops)
    else:
        groups: List[List[float]] = [[] for _ in range(depth - 1)]
        for i, h in enumerate(hops):
            groups[min(i, depth - 2)].append(h)
        boundaries = tuple(tuple(grp) for grp in groups)
        egress = ()
    stage_times = (t_comp,) * depth
    return PipelineTopology(
        n_microbatches=profile.spec.model.microbatches,
        stage_time_fwd=stage_times,
        stage_time_bwd=stage_times,  # Eq. (1)'s symmetric backward
        boundaries=boundaries,
        stage_overhead=profile.stage_overhead,
        egress=egress,
    )


# ------------------------------------------------------------------ builders
def _build_gpipe(sim: _OpSim, topo: PipelineTopology) -> None:
    """Fill/steady/drain: all forwards (microbatch-ascending), then all
    backwards (descending, so drain starts the instant the last forward
    leaves the tail stage)."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    fwd_tail: Dict[int, int] = {}    # m -> loss-producing op (incl. egress)
    arrive: Dict[int, int] = {}      # m -> last fwd hop into the next stage
    for m in range(m_count):
        for s in range(depth):
            deps = [arrive[m]] if s > 0 else []
            op = sim.add(("S", s), tf[s], deps, ("fwd", s, m, 0, -1))
            if s < depth - 1:
                prev = op
                for h, hop in enumerate(topo.boundaries[s]):
                    prev = sim.add(
                        ("F", s, h), hop, [prev], ("fwd_comm", s, m, 0, h)
                    )
                arrive[m] = prev
        op_tail = op
        for h, hop in enumerate(topo.egress):
            op_tail = sim.add(
                ("F", 0, h), hop, [op_tail], ("fwd_comm", 0, m, 0, h)
            )
        fwd_tail[m] = op_tail
    barrive: Dict[int, int] = {}
    for m in reversed(range(m_count)):
        op_in = fwd_tail[m]
        for h in reversed(range(len(topo.egress))):
            op_in = sim.add(
                ("B", 0, h), topo.egress[h], [op_in], ("bwd_comm", 0, m, 0, h)
            )
        for s in reversed(range(depth)):
            deps = [op_in] if s == depth - 1 else [barrive[m]]
            op = sim.add(("S", s), tb[s], deps, ("bwd", s, m, 0, -1))
            if s > 0:
                prev = op
                group = topo.boundaries[s - 1]
                for h in reversed(range(len(group))):
                    prev = sim.add(
                        ("B", s - 1, h),
                        group[h],
                        [prev],
                        ("bwd_comm", s - 1, m, 0, h),
                    )
                barrive[m] = prev


def _build_1f1b(sim: _OpSim, topo: PipelineTopology) -> None:
    """One-forward-one-backward with *latency-aware warmup*.

    The textbook warmup of ``L-1-s`` forwards per stage assumes transfers
    are free.  With strict 1F/1B alternation, a boundary whose warmup
    *difference* is the classic 1 inflates the steady-state period by the
    boundary's full communication round trip — even a fast intra-region hop
    costs ``2·C_s`` per microbatch, and a WAN hop as slow as a compute slot
    doubles the period (the CrossPipe observation).  The no-stall condition
    is per boundary: ``w_s - w_{s+1} >= 1 + ceil(2·C_s / (t_f + t_b))``.
    Warmups accumulate those differences tail-to-head, capped at ``M`` —
    so the schedule degrades gracefully to the classic one as comm
    vanishes, and stage by stage toward GPipe (whose phase-decoupled
    fill/drain hides comm for free) as the comm debt grows."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    if depth == 1:
        if topo.egress:
            # Alternating f,b would stall every pair on the egress round
            # trip; the phase-decoupled GPipe order hides it and costs the
            # same M·(t_f+t_b) of stage time.
            _build_gpipe(sim, topo)
            return
        # True single-stage 1F1B: f,b alternation, one activation in flight.
        for m in range(m_count):
            f = sim.add(("S", 0), tf[0], [], ("fwd", 0, m, 0, -1))
            sim.add(("S", 0), tb[0], [f], ("bwd", 0, m, 0, -1))
        return
    need = [0] * depth  # warmup demand of stage s (before the M cap)
    for s in reversed(range(depth - 1)):
        roundtrip = 2.0 * sum(topo.boundaries[s])
        need[s] = need[s + 1] + 1 + math.ceil(
            roundtrip / (tf[s] + tb[s]) - 1e-12
        )
    fwd_id: Dict[Tuple[int, int], int] = {}
    f_arrive: Dict[Tuple[int, int], int] = {}
    b_arrive: Dict[Tuple[int, int], int] = {}
    pending: List[Tuple[int, str, int, int]] = []  # (op, kind, m, s)
    for s in range(depth):
        warmup = min(m_count, need[s])
        order: List[Tuple[str, int]] = [("f", m) for m in range(warmup)]
        nf, nb = warmup, 0
        while nf < m_count:
            order.append(("f", nf))
            nf += 1
            order.append(("b", nb))
            nb += 1
        while nb < m_count:
            order.append(("b", nb))
            nb += 1
        for kind, m in order:
            if kind == "f":
                op = sim.add(("S", s), tf[s], [], ("fwd", s, m, 0, -1))
                fwd_id[(m, s)] = op
                pending.append((op, "f", m, s))
                if s < depth - 1:
                    prev = op
                    for h, hop in enumerate(topo.boundaries[s]):
                        prev = sim.add(
                            ("F", s, h), hop, [prev], ("fwd_comm", s, m, 0, h)
                        )
                    f_arrive[(m, s + 1)] = prev
            else:
                op = sim.add(("S", s), tb[s], [], ("bwd", s, m, 0, -1))
                pending.append((op, "b", m, s))
                if s > 0:
                    prev = op
                    group = topo.boundaries[s - 1]
                    for h in reversed(range(len(group))):
                        prev = sim.add(
                            ("B", s - 1, h),
                            group[h],
                            [prev],
                            ("bwd_comm", s - 1, m, 0, h),
                        )
                    b_arrive[(m, s - 1)] = prev
    for op, kind, m, s in pending:
        if kind == "f":
            if s > 0:
                sim.set_deps(op, [f_arrive[(m, s)]])
        elif s == depth - 1:
            sim.set_deps(op, [fwd_id[(m, s)]])
        else:
            sim.set_deps(op, [b_arrive[(m, s)]])


def _chunk_times(
    times: Sequence[float], overhead: float, v: int
) -> List[float]:
    """Split a stage time into ``v`` chunks; each chunk re-pays the fixed
    per-stage overhead (more, smaller kernels)."""
    out = []
    for t in times:
        out.append((t - overhead) / v + overhead if t > overhead else t / v)
    return out


def _build_interleaved(sim: _OpSim, topo: PipelineTopology, v: int) -> None:
    """Virtual stages, GPipe-flavour fill-drain: each physical stage runs
    ``v`` chunks, microbatches cycled in Megatron-style groups of ``L``.
    Chunk wrap-around (tail stage chunk ``c`` -> head stage chunk ``c+1``)
    traverses a dedicated store-and-forward return path over every hop."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    if depth == 1 or v <= 1:
        _build_gpipe(sim, topo)
        return
    tfc = _chunk_times(topo.stage_time_fwd, topo.stage_overhead, v)
    tbc = _chunk_times(topo.stage_time_bwd, topo.stage_overhead, v)
    wrap_hops = topo.all_hops
    groups = [
        range(g0, min(g0 + depth, m_count))
        for g0 in range(0, m_count, depth)
    ]
    fwd_id: Dict[Tuple[int, int, int], int] = {}
    f_arrive: Dict[Tuple[int, int, int], int] = {}
    wf_arrive: Dict[Tuple[int, int], int] = {}
    b_arrive: Dict[Tuple[int, int, int], int] = {}
    wb_arrive: Dict[Tuple[int, int], int] = {}
    pending: List[Tuple[int, str, int, int, int]] = []
    for s in range(depth):
        for grp in groups:
            for c in range(v):
                for m in grp:
                    op = sim.add(
                        ("S", s), tfc[s], [], ("fwd", s, m, c, -1)
                    )
                    fwd_id[(m, c, s)] = op
                    pending.append((op, "f", m, c, s))
                    if s < depth - 1:
                        prev = op
                        for h, hop in enumerate(topo.boundaries[s]):
                            prev = sim.add(
                                ("F", s, h),
                                hop,
                                [prev],
                                ("fwd_comm", s, m, c, h),
                            )
                        f_arrive[(m, c, s + 1)] = prev
                    elif c < v - 1:
                        prev = op
                        for h in reversed(range(len(wrap_hops))):
                            prev = sim.add(
                                ("WF", h),
                                wrap_hops[h],
                                [prev],
                                ("wrap_fwd", -1, m, c, h),
                            )
                        wf_arrive[(m, c + 1)] = prev
    for s in range(depth):
        for grp in reversed(groups):
            for c in reversed(range(v)):
                for m in reversed(grp):
                    op = sim.add(
                        ("S", s), tbc[s], [], ("bwd", s, m, c, -1)
                    )
                    pending.append((op, "b", m, c, s))
                    if s > 0:
                        prev = op
                        group = topo.boundaries[s - 1]
                        for h in reversed(range(len(group))):
                            prev = sim.add(
                                ("B", s - 1, h),
                                group[h],
                                [prev],
                                ("bwd_comm", s - 1, m, c, h),
                            )
                        b_arrive[(m, c, s - 1)] = prev
                    elif c > 0:
                        prev = op
                        for h in range(len(wrap_hops)):
                            prev = sim.add(
                                ("WB", h),
                                wrap_hops[h],
                                [prev],
                                ("wrap_bwd", -1, m, c, h),
                            )
                        wb_arrive[(m, c - 1)] = prev
    for op, kind, m, c, s in pending:
        if kind == "f":
            if s > 0:
                sim.set_deps(op, [f_arrive[(m, c, s)]])
            elif c > 0:
                sim.set_deps(op, [wf_arrive[(m, c)]])
        elif s == depth - 1:
            if c == v - 1:
                sim.set_deps(op, [fwd_id[(m, c, s)]])
            else:
                sim.set_deps(op, [wb_arrive[(m, c)]])
        else:
            sim.set_deps(op, [b_arrive[(m, c, s)]])


# ----------------------------------------------------------------- summaries
def _summarize(
    sim: _OpSim,
    start: List[float],
    finish: List[float],
    topo: PipelineTopology,
    schedule: str,
    v: int,
    keep_events: bool,
) -> SchedulePlan:
    depth = topo.n_stages
    makespan = max(finish)
    busy = [0.0] * depth
    acts: List[List[Tuple[float, float]]] = [[] for _ in range(depth)]
    weight = 1.0 / v
    for i, (kind, stage, _m, _c, _h) in enumerate(sim.meta):
        if kind == "fwd":
            busy[stage] += sim.dur[i]
            acts[stage].append((finish[i], weight))
        elif kind == "bwd":
            busy[stage] += sim.dur[i]
            acts[stage].append((finish[i], -weight))
    peaks = []
    for deltas in acts:
        # Decrements first at equal timestamps: a stash freed at t makes room
        # for one created at t.
        deltas.sort(key=lambda e: (e[0], e[1]))
        level = peak = 0.0
        for _t, d in deltas:
            level += d
            if level > peak:
                peak = level
        peaks.append(peak)
    events: Tuple[PlanEvent, ...] = ()
    edges: Tuple[Tuple[int, int], ...] = ()
    if keep_events:
        events = tuple(
            PlanEvent(*sim.meta[i], start=start[i], end=finish[i])
            for i in range(len(sim.meta))
        )
        edges = tuple(
            (d, i) for i, deps in enumerate(sim.deps) for d in deps
        )
    return SchedulePlan(
        schedule=schedule,
        n_stages=depth,
        n_microbatches=topo.n_microbatches,
        iteration_time=makespan,
        stage_busy=tuple(busy),
        stage_bubble=tuple(
            1.0 - b / makespan if makespan > 0.0 else 0.0 for b in busy
        ),
        peak_activations_per_stage=tuple(peaks),
        events=events,
        edges=edges,
    )


def _plan_gpipe_overlap(
    topo: PipelineTopology, keep_events: bool
) -> SchedulePlan:
    """Lockstep tick schedule (the jax data plane's by-construction behavior):
    every stage advances once per tick, the boundary transfer of microbatch
    ``i`` riding alongside the compute of ``i+1``, so the tick length is the
    bottleneck slot Δ and each direction takes ``M + L - 1`` ticks.  In the
    degenerate single-stage-with-hops case the trailing egress round trip is
    not hidden by any tick and is charged on top.

    The event timeline is a rendering of the lockstep model: hop chains are
    store-and-forward serial, anchored to the tick whose compute emitted
    them (a long chain may spill into later ticks), and there is no explicit
    dependency graph — the tick barrier *is* the structure, so ``edges``
    stays empty."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    delta = topo.bottleneck
    n_ticks = m_count + depth - 1
    egress_rt = 2.0 * sum(topo.egress)
    makespan = 2.0 * n_ticks * delta + egress_rt
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    busy = tuple(m_count * (tf[s] + tb[s]) for s in range(depth))
    events: List[PlanEvent] = []
    if keep_events:
        half = n_ticks * delta + egress_rt / 2.0

        def emit(kind, boundary, m, hops, start):
            cur = start
            for h, hop in enumerate(hops):
                events.append(
                    PlanEvent(kind, boundary, m, 0, h, cur, cur + hop)
                )
                cur += hop

        for tick in range(n_ticks):
            for s in range(depth):
                m = tick - s
                if 0 <= m < m_count:
                    t0 = tick * delta
                    events.append(
                        PlanEvent("fwd", s, m, 0, -1, t0, t0 + tf[s])
                    )
                    if s < depth - 1:
                        emit("fwd_comm", s, m, topo.boundaries[s], t0 + tf[s])
                    elif topo.egress:  # 1-stage degenerate case
                        emit("fwd_comm", 0, m, topo.egress, t0 + tf[s])
        for tick in range(n_ticks):
            for s in range(depth):
                mi = tick - (depth - 1 - s)
                if 0 <= mi < m_count:
                    m = m_count - 1 - mi
                    t0 = half + tick * delta
                    events.append(
                        PlanEvent("bwd", s, m, 0, -1, t0, t0 + tb[s])
                    )
                    if s > 0:
                        emit(
                            "bwd_comm", s - 1, m,
                            topo.boundaries[s - 1], t0 + tb[s],
                        )
                    elif topo.egress:
                        # Ingress: the loss gradient arrives through the
                        # trailing hops *before* this backward slot.
                        emit(
                            "bwd_comm", 0, m, topo.egress,
                            t0 - sum(topo.egress),
                        )
    return SchedulePlan(
        schedule="gpipe-overlap",
        n_stages=depth,
        n_microbatches=m_count,
        iteration_time=makespan,
        stage_busy=busy,
        stage_bubble=tuple(
            1.0 - b / makespan if makespan > 0.0 else 0.0 for b in busy
        ),
        peak_activations_per_stage=(float(m_count),) * depth,
        n_ticks=n_ticks,
        events=tuple(events),
    )


# ------------------------------------------------------------------ front end
def plan_from_topology(
    topo: PipelineTopology,
    schedule: str,
    *,
    virtual_stages: int = DEFAULT_VIRTUAL_STAGES,
    keep_events: bool = False,
) -> SchedulePlan:
    """Plan one iteration of ``schedule`` over an explicit topology."""
    if schedule not in PIPELINE_SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r} (have: {PIPELINE_SCHEDULES})"
        )
    if virtual_stages < 1:
        raise ValueError("virtual_stages must be >= 1")
    if schedule == "gpipe-overlap":
        return _plan_gpipe_overlap(topo, keep_events)
    sim = _OpSim()
    v = 1
    if schedule == "gpipe":
        _build_gpipe(sim, topo)
    elif schedule == "1f1b":
        _build_1f1b(sim, topo)
    else:  # interleaved
        v = virtual_stages if topo.n_stages > 1 else 1
        _build_interleaved(sim, topo, v)
    start, finish = sim.run()
    return _summarize(sim, start, finish, topo, schedule, v, keep_events)


@lru_cache(maxsize=256)
def _plan_cached(
    topo: PipelineTopology, schedule: str, virtual_stages: int
) -> SchedulePlan:
    return plan_from_topology(topo, schedule, virtual_stages=virtual_stages)


def plan_schedule(
    profile: JobProfile,
    placement: Placement,
    schedule: Optional[str] = None,
    *,
    virtual_stages: int = DEFAULT_VIRTUAL_STAGES,
    keep_events: bool = False,
) -> SchedulePlan:
    """Plan one training iteration of ``profile`` under ``placement``.

    ``schedule`` defaults to the job's ``JobSpec.pipeline_schedule``.  Plans
    without event materialization are memoized on the (topology, schedule)
    pair — the timing backend prices identical placements repeatedly.
    """
    if schedule is None:
        schedule = profile.spec.pipeline_schedule
    topo = topology_from_placement(profile, placement)
    if keep_events:
        return plan_from_topology(
            topo, schedule, virtual_stages=virtual_stages, keep_events=True
        )
    return _plan_cached(topo, schedule, virtual_stages)
