"""Microbatch-level schedule planner: bubble-accurate pipeline timelines.

Where ``core/timing.py`` prices a placement with the closed-form GPipe
fill-drain formula of Eq. (1), this module *executes* the schedule: every
(stage, microbatch) compute slot and every stage-boundary transfer becomes an
operation on a resource, and iteration time is the makespan of the resulting
dependency graph.  That turns the paper's central quantity — the pipeline
bubble under heterogeneous per-hop WAN bandwidth — from an analytic scalar
into an inspectable event timeline, and opens schedule-level questions
(CrossPipe-style comm-overlapped cross-DC schedules, OptPipe-style
memory/schedule trade-offs) that a closed form cannot express.

Resource model
--------------
A placement maps to a :class:`PipelineTopology`:

* ``L`` pipeline stages (``JobProfile.pipeline_depth``), each a serially
  reused compute resource with per-microbatch forward/backward times;
* ``L-1`` stage boundaries, each an ordered group of *serial hop* resources —
  one hop per GPU boundary it covers (store-and-forward: hop ``h`` can carry
  microbatch ``i+1`` while hop ``h+1`` carries ``i``).  Intra-region hops
  ride the intra-region fabric; region crossings ride the WAN share the
  placement reserved.  Tensor-parallel-widened placements (``g > L``) fold
  their surplus per-GPU hops into the last boundary group, so the planner
  pays exactly the ``g-1`` transfers Eq. (1)'s fill term pays.
* Links are full duplex: forward activations and backward gradients on the
  same boundary use independent per-direction resources.

Schedules
---------
``gpipe``          fill/steady/drain, all forwards then all backwards; the
                   deterministic-tandem makespan reproduces Eq. (1)
                   (``analytic_iteration_time``) up to float association.
``1f1b``           one-forward-one-backward with the standard per-stage
                   warmup of ``min(M, L-1-s)``; same bubble as GPipe but the
                   per-stage activation stash drops from ``M`` to ``~L-s``.
``interleaved``    virtual stages: each physical stage runs ``v`` chunks of
                   ``1/v`` of its layers, microbatches group-cycled
                   (Megatron-style groups of ``L``); chunk wrap-around
                   transfers traverse a dedicated store-and-forward return
                   path over every hop (the WAN cost that makes interleaving
                   unattractive cross-region).
``gpipe-overlap``  the lockstep tick schedule the jax data plane
                   (``pipeline/gpipe.py``) executes by construction: ticks of
                   length ``Δ = max(t_comp, max hop)``, transfer of
                   microbatch ``i`` overlapping compute of ``i+1``;
                   ``M + L - 1`` ticks per direction (the data-plane parity
                   surface).
``synthesized``    per-topology schedule *search* (CrossPipe/OptPipe
                   flavour): greedy list-scheduling over (stage, microbatch,
                   direction) ops with a critical-path lookahead, seeded from
                   the best template order (GPipe plus a family of
                   latency-/period-aware 1F1B warmup vectors) and locally
                   improved by adjacent op-swap moves, under an optional
                   per-stage peak-activation cap (``activation_cap``).  On
                   compute-bound placements (the Alg. 1 regime, every hop
                   ``≤ t_comp``) GPipe is provably makespan-optimal in this
                   op model, so the search ties it; on long-latency
                   boundaries (post-placement WAN degradation, Eq. 6's
                   violation window) the capped template warmup degrades to
                   GPipe's ``2·(M-1)·Δ`` steady state while the search keeps
                   forward and backward transfers concurrent on the
                   full-duplex link and pays ``(M-1)·Δ`` — strictly faster at
                   a fraction of the stash.

The op-level simulator is deterministic: per-resource FIFO order is fixed by
the schedule, an op starts at ``max(resource free, dependency finishes)``,
and an unexecutable schedule (a FIFO/dependency cycle) raises instead of
hanging.  The synthesizer is deterministic too: a fixed candidate family, a
fixed move order, and a fixed op-count budget — identical topologies yield
identical plans.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

from ..cluster import INTRA_REGION_BANDWIDTH
from ..job import PIPELINE_SCHEDULES, JobProfile
from ..placement import Placement

#: Default virtual-stage (chunk) count for the ``interleaved`` schedule.
DEFAULT_VIRTUAL_STAGES = 2


class PlanEvent(NamedTuple):
    """One timeline slot: a compute op or a single-hop transfer."""

    kind: str        # fwd | bwd | fwd_comm | bwd_comm | wrap_fwd | wrap_bwd
    stage: int       # compute stage; boundary index for *_comm; -1 for wrap
    microbatch: int
    chunk: int       # virtual-stage chunk (0 outside `interleaved`)
    hop: int         # serial hop index within the boundary (-1 for compute)
    start: float
    end: float


@dataclasses.dataclass(frozen=True)
class PipelineTopology:
    """Schedule-independent description of one placed pipeline.

    ``boundaries[s]`` is the ordered tuple of serial hop times between stage
    ``s`` and ``s+1``.  ``egress`` is only populated for the degenerate
    single-stage-with-hops case (``max_stages == 1`` but several GPUs): the
    hops trail the stage so the tandem total still pays them, as Eq. (1)
    does.
    """

    n_microbatches: int
    stage_time_fwd: Tuple[float, ...]
    stage_time_bwd: Tuple[float, ...]
    boundaries: Tuple[Tuple[float, ...], ...]
    stage_overhead: float = 0.0
    egress: Tuple[float, ...] = ()

    def __post_init__(self) -> None:
        if self.n_microbatches < 1:
            raise ValueError("need at least one microbatch")
        if not self.stage_time_fwd:
            raise ValueError("need at least one stage")
        if len(self.stage_time_bwd) != len(self.stage_time_fwd):
            raise ValueError("fwd/bwd stage-time length mismatch")
        if len(self.boundaries) != max(0, len(self.stage_time_fwd) - 1):
            raise ValueError("need exactly n_stages - 1 boundary groups")
        if self.egress and len(self.stage_time_fwd) != 1:
            raise ValueError("egress hops only model the single-stage case")

    @property
    def n_stages(self) -> int:
        return len(self.stage_time_fwd)

    @property
    def all_hops(self) -> Tuple[float, ...]:
        """Every serial hop time, boundary-major (the Eq. (1) fill multiset)."""
        flat: List[float] = []
        for group in self.boundaries:
            flat.extend(group)
        flat.extend(self.egress)
        return tuple(flat)

    @property
    def bottleneck(self) -> float:
        """Slowest slot (compute or single hop) — Eq. (1)'s Δ with symmetric
        backward."""
        slots = list(self.stage_time_fwd) + list(self.stage_time_bwd)
        slots.extend(self.all_hops)
        return max(slots)


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """Materialized timeline + the summary the scheduler consumes."""

    schedule: str
    n_stages: int
    n_microbatches: int
    iteration_time: float
    #: Busy seconds per stage (compute only), forward + backward.
    stage_busy: Tuple[float, ...]
    #: Per-stage bubble fraction: 1 - busy / makespan.
    stage_bubble: Tuple[float, ...]
    #: Peak concurrently-stashed activations per stage, in units of one full
    #: per-stage microbatch activation (interleaved chunks count 1/v each).
    peak_activations_per_stage: Tuple[float, ...]
    #: Lockstep schedules only: ticks per direction (gpipe-overlap), matching
    #: the data plane's ``M + S - 1``.
    n_ticks: Optional[int] = None
    #: Materialized timeline (empty unless planned with keep_events=True).
    events: Tuple[PlanEvent, ...] = ()
    #: Dependency edges as (producer, consumer) indices into ``events``.
    #: Lockstep plans (``gpipe-overlap``) have no explicit edges: the global
    #: tick barrier is their entire dependency structure.
    edges: Tuple[Tuple[int, int], ...] = ()

    @property
    def bubble_fraction(self) -> float:
        """Aggregate bubble: idle fraction of all stage-seconds."""
        total = self.n_stages * self.iteration_time
        return 1.0 - sum(self.stage_busy) / total if total > 0.0 else 0.0

    @property
    def peak_activations(self) -> float:
        return max(self.peak_activations_per_stage)

    def summary(self) -> str:
        return (
            f"{self.schedule}: t_iter={self.iteration_time:.3f}s, "
            f"bubble={self.bubble_fraction:.3f}, "
            f"peak_acts={self.peak_activations:.1f}"
        )


# ---------------------------------------------------------------- op machine
class _OpSim:
    """Deterministic resource/dependency simulator.

    Ops are appended in per-resource FIFO order (the *schedule*); deps may be
    filled in later (``set_deps``) because cross-stage producers are built in
    a different pass.  ``run`` computes start/finish in O(ops + edges): an op
    executes once it is at the head of its resource queue and all its deps
    have finished, starting at ``max(resource free, dep finishes)``.
    """

    def __init__(self) -> None:
        self.dur: List[float] = []
        self.deps: List[Tuple[int, ...]] = []
        self.meta: List[Tuple[str, int, int, int, int]] = []
        self._res: List[int] = []
        self._res_ids: Dict[object, int] = {}
        self._queues: List[List[int]] = []

    def add(
        self,
        resource: object,
        duration: float,
        deps: Sequence[int],
        meta: Tuple[str, int, int, int, int],
    ) -> int:
        rid = self._res_ids.get(resource)
        if rid is None:
            rid = len(self._queues)
            self._res_ids[resource] = rid
            self._queues.append([])
        i = len(self.dur)
        self.dur.append(duration)
        self.deps.append(tuple(deps))
        self.meta.append(meta)
        self._res.append(rid)
        self._queues[rid].append(i)
        return i

    def set_deps(self, op: int, deps: Sequence[int]) -> None:
        self.deps[op] = tuple(deps)

    def run(self) -> Tuple[List[float], List[float]]:
        n = len(self.dur)
        dur, deps, res_of = self.dur, self.deps, self._res
        n_unmet = [len(d) for d in deps]
        dependents: List[List[int]] = [[] for _ in range(n)]
        for i, ds in enumerate(deps):
            for d in ds:
                dependents[d].append(i)
        pos = [0] * n
        for q in self._queues:
            for idx, i in enumerate(q):
                pos[i] = idx
        head = [0] * len(self._queues)
        res_free = [0.0] * len(self._queues)
        start = [0.0] * n
        finish = [0.0] * n
        stack = [q[0] for q in self._queues if q and n_unmet[q[0]] == 0]
        done = 0
        while stack:
            i = stack.pop()
            r = res_of[i]
            s = res_free[r]
            for d in deps[i]:
                f = finish[d]
                if f > s:
                    s = f
            start[i] = s
            f = s + dur[i]
            finish[i] = f
            res_free[r] = f
            done += 1
            head[r] += 1
            q = self._queues[r]
            if head[r] < len(q):
                j = q[head[r]]
                if n_unmet[j] == 0:
                    stack.append(j)
            for k in dependents[i]:
                n_unmet[k] -= 1
                if n_unmet[k] == 0 and pos[k] == head[res_of[k]]:
                    stack.append(k)
        if done != n:
            raise RuntimeError(
                f"unexecutable schedule: {n - done} of {n} ops deadlocked "
                "(FIFO order inconsistent with dependencies)"
            )
        return start, finish


# ----------------------------------------------------------- topology mapping
def topology_from_placement(
    profile: JobProfile, placement: Placement, *, wan_stretch: float = 1.0
) -> PipelineTopology:
    """Derive the planner topology from a concrete placement.

    Per-GPU boundary hops are reconstructed in *stage order* from
    ``Placement.stage_regions()`` (``Placement.comm_times`` is an unordered
    multiset); the multisets are identical, which is what keeps the gpipe
    plan on Eq. (1).  GPU slot ``i`` belongs to stage ``min(i, L-1)``, so a
    tensor-parallel-widened placement folds its surplus hops into the last
    boundary group.

    ``wan_stretch`` multiplies every *inter-region* hop time (intra-region
    fabric hops are untouched): the post-placement bandwidth-contraction
    regime of Eq. (6), where a placement admitted under ``t_comm ≤ t_comp``
    runs comm-bound until the simulator migrates it — the long-latency
    topologies the schedule synthesizer is gated on.
    """
    if wan_stretch <= 0.0:
        raise ValueError("wan_stretch must be positive")
    g = placement.total_gpus
    depth = profile.pipeline_depth(g)
    # Typed grants price stages at the bottleneck granted hardware (None on
    # single-type clusters: the bit-exact reference path).
    t_comp = profile.t_comp_hw(g, placement.eff_flops)
    act = profile.spec.model.activation_bytes
    regions = placement.stage_regions()
    intra_hop = act / INTRA_REGION_BANDWIDTH
    hops: List[float] = []
    for i in range(g - 1):
        u, v = regions[i], regions[i + 1]
        hops.append(
            intra_hop
            if u == v
            else wan_stretch * (act / placement.reserved_bw[(u, v)])
        )
    if depth == 1:
        boundaries: Tuple[Tuple[float, ...], ...] = ()
        egress = tuple(hops)
    else:
        groups: List[List[float]] = [[] for _ in range(depth - 1)]
        for i, h in enumerate(hops):
            groups[min(i, depth - 2)].append(h)
        boundaries = tuple(tuple(grp) for grp in groups)
        egress = ()
    stage_times = (t_comp,) * depth
    return PipelineTopology(
        n_microbatches=profile.spec.model.microbatches,
        stage_time_fwd=stage_times,
        stage_time_bwd=stage_times,  # Eq. (1)'s symmetric backward
        boundaries=boundaries,
        stage_overhead=profile.stage_overhead,
        egress=egress,
    )


# ------------------------------------------------------------------ builders
def _build_gpipe(sim: _OpSim, topo: PipelineTopology) -> None:
    """Fill/steady/drain: all forwards (microbatch-ascending), then all
    backwards (descending, so drain starts the instant the last forward
    leaves the tail stage)."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    fwd_tail: Dict[int, int] = {}    # m -> loss-producing op (incl. egress)
    arrive: Dict[int, int] = {}      # m -> last fwd hop into the next stage
    for m in range(m_count):
        for s in range(depth):
            deps = [arrive[m]] if s > 0 else []
            op = sim.add(("S", s), tf[s], deps, ("fwd", s, m, 0, -1))
            if s < depth - 1:
                prev = op
                for h, hop in enumerate(topo.boundaries[s]):
                    prev = sim.add(
                        ("F", s, h), hop, [prev], ("fwd_comm", s, m, 0, h)
                    )
                arrive[m] = prev
        op_tail = op
        for h, hop in enumerate(topo.egress):
            op_tail = sim.add(
                ("F", 0, h), hop, [op_tail], ("fwd_comm", 0, m, 0, h)
            )
        fwd_tail[m] = op_tail
    barrive: Dict[int, int] = {}
    for m in reversed(range(m_count)):
        op_in = fwd_tail[m]
        for h in reversed(range(len(topo.egress))):
            op_in = sim.add(
                ("B", 0, h), topo.egress[h], [op_in], ("bwd_comm", 0, m, 0, h)
            )
        for s in reversed(range(depth)):
            deps = [op_in] if s == depth - 1 else [barrive[m]]
            op = sim.add(("S", s), tb[s], deps, ("bwd", s, m, 0, -1))
            if s > 0:
                prev = op
                group = topo.boundaries[s - 1]
                for h in reversed(range(len(group))):
                    prev = sim.add(
                        ("B", s - 1, h),
                        group[h],
                        [prev],
                        ("bwd_comm", s - 1, m, 0, h),
                    )
                barrive[m] = prev


def _build_1f1b(sim: _OpSim, topo: PipelineTopology) -> None:
    """One-forward-one-backward with *latency-aware warmup*.

    The textbook warmup of ``L-1-s`` forwards per stage assumes transfers
    are free.  With strict 1F/1B alternation, a boundary whose warmup
    *difference* is the classic 1 inflates the steady-state period by the
    boundary's full communication round trip — even a fast intra-region hop
    costs ``2·C_s`` per microbatch, and a WAN hop as slow as a compute slot
    doubles the period (the CrossPipe observation).  The no-stall condition
    is per boundary: ``w_s - w_{s+1} >= 1 + ceil(2·C_s / (t_f + t_b))``.
    Warmups accumulate those differences tail-to-head, capped at ``M`` —
    so the schedule degrades gracefully to the classic one as comm
    vanishes, and stage by stage toward GPipe (whose phase-decoupled
    fill/drain hides comm for free) as the comm debt grows."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    if depth == 1:
        if topo.egress:
            # Alternating f,b would stall every pair on the egress round
            # trip; the phase-decoupled GPipe order hides it and costs the
            # same M·(t_f+t_b) of stage time.
            _build_gpipe(sim, topo)
            return
        # True single-stage 1F1B: f,b alternation, one activation in flight.
        for m in range(m_count):
            f = sim.add(("S", 0), tf[0], [], ("fwd", 0, m, 0, -1))
            sim.add(("S", 0), tb[0], [f], ("bwd", 0, m, 0, -1))
        return
    _build_from_orders(
        sim, topo, _orders_from_warmup(m_count, depth, _warmup_demand(topo))
    )


def _warmup_demand(topo: PipelineTopology) -> List[int]:
    """Uncapped latency-aware 1F1B warmup demand per stage: the per-boundary
    no-stall condition ``w_s - w_{s+1} >= 1 + ceil(2·C_s / (t_f + t_b))``
    accumulated tail-to-head (see ``_build_1f1b``)."""
    depth = topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    need = [0] * depth
    for s in reversed(range(depth - 1)):
        roundtrip = 2.0 * sum(topo.boundaries[s])
        need[s] = need[s + 1] + 1 + math.ceil(
            roundtrip / (tf[s] + tb[s]) - 1e-12
        )
    return need


def _orders_from_warmup(
    m_count: int, depth: int, warmup: Sequence[int]
) -> List[List[Tuple[str, int]]]:
    """Per-stage op sequences of the 1F1B family: ``warmup[s]`` forwards,
    strict f/b alternation, backward drain.  ``warmup = M`` everywhere is
    the GPipe order; the classic schedule is ``warmup[s] = L-1-s``."""
    orders: List[List[Tuple[str, int]]] = []
    for s in range(depth):
        w = min(m_count, max(0, warmup[s]))
        order: List[Tuple[str, int]] = [("f", m) for m in range(w)]
        nf, nb = w, 0
        while nf < m_count:
            order.append(("f", nf))
            nf += 1
            order.append(("b", nb))
            nb += 1
        while nb < m_count:
            order.append(("b", nb))
            nb += 1
        orders.append(order)
    return orders


def _build_from_orders(
    sim: _OpSim,
    topo: PipelineTopology,
    orders: Sequence[Sequence[Tuple[str, int]]],
) -> None:
    """Materialize arbitrary per-stage ``("f"|"b", microbatch)`` sequences
    into the op graph.  Each stage's sequence *is* its compute-resource FIFO
    order; boundary transfers are enqueued in producer order, so the hop
    FIFO follows the producing stage's sequence.  Inconsistent orders (a
    FIFO/dependency cycle, or a missing producer) surface as
    ``RuntimeError``/``KeyError`` when the sim runs or deps are wired."""
    depth = topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    fwd_id: Dict[Tuple[int, int], int] = {}
    f_arrive: Dict[Tuple[int, int], int] = {}
    b_arrive: Dict[Tuple[int, int], int] = {}
    pending: List[Tuple[int, str, int, int]] = []  # (op, kind, m, s)
    for s in range(depth):
        for kind, m in orders[s]:
            if kind == "f":
                op = sim.add(("S", s), tf[s], [], ("fwd", s, m, 0, -1))
                fwd_id[(m, s)] = op
                pending.append((op, "f", m, s))
                if s < depth - 1:
                    prev = op
                    for h, hop in enumerate(topo.boundaries[s]):
                        prev = sim.add(
                            ("F", s, h), hop, [prev], ("fwd_comm", s, m, 0, h)
                        )
                    f_arrive[(m, s + 1)] = prev
            else:
                op = sim.add(("S", s), tb[s], [], ("bwd", s, m, 0, -1))
                pending.append((op, "b", m, s))
                if s > 0:
                    prev = op
                    group = topo.boundaries[s - 1]
                    for h in reversed(range(len(group))):
                        prev = sim.add(
                            ("B", s - 1, h),
                            group[h],
                            [prev],
                            ("bwd_comm", s - 1, m, 0, h),
                        )
                    b_arrive[(m, s - 1)] = prev
    for op, kind, m, s in pending:
        if kind == "f":
            if s > 0:
                sim.set_deps(op, [f_arrive[(m, s)]])
        elif s == depth - 1:
            sim.set_deps(op, [fwd_id[(m, s)]])
        else:
            sim.set_deps(op, [b_arrive[(m, s)]])


def _chunk_times(
    times: Sequence[float], overhead: float, v: int
) -> List[float]:
    """Split a stage time into ``v`` chunks; each chunk re-pays the fixed
    per-stage overhead (more, smaller kernels), so no chunk ever prices
    below the overhead floor.  The compute part ``max(t - overhead, 0)``
    divides by ``v``; clamping it at zero keeps the split continuous at
    ``t == overhead`` (the old ``t/v`` fallback priced a chunk *below* the
    fixed per-kernel cost, a discontinuity interleaving could exploit)."""
    if overhead <= 0.0:
        return [t / v for t in times]
    return [max(t - overhead, 0.0) / v + overhead for t in times]


def _build_interleaved(sim: _OpSim, topo: PipelineTopology, v: int) -> None:
    """Virtual stages, GPipe-flavour fill-drain: each physical stage runs
    ``v`` chunks, microbatches cycled in Megatron-style groups of ``L``.
    Chunk wrap-around (tail stage chunk ``c`` -> head stage chunk ``c+1``)
    traverses a dedicated store-and-forward return path over every hop."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    if depth == 1 or v <= 1:
        _build_gpipe(sim, topo)
        return
    tfc = _chunk_times(topo.stage_time_fwd, topo.stage_overhead, v)
    tbc = _chunk_times(topo.stage_time_bwd, topo.stage_overhead, v)
    wrap_hops = topo.all_hops
    groups = [
        range(g0, min(g0 + depth, m_count))
        for g0 in range(0, m_count, depth)
    ]
    fwd_id: Dict[Tuple[int, int, int], int] = {}
    f_arrive: Dict[Tuple[int, int, int], int] = {}
    wf_arrive: Dict[Tuple[int, int], int] = {}
    b_arrive: Dict[Tuple[int, int, int], int] = {}
    wb_arrive: Dict[Tuple[int, int], int] = {}
    pending: List[Tuple[int, str, int, int, int]] = []
    for s in range(depth):
        for grp in groups:
            for c in range(v):
                for m in grp:
                    op = sim.add(
                        ("S", s), tfc[s], [], ("fwd", s, m, c, -1)
                    )
                    fwd_id[(m, c, s)] = op
                    pending.append((op, "f", m, c, s))
                    if s < depth - 1:
                        prev = op
                        for h, hop in enumerate(topo.boundaries[s]):
                            prev = sim.add(
                                ("F", s, h),
                                hop,
                                [prev],
                                ("fwd_comm", s, m, c, h),
                            )
                        f_arrive[(m, c, s + 1)] = prev
                    elif c < v - 1:
                        prev = op
                        for h in reversed(range(len(wrap_hops))):
                            prev = sim.add(
                                ("WF", h),
                                wrap_hops[h],
                                [prev],
                                ("wrap_fwd", -1, m, c, h),
                            )
                        wf_arrive[(m, c + 1)] = prev
    for s in range(depth):
        for grp in reversed(groups):
            for c in reversed(range(v)):
                for m in reversed(grp):
                    op = sim.add(
                        ("S", s), tbc[s], [], ("bwd", s, m, c, -1)
                    )
                    pending.append((op, "b", m, c, s))
                    if s > 0:
                        prev = op
                        group = topo.boundaries[s - 1]
                        for h in reversed(range(len(group))):
                            prev = sim.add(
                                ("B", s - 1, h),
                                group[h],
                                [prev],
                                ("bwd_comm", s - 1, m, c, h),
                            )
                        b_arrive[(m, c, s - 1)] = prev
                    elif c > 0:
                        prev = op
                        for h in range(len(wrap_hops)):
                            prev = sim.add(
                                ("WB", h),
                                wrap_hops[h],
                                [prev],
                                ("wrap_bwd", -1, m, c, h),
                            )
                        wb_arrive[(m, c - 1)] = prev
    for op, kind, m, c, s in pending:
        if kind == "f":
            if s > 0:
                sim.set_deps(op, [f_arrive[(m, c, s)]])
            elif c > 0:
                sim.set_deps(op, [wf_arrive[(m, c)]])
        elif s == depth - 1:
            if c == v - 1:
                sim.set_deps(op, [fwd_id[(m, c, s)]])
            else:
                sim.set_deps(op, [wb_arrive[(m, c)]])
        else:
            sim.set_deps(op, [b_arrive[(m, c, s)]])


# ----------------------------------------------------------------- summaries
def _stage_peaks(
    sim: _OpSim, finish: List[float], depth: int, v: int
) -> List[float]:
    """Peak concurrently-stashed activations per stage: +1/v at each fwd
    finish, -1/v at each bwd finish, decrements first at equal timestamps
    (a stash freed at t makes room for one created at t)."""
    acts: List[List[Tuple[float, float]]] = [[] for _ in range(depth)]
    weight = 1.0 / v
    for i, (kind, stage, _m, _c, _h) in enumerate(sim.meta):
        if kind == "fwd":
            acts[stage].append((finish[i], weight))
        elif kind == "bwd":
            acts[stage].append((finish[i], -weight))
    peaks = []
    for deltas in acts:
        deltas.sort(key=lambda e: (e[0], e[1]))
        level = peak = 0.0
        for _t, d in deltas:
            level += d
            if level > peak:
                peak = level
        peaks.append(peak)
    return peaks


def _summarize(
    sim: _OpSim,
    start: List[float],
    finish: List[float],
    topo: PipelineTopology,
    schedule: str,
    v: int,
    keep_events: bool,
) -> SchedulePlan:
    depth = topo.n_stages
    makespan = max(finish)
    busy = [0.0] * depth
    for i, (kind, stage, _m, _c, _h) in enumerate(sim.meta):
        if kind in ("fwd", "bwd"):
            busy[stage] += sim.dur[i]
    peaks = _stage_peaks(sim, finish, depth, v)
    events: Tuple[PlanEvent, ...] = ()
    edges: Tuple[Tuple[int, int], ...] = ()
    if keep_events:
        events = tuple(
            PlanEvent(*sim.meta[i], start=start[i], end=finish[i])
            for i in range(len(sim.meta))
        )
        edges = tuple(
            (d, i) for i, deps in enumerate(sim.deps) for d in deps
        )
    return SchedulePlan(
        schedule=schedule,
        n_stages=depth,
        n_microbatches=topo.n_microbatches,
        iteration_time=makespan,
        stage_busy=tuple(busy),
        stage_bubble=tuple(
            1.0 - b / makespan if makespan > 0.0 else 0.0 for b in busy
        ),
        peak_activations_per_stage=tuple(peaks),
        events=events,
        edges=edges,
    )


def _plan_gpipe_overlap(
    topo: PipelineTopology, keep_events: bool
) -> SchedulePlan:
    """Lockstep tick schedule (the jax data plane's by-construction behavior):
    every stage advances once per tick, the boundary transfer of microbatch
    ``i`` riding alongside the compute of ``i+1``, so the tick length is the
    bottleneck slot Δ and each direction takes ``M + L - 1`` ticks.  In the
    degenerate single-stage-with-hops case the trailing egress round trip is
    not hidden by any tick and is charged on top.

    The event timeline is a rendering of the lockstep model: hop chains are
    store-and-forward serial, anchored to the tick whose compute emitted
    them (a long chain may spill into later ticks), and there is no explicit
    dependency graph — the tick barrier *is* the structure, so ``edges``
    stays empty."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    delta = topo.bottleneck
    n_ticks = m_count + depth - 1
    egress_rt = 2.0 * sum(topo.egress)
    makespan = 2.0 * n_ticks * delta + egress_rt
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    busy = tuple(m_count * (tf[s] + tb[s]) for s in range(depth))
    events: List[PlanEvent] = []
    if keep_events:
        half = n_ticks * delta + egress_rt / 2.0
        bwd_base = half
        if topo.egress:
            # Causal anchor for the backward half: the first-drained
            # microbatch's gradient can only start its ingress once that
            # microbatch's *forward* egress chain has fully left the hops
            # (its fwd starts at the last forward tick), and the ingress
            # itself takes sum(egress).  Anchoring backwards at ``half``
            # unconditionally rendered the first ingress *before* its own
            # forward egress finished whenever ``t_f + sum(egress) > Δ``.
            # The shift stays within the lockstep makespan: the last
            # backward then ends at ``2(n-1)Δ + t_f + t_b + egress_rt
            # <= 2nΔ + egress_rt`` since ``t_f + t_b <= 2Δ``.
            fwd_egress_done = (
                (n_ticks - 1) * delta + tf[0] + sum(topo.egress)
            )
            bwd_base = max(half, fwd_egress_done + sum(topo.egress))

        def emit(kind, boundary, m, hops, start):
            cur = start
            for h, hop in enumerate(hops):
                events.append(
                    PlanEvent(kind, boundary, m, 0, h, cur, cur + hop)
                )
                cur += hop

        for tick in range(n_ticks):
            for s in range(depth):
                m = tick - s
                if 0 <= m < m_count:
                    t0 = tick * delta
                    events.append(
                        PlanEvent("fwd", s, m, 0, -1, t0, t0 + tf[s])
                    )
                    if s < depth - 1:
                        emit("fwd_comm", s, m, topo.boundaries[s], t0 + tf[s])
                    elif topo.egress:  # 1-stage degenerate case
                        emit("fwd_comm", 0, m, topo.egress, t0 + tf[s])
        for tick in range(n_ticks):
            for s in range(depth):
                mi = tick - (depth - 1 - s)
                if 0 <= mi < m_count:
                    m = m_count - 1 - mi
                    t0 = bwd_base + tick * delta
                    events.append(
                        PlanEvent("bwd", s, m, 0, -1, t0, t0 + tb[s])
                    )
                    if s > 0:
                        emit(
                            "bwd_comm", s - 1, m,
                            topo.boundaries[s - 1], t0 + tb[s],
                        )
                    elif topo.egress:
                        # Ingress: the loss gradient arrives through the
                        # trailing hops *before* this backward slot.
                        emit(
                            "bwd_comm", 0, m, topo.egress,
                            t0 - sum(topo.egress),
                        )
    return SchedulePlan(
        schedule="gpipe-overlap",
        n_stages=depth,
        n_microbatches=m_count,
        iteration_time=makespan,
        stage_busy=busy,
        stage_bubble=tuple(
            1.0 - b / makespan if makespan > 0.0 else 0.0 for b in busy
        ),
        peak_activations_per_stage=(float(m_count),) * depth,
        n_ticks=n_ticks,
        events=tuple(events),
    )


# ---------------------------------------------------------------- synthesizer
#: Simulated-op budget for the op-swap local search: the number of candidate
#: evaluations scales inversely with the op-graph size, so small topologies
#: search deep and huge ones stay cheap.  Fixed budget => deterministic.
_SWAP_SIM_BUDGET = 200_000

#: Interpolation weights between the classic warmup vector and each anchor.
_SEARCH_LAMBDAS = (0.25, 0.5, 0.75)


def _evaluate_orders(
    topo: PipelineTopology,
    orders: Sequence[Sequence[Tuple[str, int]]],
    activation_cap: Optional[float],
) -> Optional[Tuple[float, float]]:
    """Score one candidate on the exact op simulator.

    Returns ``(iteration_time, max stage peak)`` — the search's ranking key —
    or ``None`` if the orders are unexecutable (FIFO/dependency cycle,
    missing producer) or bust the activation cap."""
    sim = _OpSim()
    try:
        _build_from_orders(sim, topo, orders)
        _start, finish = sim.run()
    except (RuntimeError, KeyError):
        return None
    peak = max(_stage_peaks(sim, finish, topo.n_stages, 1))
    if activation_cap is not None and peak > activation_cap + 1e-9:
        return None
    return (max(finish), peak)


def _candidate_warmups(
    topo: PipelineTopology,
) -> List[Tuple[int, ...]]:
    """Deterministic warmup-vector family seeding the search.

    The 1F1B family generalizes both endpoints: ``warmup = M`` everywhere is
    exactly the GPipe order and ``warmup[s] = L-1-s`` is the textbook
    schedule.  Anchors: GPipe, the latency-aware demand (the ``1f1b``
    template, whose per-boundary term divides the round trip by the *compute*
    pair time and therefore explodes — and caps at ``M`` — once a hop
    dominates), and a *period-aware* demand that divides by the true
    steady-state period ``p = max(max_s(t_f+t_b), max hop)`` — on comm-bound
    boundaries that is the vector that keeps forward and backward transfers
    concurrent on the full-duplex link instead of degrading to GPipe's
    serialized halves.  λ-interpolations from the classic vector toward each
    anchor fill in the middle ground."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    max_hop = max((h for g in topo.boundaries for h in g), default=0.0)
    period = max(max(tf[s] + tb[s] for s in range(depth)), max_hop)
    per_need = [0] * depth
    for s in reversed(range(depth - 1)):
        per_need[s] = per_need[s + 1] + 1 + math.ceil(
            2.0 * sum(topo.boundaries[s]) / period - 1e-12
        )
    classic = [depth - 1 - s for s in range(depth)]
    anchors = [
        [m_count] * depth,       # GPipe
        _warmup_demand(topo),    # latency-aware (the 1f1b template)
        per_need,                # period-aware
        classic,
    ]
    seen: Dict[Tuple[int, ...], None] = {}
    for anchor in anchors:
        vec = tuple(min(m_count, max(0, w)) for w in anchor)
        seen.setdefault(vec, None)
    for anchor in anchors[:3]:
        for lam in _SEARCH_LAMBDAS:
            vec = tuple(
                min(m_count, max(0, round(c + lam * (a - c))))
                for c, a in zip(classic, anchor)
            )
            seen.setdefault(vec, None)
    return list(seen)


def _greedy_orders(
    topo: PipelineTopology,
    activation_cap: Optional[float],
    prefer_bwd: bool,
) -> Optional[List[List[Tuple[str, int]]]]:
    """Greedy list-scheduling candidate with critical-path lookahead.

    Event-driven: at each step every stage offers at most two *head* ops —
    its next forward and next backward in ascending-microbatch order (heads
    only, so the boundary-hop FIFOs stay consistent with the dependency
    graph by construction) — and the op with the earliest feasible start
    commits, ties broken by direction preference then by the static
    b-level (remaining critical-path length to the microbatch's exit).
    Forwards are withheld while a stage's stash sits at ``activation_cap``.
    Boundary groups are approximated as single serial resources here; the
    exact store-and-forward cost is re-measured by ``_OpSim`` when the
    candidate is evaluated.  Returns ``None`` if the walk wedges (it cannot
    for ``cap >= 1``, but the guard keeps the search total)."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    bsum = [sum(g) for g in topo.boundaries]
    blev_b = [0.0] * depth
    blev_b[0] = tb[0]
    for s in range(1, depth):
        blev_b[s] = tb[s] + bsum[s - 1] + blev_b[s - 1]
    blev_f = [0.0] * depth
    blev_f[depth - 1] = tf[depth - 1] + blev_b[depth - 1]
    for s in reversed(range(depth - 1)):
        blev_f[s] = tf[s] + bsum[s] + blev_f[s + 1]
    orders: List[List[Tuple[str, int]]] = [[] for _ in range(depth)]
    nf = [0] * depth
    nb = [0] * depth
    stage_free = [0.0] * depth
    hop_free_f = [0.0] * depth
    hop_free_b = [0.0] * depth
    arr_f: Dict[Tuple[int, int], float] = {}
    arr_b: Dict[Tuple[int, int], float] = {}
    for _ in range(2 * m_count * depth):
        best = None
        for s in range(depth):
            m = nf[s]
            if (
                m < m_count
                and (s == 0 or nf[s - 1] > m)
                and (
                    activation_cap is None
                    or nf[s] - nb[s] <= activation_cap - 1.0 + 1e-9
                )
            ):
                est = max(stage_free[s], arr_f.get((m, s), 0.0))
                cand = (est, 1 if prefer_bwd else 0, -blev_f[s], s, "f", m)
                if best is None or cand < best:
                    best = cand
            m = nb[s]
            if m < m_count and (
                (s == depth - 1 and m < nf[s])
                or (s < depth - 1 and nb[s + 1] > m)
            ):
                est = max(stage_free[s], arr_b.get((m, s), 0.0))
                cand = (est, 0 if prefer_bwd else 1, -blev_b[s], s, "b", m)
                if best is None or cand < best:
                    best = cand
        if best is None:
            return None
        est, _pref, _lev, s, kind, m = best
        orders[s].append((kind, m))
        if kind == "f":
            fin = est + tf[s]
            stage_free[s] = fin
            nf[s] += 1
            if s < depth - 1:
                done = max(fin, hop_free_f[s]) + bsum[s]
                hop_free_f[s] = done
                arr_f[(m, s + 1)] = done
            else:
                arr_b[(m, s)] = fin
        else:
            fin = est + tb[s]
            stage_free[s] = fin
            nb[s] += 1
            if s > 0:
                done = max(fin, hop_free_b[s - 1]) + bsum[s - 1]
                hop_free_b[s - 1] = done
                arr_b[(m, s - 1)] = done
    if any(nf[s] != m_count or nb[s] != m_count for s in range(depth)):
        return None
    return orders


def _swap_improve(
    topo: PipelineTopology,
    orders: Sequence[Sequence[Tuple[str, int]]],
    score: Tuple[float, float],
    activation_cap: Optional[float],
) -> List[List[Tuple[str, int]]]:
    """Hill-climb by adjacent op swaps, deterministic order, fixed budget.

    Only mixed-direction pairs are swappable — exchanging two same-direction
    ops breaks the ascending-microbatch hop FIFO and can only deadlock.
    Each candidate is re-scored on the exact simulator and adopted iff
    strictly better on ``(iteration_time, peak)``; passes repeat until a
    fixed point or the simulated-op budget runs out."""
    cur = [list(o) for o in orders]
    n_ops = 2 * topo.n_microbatches * (
        topo.n_stages + sum(len(g) for g in topo.boundaries)
    )
    evals_left = max(8, _SWAP_SIM_BUDGET // max(1, n_ops))
    improved = True
    while improved and evals_left > 0:
        improved = False
        for seq in cur:
            for i in range(len(seq) - 1):
                if evals_left <= 0:
                    break
                a, b = seq[i], seq[i + 1]
                if a[0] == b[0]:
                    continue
                seq[i], seq[i + 1] = b, a
                res = _evaluate_orders(topo, cur, activation_cap)
                evals_left -= 1
                if res is not None and res < score:
                    score = res
                    improved = True
                else:
                    seq[i], seq[i + 1] = a, b
    return cur


def _build_single_stage_alt(sim: _OpSim, topo: PipelineTopology) -> None:
    """Strict f/b alternation for the degenerate single-stage topology,
    threading each microbatch through the egress round trip (peak stash 1;
    GPipe's phase-decoupled order hides the round trip but stashes M)."""
    tf, tb = topo.stage_time_fwd, topo.stage_time_bwd
    for m in range(topo.n_microbatches):
        tail = sim.add(("S", 0), tf[0], [], ("fwd", 0, m, 0, -1))
        for h, hop in enumerate(topo.egress):
            tail = sim.add(("F", 0, h), hop, [tail], ("fwd_comm", 0, m, 0, h))
        for h in reversed(range(len(topo.egress))):
            tail = sim.add(
                ("B", 0, h), topo.egress[h], [tail], ("bwd_comm", 0, m, 0, h)
            )
        sim.add(("S", 0), tb[0], [tail], ("bwd", 0, m, 0, -1))


def _plan_synthesized(
    topo: PipelineTopology,
    activation_cap: Optional[float],
    keep_events: bool,
    virtual_stages: int = DEFAULT_VIRTUAL_STAGES,
) -> SchedulePlan:
    """Per-topology schedule search (see the module docstring).

    Seeds: the warmup-vector family (:func:`_candidate_warmups`) plus two
    greedy list-scheduling walks (:func:`_greedy_orders`).  Every candidate
    is scored on the exact op simulator; the best feasible one is locally
    improved by adjacent op swaps.  The interleaved template lives on a
    *chunked* op graph the (stage, microbatch) move set cannot reach, so it
    is tried as one last candidate — synthesized must never lose to an
    op-graph template.  Raises ``ValueError`` when no candidate satisfies
    ``activation_cap``."""
    m_count, depth = topo.n_microbatches, topo.n_stages
    if depth == 1:
        best = None
        for build in (_build_single_stage_alt, _build_gpipe):
            sim = _OpSim()
            build(sim, topo)
            _start, finish = sim.run()
            peak = max(_stage_peaks(sim, finish, 1, 1))
            if activation_cap is not None and peak > activation_cap + 1e-9:
                continue
            key = (max(finish), peak)
            if best is None or key < best[0]:
                best = (key, build)
        if best is None:
            raise ValueError(
                f"activation_cap={activation_cap} infeasible for this "
                "topology (no candidate schedule fits)"
            )
        sim = _OpSim()
        best[1](sim, topo)
        start, finish = sim.run()
        return _summarize(sim, start, finish, topo, "synthesized", 1,
                          keep_events)
    candidates: List[List[List[Tuple[str, int]]]] = []
    seen: Dict[Tuple[Tuple[Tuple[str, int], ...], ...], None] = {}
    for warmup in _candidate_warmups(topo):
        orders = _orders_from_warmup(m_count, depth, warmup)
        key = tuple(tuple(o) for o in orders)
        if key not in seen:
            seen[key] = None
            candidates.append(orders)
    for prefer_bwd in (True, False):
        greedy = _greedy_orders(topo, activation_cap, prefer_bwd)
        if greedy is not None:
            key = tuple(tuple(o) for o in greedy)
            if key not in seen:
                seen[key] = None
                candidates.append(greedy)
    best_score: Optional[Tuple[float, float]] = None
    best_orders: Optional[List[List[Tuple[str, int]]]] = None
    for orders in candidates:
        res = _evaluate_orders(topo, orders, activation_cap)
        if res is not None and (best_score is None or res < best_score):
            best_score, best_orders = res, orders
    if best_orders is None:
        raise ValueError(
            f"activation_cap={activation_cap} infeasible for this topology "
            "(no candidate schedule fits)"
        )
    final = _swap_improve(topo, best_orders, best_score, activation_cap)
    sim = _OpSim()
    _build_from_orders(sim, topo, final)
    start, finish = sim.run()
    score = (max(finish), max(_stage_peaks(sim, finish, depth, 1)))
    if virtual_stages > 1:
        isim = _OpSim()
        _build_interleaved(isim, topo, virtual_stages)
        istart, ifinish = isim.run()
        ipeak = max(_stage_peaks(isim, ifinish, depth, virtual_stages))
        if (activation_cap is None or ipeak <= activation_cap + 1e-9) and (
            max(ifinish), ipeak
        ) < score:
            return _summarize(
                isim, istart, ifinish, topo, "synthesized",
                virtual_stages, keep_events,
            )
    return _summarize(sim, start, finish, topo, "synthesized", 1, keep_events)


# ------------------------------------------------------------------ front end
def plan_from_topology(
    topo: PipelineTopology,
    schedule: str,
    *,
    virtual_stages: int = DEFAULT_VIRTUAL_STAGES,
    keep_events: bool = False,
    activation_cap: Optional[float] = None,
) -> SchedulePlan:
    """Plan one iteration of ``schedule`` over an explicit topology.

    ``activation_cap`` (OptPipe-style per-stage memory constraint) bounds the
    peak number of concurrently-stashed activations on every stage; it is
    only meaningful for the ``synthesized`` schedule, whose search treats it
    as a feasibility constraint — templates have a fixed stash profile, so
    passing a cap with one is an error rather than a silent no-op."""
    if schedule not in PIPELINE_SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r} (have: {PIPELINE_SCHEDULES})"
        )
    if virtual_stages < 1:
        raise ValueError("virtual_stages must be >= 1")
    if activation_cap is not None:
        if schedule != "synthesized":
            raise ValueError(
                "activation_cap applies only to schedule='synthesized' "
                f"(got {schedule!r})"
            )
        if activation_cap < 1.0:
            raise ValueError("activation_cap must be >= 1 (one stash)")
    if schedule == "gpipe-overlap":
        return _plan_gpipe_overlap(topo, keep_events)
    if schedule == "synthesized":
        return _plan_synthesized(
            topo, activation_cap, keep_events, virtual_stages
        )
    sim = _OpSim()
    v = 1
    if schedule == "gpipe":
        _build_gpipe(sim, topo)
    elif schedule == "1f1b":
        _build_1f1b(sim, topo)
    else:  # interleaved
        v = virtual_stages if topo.n_stages > 1 else 1
        _build_interleaved(sim, topo, v)
    start, finish = sim.run()
    return _summarize(sim, start, finish, topo, schedule, v, keep_events)


class PlanCacheInfo(NamedTuple):
    """Snapshot of the process-wide plan memo (:func:`plan_cache_info`)."""

    hits: int
    misses: int
    size: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


# Process-wide, unbounded plan memo — the same shape as the k*-table and
# decay-table memos in ``core/job.py``.  The old ``lru_cache(maxsize=256)``
# thrashed at fleet scale: with thousands of live jobs the scheduler prices
# far more than 256 distinct (topology, schedule) pairs per decision round,
# so every round re-planned everything.  Entries are small frozen
# ``SchedulePlan``s without event timelines, so an unbounded dict is cheap;
# ``clear_plan_cache`` exists for tests and long-lived processes.
_PLAN_CACHE: Dict[
    Tuple[PipelineTopology, str, int, Optional[float]], SchedulePlan
] = {}
_PLAN_HITS = 0
_PLAN_MISSES = 0


def plan_cache_info() -> PlanCacheInfo:
    """Hits/misses/size of the process-wide plan memo."""
    return PlanCacheInfo(_PLAN_HITS, _PLAN_MISSES, len(_PLAN_CACHE))


def clear_plan_cache() -> None:
    """Drop all memoized plans and reset the hit/miss counters."""
    global _PLAN_HITS, _PLAN_MISSES
    _PLAN_CACHE.clear()
    _PLAN_HITS = 0
    _PLAN_MISSES = 0


def plan_schedule(
    profile: JobProfile,
    placement: Placement,
    schedule: Optional[str] = None,
    *,
    virtual_stages: int = DEFAULT_VIRTUAL_STAGES,
    keep_events: bool = False,
    activation_cap: Optional[float] = None,
) -> SchedulePlan:
    """Plan one training iteration of ``profile`` under ``placement``.

    ``schedule`` defaults to the job's ``JobSpec.pipeline_schedule``.  Plans
    without event materialization are memoized process-wide on the
    (topology, schedule, virtual_stages, activation_cap) key — the timing
    backend prices identical placements repeatedly, across every job whose
    profile maps to the same topology.
    """
    global _PLAN_HITS, _PLAN_MISSES
    if schedule is None:
        schedule = profile.spec.pipeline_schedule
    topo = topology_from_placement(profile, placement)
    if keep_events:
        return plan_from_topology(
            topo,
            schedule,
            virtual_stages=virtual_stages,
            keep_events=True,
            activation_cap=activation_cap,
        )
    key = (topo, schedule, virtual_stages, activation_cap)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_HITS += 1
        return plan
    _PLAN_MISSES += 1
    plan = plan_from_topology(
        topo,
        schedule,
        virtual_stages=virtual_stages,
        activation_cap=activation_cap,
    )
    _PLAN_CACHE[key] = plan
    return plan
