"""Dynamic job prioritization — paper §III-B1, Eqs. (9)–(12).

    I_j        = E_j(1) / max_k E_k(1)                  (computation intensity)
    D_j        = b_j / max_k b_k                        (bandwidth sensitivity)
    alpha      = reserved WAN bw / installed WAN bw     (Eq. 11, from ledger)
    Priority_j = (1 − alpha)·(1 − I_j) + alpha·(1 − D_j)   (Eq. 12)

Both metrics are normalized over the *current pending queue* so the score
adapts as jobs drain.  ``b_j`` is evaluated at the job's ``K*`` (the PP degree
the scheduler would ideally grant — fixed at the scheduling boundary).
"""

from __future__ import annotations

from typing import Dict, List, Sequence

from .cluster import ClusterState
from .job import JobProfile


def computation_intensity(pending: Sequence[JobProfile]) -> Dict[int, float]:
    """Eq. (9) over the pending queue."""
    singles = {p.spec.job_id: p.single_gpu_execution() for p in pending}
    top = max(singles.values(), default=0.0)
    if top <= 0.0:
        return {j: 0.0 for j in singles}
    return {j: v / top for j, v in singles.items()}


def bandwidth_sensitivity(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> Dict[int, float]:
    """Eq. (10) over the pending queue, with b_j at K*(cluster size)."""
    cap = cluster.total_gpus()
    demands = {
        p.spec.job_id: p.bandwidth_requirement(p.optimal_gpus(cap))
        for p in pending
    }
    top = max(demands.values(), default=0.0)
    if top <= 0.0:
        return {j: 0.0 for j in demands}
    return {j: v / top for j, v in demands.items()}


def priority_scores(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> Dict[int, float]:
    """Eq. (12) with alpha read live from the cluster's bandwidth ledger."""
    alpha = cluster.congestion_alpha()
    intensity = computation_intensity(pending)
    sensitivity = bandwidth_sensitivity(pending, cluster)
    return {
        p.spec.job_id: (1.0 - alpha) * (1.0 - intensity[p.spec.job_id])
        + alpha * (1.0 - sensitivity[p.spec.job_id])
        for p in pending
    }


def order_by_priority(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> List[JobProfile]:
    """Descending priority; FCFS (submit time, then id) breaks ties."""
    scores = priority_scores(pending, cluster)
    return sorted(
        pending,
        key=lambda p: (
            -scores[p.spec.job_id],
            p.spec.submit_time,
            p.spec.job_id,
        ),
    )
