"""Dynamic job prioritization — paper §III-B1, Eqs. (9)–(12).

    I_j        = E_j(1) / max_k E_k(1)                  (computation intensity)
    D_j        = b_j / max_k b_k                        (bandwidth sensitivity)
    alpha      = reserved WAN bw / installed WAN bw     (Eq. 11, from ledger)
    Priority_j = (1 − alpha)·(1 − I_j) + alpha·(1 − D_j)   (Eq. 12)

Both metrics are normalized over the *current pending queue* so the score
adapts as jobs drain.  ``b_j`` is evaluated at the job's ``K*`` (the PP degree
the scheduler would ideally grant — fixed at the scheduling boundary).

Scoring is a vectorized normalize-and-combine over per-job invariants that
``JobProfile`` memoizes at first use: one pass costs O(n) numpy arithmetic
plus an O(n log n) rank, with no ``t_comp`` recomputation (see DESIGN.md).
The element-wise operations are ordered exactly as the scalar formulas above,
so scores are bit-identical to the seed implementation.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from .cluster import ClusterState
from .job import JobProfile


def computation_intensity(pending: Sequence[JobProfile]) -> Dict[int, float]:
    """Eq. (9) over the pending queue."""
    singles = {p.spec.job_id: p.single_gpu_execution() for p in pending}
    top = max(singles.values(), default=0.0)
    if top <= 0.0:
        return {j: 0.0 for j in singles}
    return {j: v / top for j, v in singles.items()}


def bandwidth_sensitivity(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> Dict[int, float]:
    """Eq. (10) over the pending queue, with b_j at K*(cluster size)."""
    cap = cluster.total_gpus()
    demands = {p.spec.job_id: p.demand_at_cap(cap) for p in pending}
    top = max(demands.values(), default=0.0)
    if top <= 0.0:
        return {j: 0.0 for j in demands}
    return {j: v / top for j, v in demands.items()}


def _score_vector(
    singles: np.ndarray, demands: np.ndarray, alpha: float
) -> np.ndarray:
    """Eq. (12) over pre-gathered invariant vectors."""
    top_e = singles.max() if singles.size else 0.0
    top_b = demands.max() if demands.size else 0.0
    intensity = singles / top_e if top_e > 0.0 else np.zeros_like(singles)
    sensitivity = demands / top_b if top_b > 0.0 else np.zeros_like(demands)
    return (1.0 - alpha) * (1.0 - intensity) + alpha * (1.0 - sensitivity)


def score_array(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> np.ndarray:
    """Eq. (12) scores as a vector aligned with ``pending``."""
    n = len(pending)
    cap = cluster.total_gpus()
    singles = np.fromiter(
        (p.single_gpu_execution() for p in pending), dtype=float, count=n
    )
    demands = np.fromiter(
        (p.demand_at_cap(cap) for p in pending), dtype=float, count=n
    )
    return _score_vector(singles, demands, cluster.congestion_alpha())


def priority_scores(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> Dict[int, float]:
    """Eq. (12) with alpha read live from the cluster's bandwidth ledger."""
    scores = score_array(pending, cluster)
    return {p.spec.job_id: float(s) for p, s in zip(pending, scores)}


def rank_order(
    scores: np.ndarray, submits: np.ndarray, job_ids: np.ndarray
) -> np.ndarray:
    """Index permutation sorting by (-score, submit, id) — descending priority
    with FCFS tie-breaks, identical to the seed's tuple sort (ids are unique,
    so the order is total and stability is irrelevant)."""
    return np.lexsort((job_ids, submits, -scores))


def order_by_priority(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> List[JobProfile]:
    """Descending priority; FCFS (submit time, then id) breaks ties."""
    n = len(pending)
    scores = score_array(pending, cluster)
    submits = np.fromiter(
        (p.spec.submit_time for p in pending), dtype=float, count=n
    )
    ids = np.fromiter((p.spec.job_id for p in pending), dtype=np.int64, count=n)
    return [pending[i] for i in rank_order(scores, submits, ids)]
