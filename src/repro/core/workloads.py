"""Paper workloads: Table II regions, Table III jobs, Fig. 1 motivation setup.

Iteration counts derive from the paper's dataset assignment (Alpaca-52k,
WikiText-103, OpenWebText) as one pass over the dataset at the job's global
batch size, capped by ``max_iterations`` so simulated JCTs land in the paper's
"hours" scale (the paper reports normalized metrics only; relative claims are
what we validate).
"""

from __future__ import annotations

import math
import random
from typing import Iterable, List, Optional, Sequence

from .cluster import (
    BandwidthTrace,
    ClusterState,
    EnvUpdate,
    GpuPool,
    Link,
    Region,
)
from .job import JobProfile, JobSpec, ModelSpec

# ------------------------------------------------------------------- Table II
TABLE_II_REGIONS = [
    Region("eu-west", 64, 0.251),
    Region("us-east-2", 64, 0.156),
    Region("eu-central", 16, 0.288),
    Region("ea-east", 128, 0.191),
    Region("sea-south", 32, 0.222),
    Region("oc-east", 32, 0.295),
]

TABLE_II_REGION_GBPS = {
    "eu-west": 50.0,
    "us-east-2": 90.0,
    "eu-central": 30.0,
    "ea-east": 70.0,
    "sea-south": 50.0,
    "oc-east": 70.0,
}


def paper_cluster(
    *, bandwidth_factor: float = 1.0, capacity_factor: float = 1.0
) -> ClusterState:
    """Table II cluster with ``B_{i,j} = (B_i + B_j)/2`` links."""
    cluster = ClusterState.from_region_bandwidths(
        TABLE_II_REGIONS, TABLE_II_REGION_GBPS
    )
    if bandwidth_factor != 1.0 or capacity_factor != 1.0:
        cluster = cluster.scaled(
            bandwidth_factor=bandwidth_factor, capacity_factor=capacity_factor
        )
    return cluster


# ----------------------------------------------------- heterogeneous fleets
#: Accelerator generation catalog for the heterogeneous scenarios: effective
#: FLOP/s, usable memory, and board power per GPU.  "a100" matches the
#: profile's reference hardware (``job.DEFAULT_GPU_*``); the others bracket
#: it one generation up/down.
GPU_CATALOG = {
    "h100": dict(flops=300e12, memory=80e9, gpu_kw=0.70),
    "a100": dict(flops=140e12, memory=44e9, gpu_kw=0.30),
    "v100": dict(flops=60e12, memory=28e9, gpu_kw=0.25),
}

#: Per-region generation mix of the ``hetero-fleet`` scenario: Table II
#: capacities split between two generations (fractions of the region's
#: capacity, newest generation first).  Big cheap regions got refreshed
#: first; the small expensive ones still run the previous generation.
HETERO_FLEET_MIX = {
    "eu-west": (("h100", 0.25), ("a100", 0.75)),
    "us-east-2": (("h100", 0.50), ("a100", 0.50)),
    "eu-central": (("v100", 1.0),),
    "ea-east": (("a100", 0.50), ("v100", 0.50)),
    "sea-south": (("a100", 0.50), ("v100", 0.50)),
    "oc-east": (("h100", 0.25), ("a100", 0.75)),
}


def hetero_fleet_cluster() -> ClusterState:
    """Table II regions/prices/links with mixed accelerator generations: each
    region's GPU capacity is split per :data:`HETERO_FLEET_MIX` into typed
    pools drawn from :data:`GPU_CATALOG` (all on-demand)."""
    regions = []
    for base in TABLE_II_REGIONS:
        mix = HETERO_FLEET_MIX[base.name]
        pools, left = [], base.gpu_capacity
        for gtype, frac in mix[:-1]:
            count = int(round(base.gpu_capacity * frac))
            pools.append(GpuPool(gtype, count, **GPU_CATALOG[gtype]))
            left -= count
        gtype = mix[-1][0]
        pools.append(GpuPool(gtype, left, **GPU_CATALOG[gtype]))
        regions.append(Region.with_pools(base.name, base.price_kwh, pools))
    return ClusterState.from_region_bandwidths(regions, TABLE_II_REGION_GBPS)


#: Spot discount of the ``spot-churn`` scenario: spot capacity bills at this
#: fraction of the regional on-demand electricity rate.
DEFAULT_SPOT_DISCOUNT = 0.35


def spot_fleet_cluster(
    *, spot_fraction: float = 0.4, spot_discount: float = DEFAULT_SPOT_DISCOUNT
) -> ClusterState:
    """Table II cluster where ``spot_fraction`` of every region's capacity is
    reclaimable spot capacity at ``spot_discount ×`` the on-demand rate; the
    hardware itself is uniform (reference a100-class), so the scenario
    isolates the spot price/reclaim trade-off from generation mixing."""
    if not 0.0 < spot_fraction < 1.0:
        raise ValueError("spot_fraction must be in (0, 1)")
    regions = []
    for base in TABLE_II_REGIONS:
        n_spot = int(round(base.gpu_capacity * spot_fraction))
        pools = [
            GpuPool("a100", base.gpu_capacity - n_spot),
            GpuPool(
                "a100-spot", n_spot, spot=True, price_mult=spot_discount
            ),
        ]
        regions.append(Region.with_pools(base.name, base.price_kwh, pools))
    return ClusterState.from_region_bandwidths(regions, TABLE_II_REGION_GBPS)


# ------------------------------------------------------------------ Table III
#: (name, params, layers, hidden, global batch size)
TABLE_III_MODELS = [
    ("flm-101b", 101e9, 80, 10240, 128),
    ("solar-open-100b", 100e9, 48, 4096, 128),
    ("llama-3.1-70b", 70e9, 80, 8192, 128),
    ("falcon-40b", 40e9, 60, 8192, 256),
    ("qwen2.5-32b", 32e9, 64, 5120, 256),
    ("gemma-3-27b", 27e9, 62, 5376, 256),
    ("ministral-3-14b", 14e9, 40, 5120, 512),
    ("qwen2.5-14b", 14e9, 48, 5120, 512),
]

#: dataset -> (samples, simulated epoch fraction).  The fraction is a pure
#: simulation knob: one full OpenWebText epoch on a 101B model is weeks of
#: simulated time, which only rescales every policy identically; trimming the
#: larger corpora keeps JCTs in the paper's "hours" regime while preserving
#: the heavy-tailed job-duration mix that drives the HoL analysis.
DATASETS = {
    "alpaca-52k": (52_002, 1.0),
    "wikitext-103": (1_810_000, 0.20),
    "openwebtext": (8_010_000, 0.06),
}


def paper_jobs(
    *,
    n_jobs: int = 8,
    seed: int = 0,
    submit_times: Optional[Sequence[float]] = None,
    timing_model: str = "analytic",
    pipeline_schedule: str = "gpipe",
) -> List[JobSpec]:
    """Table III jobs with the paper's random dataset assignment.  For
    ``n_jobs > 8`` (Fig. 7 workload-intensity study) the model list cycles.
    ``timing_model`` / ``pipeline_schedule`` select the per-job timing
    backend (``core/timing.py`` seam); the defaults are the seed's
    closed-form Eq. (1)."""
    rng = random.Random(seed)
    jobs: List[JobSpec] = []
    datasets = list(DATASETS.items())
    for i in range(n_jobs):
        name, params, layers, hidden, batch = TABLE_III_MODELS[
            i % len(TABLE_III_MODELS)
        ]
        ds_name, (ds_samples, ds_frac) = datasets[rng.randrange(len(datasets))]
        iters = max(1, math.ceil(ds_samples * ds_frac / batch))
        spec = ModelSpec(
            name=f"{name}#{i}" if i >= len(TABLE_III_MODELS) else name,
            n_params=params,
            n_layers=layers,
            hidden=hidden,
            batch_size=batch,
        )
        jobs.append(
            JobSpec(
                job_id=i,
                model=spec,
                iterations=iters,
                submit_time=0.0 if submit_times is None else submit_times[i],
                timing_model=timing_model,
                pipeline_schedule=pipeline_schedule,
            )
        )
    return jobs


def paper_profiles(
    jobs: Optional[Sequence[JobSpec]] = None, **profile_kwargs
) -> List[JobProfile]:
    if jobs is None:
        jobs = paper_jobs()
    return [JobProfile(j, **profile_kwargs) for j in jobs]


# ------------------------------------------------------------ arrival traces
def poisson_submit_times(
    n_jobs: int, *, mean_interarrival_s: float = 1800.0, seed: int = 0
) -> List[float]:
    """Online arrivals: exponential inter-arrival gaps (Poisson process),
    replacing the seed's all-at-t=0 assumption.  Deterministic per seed."""
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n_jobs):
        t += rng.expovariate(1.0 / mean_interarrival_s)
        out.append(t)
    return out


def bursty_submit_times(
    n_jobs: int,
    *,
    burst_size: int = 4,
    burst_gap_s: float = 7200.0,
    intra_burst_s: float = 60.0,
    seed: int = 0,
) -> List[float]:
    """Bursty arrivals: tight clumps of ``burst_size`` jobs separated by long
    gaps — the HoL-amplifying regime (queue spikes while resources drain)."""
    rng = random.Random(seed)
    out: List[float] = []
    t = 0.0
    while len(out) < n_jobs:
        for _ in range(min(burst_size, n_jobs - len(out))):
            out.append(t + rng.uniform(0.0, intra_burst_s))
        t += burst_gap_s
    out.sort()
    return out


# ----------------------------------------------------------- bandwidth traces
def _inter_region_links(cluster: ClusterState) -> List[Link]:
    return sorted(cluster.bandwidth)


def diurnal_trace(
    cluster: ClusterState,
    *,
    period_s: float = 86_400.0,
    amplitude: float = 0.5,
    steps_per_period: int = 8,
    horizon_s: float = 86_400.0,
    floor: float = 0.05,
) -> BandwidthTrace:
    """Piecewise-constant diurnal wave over every inter-region link.

    The multiplier follows ``1 - amplitude * (0.5 - 0.5*cos(2*pi*t/T))`` —
    full capacity at t=0 (off-peak), dipping to ``1 - amplitude`` half a
    period in (business-hours congestion), sampled at ``steps_per_period``
    plateaus per period.  Deterministic: no randomness involved.
    """
    links = _inter_region_links(cluster)
    updates: List[EnvUpdate] = []
    step = period_s / steps_per_period
    t = step
    while t <= horizon_s + 1e-9:
        phase = 2.0 * math.pi * t / period_s
        m = max(floor, 1.0 - amplitude * (0.5 - 0.5 * math.cos(phase)))
        updates.append(EnvUpdate(time=t, bandwidth={l: m for l in links}))
        t += step
    return BandwidthTrace(updates)


def link_flap_trace(
    links: Iterable[Link],
    *,
    t_down_s: float,
    t_up_s: Optional[float] = None,
    drop_to: float = 0.1,
    symmetric: bool = True,
) -> BandwidthTrace:
    """Step-drop ("link flap"): the listed links fall to ``drop_to`` × their
    installed capacity at ``t_down_s`` and recover to full at ``t_up_s``
    (never, when None).  ``symmetric`` also flaps each reverse direction."""
    flapped: List[Link] = []
    for u, v in links:
        flapped.append((u, v))
        if symmetric:
            flapped.append((v, u))
    down = {l: drop_to for l in flapped}
    updates = [EnvUpdate(time=t_down_s, bandwidth=down)]
    if t_up_s is not None:
        if t_up_s <= t_down_s:
            raise ValueError("t_up_s must be after t_down_s")
        updates.append(
            EnvUpdate(time=t_up_s, bandwidth={l: 1.0 for l in flapped})
        )
    return BandwidthTrace(updates)


def random_fluctuation_trace(
    cluster: ClusterState,
    *,
    seed: int = 0,
    interval_s: float = 3600.0,
    horizon_s: float = 86_400.0,
    lo: float = 0.4,
    hi: float = 1.0,
) -> BandwidthTrace:
    """Seeded random per-link fluctuation: every ``interval_s`` each link
    independently draws a multiplier uniform in [lo, hi].  Same seed ⇒ the
    identical trace (links are visited in sorted order)."""
    if not 0.0 <= lo <= hi:
        raise ValueError("need 0 <= lo <= hi")
    rng = random.Random(seed)
    links = _inter_region_links(cluster)
    updates: List[EnvUpdate] = []
    t = interval_s
    while t <= horizon_s + 1e-9:
        updates.append(
            EnvUpdate(
                time=t,
                bandwidth={l: rng.uniform(lo, hi) for l in links},
            )
        )
        t += interval_s
    return BandwidthTrace(updates)


def spot_reclaim_trace(
    cluster: ClusterState,
    *,
    seed: int = 0,
    interval_s: float = 3600.0,
    horizon_s: float = 86_400.0,
    reclaim_prob: float = 0.25,
    reclaim_levels: Sequence[float] = (0.0, 0.5),
) -> BandwidthTrace:
    """Seeded spot-capacity churn: every ``interval_s`` each spot pool of the
    cluster independently either gets (partially) reclaimed — multiplier
    drawn from ``reclaim_levels`` with probability ``reclaim_prob`` — or is
    restored to its full installed count.  Multipliers are absolute against
    the installed pool count (no compounding), mirroring the bandwidth
    traces; reclaims that strand running jobs route through the simulator's
    forced-preemption pass.  Same seed ⇒ the identical trace (pools are
    visited in sorted (region, type) order)."""
    if not 0.0 <= reclaim_prob <= 1.0:
        raise ValueError("reclaim_prob must be in [0, 1]")
    for lvl in reclaim_levels:
        if not 0.0 <= lvl <= 1.0:
            raise ValueError("reclaim levels must be in [0, 1]")
    pools = cluster.spot_pools()
    if not pools:
        raise ValueError("cluster has no spot pools to reclaim")
    rng = random.Random(seed)
    updates: List[EnvUpdate] = []
    t = interval_s
    while t <= horizon_s + 1e-9:
        spot = {}
        for key in pools:
            if rng.random() < reclaim_prob:
                spot[key] = reclaim_levels[
                    rng.randrange(len(reclaim_levels))
                ]
            else:
                spot[key] = 1.0
        updates.append(EnvUpdate(time=t, spot=spot))
        t += interval_s
    return BandwidthTrace(updates)


def price_spike_trace(
    regions: Iterable[str],
    *,
    t_start_s: float,
    t_end_s: Optional[float] = None,
    factor: float = 3.0,
) -> BandwidthTrace:
    """Electricity-price spike: the listed regions' prices scale by ``factor``
    during [t_start_s, t_end_s).  Prices never trigger preemption — they only
    steer subsequent Cost-Min allocations and the cost of new segments."""
    spiked = list(regions)
    updates = [EnvUpdate(time=t_start_s, prices={r: factor for r in spiked})]
    if t_end_s is not None:
        if t_end_s <= t_start_s:
            raise ValueError("t_end_s must be after t_start_s")
        updates.append(
            EnvUpdate(time=t_end_s, prices={r: 1.0 for r in spiked})
        )
    return BandwidthTrace(updates)


# ---------------------------------------------------------------- Fig. 1 demo
def motivation_cluster() -> ClusterState:
    """Fig. 1: four regions A–D; A–C share a fat 1000 Mbps link, B–D a thin
    200 Mbps link, everything else middling."""
    regions = [
        Region("A", 4, 0.230),
        Region("B", 3, 0.222),
        Region("C", 2, 0.191),
        Region("D", 2, 0.291),
    ]
    gbps = {
        ("A", "C"): 1.0,     # 1000 Mbps (the fat pair in Fig. 1)
        ("B", "D"): 0.2,     # 200 Mbps (the thin pair)
        ("A", "B"): 0.1,
        ("A", "D"): 0.05,
        ("B", "C"): 0.1,
        ("C", "D"): 0.05,
    }
    return ClusterState.build(regions, gbps, symmetric=True)


def motivation_jobs() -> List[JobSpec]:
    """Job P (Qwen2.5-14B) before Job Q (Llama-3.1-70B), Alpaca-52k, scaled to
    the Fig. 1 toy cluster (single-digit GPUs => trimmed iteration counts)."""
    p = ModelSpec(
        "qwen2.5-14b", 14e9, 48, 5120, batch_size=16, seq_len=2048
    )
    q = ModelSpec(
        "llama-3.1-70b", 70e9, 80, 8192, batch_size=16, seq_len=2048
    )
    return [
        JobSpec(job_id=0, model=p, iterations=6),
        JobSpec(job_id=1, model=q, iterations=6),
    ]


def motivation_profiles(**kwargs) -> List[JobProfile]:
    # The toy cluster has 2–4 GPUs per region: relax the memory floor so the
    # 14B/70B stand-ins fit (the paper's figure allocates 4–6 stages total).
    # Fig. 1's own arithmetic (50 ms/μbatch, 30 MB activations, 0.2–1 Gbps
    # links) implies true-A6000 effective throughput, unlike the Table II
    # regime — so the toy uses ~20 TF/GPU (see DESIGN.md).
    kwargs.setdefault("gpu_memory", 400e9)
    kwargs.setdefault("gpu_flops", 20e12)
    return [JobProfile(j, **kwargs) for j in motivation_jobs()]
