"""Paper workloads: Table II regions, Table III jobs, Fig. 1 motivation setup.

Iteration counts derive from the paper's dataset assignment (Alpaca-52k,
WikiText-103, OpenWebText) as one pass over the dataset at the job's global
batch size, capped by ``max_iterations`` so simulated JCTs land in the paper's
"hours" scale (the paper reports normalized metrics only; relative claims are
what we validate).
"""

from __future__ import annotations

import math
import random
from typing import Dict, List, Optional, Sequence

from .cluster import ClusterState, Region
from .job import JobProfile, JobSpec, ModelSpec

# ------------------------------------------------------------------- Table II
TABLE_II_REGIONS = [
    Region("eu-west", 64, 0.251),
    Region("us-east-2", 64, 0.156),
    Region("eu-central", 16, 0.288),
    Region("ea-east", 128, 0.191),
    Region("sea-south", 32, 0.222),
    Region("oc-east", 32, 0.295),
]

TABLE_II_REGION_GBPS = {
    "eu-west": 50.0,
    "us-east-2": 90.0,
    "eu-central": 30.0,
    "ea-east": 70.0,
    "sea-south": 50.0,
    "oc-east": 70.0,
}


def paper_cluster(
    *, bandwidth_factor: float = 1.0, capacity_factor: float = 1.0
) -> ClusterState:
    """Table II cluster with ``B_{i,j} = (B_i + B_j)/2`` links."""
    cluster = ClusterState.from_region_bandwidths(
        TABLE_II_REGIONS, TABLE_II_REGION_GBPS
    )
    if bandwidth_factor != 1.0 or capacity_factor != 1.0:
        cluster = cluster.scaled(
            bandwidth_factor=bandwidth_factor, capacity_factor=capacity_factor
        )
    return cluster


# ------------------------------------------------------------------ Table III
#: (name, params, layers, hidden, global batch size)
TABLE_III_MODELS = [
    ("flm-101b", 101e9, 80, 10240, 128),
    ("solar-open-100b", 100e9, 48, 4096, 128),
    ("llama-3.1-70b", 70e9, 80, 8192, 128),
    ("falcon-40b", 40e9, 60, 8192, 256),
    ("qwen2.5-32b", 32e9, 64, 5120, 256),
    ("gemma-3-27b", 27e9, 62, 5376, 256),
    ("ministral-3-14b", 14e9, 40, 5120, 512),
    ("qwen2.5-14b", 14e9, 48, 5120, 512),
]

#: dataset -> (samples, simulated epoch fraction).  The fraction is a pure
#: simulation knob: one full OpenWebText epoch on a 101B model is weeks of
#: simulated time, which only rescales every policy identically; trimming the
#: larger corpora keeps JCTs in the paper's "hours" regime while preserving
#: the heavy-tailed job-duration mix that drives the HoL analysis.
DATASETS = {
    "alpaca-52k": (52_002, 1.0),
    "wikitext-103": (1_810_000, 0.20),
    "openwebtext": (8_010_000, 0.06),
}


def paper_jobs(
    *,
    n_jobs: int = 8,
    seed: int = 0,
    submit_times: Optional[Sequence[float]] = None,
) -> List[JobSpec]:
    """Table III jobs with the paper's random dataset assignment.  For
    ``n_jobs > 8`` (Fig. 7 workload-intensity study) the model list cycles."""
    rng = random.Random(seed)
    jobs: List[JobSpec] = []
    datasets = list(DATASETS.items())
    for i in range(n_jobs):
        name, params, layers, hidden, batch = TABLE_III_MODELS[
            i % len(TABLE_III_MODELS)
        ]
        ds_name, (ds_samples, ds_frac) = datasets[rng.randrange(len(datasets))]
        iters = max(1, math.ceil(ds_samples * ds_frac / batch))
        spec = ModelSpec(
            name=f"{name}#{i}" if i >= len(TABLE_III_MODELS) else name,
            n_params=params,
            n_layers=layers,
            hidden=hidden,
            batch_size=batch,
        )
        jobs.append(
            JobSpec(
                job_id=i,
                model=spec,
                iterations=iters,
                submit_time=0.0 if submit_times is None else submit_times[i],
            )
        )
    return jobs


def paper_profiles(
    jobs: Optional[Sequence[JobSpec]] = None, **profile_kwargs
) -> List[JobProfile]:
    if jobs is None:
        jobs = paper_jobs()
    return [JobProfile(j, **profile_kwargs) for j in jobs]


# ---------------------------------------------------------------- Fig. 1 demo
def motivation_cluster() -> ClusterState:
    """Fig. 1: four regions A–D; A–C share a fat 1000 Mbps link, B–D a thin
    200 Mbps link, everything else middling."""
    regions = [
        Region("A", 4, 0.230),
        Region("B", 3, 0.222),
        Region("C", 2, 0.191),
        Region("D", 2, 0.291),
    ]
    gbps = {
        ("A", "C"): 1.0,     # 1000 Mbps (the fat pair in Fig. 1)
        ("B", "D"): 0.2,     # 200 Mbps (the thin pair)
        ("A", "B"): 0.1,
        ("A", "D"): 0.05,
        ("B", "C"): 0.1,
        ("C", "D"): 0.05,
    }
    return ClusterState.build(regions, gbps, symmetric=True)


def motivation_jobs() -> List[JobSpec]:
    """Job P (Qwen2.5-14B) before Job Q (Llama-3.1-70B), Alpaca-52k, scaled to
    the Fig. 1 toy cluster (single-digit GPUs => trimmed iteration counts)."""
    p = ModelSpec(
        "qwen2.5-14b", 14e9, 48, 5120, batch_size=16, seq_len=2048
    )
    q = ModelSpec(
        "llama-3.1-70b", 70e9, 80, 8192, batch_size=16, seq_len=2048
    )
    return [
        JobSpec(job_id=0, model=p, iterations=6),
        JobSpec(job_id=1, model=q, iterations=6),
    ]


def motivation_profiles(**kwargs) -> List[JobProfile]:
    # The toy cluster has 2–4 GPUs per region: relax the memory floor so the
    # 14B/70B stand-ins fit (the paper's figure allocates 4–6 stages total).
    # Fig. 1's own arithmetic (50 ms/μbatch, 30 MB activations, 0.2–1 Gbps
    # links) implies true-A6000 effective throughput, unlike the Table II
    # regime — so the toy uses ~20 TF/GPU (see DESIGN.md).
    kwargs.setdefault("gpu_memory", 400e9)
    kwargs.setdefault("gpu_flops", 20e12)
    return [JobProfile(j, **kwargs) for j in motivation_jobs()]
