"""Cost-Min Allocator — paper Alg. 2.

Given an ordered region path and a target GPU count ``g``: first pin one GPU
per path region (pipeline continuity), then pour the surplus into the
cheapest regions first, capped by each region's *free* capacity.

On a heterogeneous cluster the pour is (region, type)-granular: the surplus
fills the globally cheapest *pool cells* along the path first — effective
cell price = live regional $/kWh × the pool's spot discount × board kW —
which is what lets Cost-Min prefer a remote region's spot pool over the
local on-demand one.  Within any region the cells fill in the cluster's
deterministic assign order, so the typed grant ``build_placement`` later
derives (``ClusterState.assign_types``) matches what was priced here.
Single-type clusters keep the seed's exact region-granular code path.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Dict, List, Mapping, Optional

import numpy as np

from .cluster import ClusterState
from .kernels_decide import cheapest_fill_order

if TYPE_CHECKING:
    # Typing-only obs seam (reprolint RPL601) — never imported at runtime.
    from repro.obs.protocol import TraceRecorder


def _cost_min_allocate_typed(
    cluster: ClusterState, path: List[str], g: int
) -> Dict[str, int]:
    """(region, type)-granular Alg. 2 pour; returns region totals (the typed
    split is re-derived deterministically by ``assign_types``)."""
    # Step 1: pipeline continuity — one GPU per traversed region, taken from
    # the region's cheapest cell (assign order).
    alloc = {r: 1 for r in path}
    remaining = g - len(path)

    # Step 2: surplus to the globally cheapest (region, type) cells.  Each
    # region's first cell already holds the pinned GPU.  The kW-inclusive
    # rate / region-name / type-name ordering runs as one vectorized lexsort
    # (``cheapest_fill_order``): region names sort exactly as their
    # ``_name_rank`` and type names as their (sorted) column index, so the
    # order is identical to the scalar ``sorted(..., (rate, region, type))``.
    cells: List[tuple] = []
    rates: List[float] = []
    rranks: List[int] = []
    tranks: List[int] = []
    for r in path:
        free_t = cluster.free_gpus_typed(r)
        first = True
        for gtype in cluster.gpu_types(r):
            avail = free_t[gtype]
            if first and avail > 0:
                avail -= 1  # the pinned continuity GPU
                first = False
            if avail > 0:
                cells.append((r, avail))
                rates.append(cluster.pool_rate(r, gtype))
                rranks.append(cluster.region_rank(r))
                tranks.append(cluster.gpu_type_rank(gtype))
    order = cheapest_fill_order(
        np.asarray(rates), np.asarray(rranks), np.asarray(tranks)
    )
    for ci in order:
        if remaining == 0:
            break
        r, avail = cells[ci]
        add = min(avail, remaining)
        alloc[r] += add
        remaining -= add
    if remaining != 0:  # unreachable given the capacity pre-check
        raise ValueError("allocator failed to place all GPUs")
    return alloc


def cost_min_allocate(
    cluster: ClusterState,
    path: List[str],
    g: int,
    *,
    recorder: Optional["TraceRecorder"] = None,
) -> Dict[str, int]:
    """Alg. 2.  Raises if the path cannot host ``g`` GPUs.

    ``recorder`` (only passed by callers that see ``traceable`` below)
    receives an ``on_alloc`` record of the successful pour — observational
    only, never affects the grant."""
    if len(set(path)) != len(path):
        raise ValueError("path revisits a region")
    if g < len(path):
        raise ValueError(f"need >= {len(path)} GPUs for a {len(path)}-region path")
    free = {r: cluster.free_gpus[r] for r in path}
    for r in path:
        if free[r] < 1:
            raise ValueError(f"region {r} has no free GPU for its stage")
    if sum(sorted(free.values())) < g:
        raise ValueError("path capacity below target g")

    if cluster.is_heterogeneous:
        alloc = _cost_min_allocate_typed(cluster, path, g)
        if recorder is not None:
            recorder.on_alloc(path, g, alloc)
        return alloc

    # Step 1: pipeline continuity — one GPU per traversed region.
    alloc = {r: 1 for r in path}
    remaining = g - len(path)

    # Step 2: surplus to the cheapest regions first — the same vectorized
    # (rate, region-name) lexsort the typed pour uses (type rank degenerate);
    # identical order to the scalar ``sorted(path, key=(price, name))``.
    prices = np.asarray([cluster.price(r) for r in path])
    rranks = np.asarray([cluster.region_rank(r) for r in path])
    for pi in cheapest_fill_order(
        prices, rranks, np.zeros(len(path), dtype=np.int64)
    ):
        if remaining == 0:
            break
        r = path[pi]
        add = min(free[r] - alloc[r], remaining)
        alloc[r] += add
        remaining -= add
    if remaining != 0:  # unreachable given the capacity pre-check
        raise ValueError("allocator failed to place all GPUs")
    if recorder is not None:
        recorder.on_alloc(path, g, alloc)
    return alloc


def uniform_allocate(
    cluster: ClusterState,
    path: List[str],
    g: int,
    *,
    recorder: Optional["TraceRecorder"] = None,
) -> Dict[str, int]:
    """Ablation "w/o Cost-Min" (paper §IV-E): spread GPUs evenly over the
    path, ignoring prices; overflow beyond a region's free capacity spills to
    the next region in path order."""
    if g < len(path):
        raise ValueError("need at least one GPU per path region")
    free = {r: cluster.free_gpus[r] for r in path}
    if any(free[r] < 1 for r in path) or sum(sorted(free.values())) < g:
        raise ValueError("path cannot host g GPUs")
    base, extra = divmod(g, len(path))
    alloc = {r: min(free[r], base + (1 if i < extra else 0))
             for i, r in enumerate(path)}
    alloc = {r: max(1, n) for r, n in alloc.items()}
    spill = g - sum(sorted(alloc.values()))
    for r in path:  # resolve rounding/capacity spill deterministically
        if spill <= 0:
            break
        add = min(free[r] - alloc[r], spill)
        alloc[r] += add
        spill -= add
    if spill > 0:
        raise ValueError("uniform allocator spill failure")
    if recorder is not None:
        recorder.on_alloc(path, g, alloc)
    return alloc


# Marks an allocator as accepting the keyword-only ``recorder=`` — the
# Pathfinder only forwards its recorder to allocators that opt in, so the
# positional 3-arg ``AllocatorFn`` contract holds for custom allocators.
cost_min_allocate.traceable = True  # type: ignore[attr-defined]
uniform_allocate.traceable = True  # type: ignore[attr-defined]


def allocation_cost_rate(
    cluster: ClusterState, alloc: Mapping[str, int]
) -> float:
    """Σ_r n_r · P_r (the Eq. 4 price integrand, in $/kWh·GPU units).

    Float accumulation in the allocation's own (path) order — pinned to the
    reference implementation; re-sorting would move last-ulp rounding on a
    quantity the engine compares against thresholds."""
    return sum(cluster.price(r) * n for r, n in alloc.items())  # reprolint: disable=RPL104
