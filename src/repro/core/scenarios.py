"""Named, reproducible dynamic-environment scenarios.

Each scenario bundles a cluster, a job set (with an arrival process), and an
optional ``BandwidthTrace`` into one reproducible unit: ``build(seed=s)``
twice yields identical inputs, and the simulator guarantees identical
``SimulationResult``s from identical inputs — so every scenario × policy ×
seed cell in ``benchmarks/dynamic_scenarios.py`` (and the golden-trace
tests) is deterministic.

The registry names the regimes the paper's headline claims live in:

- ``static-paper``   — Table II/III, all jobs at t=0, fixed bandwidth: the
  seed's setup, kept bit-identical across both engines (parity surface).
- ``diurnal``        — Poisson arrivals under a diurnal WAN-capacity wave
  (business-hours dips), the "real-time network utilization" regime.
- ``link-flap``      — the fattest inter-region link collapses mid-run and
  recovers later: the preemptive-migration stress case.
- ``burst-arrival``  — clumped submissions, amplifying HoL blocking.
- ``price-spike``    — the cheapest regions' electricity triples for a few
  hours; tests Cost-Min's reaction plus piecewise repricing of running
  segments and price-aware *voluntary* migration (never a forced Eq. 6
  eviction).
- ``mixed-stress``   — bursty arrivals + random link fluctuation + a price
  spike, all at once.
- ``hetero-fleet``   — Table II capacities split across mixed accelerator
  generations (typed h100/a100/v100 pools); timing, memory floors, and
  Cost-Min pricing run against the granted types.
- ``spot-churn``     — 40% of every region is discounted spot capacity under
  seeded hourly reclaim churn; reclaims preempt through the Eq. 5 pool
  ledger exactly like Eq. 6 bandwidth drops.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from .cluster import BandwidthTrace, ClusterState
from .job import JobProfile
from .scheduler import (
    DEFAULT_RESTART_PENALTY_S,
    SchedulingPolicy,
    SimulationResult,
    simulate,
)
from .workloads import (
    bursty_submit_times,
    diurnal_trace,
    hetero_fleet_cluster,
    link_flap_trace,
    paper_cluster,
    paper_jobs,
    paper_profiles,
    poisson_submit_times,
    price_spike_trace,
    random_fluctuation_trace,
    spot_fleet_cluster,
    spot_reclaim_trace,
)

#: A builder maps (seed, n_jobs, profile_kwargs, job_kwargs) to the
#: scenario's inputs.  ``job_kwargs`` reaches ``paper_jobs`` (per-``JobSpec``
#: knobs — e.g. ``timing_model="microplan"``, ``pipeline_schedule="1f1b"``
#: to price the whole scenario with the discrete schedule planner);
#: ``profile_kwargs`` reaches ``JobProfile`` as before.
_Builder = Callable[
    [int, int, dict, dict],
    Tuple[ClusterState, List[JobProfile], Optional[BandwidthTrace]],
]


#: Sentinel distinguishing "caller did not override" from an explicit None
#: (= disable voluntary migration) in ``Scenario.run``.
_UNSET = object()


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One registered scenario: metadata + input factory."""

    name: str
    description: str
    dynamic: bool  # True ⇒ vectorized-engine-only (has a trace)
    default_n_jobs: int
    builder: _Builder
    restart_penalty_s: float = DEFAULT_RESTART_PENALTY_S
    #: True ⇒ the cluster has typed GPU pools (heterogeneous generations
    #: and/or spot capacity).  These scenarios are swept by
    #: ``benchmarks/hetero_scenarios.py``; ``benchmarks/dynamic_scenarios.py``
    #: skips them so its single-type CI cells (and the legacy-engine parity
    #: surface) stay exactly as before.
    hetero: bool = False
    #: Scenario-default price-aware voluntary-migration threshold (None =
    #: off).  ``run(voluntary_migration_threshold=...)`` overrides it either
    #: way, which is how the benchmarks A/B the stay-put baseline.
    voluntary_migration_threshold: Optional[float] = None

    def build(
        self,
        *,
        seed: int = 0,
        n_jobs: Optional[int] = None,
        profile_kwargs: Optional[dict] = None,
        job_kwargs: Optional[dict] = None,
    ) -> Tuple[ClusterState, List[JobProfile], Optional[BandwidthTrace]]:
        n = self.default_n_jobs if n_jobs is None else n_jobs
        return self.builder(
            seed, n, dict(profile_kwargs or {}), dict(job_kwargs or {})
        )

    def run(
        self,
        policy: SchedulingPolicy,
        *,
        seed: int = 0,
        n_jobs: Optional[int] = None,
        engine: str = "vectorized",
        profile_kwargs: Optional[dict] = None,
        job_kwargs: Optional[dict] = None,
        voluntary_migration_threshold: object = _UNSET,
        decision_backend: str = "numpy",
        recorder: Optional[object] = None,
    ) -> SimulationResult:
        cluster, profiles, trace = self.build(
            seed=seed,
            n_jobs=n_jobs,
            profile_kwargs=profile_kwargs,
            job_kwargs=job_kwargs,
        )
        threshold = (
            self.voluntary_migration_threshold
            if voluntary_migration_threshold is _UNSET
            else voluntary_migration_threshold
        )
        return simulate(
            cluster,
            profiles,
            policy,
            engine=engine,
            trace=trace,
            restart_penalty_s=self.restart_penalty_s,
            voluntary_migration_threshold=threshold,
            decision_backend=decision_backend,
            recorder=recorder,
        )


SCENARIOS: Dict[str, Scenario] = {}


def _register(scenario: Scenario) -> Scenario:
    if scenario.name in SCENARIOS:
        raise ValueError(f"duplicate scenario {scenario.name!r}")
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r} (have: {', '.join(sorted(SCENARIOS))})"
        ) from None


def scenario_names() -> List[str]:
    return list(SCENARIOS)


# ------------------------------------------------------------------ builders
def _static_paper(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = paper_cluster()
    profiles = paper_profiles(paper_jobs(n_jobs=n_jobs, seed=seed, **jk), **pk)
    return cluster, profiles, None


def _diurnal(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = paper_cluster()
    submits = poisson_submit_times(
        n_jobs, mean_interarrival_s=1800.0, seed=seed
    )
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, submit_times=submits, **jk)
    trace = diurnal_trace(
        cluster,
        period_s=86_400.0,
        amplitude=0.6,
        steps_per_period=12,
        horizon_s=86_400.0,
    )
    return cluster, paper_profiles(jobs, **pk), trace


def _link_flap(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = paper_cluster()
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, **jk)
    # The fattest WAN pair (Table II: us-east-2 <-> ea-east carries
    # (90+70)/2 Gbps) collapses to 5% half an hour in — mid-flight for every
    # multi-region pipeline that grabbed it at t=0 — and recovers at 4 h.
    trace = link_flap_trace(
        [("us-east-2", "ea-east")],
        t_down_s=1800.0,
        t_up_s=14_400.0,
        drop_to=0.05,
    )
    return cluster, paper_profiles(jobs, **pk), trace


def _burst_arrival(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = paper_cluster()
    submits = bursty_submit_times(
        n_jobs, burst_size=4, burst_gap_s=14_400.0, seed=seed
    )
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, submit_times=submits, **jk)
    return cluster, paper_profiles(jobs, **pk), None


def _price_spike(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = paper_cluster()
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, **jk)
    # The two cheapest regions (where Cost-Min pours surplus GPUs) triple in
    # price from t=30 min to t=6 h; placements made during the spike shift.
    trace = price_spike_trace(
        ["us-east-2", "ea-east"], t_start_s=1800.0, t_end_s=21_600.0,
        factor=3.0,
    )
    return cluster, paper_profiles(jobs, **pk), trace


def _mixed_stress(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = paper_cluster()
    submits = bursty_submit_times(
        n_jobs, burst_size=4, burst_gap_s=10_800.0, seed=seed
    )
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, submit_times=submits, **jk)
    trace = random_fluctuation_trace(
        cluster,
        seed=seed + 1000,  # decoupled from the job stream, still seeded
        interval_s=3600.0,
        horizon_s=86_400.0,
        lo=0.3,
        hi=1.0,
    ).merged(
        price_spike_trace(
            ["us-east-2"], t_start_s=7200.0, t_end_s=28_800.0, factor=2.5
        )
    )
    return cluster, paper_profiles(jobs, **pk), trace


_register(
    Scenario(
        name="static-paper",
        description="Table II/III workload, all jobs at t=0, static links "
        "(the engine-parity surface)",
        dynamic=False,
        default_n_jobs=8,
        builder=_static_paper,
    )
)
_register(
    Scenario(
        name="diurnal",
        description="Poisson arrivals under a diurnal WAN-capacity wave",
        dynamic=True,
        default_n_jobs=12,
        builder=_diurnal,
    )
)
_register(
    Scenario(
        name="link-flap",
        description="Fattest inter-region link drops to 5% at t=30min, "
        "recovers at t=4h (preemptive-migration stress)",
        dynamic=True,
        default_n_jobs=8,
        builder=_link_flap,
    )
)
_register(
    Scenario(
        name="burst-arrival",
        description="Clumped online submissions (HoL-blocking amplifier)",
        dynamic=False,
        default_n_jobs=12,
        builder=_burst_arrival,
    )
)
_register(
    Scenario(
        name="price-spike",
        description="Cheapest regions' electricity triples for 5.5 h; "
        "price-aware voluntary migration on (10% threshold)",
        dynamic=True,
        # 6 jobs leaves slack capacity in the non-spiked regions at the
        # breakpoint — the regime where voluntary migration has somewhere to
        # go (8 jobs pack the cluster wall-to-wall and pin every probe).
        default_n_jobs=6,
        builder=_price_spike,
        voluntary_migration_threshold=0.10,
    )
)
def _hetero_fleet(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = hetero_fleet_cluster()
    submits = poisson_submit_times(
        n_jobs, mean_interarrival_s=1800.0, seed=seed
    )
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, submit_times=submits, **jk)
    return cluster, paper_profiles(jobs, **pk), None


def _spot_churn(seed: int, n_jobs: int, pk: dict, jk: dict):
    cluster = spot_fleet_cluster()
    jobs = paper_jobs(n_jobs=n_jobs, seed=seed, **jk)
    # Hourly seeded spot churn: each region's spot pool is independently
    # reclaimed (fully or half) with probability 25% per hour, restored
    # otherwise.  Seed decoupled from the job stream, still deterministic.
    trace = spot_reclaim_trace(
        cluster,
        seed=seed + 2000,
        interval_s=3600.0,
        horizon_s=86_400.0,
    )
    return cluster, paper_profiles(jobs, **pk), trace


_register(
    Scenario(
        name="mixed-stress",
        description="Bursty arrivals + seeded random link fluctuation + a "
        "price spike",
        dynamic=True,
        default_n_jobs=12,
        builder=_mixed_stress,
    )
)
_register(
    Scenario(
        name="hetero-fleet",
        description="Table II capacities split across mixed accelerator "
        "generations (h100/a100/v100 typed pools), Poisson arrivals",
        dynamic=False,
        default_n_jobs=10,
        builder=_hetero_fleet,
        hetero=True,
    )
)
_register(
    Scenario(
        name="spot-churn",
        description="40% of every region is discounted spot capacity under "
        "hourly seeded reclaim churn (forced preemption via Eq. 5 pools)",
        dynamic=True,
        default_n_jobs=8,
        builder=_spot_churn,
        hetero=True,
    )
)
