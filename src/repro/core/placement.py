"""Placement: the scheduler's output for one job (``S_j`` in the paper).

A placement fixes (1) the ordered cross-region pipeline path and (2) the GPU
allocation ``n_{j,r}`` along it.  From these plus the cluster's link state we
derive the per-boundary communication times ``t_comm^j(s)`` and the bandwidth
reservations that Eq. (6) accounts for.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from .cluster import INTRA_REGION_BANDWIDTH, ClusterState
from .job import JobProfile


@dataclasses.dataclass(frozen=True)
class Placement:
    """Ordered pipeline path + per-region GPU counts for one job.

    On heterogeneous clusters the grant is additionally *typed*:
    ``typed_alloc[r]`` splits ``alloc[r]`` over the region's GPU pools (the
    cluster's deterministic cheapest-first assignment), and
    ``eff_flops``/``eff_memory`` record the bottleneck hardware of the grant
    — the slowest granted type gates every stage (Eq. 1 is homogeneous per
    pipeline), so timing and the memory floor evaluate against it.  On
    single-type clusters all three stay empty/None and every quantity is
    bit-identical to the homogeneous model.
    """

    path: Tuple[str, ...]           # ordered regions hosting the stages
    alloc: Mapping[str, int]        # n_{j,r} for r in path (>=1 each)
    comm_times: Tuple[float, ...]   # t_comm(s) for each of the g-1 boundaries
    reserved_bw: Mapping[Tuple[str, str], float]  # per crossing edge, bytes/s
    #: Per-region typed grant {region: {gpu_type: count}}; empty on
    #: single-type clusters.
    typed_alloc: Mapping[str, Mapping[str, int]] = dataclasses.field(
        default_factory=dict
    )
    #: Bottleneck FLOPS / memory of the granted types (None = profile
    #: reference hardware).
    eff_flops: Optional[float] = None
    eff_memory: Optional[float] = None

    @property
    def total_gpus(self) -> int:
        return sum(sorted(self.alloc.values()))

    @property
    def n_regions(self) -> int:
        return len(self.path)

    @property
    def crossing_edges(self) -> List[Tuple[str, str]]:
        return [
            (self.path[i], self.path[i + 1])
            for i in range(len(self.path) - 1)
        ]

    def stage_regions(self) -> List[str]:
        """Region of each pipeline stage, in stage order (contiguous split)."""
        out: List[str] = []
        for r in self.path:
            out.extend([r] * self.alloc[r])
        return out

    def describe(self) -> str:
        return " -> ".join(f"{r}({self.alloc[r]})" for r in self.path)


def build_placement(
    profile: JobProfile,
    cluster: ClusterState,
    path: List[str],
    alloc: Mapping[str, int],
    *,
    require_comm_fits_comp: bool = False,
    typed_alloc: Optional[Mapping[str, Mapping[str, int]]] = None,
) -> Placement:
    """Materialize a placement: derive comm times + bandwidth reservations.

    The job reserves ``min(b_j, available)`` on every crossing edge, where
    ``b_j = A_j / t_comp(g)`` (the paper's minimum requirement).  Its actual
    per-boundary transfer time is ``A_j / reserved`` — equal to ``t_comp`` when
    the full ``b_j`` is available, *longer* when a baseline squeezed the job
    onto a thin link.  With ``require_comm_fits_comp`` (BACE-Pipe's Alg. 1
    line 13 invariant) a thin edge raises instead.

    On a heterogeneous cluster the grant is typed (``typed_alloc``, or the
    cluster's deterministic cheapest-first assignment when omitted), and
    ``t_comp``/``b_j``/the memory floor evaluate against the *bottleneck*
    granted hardware: an allocation below the floor for its granted types
    raises even when the reference hardware would have fit.
    """
    g = sum(alloc[r] for r in path)
    if g < 1:
        raise ValueError("empty allocation")
    for r in path:
        if alloc[r] < 1:
            raise ValueError(f"pipeline continuity violated: {r} has no GPU")

    eff_flops: Optional[float] = None
    eff_memory: Optional[float] = None
    typed: Dict[str, Mapping[str, int]] = {}
    if typed_alloc is not None or cluster.is_heterogeneous:
        if typed_alloc is not None:
            typed = {r: dict(typed_alloc[r]) for r in path}
            for r in path:
                if sum(sorted(typed[r].values())) != alloc[r]:
                    raise ValueError(
                        f"typed allocation for {r} does not sum to alloc"
                    )
        else:
            typed = {r: cluster.assign_types(r, alloc[r]) for r in path}
        flops_vals: List[float] = []
        mem_vals: List[float] = []
        for r, types in typed.items():
            for gtype in types:
                pool = cluster.pool(r, gtype)
                flops_vals.append(
                    pool.flops if pool.flops is not None else profile.gpu_flops
                )
                mem_vals.append(
                    pool.memory
                    if pool.memory is not None
                    else profile.gpu_memory
                )
        eff_flops = min(flops_vals)
        eff_memory = min(mem_vals)
        floor = profile.min_gpus_for_memory(eff_memory)
        if g < floor:
            raise ValueError(
                f"allocation of {g} GPUs is below the memory floor {floor} "
                "for the granted accelerator types"
            )
    b_need = profile.bandwidth_requirement_hw(g, eff_flops)
    t_comp = profile.t_comp_hw(g, eff_flops)
    act = profile.spec.model.activation_bytes

    comm_times: List[float] = []
    reserved: Dict[Tuple[str, str], float] = {}
    # Stage boundaries: within a region they ride the intra-region fabric
    # (one constant rate, so the hop time is computed once); between
    # consecutive path regions they ride the WAN link once.
    intra_hop = act / INTRA_REGION_BANDWIDTH
    for r in path:
        comm_times.extend([intra_hop] * (alloc[r] - 1))
    for u, v in zip(path[:-1], path[1:]):
        avail = cluster.available_bandwidth(u, v)
        if avail <= 0.0:
            raise ValueError(f"no residual bandwidth on {u}->{v}")
        share = min(b_need, avail)
        t = act / share
        if require_comm_fits_comp and t > t_comp * (1.0 + 1e-9):
            raise ValueError(
                f"edge {u}->{v} cannot sustain b_j: t_comm={t:.4f} > "
                f"t_comp={t_comp:.4f}"
            )
        reserved[(u, v)] = share
        comm_times.append(t)
    # comm_times is per stage boundary but unordered between intra hops of
    # different regions; Eq. (1) only needs the multiset (sum and max).
    return Placement(
        path=tuple(path),
        alloc=dict(alloc),
        comm_times=tuple(comm_times),
        reserved_bw=reserved,
        typed_alloc=typed,
        eff_flops=eff_flops,
        eff_memory=eff_memory,
    )
