"""Pipeline timing + electricity-cost model — Eqs. (1)–(4) of the paper.

    Δ_j      = max( t_comp, max_s t_comm(s) )                      (bottleneck)
    t_iter   = ( Σ_s t_comm(s) + L·t_comp + (M−1)·Δ_j ) · 2        (Eq. 1)
    E_j      = I_j · t_iter                                        (Eq. 2)
    T_j      = W_j + E_j                                           (Eq. 3)
    C_j      = E_j · Σ_r n_{j,r} · P_r                             (Eq. 4)

GPipe fill-drain semantics (Fig. 3): the fill term pays every stage-boundary
transfer once plus one compute slot per stage; steady state pays (M−1)
bottleneck slots; the trailing ·2 is the symmetric backward pass.

``iteration_time`` is a *seam*: the job's ``JobSpec.timing_model`` selects
the backend that prices a placement.  ``analytic`` (the default) is the
closed form above, bit-identical to the seed; ``microplan`` materializes the
discrete per-microbatch timeline (``core/microplan``) for the schedule named
by ``JobSpec.pipeline_schedule`` and returns its makespan.  Everything
downstream of ``iteration_time`` — Eq. (2)–(4), the simulator's completion
projections, the piecewise cost ledger — inherits the selected backend.
"""

from __future__ import annotations

import abc
from typing import Dict

from .cluster import ClusterState
from .job import JobProfile
from .placement import Placement


def bottleneck_delta(profile: JobProfile, placement: Placement) -> float:
    """Δ_j: the slowest pipeline slot (compute or communication).  Typed
    placements evaluate compute against the grant's bottleneck hardware
    (``Placement.eff_flops``); ``None`` is the reference path bit-exactly."""
    t_comp = profile.t_comp_hw(placement.total_gpus, placement.eff_flops)
    t_comm_max = max(placement.comm_times, default=0.0)
    return max(t_comp, t_comm_max)


def analytic_iteration_time(
    profile: JobProfile, placement: Placement
) -> float:
    """Eq. (1) under a concrete placement.  The fill term pays one compute
    slot per pipeline *stage* (GPUs beyond one-per-layer widen stages rather
    than deepening the pipeline)."""
    g = placement.total_gpus
    t_comp = profile.t_comp_hw(g, placement.eff_flops)
    m = profile.spec.model.microbatches
    fill_comm = sum(placement.comm_times)
    delta = bottleneck_delta(profile, placement)
    return (fill_comm + profile.pipeline_depth(g) * t_comp + (m - 1) * delta) * 2.0


# ------------------------------------------------------------ timing backends
class TimingModel(abc.ABC):
    """Pluggable backend pricing one iteration of a placed pipeline."""

    name: str = "base"

    @abc.abstractmethod
    def iteration_time(
        self, profile: JobProfile, placement: Placement
    ) -> float:
        ...


class AnalyticTimingModel(TimingModel):
    """The closed-form Eq. (1) backend (seed semantics, the default)."""

    name = "analytic"

    def iteration_time(self, profile, placement):
        return analytic_iteration_time(profile, placement)


class MicroplanTimingModel(TimingModel):
    """Discrete microbatch-level planner backend: iteration time is the
    makespan of the executable event timeline for the job's
    ``pipeline_schedule`` (see ``core/microplan``)."""

    name = "microplan"

    def iteration_time(self, profile, placement):
        from .microplan import plan_schedule

        return plan_schedule(profile, placement).iteration_time


TIMING_MODELS: Dict[str, TimingModel] = {
    m.name: m for m in (AnalyticTimingModel(), MicroplanTimingModel())
}

# ``JobSpec`` validates against ``job.TIMING_MODELS`` (job.py cannot import
# this module — timing builds on job); fail loudly at import if the two
# sources of truth ever drift.
from .job import TIMING_MODELS as _SPEC_TIMING_MODELS  # noqa: E402

if set(TIMING_MODELS) != set(_SPEC_TIMING_MODELS):
    raise ImportError(
        "timing backend registry drifted from job.TIMING_MODELS: "
        f"{sorted(TIMING_MODELS)} vs {sorted(_SPEC_TIMING_MODELS)}"
    )


def get_timing_model(name: str) -> TimingModel:
    try:
        return TIMING_MODELS[name]
    except KeyError:
        raise KeyError(
            f"unknown timing model {name!r} "
            f"(have: {', '.join(sorted(TIMING_MODELS))})"
        ) from None


def iteration_time(profile: JobProfile, placement: Placement) -> float:
    """Iteration time under the job's selected timing backend.  The default
    ``analytic`` spec takes the closed-form path directly (zero dispatch
    overhead, bit-identical to the seed)."""
    name = profile.spec.timing_model
    if name == "analytic":
        return analytic_iteration_time(profile, placement)
    return get_timing_model(name).iteration_time(profile, placement)


def execution_time(profile: JobProfile, placement: Placement) -> float:
    """Eq. (2): E_j = I_j · t_iter."""
    return profile.spec.iterations * iteration_time(profile, placement)


def placement_power_rate(
    profile: JobProfile, placement: Placement, cluster: ClusterState
) -> float:
    """Eq. (4)'s $/s term ``Σ_r n_{j,r} · P_r`` at the cluster's *current*
    (live-multiplier) prices — the rate the piecewise segment ledger
    integrates between env breakpoints.  Typed grants bill each (region,
    type) cell at its own board power and spot discount (``price_mult``)."""
    if placement.typed_alloc:
        total = 0.0
        for r, types in placement.typed_alloc.items():
            for gtype, n in types.items():
                pool = cluster.pool(r, gtype)
                total += profile.power_cost_rate(
                    cluster.price(r) * pool.price_mult, n, pool.gpu_kw
                )
        return total
    # Float accumulation in the placement's own (path) order — pinned to
    # the reference implementation, same as ``allocation_cost_rate``; this
    # rate feeds the stay-vs-move threshold and the settled ledger bytes.
    return sum(  # reprolint: disable=RPL104
        profile.power_cost_rate(cluster.price(r), n)
        for r, n in placement.alloc.items()
    )


def electricity_cost(
    profile: JobProfile,
    placement: Placement,
    cluster: ClusterState,
    *,
    execution_seconds: float | None = None,
) -> float:
    """Eq. (4): cost accrues for the whole active duration (bubbles included),
    never while queued."""
    e = (
        execution_time(profile, placement)
        if execution_seconds is None
        else execution_seconds
    )
    return e * placement_power_rate(profile, placement, cluster)


def average_price(placement: Placement, cluster: ClusterState) -> float:
    """Per-GPU mean electricity price of a placement (Alg. 1 line 19).

    Typed grants rank by the mean *billed* cell rate
    (``ClusterState.pool_rate``: price × spot discount × board kW) so the
    Pathfinder's tie-break agrees with the typed Cost-Min pour and with
    Eq. 4 billing — a cheap-kWh pool of power-hungry boards must not outrank
    a frugal one.  Candidates are only ever compared within one cluster, so
    the unit difference against the homogeneous branch (plain $/kWh, the
    seed-exact path) never mixes."""
    total = 0.0
    if placement.typed_alloc:
        for r, types in placement.typed_alloc.items():
            for gtype, n in types.items():
                total += cluster.pool_rate(r, gtype) * n
        return total / placement.total_gpus
    for r, n in placement.alloc.items():
        total += cluster.price(r) * n
    return total / placement.total_gpus
