"""Geo-distributed cluster model: regions, links, and live resource ledgers.

This is the control-plane view of the world (paper §III-A "System Model"):
``K`` regions, each with a GPU capacity ``G_r`` and electricity price ``P_r``,
joined by directed inter-region links with bandwidth ``B_{u,v}`` (asymmetry
supported).  ``ClusterState`` additionally keeps *live* ledgers — free GPUs
per region and reserved bandwidth per link — which Eq. (5)/(6) constrain and
Eq. (11)'s congestion factor ``alpha`` reads.

Storage layout (see DESIGN.md "vectorized engine"): the ledgers are backed by
numpy — a region→index map, free/capacity/price vectors, and dense R×R
installed-bandwidth + reserved matrices — so the Pathfinder and the priority
ranker operate on arrays instead of per-key dict lookups.  ``free_gpus`` and
``reserved_bw`` remain dict-like *write-through views* over those arrays, so
all seed-era call sites (and tests that poke the ledgers directly) keep
working unchanged.  ``congestion_alpha`` is maintained as an O(1) running sum
updated on every reserve/release instead of being re-summed per call.
"""

from __future__ import annotations

import dataclasses
from collections.abc import MutableMapping
from typing import Dict, Iterable, Iterator, List, Mapping, Tuple

import numpy as np

GBPS = 1e9 / 8.0  # bytes/sec per Gbit/s

#: Effective intra-region bandwidth (NVLink/NVSwitch class, bytes/s). Adjacent
#: pipeline stages placed in the same region communicate at this rate, so
#: intra-region hops are never the pipeline bottleneck.
INTRA_REGION_BANDWIDTH = 600.0 * GBPS


@dataclasses.dataclass(frozen=True)
class Region:
    """A cloud region: GPU pool + electricity price.

    ``price_kwh`` is the regional electricity price in $/kWh (paper Table II);
    the $/GPU-hour rate is ``price_kwh * gpu_kw`` with ``gpu_kw`` owned by the
    simulation config (one value per accelerator generation).
    """

    name: str
    gpu_capacity: int
    price_kwh: float

    def __post_init__(self) -> None:
        if self.gpu_capacity < 0:
            raise ValueError(f"negative GPU capacity for region {self.name}")
        if self.price_kwh < 0:
            raise ValueError(f"negative electricity price for region {self.name}")


Link = Tuple[str, str]


class _FreeGpuLedger(MutableMapping):
    """Dict view of the free-GPU vector; writes go straight to the array and
    keep the cluster's running free-GPU total in sync."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState") -> None:
        self._cs = cs

    def __getitem__(self, region: str) -> int:
        cs = self._cs
        try:
            return int(cs._free[cs._idx[region]])
        except KeyError:
            raise KeyError(region) from None

    def __setitem__(self, region: str, count: int) -> None:
        cs = self._cs
        i = cs._idx[region]  # KeyError for unknown regions
        n = int(count)
        cs._free_total += n - int(cs._free[i])
        cs._free[i] = n

    def __delitem__(self, region: str) -> None:
        raise TypeError("region ledger entries cannot be deleted")

    def __iter__(self) -> Iterator[str]:
        return iter(self._cs._idx)

    def __len__(self) -> int:
        return len(self._cs._idx)

    def __repr__(self) -> str:
        return repr(dict(self))


class _ReservedBwLedger(MutableMapping):
    """Dict view of the reserved-bandwidth matrix (write-through).

    Links absent from the installed-bandwidth matrix live in a side dict and
    are excluded from the congestion running sum — mirroring the seed
    ``congestion_alpha``, which summed installed links only."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState") -> None:
        self._cs = cs

    def __getitem__(self, link: Link) -> float:
        cs = self._cs
        ij = cs._link_idx.get(link)
        if ij is not None:
            return float(cs._res_mat[ij])
        return cs._res_extra[link]

    def __setitem__(self, link: Link, value: float) -> None:
        cs = self._cs
        v = float(value)
        ij = cs._link_idx.get(link)
        if ij is None:
            cs._res_extra[link] = v
            return
        cs._res_total += v - float(cs._res_mat[ij])
        cs._res_mat[ij] = v

    def __delitem__(self, link: Link) -> None:
        raise TypeError("link ledger entries cannot be deleted")

    def __iter__(self) -> Iterator[Link]:
        yield from self._cs._link_idx
        yield from self._cs._res_extra

    def __len__(self) -> int:
        return len(self._cs._link_idx) + len(self._cs._res_extra)

    def __repr__(self) -> str:
        return repr(dict(self))


@dataclasses.dataclass
class ClusterState:
    """Mutable cluster: capacities, prices, bandwidth, and live reservations."""

    regions: Dict[str, Region]
    bandwidth: Dict[Link, float]  # bytes/s, directed
    free_gpus: Mapping[str, int] = dataclasses.field(default_factory=dict)
    reserved_bw: Mapping[Link, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        names = list(self.regions)
        n = len(names)
        self._names: List[str] = names
        self._idx: Dict[str, int] = {r: i for i, r in enumerate(names)}
        # Rank of each region in sorted-name order: vectorized tie-breaks
        # ("max by (value, name)" / "min by (value, name)") need it.
        rank = np.empty(n, dtype=np.int64)
        for pos, i in enumerate(sorted(range(n), key=lambda i: names[i])):
            rank[i] = pos
        self._name_rank = rank
        self._cap = np.array(
            [self.regions[r].gpu_capacity for r in names], dtype=np.int64
        )
        self._price = np.array(
            [self.regions[r].price_kwh for r in names], dtype=float
        )
        self._cap_total = int(self._cap.sum())

        provided_free = dict(self.free_gpus) if self.free_gpus else None
        if provided_free is None:
            self._free = self._cap.copy()
        else:
            self._free = np.array(
                [int(provided_free.get(r, 0)) for r in names], dtype=np.int64
            )
        self._free_total = int(self._free.sum())

        self._bw_mat = np.zeros((n, n), dtype=float)
        self._link_idx: Dict[Link, Tuple[int, int]] = {}
        for (u, v), b in self.bandwidth.items():
            iu, iv = self._idx.get(u), self._idx.get(v)
            if iu is None or iv is None:
                continue
            self._bw_mat[iu, iv] = b
            self._link_idx[(u, v)] = (iu, iv)
        self._bw_total = float(sum(self.bandwidth.values()))

        self._res_mat = np.zeros((n, n), dtype=float)
        self._res_extra: Dict[Link, float] = {}
        self._res_total = 0.0
        provided_res = dict(self.reserved_bw) if self.reserved_bw else None
        self.free_gpus = _FreeGpuLedger(self)
        self.reserved_bw = _ReservedBwLedger(self)
        if provided_res:
            for link, b in provided_res.items():
                self.reserved_bw[link] = float(b)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        regions: Iterable[Region],
        bandwidth_gbps: Mapping[Link, float],
        *,
        symmetric: bool = True,
    ) -> "ClusterState":
        regs = {r.name: r for r in regions}
        bw: Dict[Link, float] = {}
        for (u, v), gbps in bandwidth_gbps.items():
            if u not in regs or v not in regs:
                raise KeyError(f"link ({u},{v}) references unknown region")
            bw[(u, v)] = gbps * GBPS
            if symmetric:
                bw.setdefault((v, u), gbps * GBPS)
        return cls(regions=regs, bandwidth=bw)

    @classmethod
    def from_region_bandwidths(
        cls, regions: Iterable[Region], region_gbps: Mapping[str, float]
    ) -> "ClusterState":
        """Paper Table II convention: ``B_{i,j} = (B_i + B_j) / 2``."""
        regs = list(regions)
        bw: Dict[Link, float] = {}
        for a in regs:
            for b in regs:
                if a.name == b.name:
                    continue
                bw[(a.name, b.name)] = (
                    (region_gbps[a.name] + region_gbps[b.name]) / 2.0
                )
        return cls.build(regs, bw, symmetric=False)

    # ------------------------------------------------------------------- gpus
    def total_gpus(self) -> int:
        return self._cap_total

    def total_free_gpus(self) -> int:
        return self._free_total

    def price(self, region: str) -> float:
        return self.regions[region].price_kwh

    def reserve_gpus(self, alloc: Mapping[str, int]) -> None:
        idx, free = self._idx, self._free
        for r, n in alloc.items():
            i = idx.get(r)
            have = int(free[i]) if i is not None else 0
            if n < 0 or n > have:
                raise ValueError(
                    f"cannot reserve {n} GPUs in {r} (free={have})"
                )
        taken = 0
        for r, n in alloc.items():
            free[idx[r]] -= n
            taken += n
        self._free_total -= taken

    def release_gpus(self, alloc: Mapping[str, int]) -> None:
        idx, free = self._idx, self._free
        for r, n in alloc.items():
            i = idx[r]
            free[i] += n
            self._free_total += n
            if free[i] > self._cap[i]:
                raise ValueError(f"GPU over-release in {r}")

    # ---------------------------------------------------------------- network
    def link_bandwidth(self, u: str, v: str) -> float:
        """Installed bandwidth of the directed link (u, v); intra-region hops
        use the constant fast fabric."""
        if u == v:
            return INTRA_REGION_BANDWIDTH
        ij = self._link_idx.get((u, v))
        return float(self._bw_mat[ij]) if ij is not None else 0.0

    def available_bandwidth(self, u: str, v: str) -> float:
        if u == v:
            return INTRA_REGION_BANDWIDTH
        ij = self._link_idx.get((u, v))
        if ij is None:
            return 0.0
        return max(0.0, float(self._bw_mat[ij]) - float(self._res_mat[ij]))

    def available_matrix(self) -> np.ndarray:
        """Dense R×R residual WAN bandwidth (bytes/s); the diagonal is 0 — use
        ``available_bandwidth`` for intra-region hops."""
        return np.maximum(0.0, self._bw_mat - self._res_mat)

    def reserve_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Eq. (6): reservations on a link may never exceed its capacity."""
        for (u, v), b in edges.items():
            if u == v:
                continue
            avail = self.available_bandwidth(u, v)
            if b > avail + 1e-6:
                raise ValueError(
                    f"bandwidth over-subscription on {u}->{v}: "
                    f"want {b:.3e}, have {avail:.3e}"
                )
        for (u, v), b in edges.items():
            if u == v:
                continue
            ij = self._link_idx.get((u, v))
            if ij is None:
                self._res_extra[(u, v)] = self._res_extra.get((u, v), 0.0) + b
            else:
                self._res_mat[ij] += b
                self._res_total += b

    def release_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Releasing more than is reserved (beyond float-drift tolerance) is a
        double-release bug and raises, mirroring ``release_gpus``.  Validation
        runs over every edge before any mutation (as ``reserve_bandwidth``
        does), so a rejected release leaves the ledger untouched."""
        updates = []
        for (u, v), b in edges.items():
            if u == v:
                continue
            ij = self._link_idx.get((u, v))
            cur = (
                self._res_extra.get((u, v), 0.0)
                if ij is None
                else float(self._res_mat[ij])
            )
            new = cur - b
            if new < -(1e-6 + 1e-9 * self.link_bandwidth(u, v)):
                raise ValueError(
                    f"bandwidth over-release on {u}->{v}: releasing {b:.3e} "
                    f"with only {cur:.3e} reserved"
                )
            updates.append(((u, v), ij, cur, max(0.0, new)))
        for link, ij, cur, new in updates:
            if ij is None:
                self._res_extra[link] = new
            else:
                self._res_mat[ij] = new
                self._res_total += new - cur
        if self._res_total < 0.0:  # guard accumulated float drift
            self._res_total = 0.0

    def congestion_alpha(self) -> float:
        """Eq. (11): ratio of reserved inter-region bandwidth to aggregate
        installed inter-region capacity, clamped to [0, 1].  O(1): both terms
        are running totals maintained by the ledgers."""
        if self._bw_total <= 0.0:
            return 0.0
        return min(1.0, max(0.0, self._res_total / self._bw_total))

    # ------------------------------------------------------------------ misc
    def region_names(self) -> List[str]:
        return list(self.regions)

    def region_index(self) -> Dict[str, int]:
        return self._idx

    def scaled(
        self,
        *,
        bandwidth_factor: float = 1.0,
        capacity_factor: float = 1.0,
    ) -> "ClusterState":
        """Fresh cluster with scaled links / GPU pools (paper Figs. 5–6)."""
        regs = [
            Region(
                name=r.name,
                gpu_capacity=max(1, int(round(r.gpu_capacity * capacity_factor))),
                price_kwh=r.price_kwh,
            )
            for r in self.regions.values()
        ]
        bw = {l: b * bandwidth_factor / GBPS for l, b in self.bandwidth.items()}
        return ClusterState.build(regs, bw, symmetric=False)

    def snapshot(self) -> "ClusterState":
        return ClusterState(
            regions=dict(self.regions),
            bandwidth=dict(self.bandwidth),
            free_gpus=dict(self.free_gpus),
            reserved_bw=dict(self.reserved_bw),
        )
