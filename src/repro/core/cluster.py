"""Geo-distributed cluster model: regions, links, and live resource ledgers.

This is the control-plane view of the world (paper §III-A "System Model"):
``K`` regions, each with a GPU capacity ``G_r`` and electricity price ``P_r``,
joined by directed inter-region links with bandwidth ``B_{u,v}`` (asymmetry
supported).  ``ClusterState`` additionally keeps *live* ledgers — free GPUs
per region and reserved bandwidth per link — which Eq. (5)/(6) constrain and
Eq. (11)'s congestion factor ``alpha`` reads.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

GBPS = 1e9 / 8.0  # bytes/sec per Gbit/s

#: Effective intra-region bandwidth (NVLink/NVSwitch class, bytes/s). Adjacent
#: pipeline stages placed in the same region communicate at this rate, so
#: intra-region hops are never the pipeline bottleneck.
INTRA_REGION_BANDWIDTH = 600.0 * GBPS


@dataclasses.dataclass(frozen=True)
class Region:
    """A cloud region: GPU pool + electricity price.

    ``price_kwh`` is the regional electricity price in $/kWh (paper Table II);
    the $/GPU-hour rate is ``price_kwh * gpu_kw`` with ``gpu_kw`` owned by the
    simulation config (one value per accelerator generation).
    """

    name: str
    gpu_capacity: int
    price_kwh: float

    def __post_init__(self) -> None:
        if self.gpu_capacity < 0:
            raise ValueError(f"negative GPU capacity for region {self.name}")
        if self.price_kwh < 0:
            raise ValueError(f"negative electricity price for region {self.name}")


Link = Tuple[str, str]


@dataclasses.dataclass
class ClusterState:
    """Mutable cluster: capacities, prices, bandwidth, and live reservations."""

    regions: Dict[str, Region]
    bandwidth: Dict[Link, float]  # bytes/s, directed
    free_gpus: Dict[str, int] = dataclasses.field(default_factory=dict)
    reserved_bw: Dict[Link, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.free_gpus:
            self.free_gpus = {r: reg.gpu_capacity for r, reg in self.regions.items()}
        for link in self.bandwidth:
            self.reserved_bw.setdefault(link, 0.0)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        regions: Iterable[Region],
        bandwidth_gbps: Mapping[Link, float],
        *,
        symmetric: bool = True,
    ) -> "ClusterState":
        regs = {r.name: r for r in regions}
        bw: Dict[Link, float] = {}
        for (u, v), gbps in bandwidth_gbps.items():
            if u not in regs or v not in regs:
                raise KeyError(f"link ({u},{v}) references unknown region")
            bw[(u, v)] = gbps * GBPS
            if symmetric:
                bw.setdefault((v, u), gbps * GBPS)
        return cls(regions=regs, bandwidth=bw)

    @classmethod
    def from_region_bandwidths(
        cls, regions: Iterable[Region], region_gbps: Mapping[str, float]
    ) -> "ClusterState":
        """Paper Table II convention: ``B_{i,j} = (B_i + B_j) / 2``."""
        regs = list(regions)
        bw: Dict[Link, float] = {}
        for a in regs:
            for b in regs:
                if a.name == b.name:
                    continue
                bw[(a.name, b.name)] = (
                    (region_gbps[a.name] + region_gbps[b.name]) / 2.0
                )
        return cls.build(regs, bw, symmetric=False)

    # ------------------------------------------------------------------- gpus
    def total_gpus(self) -> int:
        return sum(r.gpu_capacity for r in self.regions.values())

    def total_free_gpus(self) -> int:
        return sum(self.free_gpus.values())

    def price(self, region: str) -> float:
        return self.regions[region].price_kwh

    def reserve_gpus(self, alloc: Mapping[str, int]) -> None:
        for r, n in alloc.items():
            if n < 0 or n > self.free_gpus.get(r, 0):
                raise ValueError(
                    f"cannot reserve {n} GPUs in {r} (free={self.free_gpus.get(r, 0)})"
                )
        for r, n in alloc.items():
            self.free_gpus[r] -= n

    def release_gpus(self, alloc: Mapping[str, int]) -> None:
        for r, n in alloc.items():
            self.free_gpus[r] += n
            if self.free_gpus[r] > self.regions[r].gpu_capacity:
                raise ValueError(f"GPU over-release in {r}")

    # ---------------------------------------------------------------- network
    def link_bandwidth(self, u: str, v: str) -> float:
        """Installed bandwidth of the directed link (u, v); intra-region hops
        use the constant fast fabric."""
        if u == v:
            return INTRA_REGION_BANDWIDTH
        return self.bandwidth.get((u, v), 0.0)

    def available_bandwidth(self, u: str, v: str) -> float:
        if u == v:
            return INTRA_REGION_BANDWIDTH
        cap = self.bandwidth.get((u, v), 0.0)
        return max(0.0, cap - self.reserved_bw.get((u, v), 0.0))

    def reserve_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Eq. (6): reservations on a link may never exceed its capacity."""
        for (u, v), b in edges.items():
            if u == v:
                continue
            if b > self.available_bandwidth(u, v) + 1e-6:
                raise ValueError(
                    f"bandwidth over-subscription on {u}->{v}: "
                    f"want {b:.3e}, have {self.available_bandwidth(u, v):.3e}"
                )
        for (u, v), b in edges.items():
            if u == v:
                continue
            self.reserved_bw[(u, v)] = self.reserved_bw.get((u, v), 0.0) + b

    def release_bandwidth(self, edges: Mapping[Link, float]) -> None:
        for (u, v), b in edges.items():
            if u == v:
                continue
            self.reserved_bw[(u, v)] = max(0.0, self.reserved_bw.get((u, v), 0.0) - b)

    def congestion_alpha(self) -> float:
        """Eq. (11): ratio of reserved inter-region bandwidth to aggregate
        installed inter-region capacity, clamped to [0, 1]."""
        total = sum(self.bandwidth.values())
        if total <= 0.0:
            return 0.0
        used = sum(self.reserved_bw.get(l, 0.0) for l in self.bandwidth)
        return min(1.0, max(0.0, used / total))

    # ------------------------------------------------------------------ misc
    def region_names(self) -> List[str]:
        return list(self.regions)

    def scaled(
        self,
        *,
        bandwidth_factor: float = 1.0,
        capacity_factor: float = 1.0,
    ) -> "ClusterState":
        """Fresh cluster with scaled links / GPU pools (paper Figs. 5–6)."""
        regs = [
            Region(
                name=r.name,
                gpu_capacity=max(1, int(round(r.gpu_capacity * capacity_factor))),
                price_kwh=r.price_kwh,
            )
            for r in self.regions.values()
        ]
        bw = {l: b * bandwidth_factor / GBPS for l, b in self.bandwidth.items()}
        return ClusterState.build(regs, bw, symmetric=False)

    def snapshot(self) -> "ClusterState":
        return ClusterState(
            regions=dict(self.regions),
            bandwidth=dict(self.bandwidth),
            free_gpus=dict(self.free_gpus),
            reserved_bw=dict(self.reserved_bw),
        )
