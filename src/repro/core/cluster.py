"""Geo-distributed cluster model: regions, links, and live resource ledgers.

This is the control-plane view of the world (paper §III-A "System Model"):
``K`` regions, each with a GPU capacity ``G_r`` and electricity price ``P_r``,
joined by directed inter-region links with bandwidth ``B_{u,v}`` (asymmetry
supported).  ``ClusterState`` additionally keeps *live* ledgers — free GPUs
per region and reserved bandwidth per link — which Eq. (5)/(6) constrain and
Eq. (11)'s congestion factor ``alpha`` reads.

Storage layout (see DESIGN.md "vectorized engine"): the ledgers are backed by
numpy — a region→index map, free/capacity/price vectors, and dense R×R
installed-bandwidth + reserved matrices — so the Pathfinder and the priority
ranker operate on arrays instead of per-key dict lookups.  ``free_gpus`` and
``reserved_bw`` remain dict-like *write-through views* over those arrays, so
all seed-era call sites (and tests that poke the ledgers directly) keep
working unchanged.  ``congestion_alpha`` is maintained as an O(1) running sum
updated on every reserve/release instead of being re-summed per call.

Heterogeneous accelerators (see DESIGN.md "heterogeneity model"): a region
may declare typed :class:`GpuPool`\\ s — per-type capacity, FLOPS, memory,
board power, and an on-demand vs. *spot* price multiplier.  The GPU ledger is
then (region, type)-shaped: ``_cap_t``/``_used_t`` are R×T integer arrays and
the per-region free vector is the derived aggregate ``Σ_t max(0, cap − used)``.
A cluster whose regions declare no pools collapses to a single implicit
default column, and every aggregate quantity (and therefore every scheduling
decision) is bit-identical to the homogeneous layout.  Spot capacity is
reclaimable at runtime (``set_spot_multipliers`` /
``EnvUpdate.spot``): a reclaim may shrink a pool below its in-use count, in
which case ``oversubscribed_pools`` reports the deficit for the simulator's
forced-preemption pass — the GPU-side analogue of ``oversubscribed_links``.
"""

from __future__ import annotations

import dataclasses
from collections.abc import MutableMapping
from typing import Dict, Iterable, Iterator, List, Mapping, Optional, Tuple

import numpy as np

from .job import DEFAULT_GPU_KW

GBPS = 1e9 / 8.0  # bytes/sec per Gbit/s

#: Effective intra-region bandwidth (NVLink/NVSwitch class, bytes/s). Adjacent
#: pipeline stages placed in the same region communicate at this rate, so
#: intra-region hops are never the pipeline bottleneck.
INTRA_REGION_BANDWIDTH = 600.0 * GBPS


#: Type name of the implicit pool a plain (pool-less) region exposes.
DEFAULT_GPU_TYPE = "default"


@dataclasses.dataclass(frozen=True)
class GpuPool:
    """One typed accelerator pool inside a region.

    ``flops``/``memory``/``gpu_kw`` of ``None`` inherit the job profile's
    reference hardware (``JobProfile.gpu_flops`` etc.), which is what keeps a
    cluster built without explicit pools bit-identical to the homogeneous
    model.  ``spot`` marks reclaimable capacity: the pool's count may be
    rescaled at runtime (``ClusterState.set_spot_multipliers``) and its
    electricity draw is billed at ``price_mult ×`` the regional price — the
    spot discount.
    """

    gpu_type: str
    count: int
    flops: Optional[float] = None    # FLOP/s per GPU; None = profile default
    memory: Optional[float] = None   # usable bytes per GPU; None = default
    gpu_kw: Optional[float] = None   # board power draw; None = default
    spot: bool = False
    price_mult: float = 1.0

    def __post_init__(self) -> None:
        if not self.gpu_type:
            raise ValueError("empty GPU type name")
        if self.count < 0:
            raise ValueError(f"negative count for GPU pool {self.gpu_type}")
        if self.price_mult < 0.0:
            raise ValueError(f"negative price_mult for pool {self.gpu_type}")
        for field in ("flops", "memory", "gpu_kw"):
            v = getattr(self, field)
            if v is not None and v <= 0.0:
                raise ValueError(
                    f"non-positive {field} for GPU pool {self.gpu_type}"
                )

    @property
    def kw_or_default(self) -> float:
        """Board power for *ordering* decisions (cheapest-pool-first); the
        actual billed kW still honours the job profile when unset."""
        return self.gpu_kw if self.gpu_kw is not None else DEFAULT_GPU_KW


@dataclasses.dataclass(frozen=True)
class Region:
    """A cloud region: GPU pool(s) + electricity price.

    ``price_kwh`` is the regional electricity price in $/kWh (paper Table II);
    the $/GPU-hour rate is ``price_kwh * gpu_kw`` with ``gpu_kw`` owned by the
    simulation config (one value per accelerator generation).

    ``pools`` optionally splits the capacity into typed accelerator pools
    (heterogeneous fleets, spot capacity); when given, the pool counts must
    partition ``gpu_capacity`` exactly.  A pool-less region behaves as one
    implicit :data:`DEFAULT_GPU_TYPE` pool at the profile's reference
    hardware — the homogeneous paper setup.
    """

    name: str
    gpu_capacity: int
    price_kwh: float
    pools: Tuple[GpuPool, ...] = ()

    def __post_init__(self) -> None:
        if self.gpu_capacity < 0:
            raise ValueError(f"negative GPU capacity for region {self.name}")
        if self.price_kwh < 0:
            raise ValueError(f"negative electricity price for region {self.name}")
        if self.pools:
            object.__setattr__(self, "pools", tuple(self.pools))
            names = [p.gpu_type for p in self.pools]
            if len(set(names)) != len(names):
                raise ValueError(
                    f"duplicate GPU pool types in region {self.name}"
                )
            total = sum(p.count for p in self.pools)
            if total != self.gpu_capacity:
                raise ValueError(
                    f"GPU pools of region {self.name} sum to {total}, not "
                    f"gpu_capacity={self.gpu_capacity}"
                )

    @classmethod
    def with_pools(
        cls, name: str, price_kwh: float, pools: Iterable[GpuPool]
    ) -> "Region":
        """Region whose capacity is the sum of its typed pools."""
        pools = tuple(pools)
        return cls(
            name=name,
            gpu_capacity=sum(p.count for p in pools),
            price_kwh=price_kwh,
            pools=pools,
        )


Link = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class EnvUpdate:
    """One breakpoint of a piecewise-constant environment trace.

    At ``time`` the listed links take bandwidth multiplier ``bandwidth[link]``
    (absolute against the *installed* capacity, not against the previous
    value), the listed regions take electricity-price multiplier
    ``prices[region]`` (absolute against the construction-time price), and
    the listed spot pools take capacity multiplier ``spot[(region, type)]``
    (absolute against the installed pool count — a *spot reclaim* when < 1).
    Links/regions/pools not listed keep their current multiplier.
    """

    time: float
    bandwidth: Mapping[Link, float] = dataclasses.field(default_factory=dict)
    prices: Mapping[str, float] = dataclasses.field(default_factory=dict)
    spot: Mapping[Tuple[str, str], float] = dataclasses.field(
        default_factory=dict
    )

    def __post_init__(self) -> None:
        if self.time < 0.0:
            raise ValueError("EnvUpdate.time must be >= 0")
        for link, m in self.bandwidth.items():
            if m < 0.0:
                raise ValueError(f"negative bandwidth multiplier on {link}")
        for region, m in self.prices.items():
            if m < 0.0:
                raise ValueError(f"negative price multiplier for {region}")
        for pool, m in self.spot.items():
            if m < 0.0:
                raise ValueError(f"negative spot-capacity multiplier for {pool}")


class BandwidthTrace:
    """Time-varying environment: an ordered sequence of ``EnvUpdate``s.

    The model is piecewise-constant (paper-style "real-time network
    utilization" snapshots): between breakpoints the effective bandwidth
    matrix and prices are fixed; at a breakpoint the simulator applies the
    update atomically with every other event at that timestamp, then
    re-validates running placements (see ``core/scheduler.py``).  Updates are
    stored sorted by time (stable for equal times).
    """

    def __init__(self, updates: Iterable[EnvUpdate] = ()) -> None:
        self.updates: List[EnvUpdate] = sorted(updates, key=lambda u: u.time)

    def __len__(self) -> int:
        return len(self.updates)

    def __iter__(self) -> Iterator[EnvUpdate]:
        return iter(self.updates)

    def change_times(self) -> List[float]:
        out: List[float] = []
        for u in self.updates:
            if not out or u.time != out[-1]:
                out.append(u.time)
        return out

    def merged(self, other: "BandwidthTrace") -> "BandwidthTrace":
        return BandwidthTrace([*self.updates, *other.updates])


class _FreeGpuLedger(MutableMapping):
    """Dict view of the free-GPU vector; writes go straight to the array and
    keep the cluster's running free-GPU total in sync."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState") -> None:
        self._cs = cs

    def __getitem__(self, region: str) -> int:
        cs = self._cs
        try:
            return int(cs._free[cs._idx[region]])
        except KeyError:
            raise KeyError(region) from None

    def __setitem__(self, region: str, count: int) -> None:
        cs = self._cs
        i = cs._idx[region]  # KeyError for unknown regions
        n = int(count)
        if n < 0:
            # A negative free count is always a double-release (or similar)
            # bug; silently accepting it corrupts ``_free_total`` and every
            # downstream placement decision — raise like ``release_bandwidth``
            # does for over-release.
            raise ValueError(
                f"negative free-GPU count for region {region}: {n}"
            )
        cells = cs._region_cells[i]
        if len(cells) != 1:
            raise TypeError(
                f"region {region} has {len(cells)} typed GPU pools; an "
                "aggregate free count is ambiguous — mutate per type via "
                "reserve_gpus_typed/release_gpus_typed"
            )
        t = cells[0]
        cs._used_t[i, t] = int(cs._cap_t[i, t]) - n
        cs._refresh_free(i)

    def __delitem__(self, region: str) -> None:
        raise TypeError("region ledger entries cannot be deleted")

    def __iter__(self) -> Iterator[str]:
        return iter(self._cs._idx)

    def __len__(self) -> int:
        return len(self._cs._idx)

    def __repr__(self) -> str:
        return repr(dict(self))


class _ReservedBwLedger(MutableMapping):
    """Dict view of the reserved-bandwidth matrix (write-through).

    Links absent from the installed-bandwidth matrix live in a side dict and
    are excluded from the congestion running sum — mirroring the seed
    ``congestion_alpha``, which summed installed links only."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState") -> None:
        self._cs = cs

    def __getitem__(self, link: Link) -> float:
        cs = self._cs
        ij = cs._link_idx.get(link)
        if ij is not None:
            return float(cs._res_mat[ij])
        return cs._res_extra[link]

    def __setitem__(self, link: Link, value: float) -> None:
        cs = self._cs
        v = float(value)
        ij = cs._link_idx.get(link)
        if ij is None:
            cs._res_extra[link] = v
            return
        cs._res_total += v - float(cs._res_mat[ij])
        cs._res_mat[ij] = v
        cs._avail_touch(ij)

    def __delitem__(self, link: Link) -> None:
        raise TypeError("link ledger entries cannot be deleted")

    def __iter__(self) -> Iterator[Link]:
        yield from self._cs._link_idx
        yield from self._cs._res_extra

    def __len__(self) -> int:
        return len(self._cs._link_idx) + len(self._cs._res_extra)

    def __repr__(self) -> str:
        return repr(dict(self))


@dataclasses.dataclass
class ClusterState:
    """Mutable cluster: capacities, prices, bandwidth, and live reservations."""

    regions: Dict[str, Region]
    bandwidth: Dict[Link, float]  # bytes/s, directed
    free_gpus: Mapping[str, int] = dataclasses.field(default_factory=dict)
    reserved_bw: Mapping[Link, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        names = list(self.regions)
        n = len(names)
        self._names: List[str] = names
        self._idx: Dict[str, int] = {r: i for i, r in enumerate(names)}
        # Rank of each region in sorted-name order: vectorized tie-breaks
        # ("max by (value, name)" / "min by (value, name)") need it.
        rank = np.empty(n, dtype=np.int64)
        for pos, i in enumerate(sorted(range(n), key=lambda i: names[i])):
            rank[i] = pos
        self._name_rank = rank
        self._cap = np.array(
            [self.regions[r].gpu_capacity for r in names], dtype=np.int64
        )
        self._price = np.array(
            [self.regions[r].price_kwh for r in names], dtype=float
        )
        self._price_base = self._price.copy()
        self._cap_total = int(self._cap.sum())

        # ---- typed GPU pools (heterogeneity model): a plain region exposes
        # one implicit default column, so the homogeneous layout is the T=1
        # special case and every aggregate below is bit-identical to it.
        pools_by_region: List[Tuple[GpuPool, ...]] = []
        for r in names:
            reg = self.regions[r]
            pools_by_region.append(
                reg.pools
                if reg.pools
                else (GpuPool(DEFAULT_GPU_TYPE, reg.gpu_capacity),)
            )
        self._hetero = any(bool(self.regions[r].pools) for r in names)
        type_names = sorted({p.gpu_type for ps in pools_by_region for p in ps})
        self._gpu_types: List[str] = type_names
        self._tidx: Dict[str, int] = {t: j for j, t in enumerate(type_names)}
        self._cap_t = np.zeros((n, len(type_names)), dtype=np.int64)
        self._pools: Dict[Tuple[str, str], GpuPool] = {}
        #: Per-region type-column indices in *assign order*: cheapest
        #: $/GPU-hour first (spot discounts first), ties by type name — the
        #: one deterministic rule reserve_gpus, cost_min_allocate, and
        #: assign_types all share.
        self._region_cells: List[List[int]] = []
        for i, r in enumerate(names):
            cells: List[int] = []
            for p in pools_by_region[i]:
                t = self._tidx[p.gpu_type]
                self._cap_t[i, t] = p.count
                self._pools[(r, p.gpu_type)] = p
                cells.append(t)
            cells.sort(
                key=lambda t: (
                    self._pools[(r, type_names[t])].price_mult
                    * self._pools[(r, type_names[t])].kw_or_default,
                    type_names[t],
                )
            )
            self._region_cells.append(cells)
        self._cap_t_base = self._cap_t.copy()
        self._used_t = np.zeros_like(self._cap_t)
        self._spot_mult: Dict[Tuple[str, str], float] = {}
        # Dense per-(region, type) FLOPS for the batched decision kernels.
        # NaN marks a pool inheriting the job profile's reference hardware
        # (resolved against the caller's default at query time); cells with
        # no pool at all are masked separately via ``_cell_exists``.
        self._flops_t = np.full((n, len(type_names)), np.nan)
        self._cell_exists = np.zeros((n, len(type_names)), dtype=bool)
        for (r, tname), p in self._pools.items():
            i, t = self._idx[r], self._tidx[tname]
            self._cell_exists[i, t] = True
            if p.flops is not None:
                self._flops_t[i, t] = p.flops

        provided_free = dict(self.free_gpus) if self.free_gpus else None
        if provided_free is not None:
            # Aggregate free counts distribute over a region's pools in
            # assign order (``snapshot`` overwrites the typed arrays
            # wholesale afterwards, so this only matters for hand-built
            # states); a free total above capacity — the old unchecked
            # aggregate-set backdoor — lands on the last cell.
            for i, r in enumerate(names):
                want = int(provided_free.get(r, 0))
                for t in self._region_cells[i]:
                    take = min(int(self._cap_t[i, t]), want)
                    self._used_t[i, t] = int(self._cap_t[i, t]) - take
                    want -= take
                if want > 0:
                    self._used_t[i, self._region_cells[i][-1]] -= want
        self._free = np.maximum(self._cap_t - self._used_t, 0).sum(axis=1)
        self._free_total = int(self._free.sum())

        self._bw_mat = np.zeros((n, n), dtype=float)
        self._link_idx: Dict[Link, Tuple[int, int]] = {}
        for (u, v), b in self.bandwidth.items():
            iu, iv = self._idx.get(u), self._idx.get(v)
            if iu is None or iv is None:
                continue
            self._bw_mat[iu, iv] = b
            self._link_idx[(u, v)] = (iu, iv)
        # Decision input (feeds congestion_alpha): the accumulation order is
        # pinned to the reference implementation's dict order — re-sorting
        # would move the last-ulp rounding and break golden-trace
        # byte-stability.
        self._bw_total = float(sum(self.bandwidth.values()))  # reprolint: disable=RPL104
        # Installed-capacity baseline for time-varying multipliers: dynamic
        # scenarios rescale _bw_mat against this, never compounding.
        self._bw_base = self._bw_mat.copy()
        self._bw_dict_base = dict(self.bandwidth)

        self._res_mat = np.zeros((n, n), dtype=float)
        self._res_extra: Dict[Link, float] = {}
        self._res_total = 0.0
        # Memoized ``available_matrix`` storage: built once on first use,
        # then maintained entry-wise by every _bw_mat/_res_mat write (the
        # writes are per-link, so upkeep is O(1) per mutation).  Callers get
        # a read-only view of the same buffer.
        self._avail_base: Optional[np.ndarray] = None
        self._avail_view: Optional[np.ndarray] = None
        provided_res = dict(self.reserved_bw) if self.reserved_bw else None
        self.free_gpus = _FreeGpuLedger(self)
        self.reserved_bw = _ReservedBwLedger(self)
        if provided_res:
            for link, b in provided_res.items():
                self.reserved_bw[link] = float(b)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        regions: Iterable[Region],
        bandwidth_gbps: Mapping[Link, float],
        *,
        symmetric: bool = True,
    ) -> "ClusterState":
        regs = {r.name: r for r in regions}
        bw: Dict[Link, float] = {}
        for (u, v), gbps in bandwidth_gbps.items():
            if u not in regs or v not in regs:
                raise KeyError(f"link ({u},{v}) references unknown region")
            bw[(u, v)] = gbps * GBPS
            if symmetric:
                bw.setdefault((v, u), gbps * GBPS)
        return cls(regions=regs, bandwidth=bw)

    @classmethod
    def from_region_bandwidths(
        cls, regions: Iterable[Region], region_gbps: Mapping[str, float]
    ) -> "ClusterState":
        """Paper Table II convention: ``B_{i,j} = (B_i + B_j) / 2``."""
        regs = list(regions)
        bw: Dict[Link, float] = {}
        for a in regs:
            for b in regs:
                if a.name == b.name:
                    continue
                bw[(a.name, b.name)] = (
                    (region_gbps[a.name] + region_gbps[b.name]) / 2.0
                )
        return cls.build(regs, bw, symmetric=False)

    # ------------------------------------------------------------------- gpus
    def total_gpus(self) -> int:
        return self._cap_total

    def total_free_gpus(self) -> int:
        return self._free_total

    def price(self, region: str) -> float:
        """Current electricity price ($/kWh) — the construction-time price
        scaled by any live multiplier (see ``set_price_multipliers``)."""
        return float(self._price[self._idx[region]])

    def _refresh_free(self, i: int) -> None:
        """Re-derive one region's aggregate free count from the typed ledger
        (``Σ_t max(0, cap − used)``: pools a spot reclaim shrank below their
        in-use count contribute nothing) and patch the running total."""
        new = int(np.maximum(self._cap_t[i] - self._used_t[i], 0).sum())
        self._free_total += new - int(self._free[i])
        self._free[i] = new

    def reserve_gpus(self, alloc: Mapping[str, int]) -> None:
        idx, free = self._idx, self._free
        for r, n in alloc.items():
            i = idx.get(r)
            have = int(free[i]) if i is not None else 0
            if n < 0 or n > have:
                raise ValueError(
                    f"cannot reserve {n} GPUs in {r} (free={have})"
                )
        for r, n in alloc.items():
            i = idx[r]
            left = int(n)
            for t in self._region_cells[i]:
                if left == 0:
                    break
                avail = int(self._cap_t[i, t]) - int(self._used_t[i, t])
                if avail <= 0:
                    continue
                take = min(avail, left)
                self._used_t[i, t] += take
                left -= take
            if left:  # unreachable given the aggregate pre-check
                raise ValueError(f"cannot reserve {n} GPUs in {r}")
            self._refresh_free(i)

    def release_gpus(self, alloc: Mapping[str, int]) -> None:
        """Release untyped per-region counts.  All-or-nothing: releasing
        more than a region has in use is a double-release bug and raises
        before any mutation (the ``release_bandwidth`` convention)."""
        idx = self._idx
        for r, n in alloc.items():
            i = idx[r]
            in_use = int(
                self._used_t[i][self._used_t[i] > 0].sum()
            )
            if n > in_use:
                raise ValueError(f"GPU over-release in {r}")
        for r, n in alloc.items():
            i = idx[r]
            # Untyped release returns GPUs to pools in reverse assign order
            # (LIFO against reserve_gpus); typed placements go through
            # release_gpus_typed instead and never hit this heuristic.
            left = int(n)
            for t in reversed(self._region_cells[i]):
                if left == 0:
                    break
                used = int(self._used_t[i, t])
                if used <= 0:
                    continue
                give = min(used, left)
                self._used_t[i, t] -= give
                left -= give
            self._refresh_free(i)

    # ----------------------------------------------------------- typed pools
    @property
    def is_heterogeneous(self) -> bool:
        """True when any region declares explicit typed pools — the flag that
        routes the allocator/Pathfinder/timing onto the (region, type) paths.
        Plain clusters keep the seed's exact homogeneous code paths."""
        return self._hetero

    def gpu_types(self, region: str) -> List[str]:
        """The region's pool types in assign (cheapest-first) order."""
        i = self._idx[region]
        return [self._gpu_types[t] for t in self._region_cells[i]]

    def pool(self, region: str, gpu_type: str) -> GpuPool:
        try:
            return self._pools[(region, gpu_type)]
        except KeyError:
            raise KeyError(
                f"no GPU pool {gpu_type!r} in region {region!r}"
            ) from None

    def pool_rate(self, region: str, gpu_type: str) -> float:
        """Cost-ordering rate of one pool cell: live regional $/kWh × spot
        price multiplier × board kW (reference kW for pools inheriting the
        profile's hardware) — the quantity the typed Cost-Min pour sorts."""
        p = self.pool(region, gpu_type)
        return self.price(region) * p.price_mult * p.kw_or_default

    def free_gpus_typed(self, region: str) -> Dict[str, int]:
        i = self._idx[region]
        return {
            self._gpu_types[t]: max(
                0, int(self._cap_t[i, t]) - int(self._used_t[i, t])
            )
            for t in self._region_cells[i]
        }

    def capacity_typed(self, region: str) -> Dict[str, int]:
        """Current (possibly spot-shrunk) per-type capacity of a region."""
        i = self._idx[region]
        return {
            self._gpu_types[t]: int(self._cap_t[i, t])
            for t in self._region_cells[i]
        }

    def assign_types(self, region: str, n: int) -> Dict[str, int]:
        """Deterministically type an untyped grant of ``n`` GPUs in
        ``region``: cheapest $/GPU-hour pools first (spot discounts first),
        ties by type name — the identical fill order ``cost_min_allocate``
        prices, so the typed grant matches what the allocator assumed.
        Raises when the region lacks ``n`` free GPUs."""
        i = self._idx[region]
        out: Dict[str, int] = {}
        left = int(n)
        for t in self._region_cells[i]:
            if left == 0:
                break
            avail = int(self._cap_t[i, t]) - int(self._used_t[i, t])
            if avail <= 0:
                continue
            take = min(avail, left)
            out[self._gpu_types[t]] = take
            left -= take
        if left > 0:
            raise ValueError(
                f"cannot type {n} GPUs in {region}: only {n - left} free"
            )
        return out

    def min_available_flops(self, region: str, default_flops: float) -> float:
        """Most conservative per-GPU FLOPS among the region's pools that
        still have free GPUs (Pathfinder admission heuristic); pools that
        inherit the profile's reference hardware count as ``default_flops``,
        which is also returned when the region has nothing free."""
        i = self._idx[region]
        best: Optional[float] = None
        for t in self._region_cells[i]:
            if int(self._cap_t[i, t]) - int(self._used_t[i, t]) > 0:
                p = self._pools[(self._names[i], self._gpu_types[t])]
                f = p.flops if p.flops is not None else default_flops
                best = f if best is None else min(best, f)
        return default_flops if best is None else best

    def min_available_flops_vector(self, default_flops: float) -> np.ndarray:
        """``min_available_flops`` for every region at once — the (R,)-shaped
        input of the batched Pathfinder admission kernel.  One masked min over
        the typed ledger; per-element results are bit-identical to the scalar
        method (min over exact float64 values is order-independent)."""
        free_cell = ((self._cap_t - self._used_t) > 0) & self._cell_exists
        fl = np.where(np.isnan(self._flops_t), default_flops, self._flops_t)
        m = np.where(free_cell, fl, np.inf).min(axis=1)
        return np.where(np.isinf(m), default_flops, m)

    def reserve_gpus_typed(
        self, alloc: Mapping[str, Mapping[str, int]]
    ) -> None:
        """Reserve per-(region, type) counts.  All-or-nothing: every cell is
        validated against its free count before any mutation."""
        resolved: List[Tuple[int, int, int]] = []
        for r, types in alloc.items():
            i = self._idx[r]
            for gtype, n in types.items():
                if (r, gtype) not in self._pools:
                    raise KeyError(f"no GPU pool {gtype!r} in region {r!r}")
                t = self._tidx[gtype]
                have = max(
                    0, int(self._cap_t[i, t]) - int(self._used_t[i, t])
                )
                if n < 0 or n > have:
                    raise ValueError(
                        f"cannot reserve {n} {gtype} GPUs in {r} "
                        f"(free={have})"
                    )
                resolved.append((i, t, int(n)))
        for i, t, n in resolved:
            self._used_t[i, t] += n
        for i in sorted({i for i, _, _ in resolved}):
            self._refresh_free(i)

    def release_gpus_typed(
        self, alloc: Mapping[str, Mapping[str, int]]
    ) -> None:
        """Release per-(region, type) counts; releasing more than a cell has
        in use is a double-release bug and raises (all-or-nothing)."""
        resolved: List[Tuple[int, int, int]] = []
        for r, types in alloc.items():
            i = self._idx[r]
            for gtype, n in types.items():
                if (r, gtype) not in self._pools:
                    raise KeyError(f"no GPU pool {gtype!r} in region {r!r}")
                t = self._tidx[gtype]
                used = int(self._used_t[i, t])
                if n < 0 or n > used:
                    raise ValueError(
                        f"GPU over-release in {r} ({gtype}): releasing {n} "
                        f"with {used} in use"
                    )
                resolved.append((i, t, int(n)))
        for i, t, n in resolved:
            self._used_t[i, t] -= n
        for i in sorted({i for i, _, _ in resolved}):
            self._refresh_free(i)

    def spot_pools(self) -> List[Tuple[str, str]]:
        """All (region, type) cells marked reclaimable, sorted."""
        return sorted(k for k, p in self._pools.items() if p.spot)

    def set_spot_multipliers(
        self, multipliers: Mapping[Tuple[str, str], float]
    ) -> None:
        """Rescale listed *spot* pools to ``multiplier × installed count``
        (absolute against the construction-time count, no compounding — the
        same convention as ``set_link_multipliers``).  A reclaim may shrink a
        pool below its in-use count; reservations are left untouched and the
        deficit is reported by ``oversubscribed_pools`` until the simulator's
        preemption pass resolves it.  All-or-nothing validation."""
        resolved: List[Tuple[str, str, float]] = []
        for (region, gtype), m in multipliers.items():
            if m < 0.0:
                raise ValueError(
                    f"negative spot multiplier for {(region, gtype)}"
                )
            pool = self._pools.get((region, gtype))
            if pool is None:
                raise KeyError(
                    f"no GPU pool {gtype!r} in region {region!r}"
                )
            if not pool.spot:
                raise ValueError(
                    f"pool {gtype!r} in {region!r} is not spot capacity"
                )
            resolved.append((region, gtype, m))
        for region, gtype, m in resolved:
            i, t = self._idx[region], self._tidx[gtype]
            new_cap = int(round(int(self._cap_t_base[i, t]) * m))
            delta = new_cap - int(self._cap_t[i, t])
            self._spot_mult[(region, gtype)] = m
            if delta == 0:
                continue
            self._cap_t[i, t] = new_cap
            self._cap[i] += delta
            self._cap_total += delta
            self._refresh_free(i)

    def oversubscribed_pools(self) -> List[Tuple[str, str]]:
        """(region, type) cells holding more in-use GPUs than their (possibly
        spot-shrunk) capacity — the Eq. 5 violations a spot reclaim can
        introduce; the GPU analogue of ``oversubscribed_links``.  Sorted for
        deterministic preemption resolution."""
        out = [
            (region, gtype)
            for (region, gtype) in self._pools
            if int(self._used_t[self._idx[region], self._tidx[gtype]])
            > int(self._cap_t[self._idx[region], self._tidx[gtype]])
        ]
        out.sort()
        return out

    # ---------------------------------------------------------------- network
    def link_bandwidth(self, u: str, v: str) -> float:
        """Current capacity of the directed link (u, v) — the installed
        bandwidth scaled by any live multiplier (see
        ``set_link_multipliers``); intra-region hops use the constant fast
        fabric."""
        if u == v:
            return INTRA_REGION_BANDWIDTH
        ij = self._link_idx.get((u, v))
        return float(self._bw_mat[ij]) if ij is not None else 0.0

    def available_bandwidth(self, u: str, v: str) -> float:
        if u == v:
            return INTRA_REGION_BANDWIDTH
        ij = self._link_idx.get((u, v))
        if ij is None:
            return 0.0
        return max(0.0, float(self._bw_mat[ij]) - float(self._res_mat[ij]))

    def _avail_touch(self, ij: Tuple[int, int]) -> None:
        """Keep the memoized residual matrix in sync after a single-link
        capacity or reservation write."""
        base = self._avail_base
        if base is not None:
            base[ij] = max(0.0, float(self._bw_mat[ij]) - float(self._res_mat[ij]))

    def available_matrix(self) -> np.ndarray:
        """Dense R×R residual WAN bandwidth (bytes/s); the diagonal is 0 — use
        ``available_bandwidth`` for intra-region hops.

        Built once, then maintained incrementally by the per-link ledger
        writes (``_avail_touch``) and returned as a read-only view — it is
        the scheduling hot path's largest per-decision allocation, and the
        entry-wise ``max(0, bw - res)`` upkeep is bit-identical to a full
        recompute."""
        if self._avail_base is None:
            self._avail_base = np.maximum(0.0, self._bw_mat - self._res_mat)
            view = self._avail_base.view()
            view.setflags(write=False)
            self._avail_view = view
        return self._avail_view

    def reserve_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Eq. (6): reservations on a link may never exceed its capacity.

        The float-drift slack is purely *relative* to the link's capacity: an
        absolute epsilon would let tiny reservations slip onto near-zero- or
        zero-capacity links (e.g. after a full-outage multiplier), silently
        violating Eq. (6) exactly where it matters most."""
        for (u, v), b in edges.items():
            if u == v:
                continue
            avail = self.available_bandwidth(u, v)
            if b > avail + 1e-9 * self.link_bandwidth(u, v):
                raise ValueError(
                    f"bandwidth over-subscription on {u}->{v}: "
                    f"want {b:.3e}, have {avail:.3e}"
                )
        for (u, v), b in edges.items():
            if u == v:
                continue
            ij = self._link_idx.get((u, v))
            if ij is None:
                self._res_extra[(u, v)] = self._res_extra.get((u, v), 0.0) + b
            else:
                self._res_mat[ij] += b
                self._res_total += b
                self._avail_touch(ij)

    def release_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Releasing more than is reserved (beyond float-drift tolerance) is a
        double-release bug and raises, mirroring ``release_gpus``.  Validation
        runs over every edge before any mutation (as ``reserve_bandwidth``
        does), so a rejected release leaves the ledger untouched."""
        updates = []
        for (u, v), b in edges.items():
            if u == v:
                continue
            ij = self._link_idx.get((u, v))
            cur = (
                self._res_extra.get((u, v), 0.0)
                if ij is None
                else float(self._res_mat[ij])
            )
            new = cur - b
            if new < -(1e-6 + 1e-9 * self.link_bandwidth(u, v)):
                raise ValueError(
                    f"bandwidth over-release on {u}->{v}: releasing {b:.3e} "
                    f"with only {cur:.3e} reserved"
                )
            updates.append(((u, v), ij, cur, max(0.0, new)))
        for link, ij, cur, new in updates:
            if ij is None:
                self._res_extra[link] = new
            else:
                self._res_mat[ij] = new
                self._res_total += new - cur
                self._avail_touch(ij)
        if self._res_total < 0.0:  # guard accumulated float drift
            self._res_total = 0.0

    def congestion_alpha(self) -> float:
        """Eq. (11): ratio of reserved inter-region bandwidth to aggregate
        installed inter-region capacity, clamped to [0, 1].  O(1): both terms
        are running totals maintained by the ledgers."""
        if self._bw_total <= 0.0:
            return 0.0
        return min(1.0, max(0.0, self._res_total / self._bw_total))

    # ------------------------------------------------------ dynamic environment
    def set_link_multipliers(self, multipliers: Mapping[Link, float]) -> None:
        """Rescale listed links to ``multiplier × installed capacity``.

        Multipliers are absolute against the construction-time (base)
        capacity, so repeated application never compounds.  Reservations are
        left untouched: a link may transiently hold more reserved bandwidth
        than its shrunk capacity until the simulator's preemption pass
        resolves it (``oversubscribed_links`` reports such links).

        Validation runs over every entry before any mutation (the same
        convention as ``reserve_bandwidth``/``release_bandwidth``): a
        rejected update leaves the cluster untouched.
        """
        resolved = []
        for link, m in multipliers.items():
            if m < 0.0:
                raise ValueError(f"negative bandwidth multiplier on {link}")
            ij = self._link_idx.get(link)
            if ij is None:
                raise KeyError(f"link {link} is not installed")
            resolved.append((link, ij, m))
        for link, ij, m in resolved:
            new = float(self._bw_base[ij]) * m
            self._bw_total += new - float(self._bw_mat[ij])
            self._bw_mat[ij] = new
            self.bandwidth[link] = new
            self._avail_touch(ij)

    def set_price_multipliers(self, multipliers: Mapping[str, float]) -> None:
        """Rescale listed regions' electricity prices against their
        construction-time values (absolute multipliers, no compounding).
        All-or-nothing, like ``set_link_multipliers``."""
        resolved = []
        for region, m in multipliers.items():
            if m < 0.0:
                raise ValueError(f"negative price multiplier for {region}")
            i = self._idx.get(region)
            if i is None:
                raise KeyError(f"unknown region {region}")
            resolved.append((i, m))
        for i, m in resolved:
            self._price[i] = self._price_base[i] * m

    def apply_env_update(
        self, update: EnvUpdate
    ) -> Tuple[bool, bool, bool]:
        """Apply one trace breakpoint; returns ``(bandwidth_changed,
        prices_changed, spot_changed)`` — the first triggers the simulator's
        placement re-validation (forced preemption), the second its segment
        repricing and price-aware voluntary-migration passes, the third its
        spot-reclaim preemption pass (``oversubscribed_pools``).
        All-or-nothing across all three: unknown links/regions/pools are
        rejected before any multiplier set mutates."""
        for link in update.bandwidth:
            if link not in self._link_idx:
                raise KeyError(f"link {link} is not installed")
        for region in update.prices:
            if region not in self._idx:
                raise KeyError(f"unknown region {region}")
        for pool_key in update.spot:
            pool = self._pools.get(pool_key)
            if pool is None:
                raise KeyError(f"no GPU pool {pool_key!r}")
            if not pool.spot:
                raise ValueError(f"pool {pool_key!r} is not spot capacity")
        if update.prices:
            self.set_price_multipliers(update.prices)
        if update.bandwidth:
            self.set_link_multipliers(update.bandwidth)
        if update.spot:
            self.set_spot_multipliers(update.spot)
        return bool(update.bandwidth), bool(update.prices), bool(update.spot)

    def oversubscribed_links(self, *, rel_tol: float = 1e-9) -> List[Link]:
        """Links whose reserved bandwidth exceeds their (possibly shrunk)
        capacity — Eq. (6) violations a bandwidth drop can introduce.
        Uninstalled links (``_res_extra``: background reservations handed in
        at construction) have zero capacity, so any positive reservation on
        one is a standing violation and is reported too — otherwise the
        preemption pass could never even see it.  Sorted by link name for
        deterministic preemption resolution."""
        over = self._res_mat > self._bw_mat * (1.0 + rel_tol) + 1e-6
        out = [
            link for link, ij in self._link_idx.items() if over[ij]
        ]
        out.extend(link for link, b in self._res_extra.items() if b > 1e-6)
        out.sort()
        return out

    # ------------------------------------------------------------------ misc
    def region_names(self) -> List[str]:
        return list(self.regions)

    def region_index(self) -> Dict[str, int]:
        return self._idx

    # ------------------------------------------- read-only ledger views
    # The decision kernels and test/bench setup consume the dense ledgers
    # directly; these accessors hand out read-only views of the live arrays
    # (the ledgers are only ever mutated in place, so a view never goes
    # stale) without opening the mutation backdoor that made direct
    # ``_free``/``_price`` pokes bypass the memoized upkeep.
    @staticmethod
    def _frozen(arr: np.ndarray) -> np.ndarray:
        view = arr.view()
        view.flags.writeable = False
        return view

    def free_vector(self) -> np.ndarray:
        """Per-region free GPU counts, region order (read-only view)."""
        return self._frozen(self._free)

    def capacity_vector(self) -> np.ndarray:
        """Per-region total GPU capacity, region order (read-only view).
        Live values: spot churn moves them (see ``apply_env_update``)."""
        return self._frozen(self._cap)

    def price_vector(self) -> np.ndarray:
        """Current per-region $/kWh prices, region order (read-only view)."""
        return self._frozen(self._price)

    def name_rank_vector(self) -> np.ndarray:
        """Lexicographic rank of each region's name, region order
        (read-only view) — the kernels' name tie-break key."""
        return self._frozen(self._name_rank)

    def region_rank(self, region: str) -> int:
        """Lexicographic rank of one region's name among all regions."""
        return int(self._name_rank[self._idx[region]])

    def gpu_type_rank(self, gpu_type: str) -> int:
        """Column index of a GPU type in the typed ledgers — the
        deterministic type tie-break key (sorted type names)."""
        return self._tidx[gpu_type]

    def typed_capacity_matrix(self) -> np.ndarray:
        """(region, type) capacity plane (read-only view)."""
        return self._frozen(self._cap_t)

    def typed_used_matrix(self) -> np.ndarray:
        """(region, type) in-use plane (read-only view)."""
        return self._frozen(self._used_t)

    def total_link_capacity(self) -> float:
        """Σ of all directed link capacities (the congestion_alpha
        denominator)."""
        return self._bw_total

    def scaled(
        self,
        *,
        bandwidth_factor: float = 1.0,
        capacity_factor: float = 1.0,
    ) -> "ClusterState":
        """Fresh cluster with scaled links / GPU pools (paper Figs. 5–6).

        Scaling applies to the *installed* (construction-time) capacities and
        base prices; any live dynamic multipliers are then re-applied on the
        new cluster — base and dynamic state stay separated instead of the
        live bandwidth silently becoming the new cluster's installed baseline
        next to construction-time prices.  Reservations are not carried over
        (same as before: a scaled cluster starts empty).  Typed pools scale
        per pool (rounded; a region that would vanish keeps one GPU in its
        first pool, matching the plain-region ``max(1, ...)`` floor)."""
        regs: List[Region] = []
        for r in self.regions.values():
            if r.pools:
                pools = [
                    dataclasses.replace(
                        p, count=int(round(p.count * capacity_factor))
                    )
                    for p in r.pools
                ]
                if sum(p.count for p in pools) < 1:
                    pools[0] = dataclasses.replace(pools[0], count=1)
                regs.append(Region.with_pools(r.name, r.price_kwh, pools))
            else:
                regs.append(
                    Region(
                        name=r.name,
                        gpu_capacity=max(
                            1, int(round(r.gpu_capacity * capacity_factor))
                        ),
                        price_kwh=r.price_kwh,
                    )
                )
        bw = {
            l: b * bandwidth_factor / GBPS
            for l, b in self._bw_dict_base.items()
        }
        out = ClusterState.build(regs, bw, symmetric=False)
        link_mults = {}
        for link, ij in self._link_idx.items():
            base = float(self._bw_base[ij])
            if base > 0.0:
                m = float(self._bw_mat[ij]) / base
                if m != 1.0:
                    link_mults[link] = m
        price_mults = {}
        for region, i in self._idx.items():
            base = float(self._price_base[i])
            if base > 0.0:
                m = float(self._price[i]) / base
                if m != 1.0:
                    price_mults[region] = m
        if link_mults:
            out.set_link_multipliers(link_mults)
        if price_mults:
            out.set_price_multipliers(price_mults)
        spot_mults = {k: m for k, m in self._spot_mult.items() if m != 1.0}
        if spot_mults:
            out.set_spot_multipliers(spot_mults)
        return out

    def snapshot(self) -> "ClusterState":
        """Deep copy with identical live state: ledgers, *and* any dynamic
        multipliers — the copy keeps the original installed-capacity /
        base-price baselines, so later absolute multipliers rescale against
        the same base as on the source cluster."""
        snap = ClusterState(
            regions=dict(self.regions),
            bandwidth=dict(self._bw_dict_base),
            free_gpus=dict(self.free_gpus),
            reserved_bw=dict(self.reserved_bw),
        )
        np.copyto(snap._bw_mat, self._bw_mat)
        snap._bw_total = self._bw_total
        snap.bandwidth.clear()
        snap.bandwidth.update(self.bandwidth)
        np.copyto(snap._price, self._price)
        # Typed-ledger state: exact per-(region, type) capacities / in-use
        # counts (the aggregate free dict the constructor consumed cannot
        # reconstruct a multi-pool split, and spot reclaims may have moved
        # capacities off their installed baseline).
        np.copyto(snap._cap_t, self._cap_t)
        np.copyto(snap._used_t, self._used_t)
        np.copyto(snap._cap, self._cap)
        snap._cap_total = self._cap_total
        np.copyto(snap._free, self._free)
        snap._free_total = self._free_total
        snap._spot_mult = dict(self._spot_mult)
        return snap
