"""Geo-distributed cluster model: regions, links, and live resource ledgers.

This is the control-plane view of the world (paper §III-A "System Model"):
``K`` regions, each with a GPU capacity ``G_r`` and electricity price ``P_r``,
joined by directed inter-region links with bandwidth ``B_{u,v}`` (asymmetry
supported).  ``ClusterState`` additionally keeps *live* ledgers — free GPUs
per region and reserved bandwidth per link — which Eq. (5)/(6) constrain and
Eq. (11)'s congestion factor ``alpha`` reads.

Storage layout (see DESIGN.md "vectorized engine"): the ledgers are backed by
numpy — a region→index map, free/capacity/price vectors, and dense R×R
installed-bandwidth + reserved matrices — so the Pathfinder and the priority
ranker operate on arrays instead of per-key dict lookups.  ``free_gpus`` and
``reserved_bw`` remain dict-like *write-through views* over those arrays, so
all seed-era call sites (and tests that poke the ledgers directly) keep
working unchanged.  ``congestion_alpha`` is maintained as an O(1) running sum
updated on every reserve/release instead of being re-summed per call.
"""

from __future__ import annotations

import dataclasses
from collections.abc import MutableMapping
from typing import Dict, Iterable, Iterator, List, Mapping, Tuple

import numpy as np

GBPS = 1e9 / 8.0  # bytes/sec per Gbit/s

#: Effective intra-region bandwidth (NVLink/NVSwitch class, bytes/s). Adjacent
#: pipeline stages placed in the same region communicate at this rate, so
#: intra-region hops are never the pipeline bottleneck.
INTRA_REGION_BANDWIDTH = 600.0 * GBPS


@dataclasses.dataclass(frozen=True)
class Region:
    """A cloud region: GPU pool + electricity price.

    ``price_kwh`` is the regional electricity price in $/kWh (paper Table II);
    the $/GPU-hour rate is ``price_kwh * gpu_kw`` with ``gpu_kw`` owned by the
    simulation config (one value per accelerator generation).
    """

    name: str
    gpu_capacity: int
    price_kwh: float

    def __post_init__(self) -> None:
        if self.gpu_capacity < 0:
            raise ValueError(f"negative GPU capacity for region {self.name}")
        if self.price_kwh < 0:
            raise ValueError(f"negative electricity price for region {self.name}")


Link = Tuple[str, str]


@dataclasses.dataclass(frozen=True)
class EnvUpdate:
    """One breakpoint of a piecewise-constant environment trace.

    At ``time`` the listed links take bandwidth multiplier ``bandwidth[link]``
    (absolute against the *installed* capacity, not against the previous
    value) and the listed regions take electricity-price multiplier
    ``prices[region]`` (absolute against the construction-time price).
    Links/regions not listed keep their current multiplier.
    """

    time: float
    bandwidth: Mapping[Link, float] = dataclasses.field(default_factory=dict)
    prices: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.time < 0.0:
            raise ValueError("EnvUpdate.time must be >= 0")
        for link, m in self.bandwidth.items():
            if m < 0.0:
                raise ValueError(f"negative bandwidth multiplier on {link}")
        for region, m in self.prices.items():
            if m < 0.0:
                raise ValueError(f"negative price multiplier for {region}")


class BandwidthTrace:
    """Time-varying environment: an ordered sequence of ``EnvUpdate``s.

    The model is piecewise-constant (paper-style "real-time network
    utilization" snapshots): between breakpoints the effective bandwidth
    matrix and prices are fixed; at a breakpoint the simulator applies the
    update atomically with every other event at that timestamp, then
    re-validates running placements (see ``core/scheduler.py``).  Updates are
    stored sorted by time (stable for equal times).
    """

    def __init__(self, updates: Iterable[EnvUpdate] = ()) -> None:
        self.updates: List[EnvUpdate] = sorted(updates, key=lambda u: u.time)

    def __len__(self) -> int:
        return len(self.updates)

    def __iter__(self) -> Iterator[EnvUpdate]:
        return iter(self.updates)

    def change_times(self) -> List[float]:
        out: List[float] = []
        for u in self.updates:
            if not out or u.time != out[-1]:
                out.append(u.time)
        return out

    def merged(self, other: "BandwidthTrace") -> "BandwidthTrace":
        return BandwidthTrace([*self.updates, *other.updates])


class _FreeGpuLedger(MutableMapping):
    """Dict view of the free-GPU vector; writes go straight to the array and
    keep the cluster's running free-GPU total in sync."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState") -> None:
        self._cs = cs

    def __getitem__(self, region: str) -> int:
        cs = self._cs
        try:
            return int(cs._free[cs._idx[region]])
        except KeyError:
            raise KeyError(region) from None

    def __setitem__(self, region: str, count: int) -> None:
        cs = self._cs
        i = cs._idx[region]  # KeyError for unknown regions
        n = int(count)
        cs._free_total += n - int(cs._free[i])
        cs._free[i] = n

    def __delitem__(self, region: str) -> None:
        raise TypeError("region ledger entries cannot be deleted")

    def __iter__(self) -> Iterator[str]:
        return iter(self._cs._idx)

    def __len__(self) -> int:
        return len(self._cs._idx)

    def __repr__(self) -> str:
        return repr(dict(self))


class _ReservedBwLedger(MutableMapping):
    """Dict view of the reserved-bandwidth matrix (write-through).

    Links absent from the installed-bandwidth matrix live in a side dict and
    are excluded from the congestion running sum — mirroring the seed
    ``congestion_alpha``, which summed installed links only."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState") -> None:
        self._cs = cs

    def __getitem__(self, link: Link) -> float:
        cs = self._cs
        ij = cs._link_idx.get(link)
        if ij is not None:
            return float(cs._res_mat[ij])
        return cs._res_extra[link]

    def __setitem__(self, link: Link, value: float) -> None:
        cs = self._cs
        v = float(value)
        ij = cs._link_idx.get(link)
        if ij is None:
            cs._res_extra[link] = v
            return
        cs._res_total += v - float(cs._res_mat[ij])
        cs._res_mat[ij] = v

    def __delitem__(self, link: Link) -> None:
        raise TypeError("link ledger entries cannot be deleted")

    def __iter__(self) -> Iterator[Link]:
        yield from self._cs._link_idx
        yield from self._cs._res_extra

    def __len__(self) -> int:
        return len(self._cs._link_idx) + len(self._cs._res_extra)

    def __repr__(self) -> str:
        return repr(dict(self))


@dataclasses.dataclass
class ClusterState:
    """Mutable cluster: capacities, prices, bandwidth, and live reservations."""

    regions: Dict[str, Region]
    bandwidth: Dict[Link, float]  # bytes/s, directed
    free_gpus: Mapping[str, int] = dataclasses.field(default_factory=dict)
    reserved_bw: Mapping[Link, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        names = list(self.regions)
        n = len(names)
        self._names: List[str] = names
        self._idx: Dict[str, int] = {r: i for i, r in enumerate(names)}
        # Rank of each region in sorted-name order: vectorized tie-breaks
        # ("max by (value, name)" / "min by (value, name)") need it.
        rank = np.empty(n, dtype=np.int64)
        for pos, i in enumerate(sorted(range(n), key=lambda i: names[i])):
            rank[i] = pos
        self._name_rank = rank
        self._cap = np.array(
            [self.regions[r].gpu_capacity for r in names], dtype=np.int64
        )
        self._price = np.array(
            [self.regions[r].price_kwh for r in names], dtype=float
        )
        self._price_base = self._price.copy()
        self._cap_total = int(self._cap.sum())

        provided_free = dict(self.free_gpus) if self.free_gpus else None
        if provided_free is None:
            self._free = self._cap.copy()
        else:
            self._free = np.array(
                [int(provided_free.get(r, 0)) for r in names], dtype=np.int64
            )
        self._free_total = int(self._free.sum())

        self._bw_mat = np.zeros((n, n), dtype=float)
        self._link_idx: Dict[Link, Tuple[int, int]] = {}
        for (u, v), b in self.bandwidth.items():
            iu, iv = self._idx.get(u), self._idx.get(v)
            if iu is None or iv is None:
                continue
            self._bw_mat[iu, iv] = b
            self._link_idx[(u, v)] = (iu, iv)
        self._bw_total = float(sum(self.bandwidth.values()))
        # Installed-capacity baseline for time-varying multipliers: dynamic
        # scenarios rescale _bw_mat against this, never compounding.
        self._bw_base = self._bw_mat.copy()
        self._bw_dict_base = dict(self.bandwidth)

        self._res_mat = np.zeros((n, n), dtype=float)
        self._res_extra: Dict[Link, float] = {}
        self._res_total = 0.0
        provided_res = dict(self.reserved_bw) if self.reserved_bw else None
        self.free_gpus = _FreeGpuLedger(self)
        self.reserved_bw = _ReservedBwLedger(self)
        if provided_res:
            for link, b in provided_res.items():
                self.reserved_bw[link] = float(b)

    # ------------------------------------------------------------------ build
    @classmethod
    def build(
        cls,
        regions: Iterable[Region],
        bandwidth_gbps: Mapping[Link, float],
        *,
        symmetric: bool = True,
    ) -> "ClusterState":
        regs = {r.name: r for r in regions}
        bw: Dict[Link, float] = {}
        for (u, v), gbps in bandwidth_gbps.items():
            if u not in regs or v not in regs:
                raise KeyError(f"link ({u},{v}) references unknown region")
            bw[(u, v)] = gbps * GBPS
            if symmetric:
                bw.setdefault((v, u), gbps * GBPS)
        return cls(regions=regs, bandwidth=bw)

    @classmethod
    def from_region_bandwidths(
        cls, regions: Iterable[Region], region_gbps: Mapping[str, float]
    ) -> "ClusterState":
        """Paper Table II convention: ``B_{i,j} = (B_i + B_j) / 2``."""
        regs = list(regions)
        bw: Dict[Link, float] = {}
        for a in regs:
            for b in regs:
                if a.name == b.name:
                    continue
                bw[(a.name, b.name)] = (
                    (region_gbps[a.name] + region_gbps[b.name]) / 2.0
                )
        return cls.build(regs, bw, symmetric=False)

    # ------------------------------------------------------------------- gpus
    def total_gpus(self) -> int:
        return self._cap_total

    def total_free_gpus(self) -> int:
        return self._free_total

    def price(self, region: str) -> float:
        """Current electricity price ($/kWh) — the construction-time price
        scaled by any live multiplier (see ``set_price_multipliers``)."""
        return float(self._price[self._idx[region]])

    def reserve_gpus(self, alloc: Mapping[str, int]) -> None:
        idx, free = self._idx, self._free
        for r, n in alloc.items():
            i = idx.get(r)
            have = int(free[i]) if i is not None else 0
            if n < 0 or n > have:
                raise ValueError(
                    f"cannot reserve {n} GPUs in {r} (free={have})"
                )
        taken = 0
        for r, n in alloc.items():
            free[idx[r]] -= n
            taken += n
        self._free_total -= taken

    def release_gpus(self, alloc: Mapping[str, int]) -> None:
        idx, free = self._idx, self._free
        for r, n in alloc.items():
            i = idx[r]
            free[i] += n
            self._free_total += n
            if free[i] > self._cap[i]:
                raise ValueError(f"GPU over-release in {r}")

    # ---------------------------------------------------------------- network
    def link_bandwidth(self, u: str, v: str) -> float:
        """Current capacity of the directed link (u, v) — the installed
        bandwidth scaled by any live multiplier (see
        ``set_link_multipliers``); intra-region hops use the constant fast
        fabric."""
        if u == v:
            return INTRA_REGION_BANDWIDTH
        ij = self._link_idx.get((u, v))
        return float(self._bw_mat[ij]) if ij is not None else 0.0

    def available_bandwidth(self, u: str, v: str) -> float:
        if u == v:
            return INTRA_REGION_BANDWIDTH
        ij = self._link_idx.get((u, v))
        if ij is None:
            return 0.0
        return max(0.0, float(self._bw_mat[ij]) - float(self._res_mat[ij]))

    def available_matrix(self) -> np.ndarray:
        """Dense R×R residual WAN bandwidth (bytes/s); the diagonal is 0 — use
        ``available_bandwidth`` for intra-region hops."""
        return np.maximum(0.0, self._bw_mat - self._res_mat)

    def reserve_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Eq. (6): reservations on a link may never exceed its capacity."""
        for (u, v), b in edges.items():
            if u == v:
                continue
            avail = self.available_bandwidth(u, v)
            if b > avail + 1e-6:
                raise ValueError(
                    f"bandwidth over-subscription on {u}->{v}: "
                    f"want {b:.3e}, have {avail:.3e}"
                )
        for (u, v), b in edges.items():
            if u == v:
                continue
            ij = self._link_idx.get((u, v))
            if ij is None:
                self._res_extra[(u, v)] = self._res_extra.get((u, v), 0.0) + b
            else:
                self._res_mat[ij] += b
                self._res_total += b

    def release_bandwidth(self, edges: Mapping[Link, float]) -> None:
        """Releasing more than is reserved (beyond float-drift tolerance) is a
        double-release bug and raises, mirroring ``release_gpus``.  Validation
        runs over every edge before any mutation (as ``reserve_bandwidth``
        does), so a rejected release leaves the ledger untouched."""
        updates = []
        for (u, v), b in edges.items():
            if u == v:
                continue
            ij = self._link_idx.get((u, v))
            cur = (
                self._res_extra.get((u, v), 0.0)
                if ij is None
                else float(self._res_mat[ij])
            )
            new = cur - b
            if new < -(1e-6 + 1e-9 * self.link_bandwidth(u, v)):
                raise ValueError(
                    f"bandwidth over-release on {u}->{v}: releasing {b:.3e} "
                    f"with only {cur:.3e} reserved"
                )
            updates.append(((u, v), ij, cur, max(0.0, new)))
        for link, ij, cur, new in updates:
            if ij is None:
                self._res_extra[link] = new
            else:
                self._res_mat[ij] = new
                self._res_total += new - cur
        if self._res_total < 0.0:  # guard accumulated float drift
            self._res_total = 0.0

    def congestion_alpha(self) -> float:
        """Eq. (11): ratio of reserved inter-region bandwidth to aggregate
        installed inter-region capacity, clamped to [0, 1].  O(1): both terms
        are running totals maintained by the ledgers."""
        if self._bw_total <= 0.0:
            return 0.0
        return min(1.0, max(0.0, self._res_total / self._bw_total))

    # ------------------------------------------------------ dynamic environment
    def set_link_multipliers(self, multipliers: Mapping[Link, float]) -> None:
        """Rescale listed links to ``multiplier × installed capacity``.

        Multipliers are absolute against the construction-time (base)
        capacity, so repeated application never compounds.  Reservations are
        left untouched: a link may transiently hold more reserved bandwidth
        than its shrunk capacity until the simulator's preemption pass
        resolves it (``oversubscribed_links`` reports such links).

        Validation runs over every entry before any mutation (the same
        convention as ``reserve_bandwidth``/``release_bandwidth``): a
        rejected update leaves the cluster untouched.
        """
        resolved = []
        for link, m in multipliers.items():
            if m < 0.0:
                raise ValueError(f"negative bandwidth multiplier on {link}")
            ij = self._link_idx.get(link)
            if ij is None:
                raise KeyError(f"link {link} is not installed")
            resolved.append((link, ij, m))
        for link, ij, m in resolved:
            new = float(self._bw_base[ij]) * m
            self._bw_total += new - float(self._bw_mat[ij])
            self._bw_mat[ij] = new
            self.bandwidth[link] = new

    def set_price_multipliers(self, multipliers: Mapping[str, float]) -> None:
        """Rescale listed regions' electricity prices against their
        construction-time values (absolute multipliers, no compounding).
        All-or-nothing, like ``set_link_multipliers``."""
        resolved = []
        for region, m in multipliers.items():
            if m < 0.0:
                raise ValueError(f"negative price multiplier for {region}")
            i = self._idx.get(region)
            if i is None:
                raise KeyError(f"unknown region {region}")
            resolved.append((i, m))
        for i, m in resolved:
            self._price[i] = self._price_base[i] * m

    def apply_env_update(self, update: EnvUpdate) -> Tuple[bool, bool]:
        """Apply one trace breakpoint; returns ``(bandwidth_changed,
        prices_changed)`` — the first triggers the simulator's placement
        re-validation (forced preemption), the second its segment repricing
        and price-aware voluntary-migration passes.
        All-or-nothing across both halves: unknown links/regions are rejected
        before either multiplier set mutates."""
        for link in update.bandwidth:
            if link not in self._link_idx:
                raise KeyError(f"link {link} is not installed")
        for region in update.prices:
            if region not in self._idx:
                raise KeyError(f"unknown region {region}")
        if update.prices:
            self.set_price_multipliers(update.prices)
        if update.bandwidth:
            self.set_link_multipliers(update.bandwidth)
        return bool(update.bandwidth), bool(update.prices)

    def oversubscribed_links(self, *, rel_tol: float = 1e-9) -> List[Link]:
        """Links whose reserved bandwidth exceeds their (possibly shrunk)
        capacity — Eq. (6) violations a bandwidth drop can introduce.
        Uninstalled links (``_res_extra``: background reservations handed in
        at construction) have zero capacity, so any positive reservation on
        one is a standing violation and is reported too — otherwise the
        preemption pass could never even see it.  Sorted by link name for
        deterministic preemption resolution."""
        over = self._res_mat > self._bw_mat * (1.0 + rel_tol) + 1e-6
        out = [
            link for link, ij in self._link_idx.items() if over[ij]
        ]
        out.extend(link for link, b in self._res_extra.items() if b > 1e-6)
        out.sort()
        return out

    # ------------------------------------------------------------------ misc
    def region_names(self) -> List[str]:
        return list(self.regions)

    def region_index(self) -> Dict[str, int]:
        return self._idx

    def scaled(
        self,
        *,
        bandwidth_factor: float = 1.0,
        capacity_factor: float = 1.0,
    ) -> "ClusterState":
        """Fresh cluster with scaled links / GPU pools (paper Figs. 5–6).

        Scaling applies to the *installed* (construction-time) capacities and
        base prices; any live dynamic multipliers are then re-applied on the
        new cluster — base and dynamic state stay separated instead of the
        live bandwidth silently becoming the new cluster's installed baseline
        next to construction-time prices.  Reservations are not carried over
        (same as before: a scaled cluster starts empty)."""
        regs = [
            Region(
                name=r.name,
                gpu_capacity=max(1, int(round(r.gpu_capacity * capacity_factor))),
                price_kwh=r.price_kwh,
            )
            for r in self.regions.values()
        ]
        bw = {
            l: b * bandwidth_factor / GBPS
            for l, b in self._bw_dict_base.items()
        }
        out = ClusterState.build(regs, bw, symmetric=False)
        link_mults = {}
        for link, ij in self._link_idx.items():
            base = float(self._bw_base[ij])
            if base > 0.0:
                m = float(self._bw_mat[ij]) / base
                if m != 1.0:
                    link_mults[link] = m
        price_mults = {}
        for region, i in self._idx.items():
            base = float(self._price_base[i])
            if base > 0.0:
                m = float(self._price[i]) / base
                if m != 1.0:
                    price_mults[region] = m
        if link_mults:
            out.set_link_multipliers(link_mults)
        if price_mults:
            out.set_price_multipliers(price_mults)
        return out

    def snapshot(self) -> "ClusterState":
        """Deep copy with identical live state: ledgers, *and* any dynamic
        multipliers — the copy keeps the original installed-capacity /
        base-price baselines, so later absolute multipliers rescale against
        the same base as on the source cluster."""
        snap = ClusterState(
            regions=dict(self.regions),
            bandwidth=dict(self._bw_dict_base),
            free_gpus=dict(self.free_gpus),
            reserved_bw=dict(self.reserved_bw),
        )
        np.copyto(snap._bw_mat, self._bw_mat)
        snap._bw_total = self._bw_total
        snap.bandwidth.clear()
        snap.bandwidth.update(self.bandwidth)
        np.copyto(snap._price, self._price)
        return snap
