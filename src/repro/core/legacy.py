"""Seed-engine reference implementations, kept for parity + benchmarking.

The vectorized engine (``priority.py``, ``pathfinder.py``, the default
``Simulator`` path) must make bit-identical scheduling decisions to the seed
engine it replaced.  This module preserves the seed's dict-walking, recompute-
per-call implementations verbatim so that

* ``tests/test_engine_parity.py`` can prove decision-for-decision equality of
  ``simulate(..., engine="vectorized")`` and ``simulate(..., engine="legacy")``
  across every policy and ablation, and
* ``benchmarks/scheduler_scaling.py`` can measure the speedup against the true
  seed cost profile (per-job ``E_j(1)``/``b_j`` recomputed on every ordering
  pass, Prim expansion over scalar ledger lookups).

Nothing here should be used on a hot path.

Accounting note: the simulator's piecewise segment ledgers
(``core/accounting.py``) preserve this parity surface — a segment that is
never repriced (always true on the static scenarios the legacy engine is
limited to) settles to its placement-time ``electricity_cost`` projection
bit-exactly, so the settle-on-event refactor changes no legacy-comparable
float.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .allocator import cost_min_allocate
from .cluster import ClusterState
from .job import JobProfile
from .placement import Placement, build_placement
from .timing import average_price

# ------------------------------------------------------ priority (Eqs. 9-12)


def legacy_computation_intensity(
    pending: Sequence[JobProfile],
) -> Dict[int, float]:
    """Eq. (9), recomputing ``E_j(1)`` from scratch per call (seed cost)."""
    singles = {
        p.spec.job_id: p.single_gpu_execution_uncached() for p in pending
    }
    top = max(singles.values(), default=0.0)
    if top <= 0.0:
        return {j: 0.0 for j in singles}
    return {j: v / top for j, v in singles.items()}


def legacy_bandwidth_sensitivity(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> Dict[int, float]:
    """Eq. (10), recomputing ``b_j`` at ``K*`` from scratch per call."""
    cap = cluster.total_gpus()
    demands = {
        p.spec.job_id: p.bandwidth_requirement_uncached(p.optimal_gpus(cap))
        for p in pending
    }
    top = max(demands.values(), default=0.0)
    if top <= 0.0:
        return {j: 0.0 for j in demands}
    return {j: v / top for j, v in demands.items()}


def legacy_priority_scores(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> Dict[int, float]:
    """Eq. (12) with alpha read live from the cluster's bandwidth ledger."""
    alpha = cluster.congestion_alpha()
    intensity = legacy_computation_intensity(pending)
    sensitivity = legacy_bandwidth_sensitivity(pending, cluster)
    return {
        p.spec.job_id: (1.0 - alpha) * (1.0 - intensity[p.spec.job_id])
        + alpha * (1.0 - sensitivity[p.spec.job_id])
        for p in pending
    }


def legacy_order_by_priority(
    pending: Sequence[JobProfile], cluster: ClusterState
) -> List[JobProfile]:
    """Descending priority; FCFS (submit time, then id) breaks ties."""
    scores = legacy_priority_scores(pending, cluster)
    return sorted(
        pending,
        key=lambda p: (
            -scores[p.spec.job_id],
            p.spec.submit_time,
            p.spec.job_id,
        ),
    )


# -------------------------------------------------------- pathfinder (Alg. 1)


@dataclasses.dataclass(frozen=True)
class _LegacyPathCandidate:
    path: Tuple[str, ...]
    gpus: int
    avg_price: float
    alloc: Dict[str, int]


def legacy_find_placement(
    profile: JobProfile,
    cluster: ClusterState,
    *,
    k_star: Optional[int] = None,
    allocator=cost_min_allocate,
) -> Optional[Placement]:
    """Alg. 1 exactly as the seed implemented it: dict-ledger lookups, Prim
    expansion from every seed region, no early exits."""
    k = k_star if k_star is not None else profile.optimal_gpus(cluster.total_gpus())
    k = max(k, profile.min_gpus)

    # ---------------------------------------------- Phase 1: single region
    singles = [r for r, free in cluster.free_gpus.items() if free >= k]
    if singles:
        best = min(singles, key=lambda r: (cluster.price(r), r))
        return build_placement(
            profile, cluster, [best], {best: k}, require_comm_fits_comp=True
        )

    # ------------------------------------------ Phase 2: greedy expansion
    act = profile.spec.model.activation_bytes
    best_cand: Optional[_LegacyPathCandidate] = None
    for seed in cluster.region_names():
        if cluster.free_gpus[seed] < 1:
            continue
        path: List[str] = [seed]
        tail = seed
        g = min(cluster.free_gpus[seed], k)
        b_min = float("inf")
        while len(path) < len(cluster.regions) and g < k:
            # Highest-bandwidth (residual) outgoing link to a fresh region.
            cands = [
                u
                for u in cluster.region_names()
                if u not in path
                and cluster.free_gpus[u] > 0
                and cluster.available_bandwidth(tail, u) > 0.0
            ]
            if not cands:
                break
            nxt = max(
                cands, key=lambda u: (cluster.available_bandwidth(tail, u), u)
            )
            b_tmp = min(b_min, cluster.available_bandwidth(tail, nxt))
            g_new = min(g + cluster.free_gpus[nxt], k)
            # Alg. 1 line 13: communication must keep up with compute.
            if act / b_tmp > profile._t_comp_raw(g_new):
                break
            path.append(nxt)
            tail = nxt
            b_min, g = b_tmp, g_new

        if g < profile.min_gpus or g < len(path):
            continue
        try:
            alloc = allocator(cluster, path, g)
        except ValueError:
            continue
        try:
            placement = build_placement(
                profile, cluster, path, alloc, require_comm_fits_comp=True
            )
        except ValueError:
            continue
        cand = _LegacyPathCandidate(
            path=tuple(path),
            gpus=g,
            avg_price=average_price(placement, cluster),
            alloc=alloc,
        )
        if (
            best_cand is None
            or cand.gpus > best_cand.gpus
            or (cand.gpus == best_cand.gpus and cand.avg_price < best_cand.avg_price)
        ):
            best_cand = cand

    if best_cand is None:
        return None
    return build_placement(
        profile,
        cluster,
        list(best_cand.path),
        best_cand.alloc,
        require_comm_fits_comp=True,
    )
