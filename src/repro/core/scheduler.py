"""Event-driven multi-job simulator + the BACE-Pipe scheduling policy.

The simulator advances a global clock through job arrivals and completions.
At every decision point the active policy (BACE-Pipe, a baseline, or an
ablation) orders the pending queue and attempts placements; placed jobs
reserve GPUs (Eq. 5) and link bandwidth (Eq. 6) until completion.  All
policies are work-conserving: a job that cannot be placed is skipped, not a
barrier — HoL blocking in this model is *resource* occupancy, exactly the
phenomenon the paper analyses.
"""

from __future__ import annotations

import abc
import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .allocator import cost_min_allocate
from .cluster import ClusterState
from .job import JobProfile, JobSpec
from .pathfinder import find_placement
from .placement import Placement
from .priority import order_by_priority, priority_scores
from .timing import electricity_cost, execution_time, iteration_time


class SchedulingPolicy(abc.ABC):
    """Order + place: the two decisions every scheduler makes.

    ``strict_fcfs``: classic FIFO semantics — when the job at the head of the
    (policy-ordered) queue cannot be placed, the scheduling pass stops; jobs
    behind it wait.  This is how the paper's FCFS baselines exhibit HoL
    blocking.  BACE-Pipe instead *re-orders* the queue every event (Eq. 12),
    which subsumes skipping a stuck job.
    """

    name: str = "base"
    strict_fcfs: bool = False

    @abc.abstractmethod
    def order(
        self, pending: Sequence[JobProfile], cluster: ClusterState, now: float
    ) -> List[JobProfile]:
        ...

    @abc.abstractmethod
    def place(
        self, profile: JobProfile, cluster: ClusterState
    ) -> Optional[Placement]:
        ...


def fcfs_order(
    pending: Sequence[JobProfile], cluster: ClusterState, now: float
) -> List[JobProfile]:
    return sorted(pending, key=lambda p: (p.spec.submit_time, p.spec.job_id))


class BACEPipePolicy(SchedulingPolicy):
    """The paper's scheduler: dynamic priority -> Pathfinder -> Cost-Min."""

    name = "bace-pipe"

    def __init__(self, *, use_priority: bool = True) -> None:
        self.use_priority = use_priority

    def order(self, pending, cluster, now):
        if self.use_priority:
            return order_by_priority(pending, cluster)
        return fcfs_order(pending, cluster, now)

    def place(self, profile, cluster):
        return find_placement(profile, cluster, allocator=cost_min_allocate)


# --------------------------------------------------------------------- result
@dataclasses.dataclass
class JobRecord:
    job_id: int
    model_name: str
    submit: float
    start: float
    finish: float
    placement: Placement
    iteration_seconds: float

    @property
    def wait(self) -> float:  # W_j
        return self.start - self.submit

    @property
    def execution(self) -> float:  # E_j
        return self.finish - self.start

    @property
    def jct(self) -> float:  # T_j = W_j + E_j
        return self.finish - self.submit


@dataclasses.dataclass
class SimulationResult:
    policy: str
    records: List[JobRecord]
    costs: Dict[int, float]
    makespan: float

    @property
    def average_jct(self) -> float:
        return sum(r.jct for r in self.records) / len(self.records)

    @property
    def total_cost(self) -> float:
        return sum(self.costs.values())

    def summary(self) -> str:
        return (
            f"{self.policy}: avg_jct={self.average_jct / 3600.0:.3f} h, "
            f"total_cost=${self.total_cost:.2f}, "
            f"makespan={self.makespan / 3600.0:.3f} h"
        )


# ------------------------------------------------------------------ simulator
_ARRIVAL, _COMPLETION = 0, 1


class Simulator:
    """Discrete-event simulation of a policy over a job set."""

    def __init__(
        self,
        cluster: ClusterState,
        profiles: Sequence[JobProfile],
        policy: SchedulingPolicy,
    ) -> None:
        self.cluster = cluster.snapshot()
        self.profiles = {p.spec.job_id: p for p in profiles}
        self.policy = policy

    def run(self) -> SimulationResult:
        cluster = self.cluster
        pending: Dict[int, JobProfile] = {}
        running: Dict[int, Tuple[Placement, float]] = {}
        records: List[JobRecord] = []
        costs: Dict[int, float] = {}
        events: List[Tuple[float, int, int, int]] = []  # (t, kind, seq, job)
        seq = 0
        for p in self.profiles.values():
            heapq.heappush(events, (p.spec.submit_time, _ARRIVAL, seq, p.spec.job_id))
            seq += 1

        now = 0.0
        while events:
            now = events[0][0]
            # Drain all events at this timestamp before scheduling.
            while events and events[0][0] <= now + 1e-12:
                _, kind, _, job_id = heapq.heappop(events)
                if kind == _ARRIVAL:
                    pending[job_id] = self.profiles[job_id]
                else:  # completion
                    placement, start = running.pop(job_id)
                    cluster.release_gpus(placement.alloc)
                    cluster.release_bandwidth(placement.reserved_bw)

            # Scheduling pass (work-conserving).
            progressed = True
            while progressed and pending:
                progressed = False
                ordered = self.policy.order(list(pending.values()), cluster, now)
                for prof in ordered:
                    placement = self.policy.place(prof, cluster)
                    if placement is None or placement.total_gpus < prof.min_gpus:
                        if self.policy.strict_fcfs:
                            break  # HoL: the stuck head job blocks the queue
                        continue
                    cluster.reserve_gpus(placement.alloc)
                    cluster.reserve_bandwidth(placement.reserved_bw)
                    e = execution_time(prof, placement)
                    finish = now + e
                    running[prof.spec.job_id] = (placement, now)
                    records.append(
                        JobRecord(
                            job_id=prof.spec.job_id,
                            model_name=prof.spec.model.name,
                            submit=prof.spec.submit_time,
                            start=now,
                            finish=finish,
                            placement=placement,
                            iteration_seconds=iteration_time(prof, placement),
                        )
                    )
                    costs[prof.spec.job_id] = electricity_cost(
                        prof, placement, cluster, execution_seconds=e
                    )
                    del pending[prof.spec.job_id]
                    heapq.heappush(
                        events, (finish, _COMPLETION, seq, prof.spec.job_id)
                    )
                    seq += 1
                    progressed = True
                    break  # re-order: alpha/normalization changed

            if pending and not running and not events:
                stuck = sorted(pending)
                raise RuntimeError(
                    f"deadlock: jobs {stuck} unplaceable on an idle cluster "
                    f"(policy={self.policy.name})"
                )

        return SimulationResult(
            policy=self.policy.name,
            records=sorted(records, key=lambda r: r.job_id),
            costs=costs,
            makespan=now,
        )


def simulate(
    cluster: ClusterState,
    profiles: Sequence[JobProfile],
    policy: SchedulingPolicy,
) -> SimulationResult:
    return Simulator(cluster, profiles, policy).run()
